"""Hypothesis property tests: ``ColumnarWindowSeries`` must be a drop-in
replacement for ``WindowSeries`` under any interleaving of scalar ``add``
and bulk ``add_many`` ingest.

``hypothesis`` is an optional test extra (see pyproject.toml); without it
this module degrades to a skip instead of a collection error — mirroring
``tests/test_traces_properties.py``."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.monitoring import (ColumnarWindowSeries,  # noqa: E402
                                   WindowSeries)

SETTINGS = dict(max_examples=40, deadline=None)

# one ingest op: a scalar add or a bulk add_many of 0..20 samples;
# timestamps cluster around a handful of windows so interleavings hit the
# same window from both paths (the interesting aggregation case)
_sample = st.tuples(st.floats(0.0, 50.0, allow_nan=False, width=32),
                    st.floats(-100.0, 100.0, allow_nan=False, width=32))
_op = st.one_of(
    _sample.map(lambda s: ("add", [s])),
    st.lists(_sample, max_size=20).map(lambda ss: ("add_many", ss)),
)


def _ingest(series, ops):
    for kind, samples in ops:
        if kind == "add":
            (t, v), = samples
            series.add(t, v)
        else:
            ts = np.array([t for t, _ in samples])
            vs = np.array([v for _, v in samples])
            series.add_many(ts, vs)


def _assert_series_close(a, b):
    assert len(a) == len(b)
    for (ta, va), (tb, vb) in zip(a, b):
        assert ta == tb
        if math.isnan(va) or math.isnan(vb):
            assert math.isnan(va) and math.isnan(vb)
        else:
            assert va == pytest.approx(vb, rel=1e-9, abs=1e-9)


@given(st.lists(_op, max_size=30), st.floats(0.5, 20.0, allow_nan=False))
@settings(**SETTINGS)
def test_columnar_matches_reference_under_interleaving(ops, window_s):
    ref = WindowSeries(window_s)
    col = ColumnarWindowSeries(window_s)
    _ingest(ref, ops)
    _ingest(col, ops)

    assert col.count() == ref.count()
    assert col.windows() == ref.windows()
    assert col.total() == pytest.approx(ref.total(), rel=1e-9, abs=1e-9)
    # p90 is order-statistic interpolation over the same multiset: exact
    # equality modulo NaN on the empty series
    pr, pc = ref.p90(), col.p90()
    if math.isnan(pr) or math.isnan(pc):
        assert math.isnan(pr) and math.isnan(pc)
    else:
        assert pc == pr
    for agg in ("sum", "mean", "count", "p90"):
        _assert_series_close(col.series(agg), ref.series(agg))
    assert sorted(col.all_values()) == pytest.approx(
        sorted(ref.all_values()), rel=1e-9, abs=1e-9)


def test_empty_series_nan_edges():
    for cls in (WindowSeries, ColumnarWindowSeries):
        s = cls(10.0)
        assert s.count() == 0
        assert s.total() == 0.0
        assert s.windows() == []
        assert s.series("p90") == []
        assert math.isnan(s.p90())


def test_single_sample_parity():
    ref, col = WindowSeries(10.0), ColumnarWindowSeries(10.0)
    for s in (ref, col):
        s.add(3.0, 7.5)
    assert ref.p90() == col.p90() == 7.5
    assert ref.series("p90") == col.series("p90") == [(0.0, 7.5)]
    assert ref.series("mean") == col.series("mean") == [(0.0, 7.5)]
