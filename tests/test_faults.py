"""Fault tolerance: failure detection/ejection, re-delivery, hedged
requests, elastic membership, checkpoint/restart of training."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FDNControlPlane, Gateway, Invocation
from repro.core import profiles, functions
from repro.core.loadgen import attach_completion_hooks, run_load
from repro.core.types import DeploymentSpec


def build(names, **kw):
    cp = FDNControlPlane(**kw)
    for n in names:
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = functions.paper_functions()
    functions.seed_object_stores(cp.placement, location=names[0])
    cp.deploy(DeploymentSpec("t", list(fns.values()), names))
    attach_completion_hooks(cp)
    return cp, fns


def test_platform_failure_redelivers_inflight():
    cp, fns = build(["hpc-node-cluster", "old-hpc-node-cluster"])
    gw = Gateway(cp)
    # schedule a failure mid-run
    cp.clock.after(10.0, cp.platforms["hpc-node-cluster"].fail)
    res = run_load(cp.clock, lambda i: gw.request(i), fns["nodeinfo"],
                   vus=8, duration_s=40.0, sleep_s=0.05)
    cp.run_until(60.0)
    assert cp.redeliverer.redelivered >= 0
    # every request eventually completed somewhere (possibly after retry)
    done = [i for i in res.invocations if i.status == "done"]
    assert len(done) >= 0.95 * len(res.invocations)
    # detector ejected the dead platform
    cp.run_until(cp.clock.now() + 60.0)
    assert not cp.detector.check("hpc-node-cluster")
    assert cp.detector.check("old-hpc-node-cluster")


def test_failure_detector_recovery():
    cp, fns = build(["hpc-node-cluster", "old-hpc-node-cluster"])
    p = cp.platforms["hpc-node-cluster"]
    p.fail()
    cp.run_until(cp.clock.now() + 120.0)
    assert not cp.detector.check("hpc-node-cluster")
    p.recover()
    cp.run_until(cp.clock.now() + 20.0)
    assert cp.detector.check("hpc-node-cluster")
    assert p in cp.alive_platforms()


def test_hedging_cuts_stragglers():
    cp, fns = build(["hpc-node-cluster", "old-hpc-node-cluster"],
                    enable_hedging=True)
    gw = Gateway(cp)
    # seed fast-latency observations on BOTH platforms so the hedge budget
    # is small wherever the policy routes (hedging requires >=10 obs)
    for pname in ("hpc-node-cluster", "old-hpc-node-cluster"):
        for _ in range(20):
            inv = Invocation(fns["nodeinfo"], 0.0)
            inv.platform = pname
            inv.exec_time = 0.01
            inv.end_t = 0.01
            cp.perf.observe(inv)
    cp.platforms["hpc-node-cluster"].bg_cpu = 1.0   # now it's slow
    run_load(cp.clock, lambda i: gw.request(i), fns["nodeinfo"],
             vus=4, duration_s=30.0, sleep_s=0.05)
    assert cp.hedge.hedges_sent > 0


def test_elastic_platform_join_leave():
    cp, fns = build(["hpc-node-cluster"])
    assert len(cp.alive_platforms()) == 1
    newp = cp.create_platform(profiles.PAPER_PLATFORMS["cloud-cluster"])
    newp.deploy(fns["nodeinfo"])
    assert len(cp.alive_platforms()) == 2
    cp.remove_platform("cloud-cluster")
    assert len(cp.alive_platforms()) == 1


def test_checkpoint_restart_training(tmp_path):
    """Train -> checkpoint -> 'node failure' -> restore -> identical state."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.base import InputShape
    from repro.configs.registry import get_config
    from repro.models import model_api as api
    from repro.train import optimizer as opt
    from repro.train.train_step import make_train_step

    cfg = get_config("qwen3-0.6b").reduced()
    oc = opt.OptConfig(total_steps=10)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init_state(oc, api.model_specs(cfg))
    step_fn = jax.jit(make_train_step(cfg, oc))
    batch = api.make_batch(cfg, InputShape("t", 32, 2, "train"))

    ck = Checkpointer(str(tmp_path), retain=2)
    losses = []
    for i in range(3):
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["loss"]))
    ck.save(3, {"params": params, "opt": state}, extra={"step": 3})

    # crash + restore
    like = {"params": params, "opt": state}
    restored = ck.restore(3, like)
    p2, s2 = restored["params"], restored["opt"]
    # one more step from each must agree exactly
    a_params, a_state, am = step_fn(params, state, batch)
    b_params, b_state, bm = step_fn(p2, s2, batch)
    assert float(am["loss"]) == pytest.approx(float(bm["loss"]), abs=1e-6)
    la = jax.tree_util.tree_leaves(a_params)
    lb = jax.tree_util.tree_leaves(b_params)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_retention_and_latest(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(str(tmp_path), retain=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": np.arange(s + 1)})
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(str(tmp_path), retain=3, async_save=True)
    ck.save(1, {"x": np.ones(1000)})
    ck.wait()
    out = ck.restore(1, {"x": np.zeros(1000)})
    np.testing.assert_array_equal(out["x"], np.ones(1000))
