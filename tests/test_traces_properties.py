"""Hypothesis property tests for the FDNInspector trace library.

``hypothesis`` is an optional test extra (see pyproject.toml); without it
this module degrades to a skip instead of a collection error — mirroring
``tests/test_properties.py``."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.inspector import traces  # noqa: E402

SETTINGS = dict(max_examples=30, deadline=None)


@given(st.integers(0, 2**31 - 1), st.floats(1.0, 80.0),
       st.floats(5.0, 120.0))
@settings(**SETTINGS)
def test_diurnal_deterministic_monotone_bounded(seed, rps, duration):
    a = traces.diurnal_arrivals(rps, duration, seed=seed,
                                period_s=duration)
    b = traces.diurnal_arrivals(rps, duration, seed=seed,
                                period_s=duration)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0.0)
    assert a.size == 0 or (a[0] >= 0.0 and a[-1] < duration)


@given(st.integers(0, 2**31 - 1), st.floats(0.0, 30.0),
       st.floats(0.0, 300.0), st.floats(2.0, 60.0))
@settings(**SETTINGS)
def test_mmpp_deterministic_monotone_bounded(seed, base, burst, duration):
    a = traces.mmpp_arrivals(base, burst, duration, seed=seed)
    b = traces.mmpp_arrivals(base, burst, duration, seed=seed)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0.0)
    assert a.size == 0 or (a[0] >= 0.0 and a[-1] <= duration)


@given(st.lists(st.integers(0, 50), min_size=1, max_size=60),
       st.integers(0, 2**31 - 1), st.floats(0.05, 4.0))
@settings(**SETTINGS)
def test_azure_counts_expand_exactly(counts, seed, scale):
    t = traces.counts_to_arrivals(counts, seed=seed, time_scale=scale)
    assert t.size == sum(counts)
    assert np.all(np.diff(t) >= 0.0)
    assert t.size == 0 or t[0] >= 0.0


@given(st.integers(0, 2**31 - 1),
       st.lists(st.tuples(st.sampled_from(["f1", "f2", "f3"]),
                          st.floats(0.5, 40.0)),
                min_size=1, max_size=4))
@settings(**SETTINGS)
def test_workload_mix_invariants(seed, streams):
    mix = traces.WorkloadMix()
    want = {}
    for i, (name, rps) in enumerate(streams):
        arr = traces.build_arrivals({"kind": "poisson", "rps": rps}, 10.0,
                                    seed=seed + i)
        mix.add(name, arr)
        want[name] = want.get(name, 0) + arr.size
    times, idx, names = mix.merge()
    assert np.all(np.diff(times) >= 0.0)
    assert times.size == sum(want.values())
    got = {names[f]: int((idx == f).sum()) for f in set(idx.tolist())}
    for name, n in want.items():
        assert got.get(name, 0) == n
