"""Predictive autoscaling subsystem (repro.autoscale): warm-pool
lifecycle transitions on the platform, keep-alive policies, forecaster
backend parity (byte-identical prewarm decisions), controller
determinism, cold-start-rate accounting, and the warm-pool scheduler
columns."""
import json

import numpy as np
import pytest

from repro.autoscale import (ConcurrencyTargetPolicy, FixedTTLPolicy,
                             PredictivePolicy, ScaleToZeroPolicy,
                             WarmPoolController, make_policy)
from repro.core import FDNControlPlane, WarmAwarePolicy
from repro.core import profiles as prof_mod
from repro.core.faults import HedgePolicy
from repro.core.platform import PREWARM, WARM
from repro.core.scheduler import PlatformSnapshot
from repro.core.simulator import SimClock
from repro.core.types import DeploymentSpec, FunctionSpec, Invocation
from repro.inspector import Scenario, Workload, registry, run_scenario
from repro.inspector.scenario import run_scenario_state

NODEINFO = FunctionSpec(name="nodeinfo", flops=1e6, memory_mb=128)
HEAVY = FunctionSpec(name="heavy", flops=1e9, memory_mb=512)


def make_platform(cp=None, name="cloud-cluster"):
    cp = cp or FDNControlPlane()
    p = cp.create_platform(prof_mod.PAPER_PLATFORMS[name])
    cp.deploy(DeploymentSpec("t", [NODEINFO, HEAVY], [name]))
    return cp, p


def live_replicas(p, fn):
    return [r for r in p.replicas[fn] if not r.retired]


def mem_brute_force(p):
    total = 0.0
    for fn, rs in p.replicas.items():
        spec = p.deployed.get(fn)
        if spec is not None:
            total += sum(spec.memory_mb for r in rs if not r.retired)
    return total


# ---------------------------------------------------- pool transitions ---

def test_prewarm_and_retire_update_o1_accounting():
    cp, p = make_platform()
    base_mem = p._mem_replicas_mb
    assert base_mem == mem_brute_force(p)
    p.prewarm("nodeinfo", 3)
    assert p.idle_warm("nodeinfo") == 3 + p.prof.prewarm_pool
    assert p._mem_replicas_mb == mem_brute_force(p)
    retired = p.retire("nodeinfo", 2)
    assert retired == 2
    assert p.idle_warm("nodeinfo") == 1 + p.prof.prewarm_pool
    assert p._mem_replicas_mb == mem_brute_force(p)
    # retiring more than exist retires only what is idle
    retired = p.retire("nodeinfo", 99)
    assert retired == 1 + p.prof.prewarm_pool
    assert p.idle_warm("nodeinfo") == 0
    assert p._mem_replicas_mb == mem_brute_force(p) == base_mem - \
        p.prof.prewarm_pool * NODEINFO.memory_mb


def test_idle_counts_track_replica_lifecycle():
    cp, p = make_platform()
    inv = Invocation(NODEINFO, 0.0)
    p.invoke(inv)
    # the prewarm-pool replica was consumed by the start
    assert p.idle_warm("nodeinfo") == p.prof.prewarm_pool - 1
    cp.clock.run_until(10.0)
    assert inv.status == "done"
    # finished replica returns to the idle pool as WARM
    counts = p._idle_counts["nodeinfo"]
    assert counts[WARM] == 1
    assert p.idle_warm("nodeinfo") == p.prof.prewarm_pool
    assert p._mem_replicas_mb == mem_brute_force(p)


def test_prewarmed_start_is_not_a_cold_start():
    cp, p = make_platform()
    a = Invocation(NODEINFO, 0.0)
    p.invoke(a)                      # consumes the PREWARM pool replica
    cp.clock.run_until(5.0)
    assert a.status == "done" and a.cold_start is False
    b = Invocation(HEAVY, cp.clock.now())
    p.invoke(b)                      # heavy's prewarm replica
    c = Invocation(HEAVY, cp.clock.now())
    p.invoke(c)                      # no free replica left -> cold
    cp.clock.run_until(50.0)
    assert b.cold_start is False
    assert c.cold_start is True


def test_enforce_keepalive_ttl_and_floor():
    cp, p = make_platform()
    p.prewarm("nodeinfo", 4)
    n_idle = p.idle_warm("nodeinfo")
    cp.clock.run_until(10.0)
    # nothing expired yet at ttl=60
    retired, due = p.enforce_keepalive("nodeinfo", 60.0, keep=0)
    assert retired == 0 and due == pytest.approx(60.0)
    cp.clock.run_until(61.0)
    retired, due = p.enforce_keepalive("nodeinfo", 60.0, keep=2)
    assert retired == n_idle - 2
    assert p.idle_warm("nodeinfo") == 2
    assert p._mem_replicas_mb == mem_brute_force(p)
    # the floor protects the youngest two even though they are expired
    retired, due = p.enforce_keepalive("nodeinfo", 60.0, keep=2)
    assert retired == 0 and due == pytest.approx(cp.clock.now() + 60.0)


def test_retire_never_touches_busy_replicas():
    cp, p = make_platform()
    invs = [Invocation(NODEINFO, 0.0) for _ in range(3)]
    p.invoke_batch(invs)
    busy_before = p.busy_replicas()
    assert busy_before == 3
    p.retire("nodeinfo", 99)
    assert p.busy_replicas() == busy_before
    cp.clock.run_until(20.0)
    assert all(i.status == "done" for i in invs)


# ------------------------------------------------------------ policies ---

def test_make_policy_kinds():
    assert isinstance(make_policy("ttl", ttl_s=10.0), FixedTTLPolicy)
    assert isinstance(make_policy("scale_to_zero"), ScaleToZeroPolicy)
    assert isinstance(make_policy("concurrency"), ConcurrencyTargetPolicy)
    assert isinstance(make_policy("predictive"), PredictivePolicy)
    with pytest.raises(KeyError):
        make_policy("nope")


def test_fixed_ttl_policy_never_prewarms():
    pol = FixedTTLPolicy(ttl_s=12.0)
    pol.resize(4)
    desired, ttl = pol.tick(np.array([5.0, 0.0, 3.0, 0.0]), True)
    assert desired.tolist() == [0.0] * 4
    assert ttl.tolist() == [12.0] * 4


def test_predictive_policy_scales_with_forecast():
    pol = PredictivePolicy()
    pol.resize(2)
    pol.set_exec(np.array([0.5, 0.5]), 1.0)
    for _ in range(30):                     # steady 8/tick on row 0 only
        desired, ttl = pol.tick(np.array([8.0, 0.0]), True)
    assert desired[0] >= 4                  # ~8 rps * 0.5 s * headroom
    assert desired[1] == 0.0
    # rate collapses -> the forecast decays -> pool target follows
    for _ in range(60):
        desired, ttl = pol.tick(np.array([0.0, 0.0]), False)
    desired, ttl = pol.tick(np.array([1.0, 0.0]), True)   # catch-up tick
    assert desired[0] <= 2


def test_forecaster_backend_parity_byte_identical():
    """NumPy and jax forecaster backends must produce byte-identical
    prewarm decisions (desired pools, TTLs) on a seeded arrival stream."""
    pytest.importorskip("jax")
    rng = np.random.default_rng(3)
    rows, ticks = 9, 300
    bursts = rng.poisson(3.0, size=(ticks, rows)) * \
        (rng.random(size=(ticks, rows)) < 0.25)
    exec_s = rng.uniform(0.02, 0.8, rows)
    out = {}
    for backend in ("numpy", "jax"):
        pol = PredictivePolicy(backend=backend)
        pol.resize(rows)
        pol.set_exec(exec_s, 1.0)
        trace = []
        for k in range(ticks):
            counts = bursts[k].astype(float)
            desired, ttl = pol.tick(counts, bool(counts.any()))
            trace.append((desired.astype(int).tolist(),
                          np.asarray(ttl).astype(int).tolist()))
        out[backend] = trace
    assert out["numpy"] == out["jax"]


# ---------------------------------------------------------- controller ---

def autoscale_scenario(pol, **kw):
    base = dict(
        name="test/autoscale",
        platforms=("cloud-cluster",),
        platform_override="cloud-cluster",
        workloads=(Workload("nodeinfo",
                            arrival={"kind": "diurnal", "mean_rps": 5.0,
                                     "period_s": 60.0,
                                     "peak_frac": 0.9}),),
        duration_s=120.0, drain_s=20.0,
        keepalive_w_per_replica=2.0, autoscale=pol)
    base.update(kw)
    return Scenario(**base)


def test_autoscale_ticks_are_seed_deterministic():
    sc = autoscale_scenario({"policy": "predictive"})
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.to_json() == b.to_json()
    assert a.totals["autoscale"]["ticks"] > 0
    c = run_scenario(sc.replace(seed=43))
    assert a.to_json() != c.to_json()


def test_controller_takes_over_keepalive_and_reclaims_memory():
    sc = autoscale_scenario(
        {"policy": "scale_to_zero", "policy_kwargs": {"idle_s": 2.0}})
    rep, cp, _sink = run_scenario_state(sc)
    p = cp.platforms["cloud-cluster"]
    assert p.managed_keepalive is True
    assert rep.totals["autoscale"]["retired"] > 0
    # scale-to-zero leaves no idle pool at the end of the drain, and the
    # O(1) memory running total agrees with a brute-force rescan
    assert p.idle_warm("nodeinfo") == 0
    assert p._mem_replicas_mb == mem_brute_force(p)
    assert p.idle_warm_total() == sum(
        1 for rs in p.replicas.values() for r in rs
        if not r.retired and not r.busy)


def test_predictive_controller_prewarms():
    rep, cp, _sink = run_scenario_state(
        autoscale_scenario({"policy": "predictive"}))
    a = rep.totals["autoscale"]
    assert a["policy"] == "predictive"
    assert a["prewarmed"] > 0


def test_scale_to_zero_saves_idle_wh_at_worse_p99():
    sparse = {"kind": "poisson", "rps": 0.08}
    ttl = run_scenario(autoscale_scenario(
        {"policy": "ttl", "policy_kwargs": {"ttl_s": 60.0}},
        workloads=(Workload("nodeinfo", arrival=sparse),),
        duration_s=400.0)).totals
    s2z = run_scenario(autoscale_scenario(
        {"policy": "scale_to_zero", "policy_kwargs": {"idle_s": 2.0}},
        workloads=(Workload("nodeinfo", arrival=sparse),),
        duration_s=400.0)).totals
    assert s2z["idle_wh"] < ttl["idle_wh"]
    assert s2z["p99_s"] > ttl["p99_s"]
    assert s2z["cold_start_rate"] > ttl["cold_start_rate"]


def test_cold_start_rate_matches_per_invocation_flags():
    sc = autoscale_scenario(
        {"policy": "scale_to_zero", "policy_kwargs": {"idle_s": 1.0}},
        retain_objects=True)
    rep, cp, sink = run_scenario_state(sc)
    flags = sum(1 for inv in cp.completed if inv.cold_start)
    assert rep.totals["cold_starts"] == flags
    assert rep.totals["cold_start_rate"] == pytest.approx(
        flags / rep.totals["completed"])
    per_fn = rep.per_function["nodeinfo"]
    assert per_fn["cold_start_rate"] == pytest.approx(
        per_fn["cold_starts"] / per_fn["completed"])


def test_idle_wh_accounting_zero_without_keepalive_watts():
    sc = autoscale_scenario({"policy": "ttl"}, keepalive_w_per_replica=0.0)
    rep = run_scenario(sc)
    assert rep.totals["idle_wh"] == 0.0
    sc = autoscale_scenario({"policy": "ttl"})
    rep = run_scenario(sc)
    assert rep.totals["idle_wh"] > 0.0
    assert rep.totals["idle_wh_per_completion"] == pytest.approx(
        rep.totals["idle_wh"] / rep.totals["completed"])
    # keep-alive joules are part of the total energy
    pp = rep.per_platform["cloud-cluster"]
    assert pp["energy_wh"] >= pp["idle_wh"]


def test_elastic_platform_adopted_mid_run():
    cp = FDNControlPlane()
    cp.create_platform(prof_mod.PAPER_PLATFORMS["cloud-cluster"])
    cp.deploy(DeploymentSpec("t", [NODEINFO], ["cloud-cluster"]))
    ctl = cp.attach_autoscaler(policy="ttl", start=False)
    late = cp.create_platform(prof_mod.PAPER_PLATFORMS["edge-cluster"])
    assert late.managed_keepalive is True
    assert late.autoscale_counts is not None
    assert "edge-cluster" in ctl._by_name


# ------------------------------------------- warm-pool snapshot columns --

def test_snapshot_warm_columns():
    cp = FDNControlPlane()
    a = cp.create_platform(prof_mod.PAPER_PLATFORMS["cloud-cluster"])
    b = cp.create_platform(prof_mod.PAPER_PLATFORMS["edge-cluster"])
    fn = NODEINFO.replace(runtime="python3")
    cp.deploy(DeploymentSpec("t", [fn], ["cloud-cluster", "edge-cluster"]))
    b.prewarm(fn.name, 3)
    snap = PlatformSnapshot([a, b])
    assert snap.warm_total.tolist() == [float(a.idle_warm_total()),
                                        float(b.idle_warm_total())]
    view = snap.fn_view(fn)
    assert view.warm_free.tolist() == [float(a.idle_warm(fn.name)),
                                       float(b.idle_warm(fn.name))]


def test_warm_aware_policy_prefers_warm_capacity():
    cp = FDNControlPlane()
    fast = cp.create_platform(prof_mod.PAPER_PLATFORMS["hpc-node-cluster"])
    slow = cp.create_platform(prof_mod.PAPER_PLATFORMS["cloud-cluster"])
    fn = NODEINFO
    cp.deploy(DeploymentSpec("t", [fn], [fast.prof.name, slow.prof.name]))
    fast.retire(fn.name, 99)               # no warm capacity on fast
    slow.retire(fn.name, 99)
    slow.prewarm(fn.name, 1)
    pol = WarmAwarePolicy(cp.perf, cp.placement)
    choice = pol.choose(Invocation(fn, 0.0), list(cp.platforms.values()))
    assert choice is slow                  # cold-start penalty dominates
    slow.retire(fn.name, 1)
    fast.prewarm(fn.name, 1)
    choice = pol.choose(Invocation(fn, 0.0), list(cp.platforms.values()))
    assert choice is fast


def test_warm_aware_policy_registry_and_jax_parity():
    pytest.importorskip("jax")
    from repro.core import scheduler as sched
    cp = FDNControlPlane()
    for name in ("hpc-node-cluster", "cloud-cluster", "edge-cluster"):
        cp.create_platform(prof_mod.PAPER_PLATFORMS[name])
    fns = [NODEINFO, HEAVY]
    cp.deploy(DeploymentSpec("t", fns, list(cp.platforms)))
    cp.platforms["cloud-cluster"].prewarm("nodeinfo", 2)
    pol = WarmAwarePolicy(cp.perf, cp.placement)
    snap = PlatformSnapshot(list(cp.platforms.values()))
    try:
        sched.set_score_backend("numpy")
        idx_np, ok_np = pol.fn_decisions(fns, snap, n=10_000)
        sched.set_score_backend("jax")
        idx_jx, ok_jx = pol.fn_decisions(fns, snap, n=10_000)
    finally:
        sched.set_score_backend("auto")
    assert idx_np.tolist() == idx_jx.tolist()
    assert ok_np.tolist() == ok_jx.tolist()


# ------------------------------------------- hedge-timer cancellation ---

def seeded_perf(cp, fn, platforms, n=12):
    for pname in platforms:
        for _ in range(n):
            inv = Invocation(fn, 0.0)
            inv.platform = pname
            inv.exec_time = 0.05
            inv.end_t = 0.05
            cp.perf.observe(inv)


def test_hedge_group_timer_cancelled_when_all_members_complete():
    cp = FDNControlPlane()
    a = cp.create_platform(prof_mod.PAPER_PLATFORMS["hpc-node-cluster"])
    b = cp.create_platform(prof_mod.PAPER_PLATFORMS["cloud-cluster"])
    cp.deploy(DeploymentSpec("t", [NODEINFO], [a.prof.name, b.prof.name]))
    seeded_perf(cp, NODEINFO, [a.prof.name, b.prof.name])
    hedge = HedgePolicy(cp.clock, cp.perf, enabled=True)
    sent = []
    invs = [Invocation(NODEINFO, 0.0) for _ in range(16)]
    hedge.watch_group(invs, a, [b], lambda dups, p: sent.extend(dups))
    assert hedge.group_timers_armed == 1
    assert hedge.live_group_timers() == 1
    pending_before = cp.clock.pending
    for inv in invs:                       # all complete before the budget
        inv.status = "done"
        hedge.completed(inv)
    # the timer is dropped, not left to fire as a no-op
    assert hedge.group_timers_cancelled == 1
    assert hedge.live_group_timers() == 0
    assert hedge._groups == {}
    cp.clock.run_until(60.0)
    assert sent == [] and hedge.hedges_sent == 0
    assert cp.clock.pending <= pending_before


def test_hedge_group_timer_still_fires_for_stragglers():
    cp = FDNControlPlane()
    a = cp.create_platform(prof_mod.PAPER_PLATFORMS["hpc-node-cluster"])
    b = cp.create_platform(prof_mod.PAPER_PLATFORMS["cloud-cluster"])
    cp.deploy(DeploymentSpec("t", [NODEINFO], [a.prof.name, b.prof.name]))
    seeded_perf(cp, NODEINFO, [a.prof.name, b.prof.name])
    hedge = HedgePolicy(cp.clock, cp.perf, enabled=True)
    sent = []
    invs = [Invocation(NODEINFO, 0.0) for _ in range(8)]
    hedge.watch_group(invs, a, [b], lambda dups, p: sent.extend(dups))
    for inv in invs[:5]:
        inv.status = "done"
        hedge.completed(inv)
    assert hedge.live_group_timers() == 1   # stragglers keep it armed
    cp.clock.run_until(60.0)
    assert len(sent) == 3                   # one duplicate per straggler
    assert hedge.hedges_sent == 3
    assert hedge.live_group_timers() == 0
    assert hedge._groups == {}


def test_hedge_timer_count_under_sustained_bursts():
    """N fully-completed admission groups leave ZERO live timers (the
    cancellable index drops them); only straggling groups stay armed."""
    cp = FDNControlPlane()
    a = cp.create_platform(prof_mod.PAPER_PLATFORMS["hpc-node-cluster"])
    b = cp.create_platform(prof_mod.PAPER_PLATFORMS["cloud-cluster"])
    cp.deploy(DeploymentSpec("t", [NODEINFO], [a.prof.name, b.prof.name]))
    seeded_perf(cp, NODEINFO, [a.prof.name, b.prof.name])
    hedge = HedgePolicy(cp.clock, cp.perf, enabled=True)
    groups = []
    for _ in range(50):
        invs = [Invocation(NODEINFO, 0.0) for _ in range(4)]
        hedge.watch_group(invs, a, [b], lambda dups, p: None)
        groups.append(invs)
    assert hedge.group_timers_armed == 50
    for invs in groups[:47]:
        for inv in invs:
            inv.status = "done"
            hedge.completed(inv)
    assert hedge.group_timers_cancelled == 47
    assert hedge.live_group_timers() == 3
    # index holds only the straggling groups' members
    assert len(hedge._groups) == 3 * 4


# ----------------------------------------------------- registry wiring ---

def test_autoscale_registry_scenarios_build_and_validate():
    names = [n for n in registry.names() if n.startswith("autoscale/")]
    assert len(names) >= 10
    sc = registry.get("autoscale/diurnal-predictive")
    assert sc.autoscale["policy"] == "predictive"
    assert sc.keepalive_w_per_replica > 0.0


def test_report_schema_requires_autoscale_sections():
    from repro.inspector import ScenarioReport
    rep = run_scenario(registry.get("smoke/tiny"))
    d = json.loads(rep.to_json())
    ScenarioReport.validate(d)
    bad = dict(d, totals={k: v for k, v in d["totals"].items()
                          if k != "idle_wh"})
    with pytest.raises(ValueError):
        ScenarioReport.validate(bad)
