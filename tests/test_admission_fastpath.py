"""JIT-compiled admission fast path: jitted-vs-NumPy score-backend
parity (every Policy subclass, byte-identical choices on seeded
scenarios), the Pallas fused filter+argmin variant, grouped hedge timers
vs per-invocation watchers, batched local-trigger delegation, and the
columnar drain's exact equivalence to sequential invokes."""
import numpy as np
import pytest

from repro.core import functions, profiles
from repro.core.control_plane import FDNControlPlane
from repro.core.faults import HedgePolicy
from repro.core.loadgen import attach_completion_hooks
from repro.core import scheduler as sched
from repro.core.scheduler import (DataLocalityPolicy, EnergyAwarePolicy,
                                  PerformanceRankedPolicy,
                                  RoundRobinCollaboration,
                                  SLOCompositePolicy,
                                  UtilizationAwarePolicy,
                                  WeightedCollaboration)
from repro.core.types import SLO, DeploymentSpec, Invocation


@pytest.fixture(autouse=True)
def _restore_backend():
    yield
    sched.set_score_backend("auto")


def build(names=None, **kw):
    cp = FDNControlPlane(**kw)
    for n in (names or list(profiles.PAPER_PLATFORMS)):
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = {k: f.replace(real_fn=None)
           for k, f in functions.paper_functions().items()}
    functions.seed_object_stores(cp.placement, location="cloud-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    return cp, fns


def _randomized_state(cp, fns, rng):
    for p in cp.platforms.values():
        p.bg_cpu = float(rng.uniform(0, 1.2))
        p.bg_mem = float(rng.uniform(0, 0.8))
    for fn in fns.values():
        for pname in cp.platforms:
            for _ in range(int(rng.integers(0, 15))):
                inv = Invocation(fn, 0.0)
                inv.platform = pname
                inv.exec_time = float(rng.uniform(0.01, 8.0))
                inv.end_t = inv.exec_time
                cp.perf.observe(inv)


def _mixed_invs(fns, rng, n):
    specs = list(fns.values())
    specs = [s if rng.random() < 0.5 else
             s.replace(slo=SLO(p90_response_s=float(rng.uniform(0.05, 10))))
             for s in specs]
    return [Invocation(specs[int(rng.integers(0, len(specs)))], 0.0)
            for _ in range(n)]


POLICY_FACTORIES = {
    "perf_ranked": lambda cp: PerformanceRankedPolicy(cp.perf),
    "utilization": lambda cp: UtilizationAwarePolicy(cp.perf,
                                                     cpu_threshold=0.7),
    "round_robin": lambda cp: RoundRobinCollaboration(),
    "weighted": lambda cp: WeightedCollaboration(
        {"hpc-node-cluster": 5, "cloud-cluster": 1, "edge-cluster": 2}),
    "data_locality": lambda cp: DataLocalityPolicy(cp.perf, cp.placement),
    "energy": lambda cp: EnergyAwarePolicy(cp.perf),
    "slo_composite": lambda cp: SLOCompositePolicy(cp.perf, cp.placement),
}


# ---------------------------------------------------------------------------
# jitted-vs-NumPy backend parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pname", sorted(POLICY_FACTORIES))
def test_jax_backend_matches_numpy_choices(pname):
    """Every Policy subclass must pick byte-identical platforms under the
    numpy and jax score backends, across randomized seeded platform
    states, invocation mixes, and platform subsets."""
    rng = np.random.default_rng(20260730)
    all_names = list(profiles.PAPER_PLATFORMS)
    for trial in range(4):
        k = int(rng.integers(2, len(all_names) + 1))
        names = list(rng.choice(all_names, size=k, replace=False))
        cp, fns = build(names=names)
        _randomized_state(cp, fns, rng)
        specs = _mixed_invs(fns, rng, 96)
        plats = list(cp.platforms.values())

        picks = {}
        for backend in ("numpy", "jax"):
            sched.set_score_backend(backend)
            pol = POLICY_FACTORIES[pname](cp)   # fresh rotation state
            invs = [Invocation(i.fn, 0.0) for i in specs]
            picks[backend] = [p.prof.name if p else None
                              for p in pol.choose_batch(invs, plats)]
        assert picks["numpy"] == picks["jax"], \
            f"{pname} trial {trial}: backend decisions diverge"


def test_jax_backend_matches_numpy_on_registry_scenarios():
    """End-to-end: running a registry scenario with the score backend
    forced to jax produces the same canonical report as numpy (admission
    decisions — and so every downstream metric — are identical)."""
    from repro.inspector import registry, run_scenario
    for name in ("smoke/tiny", "burst/mmpp-storm"):
        reports = {}
        for backend in ("numpy", "jax"):
            sched.set_score_backend(backend)
            reports[backend] = run_scenario(registry.get(name)).to_json()
        assert reports["numpy"] == reports["jax"], \
            f"{name}: scenario report drifts across score backends"


def test_pallas_composite_matches_numpy():
    from repro.kernels import policy_score as ps
    rng = np.random.default_rng(7)
    cp, fns = build()
    _randomized_state(cp, fns, rng)
    invs = _mixed_invs(fns, rng, 64)
    plats = list(cp.platforms.values())
    sched.set_score_backend("numpy")
    want = [p.prof.name if p else None for p in
            SLOCompositePolicy(cp.perf, cp.placement).choose_batch(
                invs, plats)]
    sched.set_score_backend("jax")
    ps.set_use_pallas(True)
    try:
        got = [p.prof.name if p else None for p in
               SLOCompositePolicy(cp.perf, cp.placement).choose_batch(
                   [Invocation(i.fn, 0.0) for i in invs], plats)]
    finally:
        ps.set_use_pallas(False)
    assert got == want


def test_fn_decisions_match_full_score_matrix():
    """The fused per-function decision must equal row-argmin over the
    full (N, P) score matrix for stateless policies."""
    rng = np.random.default_rng(3)
    cp, fns = build()
    _randomized_state(cp, fns, rng)
    invs = _mixed_invs(fns, rng, 40)
    snap = sched.PlatformSnapshot(list(cp.platforms.values()))
    pol = SLOCompositePolicy(cp.perf, cp.placement)
    groups = sched.group_by_fn(invs)
    idx, ok = pol.fn_decisions([g[0] for g in groups], snap)
    costs = pol.score(invs, snap)
    finite = np.isfinite(costs)
    row_idx = np.argmin(np.where(finite, costs, np.inf), axis=1)
    for g, (_fn, idxs) in enumerate(groups):
        for i in idxs:
            assert finite[i].any() == ok[g]
            if ok[g]:
                assert row_idx[i] == idx[g]


def test_backend_behavior_without_jax(monkeypatch):
    """With the jitted module unavailable, "auto" silently degrades to
    numpy (never require new deps), but an EXPLICIT "jax" request raises
    — it must not silently measure (or CI-gate) the numpy path."""
    monkeypatch.setattr(sched, "_ps_mod", None)
    monkeypatch.setattr(sched, "_ps_error", ImportError("no jax"))
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])
    plats = list(cp.platforms.values())
    invs = [Invocation(fns["nodeinfo"], 0.0) for _ in range(80)]
    sched.set_score_backend("auto")
    assert cp.policy.choose_batch(invs, plats)[0] is not None
    sched.set_score_backend("jax")
    with pytest.raises(RuntimeError, match="jax"):
        cp.policy.choose_batch(invs, plats)


# ---------------------------------------------------------------------------
# grouped hedge timers
# ---------------------------------------------------------------------------

def _seed_resp_obs(cp, fns, names, value=0.05, count=12):
    for fname in names:
        for pname in cp.platforms:
            for _ in range(count):
                inv = Invocation(fns[fname], 0.0)
                inv.platform = pname
                inv.exec_time = value
                inv.end_t = value
                cp.perf.observe(inv)


def test_group_hedge_timer_equivalent_to_per_invocation_watchers():
    """ONE timer per (fn, platform) admission group must fire equivalently
    to per-invocation watchers: same hedges for the same stragglers, same
    total completions — with an order-of-batch fewer clock events."""
    n = 60
    results = {}
    for mode in ("grouped", "per_inv"):
        cp, fns = build(names=["hpc-node-cluster", "old-hpc-node-cluster"])
        _seed_resp_obs(cp, fns, ("nodeinfo", "primes-python"))
        # make every platform slow so originals straggle past the budget
        for p in cp.platforms.values():
            p.bg_cpu = 1.0
        cp.kb.log_decisions = False
        specs = [fns["nodeinfo"], fns["primes-python"]]
        invs = [Invocation(specs[i % 2], 0.0) for i in range(n)]
        if mode == "grouped":
            cp.hedge.enabled = True
            cp.submit_batch(invs)
            timers = cp.clock.pending
        else:
            hedge = cp.hedge
            hedge.enabled = False          # plain admission...
            cp.submit_batch(invs)
            hedge.enabled = True           # ...then PR-1 per-inv watchers
            alive = cp.alive_platforms()
            for inv in invs:
                target = cp.platforms[inv.platform]
                alternates = [p for p in alive if p is not target]
                hedge.watch(inv, target, alternates,
                            lambda i, p: cp.sidecars[p.prof.name].admit(i))
            timers = cp.clock.pending
        cp.run_until(300.0)
        done = sum(1 for i in invs if i.status == "done")
        results[mode] = {"hedges_sent": cp.hedge.hedges_sent,
                         "hedged_from": None, "done": done,
                         "timers": timers}
    assert results["grouped"]["hedges_sent"] == \
        results["per_inv"]["hedges_sent"] > 0
    assert results["grouped"]["done"] == results["per_inv"]["done"] == n
    # the grouped path arms one timer per (fn, platform) group, not per inv
    assert results["grouped"]["timers"] < results["per_inv"]["timers"] - n // 2


def test_group_hedge_skips_completed_invocations():
    cp, fns = build(names=["hpc-node-cluster", "old-hpc-node-cluster"],
                    enable_hedging=True)
    # generous learned P90 -> hedge budget far beyond actual latency
    _seed_resp_obs(cp, fns, ("nodeinfo",), value=5.0)
    invs = [Invocation(fns["nodeinfo"], 0.0) for _ in range(10)]
    cp.submit_batch(invs)
    cp.run_until(120.0)            # fast platform: all done before budget
    assert all(i.status == "done" for i in invs)
    assert cp.hedge.hedges_sent == 0


# ---------------------------------------------------------------------------
# batched local-trigger delegation
# ---------------------------------------------------------------------------

def test_handle_local_triggers_matches_scalar_path():
    for pressured in (False, True):
        cp_a, fns_a = build(names=["edge-cluster", "cloud-cluster"])
        cp_b, fns_b = build(names=["edge-cluster", "cloud-cluster"])
        if pressured:
            cp_a.platforms["edge-cluster"].bg_cpu = 1.0
            cp_b.platforms["edge-cluster"].bg_cpu = 1.0
        # teach an SLO risk for one function only
        for cp, fns in ((cp_a, fns_a), (cp_b, fns_b)):
            for _ in range(12):
                inv = Invocation(fns["primes-python"], 0.0)
                inv.platform = "edge-cluster"
                inv.exec_time = 30.0
                inv.end_t = 30.0
                cp.perf.observe(inv)
        mix = ["nodeinfo", "primes-python"] * 8
        invs_a = [Invocation(fns_a[m], 0.0) for m in mix]
        invs_b = [Invocation(fns_b[m], 0.0) for m in mix]
        sc_a = cp_a.sidecars["edge-cluster"]
        sc_b = cp_b.sidecars["edge-cluster"]
        del_a, del_b = [], []
        for inv in invs_a:
            sc_a.handle_local_trigger(inv, delegate=del_a.append)
        sc_b.handle_local_triggers(invs_b, delegate_batch=del_b.extend)
        assert (sc_a.local, sc_a.delegated) == (sc_b.local, sc_b.delegated)
        assert [i.fn.name for i in del_a] == [i.fn.name for i in del_b]
        assert len(cp_a.platforms["edge-cluster"].queue) == \
            len(cp_b.platforms["edge-cluster"].queue)


# ---------------------------------------------------------------------------
# columnar drain: exact equivalence with sequential invokes
# ---------------------------------------------------------------------------

def test_vectorized_drain_bitwise_matches_sequential_invokes():
    """The batched drain's vectorized start math must reproduce the
    sequential per-invocation drain bit for bit: same start/queue/exec
    times, same cold-start flags, same completion times — including
    interference crossovers mid-burst."""
    cp_a, fns_a = build(names=["old-hpc-node-cluster"])
    cp_b, fns_b = build(names=["old-hpc-node-cluster"])
    pa = cp_a.platforms["old-hpc-node-cluster"]
    pb = cp_b.platforms["old-hpc-node-cluster"]
    pa.bg_cpu = pb.bg_cpu = 0.5          # busy crossover mid-burst
    mix = ["nodeinfo", "JSON-loads", "primes-python"] * 10
    invs_a = [Invocation(fns_a[m], 0.0) for m in mix]
    invs_b = [Invocation(fns_b[m], 0.0) for m in mix]
    for inv in invs_a:
        pa.invoke(inv)
    pb.invoke_batch(invs_b)
    for a, b in zip(invs_a, invs_b):
        assert a.status == b.status
        assert a.cold_start == b.cold_start
        if a.status == "running":
            assert a.start_t == b.start_t
            assert a.queue_time == b.queue_time
            assert a.exec_time == b.exec_time
            assert a.data_time == b.data_time
    cp_a.run_until(600.0)
    cp_b.run_until(600.0)
    ends_a = sorted(i.end_t for i in invs_a if i.end_t is not None)
    ends_b = sorted(i.end_t for i in invs_b if i.end_t is not None)
    assert ends_a == ends_b
    assert pa.mem_used_mb() == pb.mem_used_mb()


def test_mem_accounting_running_total_matches_scan():
    """The O(1) replica-memory counter must track the old full scan
    through deploy / prewarm / idler / destroy / recover."""
    cp, fns = build(names=["cloud-cluster"])
    p = cp.platforms["cloud-cluster"]

    def scan():
        return sum(len(rs) * p.deployed[f].memory_mb
                   for f, rs in p.replicas.items() if f in p.deployed)

    assert p._mem_replicas_mb == scan()
    p.prewarm("nodeinfo", 3)
    assert p._mem_replicas_mb == scan()
    for _ in range(10):
        p.invoke(Invocation(fns["JSON-loads"], 0.0))
    assert p._mem_replicas_mb == scan()
    cp.run_until(2000.0)                 # idler retires idle replicas
    assert p._mem_replicas_mb == scan()
    p.destroy("nodeinfo")
    assert p._mem_replicas_mb == scan()
    p.recover()
    assert p._mem_replicas_mb == scan() == 0


# ---------------------------------------------------------------------------
# chains: hedged duplicates complete stages
# ---------------------------------------------------------------------------

def test_hedged_duplicate_completes_chain_stage():
    from repro.chains.planner import ChainPlan
    from repro.chains.spec import EXTERNAL, Chain, DataEdge, Stage

    cp = FDNControlPlane(enable_hedging=True)
    # planned platform: old-hpc (slow, and soon throttled); the hedge
    # alternate is the fast hpc cluster
    for n in ("old-hpc-node-cluster", "hpc-node-cluster"):
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = {k: f.replace(real_fn=None)
           for k, f in functions.paper_functions().items()}
    slow_fn = fns["primes-python"].replace(name="crunch", flops=20e9)
    fns["crunch"] = slow_fn
    functions.seed_object_stores(cp.placement,
                                 location="old-hpc-node-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    _seed_resp_obs(cp, fns, ("crunch",))
    # planned platform straggles: background load doubles its latency
    cp.platforms["old-hpc-node-cluster"].bg_cpu = 1.0

    chain = Chain("one", (Stage("s0", "crunch"),),
                  (DataEdge(EXTERNAL, "s0", "in/obj", 1e6),))
    cp.placement.stores["old-hpc-node-cluster"].put("in/obj", 1e6)
    plan = ChainPlan(chain="one", mode="pin", requested_mode="pin",
                     assignment={"s0": "old-hpc-node-cluster"},
                     est_makespan_s=0.0, est_compute_s=0.0,
                     est_transfer_s=0.0, est_bytes_moved=0.0)
    ex = cp.chain_executor(fns)
    inst = ex.launch(chain, plan)
    cp.run_until(600.0)
    assert inst.status == "done"
    assert cp.hedge.hedges_sent >= 1
    assert cp.hedge.hedges_won >= 1
    # the duplicate won on the fast alternate well before the straggling
    # original (>= 2 * 20e9/4.2e9 s ~ 9.5 s) would have finished
    straggler_exec = 2 * (slow_fn.flops /
                          profiles.PAPER_PLATFORMS["old-hpc-node-cluster"]
                          .replica_flops)
    assert inst.latency < 0.7 * straggler_exec
