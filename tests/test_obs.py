"""Flight recorder (repro.obs): lifecycle tracing, latency decomposition
and SLO attribution.

The load-bearing invariant pinned here: for every traced completion the
six lifecycle segments sum *bitwise* to the result sink's
``end - arrival`` — the decomposition is exact, not approximate — on the
object path (smoke/tiny), the chain executor (chains/etl-pipeline) and
the autoscale controller path (autoscale/burst-predictive)."""
import json
import types

import numpy as np
import pytest

from repro.inspector import registry
from repro.inspector.scenario import run_scenario_state
from repro.obs import (CHAIN_STAGE, HEDGE, REJECT, FlightRecorder,
                       SpanBuffer, chain_critical_paths, decompose,
                       reconcile, write_chrome_trace)


@pytest.fixture(scope="module")
def traced_tiny():
    sc = registry.get("smoke/tiny").replace(trace=True)
    return run_scenario_state(sc)


@pytest.fixture(scope="module")
def traced_etl():
    sc = registry.get("chains/etl-pipeline").replace(trace=True,
                                                     duration_s=20.0)
    return run_scenario_state(sc)


@pytest.fixture(scope="module")
def traced_autoscale():
    sc = registry.get("autoscale/burst-predictive").replace(
        trace=True, duration_s=60.0)
    return run_scenario_state(sc)


def _assert_exact(report, cp, sink):
    lb = report.latency_breakdown
    assert lb["enabled"] is True
    completed = report.totals["completed"]
    assert completed > 0
    # sample=1.0: every completion is traced, matched, and reconciles
    # bitwise against the sink
    assert lb["traced_invocations"] == completed
    assert lb["matched_completions"] == completed
    assert lb["exact_reconciled"] == completed
    assert lb["max_reconcile_err_s"] == 0.0
    assert lb["exec_residual_err_s"] < 1e-6
    # same invariant straight from the arrays: segment rows sum to the
    # sink's response times exactly
    decomp = decompose(cp.recorder)
    np.testing.assert_array_equal(decomp.segments.sum(axis=1),
                                  decomp.response)
    rc = reconcile(decomp, sink.completion_columns())
    assert rc["exact"] == rc["matched"] == completed


def test_exact_reconciliation_smoke_tiny(traced_tiny):
    _assert_exact(*traced_tiny)


def test_exact_reconciliation_chain_etl(traced_etl):
    _assert_exact(*traced_etl)


def test_exact_reconciliation_autoscale(traced_autoscale):
    _assert_exact(*traced_autoscale)


def test_tracing_does_not_perturb_results():
    sc = registry.get("smoke/tiny")
    plain = json.loads(run_scenario_state(sc)[0].to_json())
    traced = json.loads(
        run_scenario_state(sc.replace(trace=True))[0].to_json())
    for rep in (plain, traced):
        rep.pop("latency_breakdown", None)
        rep.pop("scenario", None)          # echoes the trace flag itself
    assert traced == plain


def test_sampling_deterministic_and_subsetting():
    # invocation ids come from a process-global counter; reset it before
    # each run so back-to-back runs see the id stream a fresh process
    # would (the sampling hash keys on ids)
    import itertools

    from repro.core import types as core_types

    def run_fresh(sample):
        core_types._inv_counter = itertools.count()
        sc = registry.get("smoke/tiny").replace(trace=True,
                                                trace_sample=sample)
        return run_scenario_state(sc)[1].recorder

    rec_a = run_fresh(0.25)
    rec_b = run_fresh(0.25)
    a, b = rec_a.spans.columns(), rec_b.spans.columns()
    for key in a:
        np.testing.assert_array_equal(a[key], b[key])
    full = run_fresh(1.0)
    assert 0 < rec_a.traced_invocations() < full.traced_invocations()
    # head-based: all-or-nothing per invocation id — every sampled id has
    # its ingress+exec pair, so the decomposition loses no rows
    d = decompose(rec_a)
    assert d.inv.size == rec_a.traced_invocations()


def test_chain_critical_path(traced_etl):
    report, cp, _sink = traced_etl
    cpaths = chain_critical_paths(cp.recorder)
    assert cpaths["instances"] > 0
    assert cpaths["mean_critical_s"] > 0.0
    assert set(cpaths["stage_counts"]) <= set(cp.recorder.fn_names())
    assert report.latency_breakdown["chain_critical_path"] == cpaths


def test_slo_attribution_overload(traced_tiny):
    report = traced_tiny[0]
    att = report.latency_breakdown["slo_attribution"]
    assert att["violations"] == report.totals["slo_violations"] > 0
    assert sum(att["dominant_segment"].values()) == att["violations"]
    assert sum(f["violations"] for f in att["per_function"].values()) \
        == att["violations"]


def test_trace_scenarios_registered():
    for name in ("trace/hpc-outage", "trace/burst-storm",
                 "trace/overload-ramp"):
        sc = registry.get(name)
        assert sc.trace is True


def test_hedge_span_unit():
    rec = FlightRecorder()
    fn = types.SimpleNamespace(name="nodeinfo")
    orig = types.SimpleNamespace(id=7, fn=fn)
    dup = types.SimpleNamespace(id=9, fn=fn)
    rec.record_hedge(dup, orig, 3.25)
    cols = rec.spans.columns()
    assert cols["kind"].tolist() == [HEDGE]
    assert cols["inv"].tolist() == [9]
    assert cols["link"].tolist() == [7]
    assert cols["t0"].tolist() == [3.25]


def test_chrome_trace_export(traced_tiny, tmp_path):
    _report, cp, _sink = traced_tiny
    path = tmp_path / "trace.json"
    n = write_chrome_trace(cp.recorder, str(path))
    data = json.loads(path.read_text())
    events = data["traceEvents"]
    assert len(events) == n > 0
    metas = [e for e in events if e["ph"] == "M"]
    spans = [e for e in events if e["ph"] == "X"]
    assert {m["args"]["name"] for m in metas} >= \
        set(cp.recorder.platform_names())
    assert len(spans) == cp.recorder.spans.n
    for e in spans[:50]:
        assert e["dur"] >= 0.0 and e["ts"] >= 0.0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)


def test_span_buffer_growth():
    buf = SpanBuffer(capacity=2)
    for i in range(5):
        buf.add(i, 0, float(i), float(i + 1), 0, 0, 1)
    buf.add_many(np.arange(100), 1, 0.0, 1.0, 0, 0, 1)
    assert buf.n == 105
    cols = buf.columns()
    assert cols["inv"][:5].tolist() == [0, 1, 2, 3, 4]
    assert cols["inv"][5:].tolist() == list(range(100))
    assert cols["kind"][:5].tolist() == [0] * 5


def test_gateway_unauthorized_records_reject():
    from benchmarks.fdn_common import build_fdn
    from repro.core.types import Invocation
    cp, gw, fns = build_fdn(analytic=True)
    cp.attach_recorder(FlightRecorder())
    inv = Invocation(fn=fns["nodeinfo"], arrival_t=0.0)
    assert gw.request(inv, token="wrong") is False
    cols = cp.recorder.spans.columns()
    rejects = cols["kind"] == REJECT
    assert rejects.sum() == 1
    assert cols["link"][rejects].tolist() == [1]


def test_chain_stage_spans_cover_instances(traced_etl):
    _report, cp, _sink = traced_etl
    cols = cp.recorder.spans.columns()
    m = cols["kind"] == CHAIN_STAGE
    assert m.any()
    # stage spans are well-formed intervals tied to real invocations
    assert np.all(cols["t1"][m] >= cols["t0"][m])
    assert np.all(cols["inv"][m] >= 0)


def test_scenario_diff_tolerates_added_section():
    from benchmarks.scenario_diff import diff_reports
    a = {"schema_version": 1, "scenario": {"name": "x"},
         "totals": {"completed": 3},
         "latency_breakdown": {"enabled": True}}
    golden = {"schema_version": 1, "scenario": {"name": "x"},
              "totals": {"completed": 3}}
    warnings = []
    assert diff_reports(a, golden, warnings=warnings) == []
    assert len(warnings) == 1 and "latency_breakdown" in warnings[0]
    # the reverse — the new report *dropped* a section — is still drift
    drifts = diff_reports(golden, a)
    assert any("latency_breakdown" in d.path for d in drifts)


def test_alert_annotation_events_track_mapping():
    from repro.obs import alert_annotation_events
    slo = [{"t": 12.0, "kind": "fire", "fn": "f", "rule": "fast_burn",
            "severity": "page", "burn_short": 9.1, "burn_long": 8.2}]
    health = [{"t": 30.0, "kind": "fire", "platform": "edge-cluster",
               "metric": "queue_depth", "z": 7.5},
              {"t": 31.0, "kind": "resolve", "platform": "never-seen",
               "metric": "watts", "z": 1.0}]
    pnames = ["hpc-node-cluster", "edge-cluster"]
    events = alert_annotation_events(slo, health, pnames)
    assert len(events) == 3
    for e in events:
        assert e["ph"] == "i" and e["s"] == "p" and e["cat"] == "alert"
        assert isinstance(e["pid"], int) and e["tid"] == 0
    # SLO burn alerts land on the control track (pid 0)
    assert events[0]["name"] == "slo:fast_burn:fire"
    assert events[0]["pid"] == 0 and events[0]["ts"] == 12.0 * 1e6
    assert events[0]["args"]["severity"] == "page"
    # health alerts land on THEIR platform's span track (index + 1)
    assert events[1]["name"] == "health:queue_depth:fire"
    assert events[1]["pid"] == pnames.index("edge-cluster") + 1
    assert events[1]["args"]["z"] == 7.5
    # a platform the recorder never saw falls back to the control track
    assert events[2]["pid"] == 0


def test_chrome_trace_alert_annotation_round_trip(tmp_path):
    import itertools

    from repro.core import types as core_types
    from repro.inspector.scenario import run_scenario_state

    core_types._inv_counter = itertools.count()
    sc = registry.get("telemetry/hpc-outage").replace(trace=True)
    report, cp, _sink = run_scenario_state(sc)
    alerts = report.alerts
    assert alerts["enabled"] and alerts["health"]["fires"] > 0
    path = tmp_path / "trace.json"
    plain = write_chrome_trace(cp.recorder, str(path))
    n = write_chrome_trace(cp.recorder, str(path), alerts=alerts)
    events = json.loads(path.read_text())["traceEvents"]
    notes = [e for e in events if e.get("cat") == "alert"]
    expect = len(alerts["slo"]["events"]) + len(alerts["health"]["events"])
    assert len(notes) == expect > 0
    assert n == plain + expect       # annotations are purely additive
    # every health annotation sits on the track whose process_name meta
    # is its platform — Perfetto shows the alert above that row's spans
    track = {e["pid"]: e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    for e in notes:
        if e["name"].startswith("health:"):
            assert track[e["pid"]] == e["args"]["platform"]
        else:
            assert e["pid"] == 0
