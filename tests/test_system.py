"""End-to-end behaviour tests for the full system: real training runs with
loss decrease, the FDN serving pipeline over heterogeneous platforms, and
policy-vs-policy outcome comparisons (the paper's headline results in
miniature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import get_config
from repro.models import model_api as api
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def test_training_loss_decreases():
    """~40 steps of real training on CPU must reduce the LM loss."""
    from repro.data.pipeline import DataConfig, TokenStream
    cfg = get_config("qwen3-0.6b").reduced()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    seed=3, mean_doc_len=16)
    stream = TokenStream(dc)
    oc = opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init_state(oc, api.model_specs(cfg))
    step = jax.jit(make_train_step(cfg, oc))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)
    assert np.isfinite(losses).all()


def test_training_with_microbatches_matches_single():
    """Grad accumulation must match the single-batch step (same arithmetic)."""
    cfg = get_config("qwen3-0.6b").reduced()
    oc = opt.OptConfig(lr=1e-3, warmup_steps=0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = api.make_batch(cfg, InputShape("t", 32, 4, "train"))
    s1 = opt.init_state(oc, api.model_specs(cfg))
    s2 = opt.init_state(oc, api.model_specs(cfg))
    p1, _, m1 = jax.jit(make_train_step(cfg, oc, 1))(params, s1, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, oc, 2))(params, s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_fdn_serves_ml_functions_across_platforms():
    """The FDN delivers serve-<arch> functions; energy-aware routing sends
    small models to the edge pod, big models to the big pod."""
    from repro.core import EnergyAwarePolicy, FDNControlPlane, Gateway
    from repro.core import functions as fn_mod
    from repro.core import profiles
    from repro.core.loadgen import attach_completion_hooks, run_load
    from repro.core.types import DeploymentSpec, SLO

    cp = FDNControlPlane()
    for name in ("hpc-pod", "edge-tpu"):
        cp.create_platform(profiles.TPU_PLATFORMS[name])
    small = fn_mod.serving_function("qwen3-0.6b").replace(slo=SLO(5.0))
    big = fn_mod.serving_function("llama3-405b").replace(slo=SLO(5.0))
    cp.deploy(DeploymentSpec("serve", [small, big],
                             ["hpc-pod", "edge-tpu"]))
    attach_completion_hooks(cp)
    cp.policy = EnergyAwarePolicy(cp.perf)
    gw = Gateway(cp)
    run_load(cp.clock, lambda i: gw.request(i), small, vus=4,
             duration_s=30.0, sleep_s=0.1)
    run_load(cp.clock, lambda i: gw.request(i), big, vus=4,
             duration_s=30.0, sleep_s=0.1)
    small_on_edge = cp.metrics.requests_served("edge-tpu", small.name)
    big_on_hpc = cp.metrics.requests_served("hpc-pod", big.name)
    assert small_on_edge > 0, "small model should run on the edge pod"
    assert big_on_hpc > 0, "large model should run on the big pod"


def test_composite_beats_static_worst_platform():
    """The FDN composite policy must beat always-picking the edge platform
    for a compute-heavy function (the paper's core value proposition)."""
    from repro.core import FDNControlPlane, Gateway
    from repro.core import functions as fn_mod
    from repro.core import profiles
    from repro.core.loadgen import attach_completion_hooks, run_load
    from repro.core.types import DeploymentSpec

    def run(force_edge):
        cp = FDNControlPlane()
        for n in ("hpc-node-cluster", "edge-cluster"):
            cp.create_platform(profiles.PAPER_PLATFORMS[n])
        fns = fn_mod.paper_functions()
        fn_mod.seed_object_stores(cp.placement,
                                  location="hpc-node-cluster")
        cp.deploy(DeploymentSpec("t", list(fns.values()),
                                 list(cp.platforms)))
        attach_completion_hooks(cp)
        gw = Gateway(cp)
        if force_edge:
            submit = lambda i: cp.submit(i, platform_override="edge-cluster")
        else:
            submit = lambda i: gw.request(i)
        res = run_load(cp.clock, submit, fns["primes-python"], vus=10,
                       duration_s=40.0, sleep_s=0.1)
        return res.p90_response()

    p90_fdn = run(False)
    p90_edge = run(True)
    assert p90_fdn < p90_edge, (p90_fdn, p90_edge)


def test_scale_to_zero_reclaims_replicas():
    from repro.core import FDNControlPlane, Gateway
    from repro.core import functions as fn_mod
    from repro.core import profiles
    from repro.core.loadgen import attach_completion_hooks, run_load
    from repro.core.types import DeploymentSpec

    cp = FDNControlPlane()
    cp.create_platform(profiles.PAPER_PLATFORMS["cloud-cluster"])
    fns = fn_mod.paper_functions()
    fn_mod.seed_object_stores(cp.placement, location="cloud-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()), ["cloud-cluster"]))
    attach_completion_hooks(cp)
    gw = Gateway(cp)
    run_load(cp.clock, lambda i: gw.request(i), fns["nodeinfo"], vus=5,
             duration_s=20.0, sleep_s=0.05)
    p = cp.platforms["cloud-cluster"]
    assert p.replica_count("nodeinfo") > 0
    # idle long past the faas-idler window
    cp.run_until(cp.clock.now() + 3 * p.prof.scale_to_zero_s)
    assert p.replica_count("nodeinfo") <= p.prof.prewarm_pool + 1


def test_predictive_prewarm_reduces_cold_starts():
    from repro.core import FDNControlPlane, Gateway
    from repro.core import functions as fn_mod
    from repro.core import profiles
    from repro.core.loadgen import attach_completion_hooks, run_load
    from repro.core.types import DeploymentSpec

    def run(prewarm):
        cp = FDNControlPlane(predictive_prewarm=prewarm)
        cp.create_platform(profiles.PAPER_PLATFORMS["cloud-cluster"])
        fns = fn_mod.paper_functions()
        fn_mod.seed_object_stores(cp.placement, location="cloud-cluster")
        cp.deploy(DeploymentSpec("t", list(fns.values()),
                                 ["cloud-cluster"]))
        attach_completion_hooks(cp)
        gw = Gateway(cp)
        run_load(cp.clock, lambda i: gw.request(i), fns["nodeinfo"],
                 vus=12, duration_s=60.0, sleep_s=0.05)
        return cp.metrics.total("cloud-cluster", "nodeinfo", "cold_starts")

    assert run(True) <= run(False)
