"""Chunked streaming replay: conservation, determinism, columnar-sink
folds, and the trace-chunk equivalence with ``counts_to_arrivals``."""
import numpy as np
import pytest

from repro.core.scheduler import SLOCompositePolicy
from repro.inspector.streaming import chunk_batch, stream_replay
from repro.inspector.traces import counts_to_arrivals, synthetic_azure_counts

from benchmarks.fdn_common import build_fdn

FNS = ("nodeinfo", "primes-python", "JSON-loads")


def _replay(chunk_minutes=7, seed=3, policy=None, minutes=30, mean_rpm=40.0):
    cp, _gw, fns = build_fdn(analytic=True)
    cp.kb.log_decisions = False
    if policy is not None:
        cp.policy = policy(cp.perf, cp.placement)
    counts = synthetic_azure_counts(FNS, minutes=minutes,
                                    mean_rpm=mean_rpm, seed=seed)
    stats = stream_replay(cp, fns, counts, chunk_minutes=chunk_minutes,
                          seed=seed)
    return cp, counts, stats


def test_every_arrival_is_decided():
    _cp, counts, stats = _replay()
    total = sum(int(c.sum()) for c in counts.values())
    assert stats.submitted == total
    assert stats.admitted + stats.rejected == stats.submitted
    assert sum(stats.per_platform.values()) == stats.admitted
    assert sum(stats.per_function.values()) == stats.admitted


def test_replay_is_deterministic():
    _, _, a = _replay(chunk_minutes=7, seed=11)
    _, _, b = _replay(chunk_minutes=7, seed=11)
    assert a.to_dict() == b.to_dict()


def test_chunk_size_does_not_change_totals():
    _, _, a = _replay(chunk_minutes=1)
    _, _, b = _replay(chunk_minutes=30)
    assert a.submitted == b.submitted
    assert b.chunks == 1 and a.chunks > 1
    assert a.peak_chunk_rows <= b.peak_chunk_rows


def test_columnar_sink_absorbs_folded_population():
    cp, _counts, stats = _replay()
    folded = 0
    for name in FNS:
        fi = cp.perf._frow.get(name)
        if fi is not None:
            folded += int(cp.perf._state.exec_n[fi, :].sum())
    assert folded == stats.admitted
    # arrival-rate windows and co-invocation edges saw the stream too
    assert any(cp.events.forecast_rate(name) > 0 for name in FNS)
    assert cp.interactions.edges


def test_chunk_batch_matches_counts_to_arrivals_single_fn():
    """One function's chunk columns are byte-identical to the trace
    library's canonical minute-count expansion under the same seed."""
    cp, _gw, fns = build_fdn(analytic=True)
    counts = np.array([3, 0, 5, 2])
    batch = chunk_batch([fns["nodeinfo"]], counts[None, :], 0, 60.0, seed=9)
    expect = counts_to_arrivals(counts, minute_s=60.0, seed=9)
    assert batch.n == int(counts.sum())
    np.testing.assert_array_equal(batch.arrival_t, expect)
    assert set(batch.fn_idx.tolist()) == {0}


class _StatefulPolicy(SLOCompositePolicy):
    def fn_decisions(self, fns, snap, n=None):
        return None                       # force the representative path


def test_stateful_policy_uses_representative_rows():
    _cp, counts, stats = _replay(policy=_StatefulPolicy)
    total = sum(int(c.sum()) for c in counts.values())
    assert stats.submitted == total
    assert stats.admitted + stats.rejected == total


def test_empty_minutes_are_skipped():
    cp, _gw, fns = build_fdn(analytic=True)
    counts = {"nodeinfo": np.zeros(10)}
    stats = stream_replay(cp, fns, counts, chunk_minutes=4)
    assert stats.submitted == 0 and stats.chunks == 0


def test_replay_stays_object_free():
    """No Invocation objects may be born during a columnar replay."""
    cp, _counts, stats = _replay()
    assert stats.admitted > 0
    assert cp.completed_count == 0
    assert all(not p.inflight for p in cp.platforms.values())
