"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED same-family config runs one forward/train step on CPU with finite
outputs and the right shapes, plus prefill/decode consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model_api as api

TRAIN = InputShape("t", 64, 2, "train")
PREFILL = InputShape("p", 64, 2, "prefill")
DECODE = InputShape("d", 64, 2, "decode")


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        out[arch] = (cfg, api.init_params(cfg, jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(zoo, arch):
    cfg, params = zoo[arch]
    batch = api.make_batch(cfg, TRAIN)
    loss, metrics = jax.jit(
        lambda p, b: api.loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(zoo, arch):
    cfg, params = zoo[arch]
    pb = api.make_batch(cfg, PREFILL)
    logits, cache = jax.jit(lambda p, b: api.prefill(cfg, p, b))(params, pb)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    db = api.make_batch(cfg, DECODE)
    logits2, cache2 = jax.jit(
        lambda p, c, b: api.decode_step(cfg, p, c, b))(params, cache, db)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))
    # positions advanced for every row
    assert np.all(np.asarray(cache2["pos"]) == np.asarray(cache["pos"]) + 1)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x7b",
                                  "mamba2-2.7b", "recurrentgemma-9b"])
def test_decode_matches_full_forward(zoo, arch):
    """Greedy decode after prefill == argmax of a full re-forward."""
    cfg, params = zoo[arch]
    rng = np.random.default_rng(1)
    toks = rng.integers(1, cfg.vocab_size, (1, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros((1, cfg.n_img_tokens, cfg.d_model),
                                          jnp.bfloat16)
    logits, cache = api.prefill(cfg, params, batch, 48)
    seq = list(toks[0])
    for step in range(3):
        nxt = int(jnp.argmax(logits[0, -1]))
        # reference: full forward over the extended sequence
        from repro.models import transformer as tfm
        from repro.models import rglru, mamba2
        full = {"tokens": jnp.asarray([seq], jnp.int32)}
        if cfg.family in ("dense", "moe"):
            emb = tfm.embed_inputs(cfg, params, full)
            h, _, _ = tfm.forward_hidden(cfg, params, emb)
        elif cfg.family == "hybrid":
            emb = jnp.take(params["embed"], full["tokens"], axis=0)
            h, _, _ = rglru.forward_hidden(cfg, params, emb)
        else:
            emb = jnp.take(params["embed"], full["tokens"], axis=0)
            h, _, _ = mamba2.forward_hidden(cfg, params, emb)
        ref_logits = tfm.logits_fn(cfg, params, h[:, -1:, :])
        assert int(jnp.argmax(ref_logits[0, -1])) == nxt, \
            f"{arch}: decode diverges at step {step}"
        seq.append(nxt)
        logits, cache = api.decode_step(cfg, params, cache,
                                        {"token": jnp.asarray([[nxt]],
                                                              jnp.int32)})


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_close_to_analytic(zoo, arch):
    cfg, _ = zoo[arch]
    real = api.param_count(cfg)
    analytic = cfg.n_params()
    assert abs(real - analytic) / max(real, 1) < 0.30, (real, analytic)


def test_full_config_param_counts():
    """Full (non-reduced) configs should land near their advertised sizes."""
    expect = {"qwen3-1.7b": (1.6e9, 2.4e9), "qwen3-0.6b": (0.55e9, 0.9e9),
              "yi-34b": (30e9, 38e9), "llama3-405b": (380e9, 430e9),
              "mixtral-8x7b": (42e9, 50e9), "dbrx-132b": (110e9, 140e9),
              "recurrentgemma-9b": (7.5e9, 11e9),
              "phi-3-vision-4.2b": (3.5e9, 4.8e9),
              "mamba2-2.7b": (2.2e9, 3.1e9),
              "whisper-small": (0.2e9, 0.36e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_vlm_concatenates_image_tokens(zoo):
    cfg, params = zoo["phi-3-vision-4.2b"]
    batch = api.make_batch(cfg, TRAIN)
    assert batch["tokens"].shape[1] == 64 - cfg.n_img_tokens
    loss, _ = api.loss_fn(cfg, params, batch, remat=False)
    assert jnp.isfinite(loss)


def test_sliding_window_cache_is_bounded():
    cfg = get_config("mixtral-8x7b").reduced()
    specs = api.cache_specs(cfg, 2, 1000)
    assert specs["k"].shape[2] <= cfg.sliding_window


def test_ssm_cache_is_o1():
    cfg = get_config("mamba2-2.7b").reduced()
    s1 = api.cache_specs(cfg, 2, 100)
    s2 = api.cache_specs(cfg, 2, 100_000)
    assert s1["h"].shape == s2["h"].shape
