"""Columnar performance-model state: the preallocated-array estimators
must be bit-identical to the classic per-object P²/EWMA estimators, and
``predict_matrix`` must equal the scalar ``predict_*`` loop element for
element — the parity the fused admission step is built on."""
import numpy as np
import pytest

from repro.core.behavioral import (EWMA, FunctionPerformanceModel,
                                   P2Quantile)
from repro.core.types import FunctionSpec, Invocation, PlatformProfile


def _profiles(n=4):
    return [PlatformProfile(name=f"p{i}", faas="openwhisk", nodes=i + 1,
                            replica_flops=1e9 * (i + 1),
                            net_bw=1e8 * (i + 1),
                            loaded_w_per_node=10.0 + 3.0 * i)
            for i in range(n)]


def _functions(n=6):
    return [FunctionSpec(name=f"f{i}", flops=1e6 * (i + 1),
                         read_bytes=1e4 * i, write_bytes=5e3 * i)
            for i in range(n)]


def _observe(perf, fn, prof, exec_t, resp_t, cold=False, queue_t=0.0):
    inv = Invocation(fn, 0.0)
    inv.platform = prof.name
    inv.exec_time = exec_t
    inv.end_t = resp_t            # response_time = end_t - arrival_t
    inv.cold_start = cold
    inv.queue_time = queue_t
    return perf.observe(inv)


def _randomized(perf, fns, profs, seed=0, max_obs=25):
    rng = np.random.default_rng(seed)
    ref_ewma = {}
    ref_resp = {}
    for fn in fns:
        for prof in profs:
            k = int(rng.integers(0, max_obs))
            e, p = EWMA(), P2Quantile()
            for _ in range(k):
                et = float(rng.uniform(0.01, 2.0))
                rt = et * float(rng.uniform(1.0, 3.0))
                _observe(perf, fn, prof, et, rt,
                         cold=bool(rng.random() < 0.2),
                         queue_t=float(rng.uniform(0.0, 0.5)))
                e.add(et)
                p.add(rt)
            ref_ewma[(fn.name, prof.name)] = e
            ref_resp[(fn.name, prof.name)] = p
    return ref_ewma, ref_resp


def test_cells_bitwise_match_reference_estimators():
    perf = FunctionPerformanceModel()
    fns, profs = _functions(), _profiles()
    ref_ewma, ref_resp = _randomized(perf, fns, profs, seed=3)
    for fn in fns:
        for prof in profs:
            key = (fn.name, prof.name)
            e, p = ref_ewma[key], ref_resp[key]
            cell = perf.exec_ewma.get(key)
            if e.count == 0:
                assert cell is None
            else:
                assert cell.count == e.count
                assert cell.value() == e.value()
            rcell = perf.resp_p90.get(key)
            if p.count == 0:
                assert rcell is None
            else:
                assert rcell.count == p.count
                v, rv = p.value(), rcell.value()
                assert v == rv or (np.isnan(v) and np.isnan(rv))


def test_scalar_predicts_match_reference():
    perf = FunctionPerformanceModel()
    fns, profs = _functions(), _profiles()
    ref_ewma, ref_resp = _randomized(perf, fns, profs, seed=11)
    for fn in fns:
        for prof in profs:
            key = (fn.name, prof.name)
            e, p = ref_ewma[key], ref_resp[key]
            want = e.value() if e.count >= 3 else \
                perf.analytic_exec(fn, prof)
            assert perf.predict_exec(fn, prof) == want
            wantp = p.value() if p.count >= 10 else want * 1.5
            assert perf.predict_p90_response(fn, prof) == wantp
            assert perf.predict_energy(fn, prof) == \
                want * prof.nodes * prof.loaded_w_per_node


def test_predict_matrix_bitwise_matches_scalar_loop():
    perf = FunctionPerformanceModel()
    fns, profs = _functions(), _profiles()
    _randomized(perf, fns, profs, seed=42)
    # include a function and platform the model has never seen
    fns = fns + [FunctionSpec(name="unseen", flops=3e7, read_bytes=1e5)]
    profs = profs + [PlatformProfile(name="fresh", faas="gcf", nodes=2)]
    m = perf.predict_matrix(fns, profs, p90=True, energy=True)
    for i, fn in enumerate(fns):
        for j, prof in enumerate(profs):
            assert m["exec_s"][i, j] == perf.predict_exec(fn, prof)
            assert m["p90_s"][i, j] == perf.predict_p90_response(fn, prof)
            assert m["energy_j"][i, j] == perf.predict_energy(fn, prof)


def test_state_grows_past_preallocation():
    perf = FunctionPerformanceModel()
    profs = [PlatformProfile(name=f"plat{i}", faas="openwhisk")
             for i in range(20)]
    fns = [FunctionSpec(name=f"fn{i}") for i in range(80)]
    for i, fn in enumerate(fns):
        prof = profs[i % len(profs)]
        for k in range(3):
            _observe(perf, fn, prof, 0.1 * (i + 1), 0.2 * (i + 1))
    assert perf._state.exec_n.shape[0] >= 80
    assert perf._state.exec_n.shape[1] >= 20
    for i, fn in enumerate(fns):
        prof = profs[i % len(profs)]
        assert perf.exec_ewma.get((fn.name, prof.name)).count == 3
        assert perf.predict_exec(fn, prof) == pytest.approx(0.1 * (i + 1))


def test_cold_start_ewma_tracked_per_platform():
    perf = FunctionPerformanceModel()
    fn, prof = _functions(1)[0], _profiles(1)[0]
    ref = EWMA()
    for q in (1.5, 2.5, 0.5):
        _observe(perf, fn, prof, 0.1, 0.2, cold=True, queue_t=q)
        ref.add(q)
    assert perf.predict_cold(prof.name) == ref.value()
    assert np.isnan(perf.predict_cold("never-seen"))


def test_fold_observations_closed_form_ewma():
    perf = FunctionPerformanceModel()
    fn, prof = _functions(1)[0], _profiles(1)[0]
    _observe(perf, fn, prof, 0.4, 0.6)
    perf.fold_observations(fn.name, prof.name, 0.2, 0.3, k=50)
    cell = perf.exec_ewma.get((fn.name, prof.name))
    assert cell.count == 51
    # closed form: v' = x + (1-a)^k (v0 - x)
    want = 0.2 + (1 - perf.ALPHA) ** 50 * (0.4 - 0.2)
    assert cell.value() == pytest.approx(want, rel=1e-12)
    # folded population counts toward the P90 observation gates
    assert perf.resp_p90.get((fn.name, prof.name)).count == 51
