"""Shared fixtures. NOTE: no XLA device-count flag here — smoke tests and
benches must see 1 CPU device (the 512-device flag belongs ONLY to the
dry-run / roofline entry points)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
