"""Batched scheduling fast path: vectorized-policy parity with the scalar
``choose``, batch submit bookkeeping, arrival record-once semantics, and
open-loop load generation determinism."""
import numpy as np
import pytest

from repro.core import (DataLocalityPolicy, EnergyAwarePolicy,
                        FDNControlPlane, Gateway, Invocation,
                        PerformanceRankedPolicy, RoundRobinCollaboration,
                        SLOCompositePolicy, UtilizationAwarePolicy,
                        WeightedCollaboration)
from repro.core import functions, profiles
from repro.core.loadgen import (ColumnarResultSink, attach_completion_hooks,
                                poisson_arrivals, run_arrivals,
                                trace_arrivals, uniform_arrivals)
from repro.core.scheduler import PlatformSnapshot
from repro.core.types import DeploymentSpec, SLO


def build(names=None, policy=None):
    cp = FDNControlPlane(policy=policy)
    for n in (names or list(profiles.PAPER_PLATFORMS)):
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = {k: f.replace(real_fn=None)
           for k, f in functions.paper_functions().items()}
    functions.seed_object_stores(cp.placement, location="cloud-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    return cp, fns


def _randomized_state(cp, fns, rng):
    """Vary platform pressure and teach the perf model random latencies so
    every policy filter stage (utilization, SLO, locality) gets exercised."""
    for p in cp.platforms.values():
        p.bg_cpu = float(rng.uniform(0, 1.2))
        p.bg_mem = float(rng.uniform(0, 0.8))
    for fn in fns.values():
        for pname in cp.platforms:
            n_obs = int(rng.integers(0, 15))
            for _ in range(n_obs):
                inv = Invocation(fn, 0.0)
                inv.platform = pname
                inv.exec_time = float(rng.uniform(0.01, 8.0))
                inv.end_t = inv.exec_time
                cp.perf.observe(inv)


def _mixed_invs(fns, rng, n):
    specs = list(fns.values())
    # randomized SLOs so SLO-feasibility masks differ per invocation mix
    specs = [s if rng.random() < 0.5 else
             s.replace(slo=SLO(p90_response_s=float(rng.uniform(0.05, 10))))
             for s in specs]
    return [specs[int(rng.integers(0, len(specs)))] for _ in range(n)]


POLICY_FACTORIES = {
    "perf_ranked": lambda cp: PerformanceRankedPolicy(cp.perf),
    "utilization": lambda cp: UtilizationAwarePolicy(cp.perf,
                                                     cpu_threshold=0.7),
    "round_robin": lambda cp: RoundRobinCollaboration(),
    "weighted": lambda cp: WeightedCollaboration(
        {"hpc-node-cluster": 5, "cloud-cluster": 1, "edge-cluster": 2}),
    "data_locality": lambda cp: DataLocalityPolicy(cp.perf, cp.placement),
    "energy": lambda cp: EnergyAwarePolicy(cp.perf),
    "slo_composite": lambda cp: SLOCompositePolicy(cp.perf, cp.placement),
}


@pytest.mark.parametrize("pname", sorted(POLICY_FACTORIES))
def test_score_matches_choose_randomized(pname):
    """choose_batch (vectorized score + argmin) must pick exactly the same
    platform as N scalar choose calls, across randomized platform states,
    invocation mixes, and platform subsets."""
    rng = np.random.default_rng(1234)
    all_names = list(profiles.PAPER_PLATFORMS)
    for trial in range(5):
        k = int(rng.integers(2, len(all_names) + 1))
        names = list(rng.choice(all_names, size=k, replace=False))
        cp, fns = build(names=names)
        _randomized_state(cp, fns, rng)
        specs = _mixed_invs(fns, rng, 40)
        invs_a = [Invocation(s, 0.0) for s in specs]
        invs_b = [Invocation(s, 0.0) for s in specs]
        plats = list(cp.platforms.values())

        pol_scalar = POLICY_FACTORIES[pname](cp)
        pol_batch = POLICY_FACTORIES[pname](cp)   # fresh rotation state
        scalar = [pol_scalar.choose(i, plats) for i in invs_a]
        batch = pol_batch.choose_batch(invs_b, plats)
        got = [p.prof.name if p else None for p in batch]
        want = [p.prof.name if p else None for p in scalar]
        assert got == want, f"{pname} trial {trial}: {got} != {want}"


def test_choose_batch_rejects_unplaceable():
    cp, fns = build(names=["edge-cluster"])
    huge = fns["nodeinfo"].replace(name="huge", memory_mb=1 << 30)
    pol = PerformanceRankedPolicy(cp.perf)
    assert pol.choose_batch([Invocation(huge, 0.0)],
                            list(cp.platforms.values())) == [None]


def test_snapshot_reuse_across_policies():
    cp, fns = build()
    snap = PlatformSnapshot(list(cp.platforms.values()))
    inv = Invocation(fns["primes-python"], 0.0)
    a = PerformanceRankedPolicy(cp.perf).choose(inv, snap)
    b = SLOCompositePolicy(cp.perf, cp.placement).choose(inv, snap)
    assert a is not None and b is not None


def test_submit_batch_matches_sequential_submits():
    """Same invocation mix through submit_batch vs N submits: identical
    platform decisions, knowledge-base rows, and rate-model counts.

    (Exact decision parity holds while no platform crosses a utilization
    threshold mid-sequence — a batch scores ONE snapshot, sequential
    submits re-observe state between decisions — so the mix is sized
    below every platform's pressure knee.)"""
    n = 24
    cp_a, fns_a = build()
    cp_b, fns_b = build()
    specs_a = [list(fns_a.values())[i % 4] for i in range(n)]
    specs_b = [list(fns_b.values())[i % 4] for i in range(n)]
    for inv in [Invocation(s, 0.0) for s in specs_a]:
        cp_a.submit(inv)
    cp_b.submit_batch([Invocation(s, 0.0) for s in specs_b])
    dec_a = [(d["fn"], d["platform"]) for d in cp_a.kb.decisions]
    dec_b = [(d["fn"], d["platform"]) for d in cp_b.kb.decisions]
    assert dec_a == dec_b
    assert len(cp_a.rejected) == len(cp_b.rejected) == 0
    for name in {s.name for s in specs_a}:
        assert cp_a.events._counts[name] == cp_b.events._counts[name]
    # batch completes identically once the clock runs
    cp_a.run_until(120.0)
    cp_b.run_until(120.0)
    assert len(cp_a.completed) == len(cp_b.completed) == n


def test_arrival_recorded_exactly_once_on_redelivery():
    """A redelivered invocation must not double-count in the EventModel
    (the old submit path re-recorded every retry)."""
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])
    inv = Invocation(fns["nodeinfo"], 0.0)
    assert cp.submit(inv)
    # force a redelivery through the same submit path
    cp.submit(inv)
    w = int(cp.clock.now() // cp.events.window_s)
    assert cp.events._counts["nodeinfo"][w] == 1


def test_gateway_lb_single_record(monkeypatch):
    """The gateway's lb fall-through must submit (and record) once."""
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])

    class NonePolicy(RoundRobinCollaboration):
        def choose(self, inv, platforms):
            return None

        def choose_batch(self, invs, platforms):
            return [None] * len(invs)

    gw = Gateway(cp, lb_policy=NonePolicy())
    inv = Invocation(fns["nodeinfo"], 0.0)
    assert gw.request(inv)
    w = int(cp.clock.now() // cp.events.window_s)
    assert cp.events._counts["nodeinfo"][w] == 1


def test_gateway_request_batch_auth_and_routing():
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])
    gw = Gateway(cp)
    bad = [Invocation(fns["nodeinfo"], 0.0) for _ in range(3)]
    assert gw.request_batch(bad, principal="intruder", token="no") == 0
    assert gw.unauthorized == 3
    good = [Invocation(fns["nodeinfo"], 0.0) for _ in range(8)]
    assert gw.request_batch(good) == 8
    assert len(cp.kb.decisions) == 8


def test_open_loop_arrivals_deterministic():
    a = poisson_arrivals(50.0, 30.0, seed=9)
    b = poisson_arrivals(50.0, 30.0, seed=9)
    c = poisson_arrivals(50.0, 30.0, seed=10)
    np.testing.assert_array_equal(a, b)
    assert a.size != c.size or not np.array_equal(a, c)
    assert float(a[-1]) < 30.0 and np.all(np.diff(a) >= 0)
    # rate sanity: ~50 rps over 30 s
    assert 0.7 * 1500 <= a.size <= 1.3 * 1500
    u = uniform_arrivals(40.0, 10.0)
    assert u.size == 400 and u[0] == 0.0
    tr = trace_arrivals([5.0, 1.0, 3.0], t0=2.0)
    np.testing.assert_allclose(tr, [2.0, 4.0, 6.0])


def test_run_arrivals_columnar_sink():
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])
    sink = ColumnarResultSink().install(cp)
    arrivals = poisson_arrivals(40.0, 20.0, seed=3)
    run_arrivals(cp.clock, cp.submit_batch, fns["nodeinfo"], arrivals,
                 batch_window_s=0.1, sink=sink)
    assert sink.submitted == arrivals.size
    assert sink.rejected == 0
    assert sink.completed == arrivals.size
    assert np.isfinite(sink.p90_response())
    assert sink.p90_response() < 7.0
    assert sum(sink.platform_counts().values()) == sink.completed
    # deterministic end-to-end: rerun produces identical latency columns
    cp2, fns2 = build(names=["hpc-node-cluster", "cloud-cluster"])
    sink2 = ColumnarResultSink().install(cp2)
    run_arrivals(cp2.clock, cp2.submit_batch, fns2["nodeinfo"],
                 poisson_arrivals(40.0, 20.0, seed=3),
                 batch_window_s=0.1, sink=sink2)
    np.testing.assert_allclose(np.sort(sink.response_times()),
                               np.sort(sink2.response_times()))


def test_sink_to_metrics_bulk_ingest():
    cp, fns = build(names=["hpc-node-cluster"])
    sink = ColumnarResultSink().install(cp)
    run_arrivals(cp.clock, cp.submit_batch, fns["nodeinfo"],
                 uniform_arrivals(20.0, 10.0), batch_window_s=0.25,
                 sink=sink)
    sink.to_metrics(cp.metrics, platform="_loadgen", fn="nodeinfo")
    ws = cp.metrics._get("_loadgen", "nodeinfo", "response_time")
    assert ws.count() == sink.completed
    assert ws.p90() == pytest.approx(sink.p90_response())


def test_vu_not_duplicated_when_failed_submit_also_fires_on_done():
    """Regression: the failed-submit fallback in the VU loop used to
    reschedule without checking done_flag, so a platform that both failed
    an invocation AND later fired _on_done (redelivery, hedging) forked
    the virtual user — VU count grew without bound."""
    from repro.core.loadgen import run_load
    from repro.core.simulator import SimClock
    from repro.core import functions

    fn = functions.paper_functions()["nodeinfo"].replace(real_fn=None)
    clock = SimClock()
    submitted = []

    def submit(inv):
        # fail the submit synchronously AND complete it later anyway
        submitted.append(inv)
        inv.status = "failed"

        def late_done():
            cb = getattr(inv, "_on_done", None)
            if cb is not None:
                cb()

        clock.after(0.05, late_done)

    res = run_load(clock, submit, fn, vus=1, duration_s=2.0,
                   sleep_s=0.1, seed=1, jitter=0.0, drain_s=1.0)
    # one VU iterating every ~0.1 s (fallback) for 2 s: ~20 invocations.
    # with the double-spawn bug the VU forks every iteration -> ~2^20.
    assert len(res.invocations) <= 25
    assert len(submitted) == len(res.invocations)


def test_run_open_loop_wrapper_equivalent():
    """run_open_loop is now a thin wrapper over uniform_arrivals +
    run_arrivals; it must keep its LoadResult contract and serve the
    offered load."""
    from repro.core.loadgen import run_open_loop

    cp, fns = build(names=["hpc-node-cluster"])
    res = run_open_loop(
        cp.clock,
        lambda inv: cp.submit(inv, platform_override="hpc-node-cluster"),
        fns["nodeinfo"], rps=20.0, duration_s=10.0)
    assert len(res.invocations) == 200
    assert len(res.completed) == 200
    arrivals = sorted(i.arrival_t for i in res.invocations)
    np.testing.assert_allclose(arrivals, np.arange(200) / 20.0)
    assert res.p90_response() < 2.0


def test_invoke_batch_matches_sequential_invokes():
    cp_a, fns_a = build(names=["cloud-cluster"])
    cp_b, fns_b = build(names=["cloud-cluster"])
    pa = cp_a.platforms["cloud-cluster"]
    pb = cp_b.platforms["cloud-cluster"]
    invs_a = [Invocation(fns_a["nodeinfo"], 0.0) for _ in range(30)]
    invs_b = [Invocation(fns_b["nodeinfo"], 0.0) for _ in range(30)]
    for inv in invs_a:
        pa.invoke(inv)
    pb.invoke_batch(invs_b)
    assert pa.busy_replicas() == pb.busy_replicas()
    assert len(pa.queue) == len(pb.queue)
    cp_a.run_until(60.0)
    cp_b.run_until(60.0)
    assert sum(1 for i in invs_a if i.status == "done") == \
        sum(1 for i in invs_b if i.status == "done") == 30
