"""Function-chain subsystem: DAG spec validation, data-placement fixes
(O(1) eviction / nearest-replica locate), data-gravity planner parity and
WAN-flip decisions, chain execution through the control plane, scenario
integration (per_chain reports, determinism, split-vs-colocate A/B), and
the scenario-diff tool."""
import json

import numpy as np
import pytest

from repro.chains import (EXTERNAL, Chain, ChainExecutor, DataEdge,
                          DataGravityPlanner, Stage, catalog)
from repro.core import profiles as prof_mod
from repro.core import functions as fn_mod
from repro.core.control_plane import FDNControlPlane
from repro.core.data_placement import (DataPlacementManager, LRUCache,
                                       ObjectStore)
from repro.core.loadgen import attach_completion_hooks
from repro.core.scheduler import PerformanceRankedPolicy
from repro.core.types import DeploymentSpec, FunctionSpec, Invocation
from repro.inspector import Scenario, ScenarioReport, Workload, run_scenario
from repro.inspector.registry import chain_etl, split_vs_colocate

AB_PAIR = ("cloud-cluster", "old-hpc-node-cluster")


# ------------------------------------------------------------ chain spec --

def test_chain_validation():
    with pytest.raises(ValueError, match="duplicate"):
        Chain("dup", (Stage("a", "f"), Stage("a", "f")))
    with pytest.raises(ValueError, match="unknown stage"):
        Chain("bad", (Stage("a", "f"),),
              (DataEdge("a", "zzz", "k", 1.0),))
    with pytest.raises(ValueError, match="cycle"):
        Chain("loop", (Stage("a", "f"), Stage("b", "f")),
              (DataEdge("a", "b", "x", 1.0),
               DataEdge("b", "a", "y", 1.0)))


def test_chain_structure():
    ch = Chain("diamond",
               (Stage("src", "f"), Stage("l", "f"), Stage("r", "f"),
                Stage("sink", "f")),
               (DataEdge(EXTERNAL, "src", "in", 5.0),
                DataEdge("src", "l", "a", 1.0),
                DataEdge("src", "r", "b", 2.0),
                DataEdge("l", "sink", "c", 3.0),
                DataEdge("r", "sink", "d", 4.0)))
    assert ch.topo_order() == ("src", "l", "r", "sink")
    assert ch.preds("sink") == ("l", "r")
    assert ch.succs("src") == ("l", "r")
    assert ch.sinks() == ("sink",)
    assert [e.key for e in ch.external_inputs()] == ["in"]
    assert not ch.in_edges("sink")[0].external


# -------------------------------------------------- data placement fixes --

def test_object_store_used_running_total():
    st = ObjectStore("x")
    st.put("a", 100.0)
    st.put("b", 50.0)
    assert st.used() == 150.0
    st.put("a", 30.0)                       # overwrite adjusts the total
    assert st.used() == 80.0
    st.remove("b")
    assert st.used() == 30.0
    st.remove("nope")                       # no-op
    assert st.used() == 30.0


def test_lru_eviction_order_pinned():
    c = LRUCache(100.0)
    c.put("a", 40.0)
    c.put("b", 40.0)
    c.put("c", 15.0)
    assert c.used() == 95.0
    assert c.get("a")                        # refresh a -> b is now LRU
    c.put("d", 40.0)                         # evicts b (front) and stops
    assert not c.get("b")
    assert c.get("c") and c.get("a") and c.get("d")
    assert c.used() == 95.0
    c.put("e", 20.0)                         # evicts c (the LRU after the
    assert not c.get("c")                    # gets above refreshed c,a,d)
    assert c.get("a") and c.get("d") and c.get("e")
    assert c.used() == 100.0
    c.put("d", 10.0)                         # re-put shrinks, no eviction
    assert c.used() == 70.0
    c.put("huge", 1000.0)                    # over capacity: ignored
    assert c.used() == 70.0


def test_locate_returns_nearest_replica():
    pm = DataPlacementManager(wan_bw=1e6)
    for loc in ("a", "b", "c"):
        pm.add_store(loc)
    pm.stores["a"].put("obj", 10.0)
    pm.stores["c"].put("obj", 10.0)
    pm.set_bandwidth("b", "c", 1e9)          # c is b's fast neighbour
    # regression: the old locate ignored the origin and returned the
    # first store in registration order ("a") regardless of bandwidth
    assert pm.locate("obj", origin="b") == "c"
    assert pm.locate("obj", origin="a") == "a"      # local replica wins
    assert pm.locate("obj") == "a"                  # no origin: first
    assert pm.locate("missing", origin="b") is None


def test_migrate_copies_from_nearest():
    pm = DataPlacementManager(wan_bw=1e6)
    for loc in ("a", "b", "c"):
        pm.add_store(loc)
    pm.stores["a"].put("obj", 42.0, payload="payload")
    pm.migrate("obj", "b")
    assert pm.stores["b"].has("obj")
    assert pm.bytes_migrated == 42.0
    pm.migrate("obj", "b")                   # already local: no-op
    assert pm.migrations == 1


def test_bandwidth_matrix():
    pm = DataPlacementManager(local_bw=10.0, wan_bw=1.0)
    pm.add_store("a")
    pm.add_store("b")
    pm.set_bandwidth("a", "b", 5.0)
    m = pm.bandwidth_matrix(["a", "b"])
    assert m.shape == (2, 2)
    assert m[0, 0] == m[1, 1] == 10.0
    assert m[0, 1] == m[1, 0] == 5.0
    assert pm.transfer_seconds(10.0, "a", "b") == 2.0


# ---------------------------------------------------------- planner ------

def _ab_harness(bw):
    cp = FDNControlPlane()
    for name in AB_PAIR:
        cp.create_platform(prof_mod.PAPER_PLATFORMS[name])
    cp.policy = PerformanceRankedPolicy(cp.perf)
    cp.placement.set_bandwidth(*AB_PAIR, bw)
    tmpl = catalog.get("ab-dual-source")
    fns = dict(tmpl.functions)
    cp.deploy(DeploymentSpec("ab", list(fns.values()), list(AB_PAIR)))
    for inp in tmpl.inputs:
        cp.placement.stores[inp.location].put(inp.key, inp.size_bytes)
    attach_completion_hooks(cp)
    return cp, fns, tmpl


def test_single_stage_chain_matches_scalar_choose():
    """Parity: planning a one-stage chain equals the scalar per-invocation
    decision when the chain's external edge mirrors the function's data
    objects."""
    cp = FDNControlPlane()
    for name in prof_mod.PAPER_PLATFORMS:
        cp.create_platform(prof_mod.PAPER_PLATFORMS[name])
    fns = {k: f.replace(real_fn=None)
           for k, f in fn_mod.paper_functions().items()}
    fn_mod.seed_object_stores(cp.placement, location="edge-cluster")
    cp.deploy(DeploymentSpec("parity", list(fns.values()),
                             list(cp.platforms)))
    spec = fns["image-processing"]           # has data_objects=(IMAGE_KEY,)
    chain = Chain("one", (Stage("only", "image-processing"),),
                  (DataEdge(EXTERNAL, "only", spec.data_objects[0], 2e6),))
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plats = list(cp.platforms.values())
    for mode in ("auto", "gravity", "colocate"):
        plan = planner.plan(chain, plats, mode=mode)
        expected = cp.policy.choose(Invocation(spec, 0.0), plats)
        assert plan.assignment["only"] == expected.prof.name, mode


def test_planner_wan_bandwidth_flips_decision():
    """The data-gravity planner's auto mode splits across platforms on a
    fast interconnect and collapses to co-location on a slow WAN."""
    fast_cp, fast_fns, tmpl = _ab_harness(2e9)
    planner = DataGravityPlanner(fast_cp.policy, fast_cp.placement,
                                 fast_fns)
    plats = [fast_cp.platforms[n] for n in AB_PAIR]
    fast_plan = planner.plan(tmpl.chain, plats, mode="auto")
    assert fast_plan.mode == "gravity"
    assert len(set(fast_plan.assignment.values())) > 1    # genuine split

    slow_cp, slow_fns, tmpl = _ab_harness(3e6)
    planner = DataGravityPlanner(slow_cp.policy, slow_cp.placement,
                                 slow_fns)
    plats = [slow_cp.platforms[n] for n in AB_PAIR]
    slow_plan = planner.plan(tmpl.chain, plats, mode="auto")
    assert slow_plan.mode == "colocate"
    assert len(set(slow_plan.assignment.values())) == 1
    # the co-located home is the big source's platform (data gravity)
    assert set(slow_plan.assignment.values()) == {"cloud-cluster"}


def test_planner_rejects_unknown_mode_and_infeasible():
    cp, fns, tmpl = _ab_harness(2e9)
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plats = [cp.platforms[n] for n in AB_PAIR]
    with pytest.raises(ValueError, match="unknown plan mode"):
        planner.plan(tmpl.chain, plats, mode="nope")
    undeployed = Chain("undeployed", (Stage("s", "never-deployed"),))
    planner.fns["never-deployed"] = FunctionSpec(name="never-deployed")
    with pytest.raises(ValueError, match="no feasible platform"):
        planner.plan(undeployed, plats, mode="gravity")


# ---------------------------------------------------------- executor -----

def test_chain_executes_and_accounts_transfers():
    cp, fns, tmpl = _ab_harness(2e9)
    ex = ChainExecutor(cp, fns)
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plats = [cp.platforms[n] for n in AB_PAIR]
    plan = planner.plan(tmpl.chain, plats, mode="gravity")
    inst = ex.launch(tmpl.chain, plan, label="t")
    cp.clock.run_until(600.0)
    assert inst.status == "done"
    assert inst.latency is not None and inst.latency > 0
    assert ex.completed == 1 and ex.failed == 0
    # split plan crossed at least one edge -> bytes + seconds accounted
    assert inst.bytes_moved > 0 and inst.transfer_s > 0
    assert cp.metrics.total("_chain", "t", "bytes_moved") == \
        inst.bytes_moved
    # intermediates were recorded, then cleaned after completion
    for e in tmpl.chain.edges:
        if not e.external:
            key = ex.instance_key(inst, e)
            assert all(not st.has(key)
                       for st in cp.placement.stores.values())


def test_chain_fan_out_runs_all_invocations():
    cp, fns, tmpl = _ab_harness(2e9)
    ex = ChainExecutor(cp, fns)
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plats = [cp.platforms[n] for n in AB_PAIR]
    plan = planner.plan(tmpl.chain, plats, mode="colocate")
    ex.launch(tmpl.chain, plan)
    cp.clock.run_until(600.0)
    # 1 extract + 4 shards + 1 join + 1 report
    assert cp.completed_count == 7
    assert ex.completed == 1


def test_colocated_chain_moves_fewer_bytes():
    cp, fns, tmpl = _ab_harness(2e9)
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plats = [cp.platforms[n] for n in AB_PAIR]
    ex = ChainExecutor(cp, fns)
    a = ex.launch(tmpl.chain,
                  planner.plan(tmpl.chain, plats, mode="colocate"),
                  label="coloc")
    b = ex.launch(tmpl.chain,
                  planner.plan(tmpl.chain, plats, mode="split"),
                  label="split")
    cp.clock.run_until(600.0)
    assert a.status == b.status == "done"
    assert a.bytes_moved < b.bytes_moved


def test_platform_failure_redelivers_or_fails_instances():
    """A failed planned platform must not leave instances stuck in
    'running': with an alternative alive the stages are redelivered and
    the chain completes; with every platform down the instance is
    marked failed."""
    cp, fns, tmpl = _ab_harness(2e9)
    ex = ChainExecutor(cp, fns)
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plats = [cp.platforms[n] for n in AB_PAIR]
    plan = planner.plan(tmpl.chain, plats, mode="colocate")
    inst = ex.launch(tmpl.chain, plan)
    cp.platforms[plan.assignment["join"]].fail()     # colocation home down
    cp.clock.run_until(600.0)
    assert inst.status == "done"                     # redelivered
    assert cp.redeliverer.redelivered > 0

    cp, fns, tmpl = _ab_harness(2e9)
    ex = ChainExecutor(cp, fns)
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plats = [cp.platforms[n] for n in AB_PAIR]
    plan = planner.plan(tmpl.chain, plats, mode="colocate")
    inst = ex.launch(tmpl.chain, plan)
    for p in cp.platforms.values():                  # everything down
        p.fail()
    cp.clock.run_until(600.0)
    assert inst.status == "failed"
    assert ex.failed == 1 and ex.completed == 0


def test_proactive_staging_accounts_bytes():
    """Staged external inputs are still real transfers: the triggering
    instance is charged their bytes/seconds even though the consumer
    later reads a local replica."""
    cp, fns, _tmpl = _ab_harness(2e9)
    chain = Chain(
        "staged",
        (Stage("a", "chain-report"), Stage("b", "chain-join")),
        (DataEdge("a", "b", "mid", 1e6),
         DataEdge(EXTERNAL, "b", "chains/ab/big-source", 48e6)))
    from repro.chains import ChainPlan
    home = "old-hpc-node-cluster"                    # big-source is remote
    plan = ChainPlan(chain="staged", mode="colocate",
                     requested_mode="colocate",
                     assignment={"a": home, "b": home},
                     est_makespan_s=0.0, est_compute_s=0.0,
                     est_transfer_s=0.0, est_bytes_moved=0.0)
    ex = ChainExecutor(cp, fns)
    inst = ex.launch(chain, plan)
    cp.clock.run_until(600.0)
    assert inst.status == "done"
    # staging replicated the 48 MB source to the home platform and the
    # instance was charged for it exactly once
    assert cp.placement.stores[home].has("chains/ab/big-source")
    assert inst.bytes_moved == pytest.approx(48e6)
    assert inst.transfer_s > 0


# ------------------------------------------------- scenario integration --

def test_chain_scenario_report_deterministic():
    sc = chain_etl(duration_s=20.0)
    a = run_scenario(sc)
    b = run_scenario(sc)
    ja, jb = a.to_json(), b.to_json()
    assert ja == jb
    ScenarioReport.validate(json.loads(ja))
    pc = a.per_chain["etl-pipeline@auto"]
    assert pc["completed"] > 0
    assert pc["launched"] >= pc["completed"]
    assert set(pc["placement"]) == {"extract", "transform", "aggregate",
                                    "load"}
    assert a.totals["chains_completed"] == pc["completed"]
    assert np.isfinite(pc["p90_s"])


def test_chain_scenario_seed_changes_report():
    a = run_scenario(chain_etl(duration_s=20.0))
    b = run_scenario(chain_etl(duration_s=20.0).replace(seed=7))
    assert a.to_json() != b.to_json()


def test_chain_workload_validation():
    sc = Scenario(name="x", platforms=AB_PAIR,
                  workloads=(Workload(mode="chain",
                                      chain="ab-dual-source"),),
                  duration_s=1.0)
    with pytest.raises(ValueError, match="chain workload"):
        run_scenario(sc)


def test_split_vs_colocate_ab_flips_with_wan_bandwidth():
    """Acceptance: collaborative execution beats forced co-location on
    end-to-end chain p90 when the interconnect is fast; a slow WAN
    reverses the order."""
    fast = run_scenario(split_vs_colocate(2e9, duration_s=40.0))
    assert fast.per_chain["ab@split"]["p90_s"] < \
        fast.per_chain["ab@colocate"]["p90_s"]
    slow = run_scenario(split_vs_colocate(3e6, rps=1.0, duration_s=40.0,
                                          suffix="-slowwan"))
    assert slow.per_chain["ab@split"]["p90_s"] > \
        slow.per_chain["ab@colocate"]["p90_s"]
    # both arms completed everything they launched (stable regimes)
    for rep in (fast, slow):
        for arm in rep.per_chain.values():
            assert arm["completed"] == arm["launched"] > 0


# ------------------------------------------------------- scenario-diff ---

def _mini_report():
    rep = run_scenario(chain_etl(duration_s=10.0))
    return json.loads(rep.to_json())


def test_scenario_diff_self_compare_clean():
    from benchmarks.scenario_diff import diff_reports
    a = _mini_report()
    assert diff_reports(a, json.loads(json.dumps(a))) == []


def test_scenario_diff_flags_drift_and_missing():
    from benchmarks.scenario_diff import diff_reports
    a = _mini_report()
    b = json.loads(json.dumps(a))
    b["totals"]["completed"] = int(a["totals"]["completed"] * 1.5)
    drifts = diff_reports(a, b)
    assert any(d.path == "totals.completed" for d in drifts)
    c = json.loads(json.dumps(a))
    del c["totals"]["energy_wh"]
    drifts = diff_reports(a, c)
    assert any("energy_wh" in d.path for d in drifts)


def test_scenario_diff_respects_tolerances():
    from benchmarks.scenario_diff import diff_reports
    a = _mini_report()
    b = json.loads(json.dumps(a))
    b["totals"]["p90_s"] = a["totals"]["p90_s"] * 1.05   # inside 10%
    assert not [d for d in diff_reports(a, b)
                if d.path == "totals.p90_s"]
    b["totals"]["p90_s"] = a["totals"]["p90_s"] * 1.25   # outside
    assert [d for d in diff_reports(a, b) if d.path == "totals.p90_s"]


def test_scenario_diff_cli_bad_args():
    from benchmarks.scenario_diff import _parse_args
    assert _parse_args(["a", "b", "--tol", "p90_s=0.2", "--tol", "0.1"]) \
        == ("a", "b", {"p90_s": 0.2, "*": 0.1})
    for bad in (["a"], ["a", "b", "--tol"], ["a", "b", "--tol", "abc"]):
        with pytest.raises(SystemExit):
            _parse_args(bad)


def test_scenario_diff_cli_exit_codes(tmp_path):
    from benchmarks.scenario_diff import main
    a = _mini_report()
    pa = tmp_path / "a.json"
    pb = tmp_path / "b.json"
    pa.write_text(json.dumps(a))
    pb.write_text(json.dumps(a))
    assert main([str(pa), str(pb)]) == 0
    a["totals"]["p90_s"] *= 3.0
    pb.write_text(json.dumps(a))
    assert main([str(pa), str(pb)]) == 1
