"""§Perf variants must be numerically equivalent to the baselines:
sorted / shard_map MoE dispatch vs one-hot einsum, shard_mapped flash
decode vs the GSPMD decode path, and kv-sliced chunked attention vs the
full-mask oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as shd
from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh
from repro.models import model_api as api
from repro.models import moe


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def mixtral():
    cfg = get_config("mixtral-8x7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    layer0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])["moe"]
    return cfg, layer0


@pytest.mark.parametrize("cf", [8.0, 0.6])     # without and with drops
def test_moe_sorted_matches_einsum(mixtral, cf):
    cfg, p = mixtral
    cfg = cfg.replace(capacity_factor=cf)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.1,
                    jnp.bfloat16)
    y1, a1 = moe.moe_block(cfg, p, x)
    y2, a2 = moe.moe_block(cfg.replace(moe_impl="sorted"), p, x)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=1e-3)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_moe_shard_map_matches_einsum(mixtral):
    cfg, p = mixtral
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.1,
                    jnp.bfloat16)
    y1, a1 = moe.moe_block(cfg, p, x)
    with shd.use_mesh(_mesh11()):
        y2, a2 = jax.jit(lambda p, x: moe.moe_block(
            cfg.replace(moe_impl="sorted_shmap"), p, x))(p, x)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=1e-3)
    assert float(a1) == pytest.approx(float(a2), rel=1e-4)


def test_moe_loss_with_shmap_variant():
    """Full train loss through the shard_map MoE path (grad-able)."""
    from repro.configs.base import InputShape
    cfg = get_config("mixtral-8x7b").reduced().replace(
        moe_impl="sorted_shmap")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = api.make_batch(cfg, InputShape("t", 32, 2, "train"))
    with shd.use_mesh(_mesh11()):
        loss, _ = jax.jit(
            lambda p, b: api.loss_fn(cfg, p, b, remat=False))(params, batch)
    ref, _ = api.loss_fn(cfg.replace(moe_impl="einsum"), params, batch,
                         remat=False)
    assert float(loss) == pytest.approx(float(ref), rel=5e-3)


def test_shmap_flash_decode_matches_gspmd():
    cfg = get_config("qwen3-0.6b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = rng.integers(1, cfg.vocab_size, (2, 16)).astype(np.int32)
    logits, cache = api.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                                30)
    db = {"token": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)),
                               jnp.int32)}
    mesh = _mesh11()
    with shd.use_mesh(mesh):
        l1, c1 = jax.jit(lambda p, c, b: api.decode_step(cfg, p, c, b))(
            params, cache, db)
        cfg2 = cfg.replace(decode_impl="shmap_flash")
        l2, c2 = jax.jit(lambda p, c, b: api.decode_step(cfg2, p, c, b))(
            params, cache, db)
    # bf16 1-ulp differences from different fusion/rounding are expected
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=5e-2,
                               rtol=5e-2)
    assert int(jnp.argmax(l1[0, -1])) == int(jnp.argmax(l2[0, -1]))
    np.testing.assert_allclose(np.asarray(c1["k"], np.float32),
                               np.asarray(c2["k"], np.float32), atol=5e-2)
    np.testing.assert_array_equal(np.asarray(c1["pos"]),
                                  np.asarray(c2["pos"]))


def test_chunked_attention_kv_slicing_variants():
    """SWA dynamic-slice path and causal unrolled path vs the oracle."""
    from repro.kernels import ref
    from repro.models.layers import chunked_attention
    rng = np.random.default_rng(0)

    def arr(*s):
        return jnp.asarray(rng.normal(size=s) * 0.3, jnp.float32)

    q, k, v = arr(2, 256, 4, 32), arr(2, 256, 2, 32), arr(2, 256, 2, 32)
    for window in (None, 48, 100, 1000):
        out = chunked_attention(q, k, v, q_chunk=64, window=window)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=3e-5, err_msg=f"window={window}")


def test_yi_head_padding_is_function_preserving():
    """Zero-padding attention heads (56->64 at pod scale; 4->6 here) with
    zero wo rows must not change the model function."""
    cfg = get_config("yi-34b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    cfg_pad = cfg.replace(n_heads=6)
    l, d, dh = cfg.num_layers, cfg.d_model, cfg.head_dim
    kh = cfg.n_kv_heads
    g, g_pad = cfg.n_heads // kh, cfg_pad.n_heads // kh

    # GQA groups must keep their kv assignment: pad WITHIN each kv group
    def pad_wq(arr):                        # (L, D, H*Dh)
        a = arr.reshape(l, d, kh, g, dh)
        a = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (0, g_pad - g), (0, 0)))
        return a.reshape(l, d, kh * g_pad * dh)

    def pad_wo(arr):                        # (L, H*Dh, D)
        a = arr.reshape(l, kh, g, dh, d)
        a = jnp.pad(a, ((0, 0), (0, 0), (0, g_pad - g), (0, 0), (0, 0)))
        return a.reshape(l, kh * g_pad * dh, d)

    layers = dict(params["layers"])
    attn = dict(layers["attn"])
    attn["wq"] = pad_wq(attn["wq"])
    attn["wo"] = pad_wo(attn["wo"])
    layers["attn"] = attn
    pad_params = dict(params)
    pad_params["layers"] = layers

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 32)), jnp.int32)
    from repro.models import transformer as tfm
    h1, _, _ = tfm.forward_hidden(cfg, params,
                                  tfm.embed_inputs(cfg, params,
                                                   {"tokens": toks}))
    h2, _, _ = tfm.forward_hidden(cfg_pad, pad_params,
                                  tfm.embed_inputs(cfg_pad, pad_params,
                                                   {"tokens": toks}))
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=2e-2)
