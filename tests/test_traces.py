"""FDNInspector trace library: seed determinism, monotonic non-negative
timestamps, time_scale dilation, WorkloadMix merge invariants, Azure CSV
loading, declarative dispatch."""
import numpy as np
import pytest

from repro.core.loadgen import trace_arrivals
from repro.inspector import traces

GENERATORS = {
    "poisson": lambda seed: traces.build_arrivals(
        {"kind": "poisson", "rps": 30.0}, 40.0, seed=seed),
    "diurnal": lambda seed: traces.diurnal_arrivals(
        20.0, 60.0, seed=seed, period_s=60.0, peak_frac=0.8),
    "mmpp": lambda seed: traces.mmpp_arrivals(
        10.0, 200.0, 60.0, seed=seed, mean_quiet_s=10.0, mean_burst_s=2.0),
    "ramp": lambda seed: traces.ramp_arrivals(2.0, 50.0, 60.0, seed=seed),
    "azure": lambda seed: traces.counts_to_arrivals(
        [5, 0, 17, 3, 40], minute_s=60.0, seed=seed),
}


@pytest.mark.parametrize("kind", sorted(GENERATORS))
def test_generators_deterministic_and_well_formed(kind):
    gen = GENERATORS[kind]
    a, b, c = gen(7), gen(7), gen(8)
    np.testing.assert_array_equal(a, b)          # same seed -> identical
    assert a.size != c.size or not np.array_equal(a, c)  # seed matters
    assert a.size > 0
    assert np.all(a >= 0.0)
    assert np.all(np.diff(a) >= 0.0)             # monotonic non-decreasing


def test_generator_rates_roughly_match():
    d = traces.diurnal_arrivals(20.0, 600.0, seed=1, period_s=600.0)
    assert 0.6 * 12000 <= d.size <= 1.4 * 12000
    r = traces.ramp_arrivals(0.0, 100.0, 100.0, seed=1)
    # linear 0 -> 100 rps over 100 s integrates to ~5000 arrivals
    assert 0.6 * 5000 <= r.size <= 1.4 * 5000
    # ramp density grows: second half must hold well over half the mass
    assert (r > 50.0).sum() > 0.6 * r.size


def test_time_scale_dilation():
    times = [0.0, 10.0, 30.0, 60.0]
    half = trace_arrivals(times, time_scale=0.5)
    np.testing.assert_allclose(half, [0.0, 5.0, 15.0, 30.0])
    counts = [10, 0, 25]
    full = traces.counts_to_arrivals(counts, seed=3)
    fast = traces.counts_to_arrivals(counts, seed=3, time_scale=0.25)
    assert full.size == fast.size == 35
    np.testing.assert_allclose(fast, full * 0.25)


def test_counts_to_arrivals_minute_buckets():
    counts = [4, 0, 9]
    t = traces.counts_to_arrivals(counts, minute_s=60.0, seed=5)
    assert t.size == 13
    per_minute = np.bincount((t // 60.0).astype(int), minlength=3)
    np.testing.assert_array_equal(per_minute, counts)


def test_workload_mix_preserves_counts_and_order():
    rng = np.random.default_rng(0)
    mix = traces.WorkloadMix()
    streams = {"a": np.sort(rng.uniform(0, 50, 200)),
               "b": np.sort(rng.uniform(0, 50, 120)),
               "c": np.sort(rng.uniform(0, 50, 77))}
    for name, arr in streams.items():
        mix.add(name, arr)
    times, idx, names = mix.merge()
    assert names == ["a", "b", "c"]
    assert times.size == idx.size == 397
    assert np.all(np.diff(times) >= 0.0)          # global sort order
    for name, arr in streams.items():             # per-function counts
        fid = names.index(name)
        assert int((idx == fid).sum()) == arr.size
        np.testing.assert_allclose(np.sort(times[idx == fid]), arr)
    assert mix.counts() == {k: v.size for k, v in streams.items()}


def test_workload_mix_stable_ties_and_same_fn_merge():
    mix = traces.WorkloadMix()
    mix.add("x", [1.0, 2.0]).add("y", [1.0]).add("x", [1.0])
    times, idx, names = mix.merge()
    assert names == ["x", "y"]
    np.testing.assert_allclose(times, [1.0, 1.0, 1.0, 2.0])
    # stable: stream insertion order preserved among the t=1.0 ties
    assert idx.tolist() == [0, 1, 0, 0]
    assert mix.counts() == {"x": 3, "y": 1}


def test_load_azure_invocations_csv(tmp_path):
    p = tmp_path / "invocations.csv"
    p.write_text(
        "HashOwner,HashApp,HashFunction,Trigger,1,2,3\n"
        "o1,a1,fnA,http,3,0,5\n"
        "o1,a1,fnB,timer,1,1,1\n"
        "o2,a2,fnA,http,2,0,0\n")
    counts = traces.load_azure_invocations_csv(str(p))
    np.testing.assert_array_equal(counts["fnA"], [5.0, 0.0, 5.0])
    np.testing.assert_array_equal(counts["fnB"], [1.0, 1.0, 1.0])
    t = traces.counts_to_arrivals(counts["fnA"], seed=0)
    assert t.size == 10


def test_synthetic_azure_counts_deterministic():
    a = traces.synthetic_azure_counts(["f", "g"], minutes=30, seed=2)
    b = traces.synthetic_azure_counts(["f", "g"], minutes=30, seed=2)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
        assert a[k].size == 30 and np.all(a[k] >= 0)


def test_build_arrivals_dispatch_and_unknown_kind():
    u = traces.build_arrivals({"kind": "uniform", "rps": 10.0}, 5.0)
    assert u.size == 50
    tr = traces.build_arrivals(
        {"kind": "trace", "times": [3.0, 1.0], "time_scale": 2.0}, 5.0)
    np.testing.assert_allclose(tr, [0.0, 4.0])
    with pytest.raises(KeyError):
        traces.build_arrivals({"kind": "nope"}, 5.0)
    # spec-level overrides beat scenario defaults
    short = traces.build_arrivals(
        {"kind": "uniform", "rps": 10.0, "duration_s": 2.0}, 5.0)
    assert short.size == 20
