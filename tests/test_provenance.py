"""Decision provenance (repro.obs.provenance / whatif): the columnar
decision journal, the calibration analyzer and counterfactual replay.

Load-bearing invariants pinned here:

  * same-policy replay oracle — re-scoring the journaled feature columns
    under the journaled policy + params reproduces every original choice
    byte-identically, on all three prov/* acceptance scenarios AND for
    every stateless registry policy driven directly;
  * backend parity — the jitted ``composite_explain`` kernel and the
    host ``SLOCompositePolicy.cascade`` agree on choice / kill bits /
    runner-up margin bit-for-bit on a dyadic input grid (values exactly
    representable in float32, so the f32/f64 width difference vanishes);
  * join integrity — every completion stamped with a journal row id ran
    on exactly the platform that journal row chose;
  * persistence — ``save``/``load_journal`` round-trips every column and
    the loaded journal still passes the replay oracle.
"""
import json

import numpy as np
import pytest

from repro.core import (FDNControlPlane, Invocation, functions, profiles)
from repro.core.loadgen import attach_completion_hooks
from repro.core.scheduler import (POLICIES, RoundRobinCollaboration,
                                  SLOCompositePolicy,
                                  WeightedCollaboration)
from repro.core.types import DeploymentSpec
from repro.inspector import registry
from repro.inspector.scenario import ScenarioReport, run_scenario_state
from repro.obs import (DecisionJournal, WhatIfConfig, load_journal, replay,
                       replay_matches, whatif_section)
from repro.obs.provenance import FEATURE_COLS, KILL_PAD

try:                 # hypothesis is an optional test extra; without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # the seeded sweep twin below still runs
    HAVE_HYPOTHESIS = False

    def given(*a, **kw):
        return lambda fn: pytest.mark.skip("hypothesis not installed")(fn)

    def settings(*a, **kw):
        return lambda fn: fn

    class st:        # placeholder strategies so decorators still build
        @staticmethod
        def _none(*a, **kw):
            return None
        integers = _none

try:
    from repro.kernels import policy_score as ps
    HAVE_JAX = True
except Exception:
    ps = None
    HAVE_JAX = False


@pytest.fixture(scope="module")
def prov_tiny():
    return run_scenario_state(registry.get("prov/smoke-tiny"))


@pytest.fixture(scope="module")
def prov_etl():
    return run_scenario_state(registry.get("prov/etl-pipeline"))


@pytest.fixture(scope="module")
def prov_drr():
    return run_scenario_state(registry.get("prov/burst-storm-drr"))


# ---------------------------------------------------------------------------
# journal recording + report section
# ---------------------------------------------------------------------------

def test_journal_columns_are_consistent(prov_tiny):
    _report, cp, _sink = prov_tiny
    j = cp.journal
    assert j is not None and j.n > 0
    jc = j.columns()
    n = j.n
    pmax = jc["kill"].shape[1]
    assert all(jc[k].shape == (n, pmax) for k in FEATURE_COLS)
    assert jc["alive"].shape == (n, pmax)
    assert jc["alive"].dtype == bool
    # every pset id resolves, every choice is a valid slot of its set
    width = np.array([len(j.pset_names[int(p)]) for p in jc["pset"]])
    assert (width <= pmax).all()
    assert ((jc["choice"] >= -1) & (jc["choice"] < width)).all()
    assert (jc["count"] > 0).all()
    # pad slots past each row's platform-set width: never alive, kill
    # bits all-set, features NaN
    pad = np.arange(pmax)[None, :] >= width[:, None]
    assert (jc["kill"][pad] == KILL_PAD).all()
    assert not jc["alive"][pad].any()
    assert np.isnan(jc["exec_s"][pad]).all()
    # feasible chosen slots carry kill == 0
    ok = jc["choice"] >= 0
    assert (jc["kill"][np.nonzero(ok)[0], jc["choice"][ok]] == 0).all()


def test_report_section_schema_and_validate(prov_tiny):
    report, cp, _sink = prov_tiny
    dp = report.decision_provenance
    assert dp["policy"] == cp.journal.policy_name
    assert dp["decisions"] == cp.journal.n
    assert dp["invocations"] > 0
    assert dp["matched_completions"] > 0
    assert set(dp["kill_counts"]) == {"dead", "utilization", "slo"}
    # each matched completion lands in exactly one calibration cell
    cells = [c for per_p in dp["calibration"].values()
             for c in per_p.values()]
    assert cells and sum(c["count"] for c in cells) == \
        dp["matched_completions"]
    for c in cells:
        assert c["mean_abs_err_s"] >= 0.0
        assert abs(c["bias_s"]) <= c["mean_abs_err_s"] + 1e-12
    assert 0.0 <= dp["churn"]["overall"] <= 1.0
    # the full report (with the additive section) passes schema check
    ScenarioReport.validate(json.loads(report.to_json()))


def test_decision_ids_join_to_the_chosen_platform(prov_tiny):
    """Every completion stamped with a journal row id ran on exactly the
    platform that row chose — the join the calibration analyzer relies
    on is not merely shape-compatible but semantically exact."""
    _report, cp, sink = prov_tiny
    cols = sink.completion_columns()
    d = np.asarray(cols["decision"])
    sel = d >= 0
    assert sel.any()
    pid_to_name = {v: k for k, v in cols["platform_ids"].items()}
    plat = cols["platform"]
    for i in np.nonzero(sel)[0]:
        assert pid_to_name[int(plat[i])] == cp.journal.platform_of(int(d[i]))


# ---------------------------------------------------------------------------
# same-policy replay oracle (the byte-identity guarantee)
# ---------------------------------------------------------------------------

def test_replay_oracle_smoke_tiny(prov_tiny):
    assert replay_matches(prov_tiny[1].journal)


def test_replay_oracle_etl_pipeline(prov_etl):
    assert replay_matches(prov_etl[1].journal)


def test_replay_oracle_burst_storm_drr(prov_drr):
    assert replay_matches(prov_drr[1].journal)


_STATELESS_BUILDERS = {
    "perf_ranked": lambda cp: POLICIES["perf_ranked"](cp.perf),
    "utilization_aware":
        lambda cp: POLICIES["utilization_aware"](cp.perf),
    "data_locality":
        lambda cp: POLICIES["data_locality"](cp.perf, cp.placement),
    "warm_aware": lambda cp: POLICIES["warm_aware"](cp.perf, cp.placement),
    "energy_aware": lambda cp: POLICIES["energy_aware"](cp.perf),
    "slo_composite":
        lambda cp: POLICIES["slo_composite"](cp.perf, cp.placement),
}


def _drive(cp, fns, rounds=5):
    """Several small mixed-function bursts (below JAX_DECIDE_MIN, so the
    fused decision runs on the numpy host path); platform queues fill
    between rounds, so the journaled features actually vary."""
    picks = [fns["nodeinfo"], fns["image-processing"], fns["JSON-loads"]]
    for r in range(rounds):
        t = float(r)
        cp.submit_batch([Invocation(f, t)
                         for f in picks[:1 + r % 3] for _ in range(4)])


def _build_cp(names=("cloud-cluster", "edge-cluster")):
    cp = FDNControlPlane()
    for n in names:
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = {k: f.replace(real_fn=None)
           for k, f in functions.paper_functions().items()}
    functions.seed_object_stores(cp.placement, location=names[0])
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    return cp, fns


def test_stateless_builders_cover_the_registry():
    stateless = {n for n, c in POLICIES.items() if c.cascade is not None}
    assert stateless == set(_STATELESS_BUILDERS)


@pytest.mark.parametrize("policy_name", sorted(_STATELESS_BUILDERS))
def test_replay_oracle_every_stateless_policy(policy_name):
    cp, fns = _build_cp()
    cp.policy = _STATELESS_BUILDERS[policy_name](cp)
    journal = cp.attach_provenance(DecisionJournal())
    _drive(cp, fns)
    assert journal.policy_name == policy_name
    assert journal.n > 0
    assert replay_matches(journal)


@pytest.mark.parametrize("policy", [
    RoundRobinCollaboration(),
    WeightedCollaboration({"cloud-cluster": 2, "edge-cluster": 1}),
], ids=["round_robin", "weighted"])
def test_stateful_policies_never_journal(policy):
    cp, fns = _build_cp()
    cp.policy = policy
    journal = cp.attach_provenance(DecisionJournal())
    _drive(cp, fns, rounds=2)
    assert journal.n == 0            # object fallback: nothing recorded
    with pytest.raises(ValueError, match="stateful"):
        replay(journal)


# ---------------------------------------------------------------------------
# counterfactual what-if
# ---------------------------------------------------------------------------

def test_whatif_section_is_conserved(prov_tiny):
    j = prov_tiny[1].journal
    base = replay(j)
    alt = replay(j, WhatIfConfig("energy_aware"))
    sec = whatif_section(j, base, alt)
    assert sec["policy"] == "energy_aware"
    assert sec["decisions"] == j.n
    assert sec["changed_decisions"] == \
        int((alt.choice != j.columns()["choice"]).sum())
    total = int(j.columns()["count"].sum())
    # invocation mass is conserved: shares + infeasible cover everything
    for key, res in (("platform_share_before", base),
                     ("platform_share_after", alt)):
        routed = sum(sec[key].values())
        unrouted = int(j.columns()["count"][res.choice < 0].sum())
        assert routed + unrouted == total


def test_whatif_parse_rejects_missing_policy():
    with pytest.raises(ValueError):
        WhatIfConfig.parse("slo_scale=2.0")
    cfg = WhatIfConfig.parse("policy=slo_composite,energy_weight=0.5,"
                             "slo_scale=2.0")
    assert cfg.policy == "slo_composite"
    assert cfg.params == {"energy_weight": 0.5}
    assert cfg.slo_scale == 2.0


def test_slo_scale_feasibility_monotone(prov_tiny):
    """Scaling every SLO budget up can only keep or grow the feasible
    set; scaling down can only shrink it (graceful degrade means routed
    counts move monotonically, never erratically)."""
    j = prov_tiny[1].journal
    name = j.policy_name

    def routed(scale):
        r = replay(j, WhatIfConfig(name, slo_scale=scale))
        assert r.ok.sum() == (r.choice >= 0).sum()
        return int((r.choice >= 0).sum())

    base = int((replay(j).choice >= 0).sum())
    assert routed(4.0) >= base
    assert routed(0.25) <= base


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(tmp_path, prov_tiny):
    j = prov_tiny[1].journal
    path = str(tmp_path / "journal.npz")
    j.save(path)
    j2 = load_journal(path)
    assert j2.n == j.n
    assert j2.policy_name == j.policy_name
    assert j2.params == {k: float(v) for k, v in j.params.items()}
    assert j2.fn_names == j.fn_names
    assert j2.pset_names == j.pset_names
    a, b = j.columns(), j2.columns()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    # the loaded journal still satisfies the oracle (replay resolves the
    # cascade from policy_name — no live bindings required)
    assert replay_matches(j2)


# ---------------------------------------------------------------------------
# jitted-kernel vs host-cascade parity (numpy/jax backend identity)
# ---------------------------------------------------------------------------

# dyadic grid: every value is k/64 (and the energy weight 1/8), so the
# cascade arithmetic is exact in float32 and the jitted kernel must agree
# with the float64 host cascade bit-for-bit — no near-tie caveat.
_PARAMS = {"cpu_threshold": 0.75, "mem_threshold": 0.875,
           "energy_weight": 0.125}


def _dyadic_case(F, P, seed):
    rng = np.random.default_rng(seed)

    def grid(shape, span=256):
        return rng.integers(0, span, shape).astype(np.float64) / 64.0

    feats = {
        "exec_s": grid((F, P)), "data_s": grid((F, P)),
        "p90_s": grid((F, P)), "energy_j": grid((F, P)),
        "alive": rng.random((F, P)) < 0.85,
        "cpu_util": grid(P, 96), "mem_util": grid(P, 96),
        "slo_s": grid(F),
    }
    return feats


def _host_explain(feats):
    cost, kill = SLOCompositePolicy.cascade(feats, _PARAMS)
    masked = np.where((kill == 0) & np.isfinite(cost), cost, np.inf)
    choice = np.argmin(masked, axis=1)
    ok = np.isfinite(masked).any(axis=1)
    rest = masked.copy()
    rest[np.arange(choice.size), choice] = np.inf
    best2 = rest.min(axis=1)
    has2 = np.isfinite(best2)
    runner = np.where(has2, np.argmin(rest, axis=1), -1)
    chosen = masked[np.arange(choice.size), choice]
    with np.errstate(invalid="ignore"):   # inf - inf on all-dead rows
        margin = np.where(has2, best2 - chosen, np.inf)
    return choice, ok, kill, runner, margin, cost


def _assert_backend_parity(F, P, seed):
    feats = _dyadic_case(F, P, seed)
    h_choice, h_ok, h_kill, h_runner, h_margin, h_cost = \
        _host_explain(feats)
    unloaded = (feats["cpu_util"] < _PARAMS["cpu_threshold"]) & \
        (feats["mem_util"] < _PARAMS["mem_threshold"])
    out = ps.composite_explain(feats["exec_s"], feats["data_s"],
                               feats["p90_s"], feats["energy_j"],
                               feats["alive"], unloaded, feats["slo_s"],
                               _PARAMS["energy_weight"])
    choice, ok, kill, runner, margin, cost = \
        (np.asarray(a) for a in out)
    np.testing.assert_array_equal(ok, h_ok)
    np.testing.assert_array_equal(kill, h_kill)
    np.testing.assert_array_equal(cost.astype(np.float64), h_cost)
    np.testing.assert_array_equal(choice[h_ok], h_choice[h_ok])
    np.testing.assert_array_equal(margin.astype(np.float64)[h_ok],
                                  h_margin[h_ok])
    fin = h_ok & np.isfinite(h_margin)
    np.testing.assert_array_equal(runner[fin], h_runner[fin])


@pytest.mark.skipif(not HAVE_JAX, reason="jax kernels unavailable")
@given(st.integers(1, 6), st.integers(1, 5), st.integers(0, 2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_composite_explain_matches_host_cascade(F, P, seed):
    _assert_backend_parity(F, P, seed)


@pytest.mark.skipif(not HAVE_JAX, reason="jax kernels unavailable")
def test_composite_explain_parity_seeded_sweep():
    """Always-on twin of the hypothesis property (hypothesis is an
    optional extra): 200 seeded shapes including the degenerate 1x1."""
    rng = np.random.default_rng(7)
    _assert_backend_parity(1, 1, 0)
    for _ in range(200):
        _assert_backend_parity(int(rng.integers(1, 7)),
                               int(rng.integers(1, 6)),
                               int(rng.integers(0, 2**32)))
