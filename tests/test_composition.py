"""Function composition (§6.3) + the InteractionModel's columnar batch
fold — the surviving pieces of the retired tuning module, now living in
``repro.core.behavioral``."""
import numpy as np

from repro.core.behavioral import (InteractionModel, compose_functions,
                                   composition_plan)
from repro.core.types import FunctionSpec, SLO


def test_compose_functions_removes_internal_io():
    a = FunctionSpec(name="a", flops=1e6, read_bytes=100.0,
                     write_bytes=500.0, memory_mb=128, slo=SLO(5.0))
    b = FunctionSpec(name="b", flops=2e6, read_bytes=500.0,
                     write_bytes=50.0, memory_mb=256, slo=SLO(3.0))
    c = compose_functions(a, b)
    assert c.name == "a+b"
    assert c.flops == 3e6
    assert c.read_bytes == 100.0          # b's read of a's output is free
    assert c.write_bytes == 50.0
    assert c.memory_mb == 256
    assert c.slo.p90_response_s == 3.0


def test_compose_functions_chains_real_fns():
    a = FunctionSpec(name="a", real_fn=lambda x: x + 1)
    b = FunctionSpec(name="b", real_fn=lambda x: x * 10)
    c = compose_functions(a, b)
    assert c.real_fn(2) == 30


def test_composition_plan_from_interaction_model():
    im = InteractionModel(window_s=1.0)
    t = 0.0
    for _ in range(12):
        im.record("a", t)
        im.record("b", t + 0.1)
        t += 10.0
    fns = {"a": FunctionSpec(name="a"), "b": FunctionSpec(name="b")}
    plan = composition_plan(im, fns, min_count=10)
    assert [f.name for f in plan] == ["a+b"]


def test_record_batch_columns_matches_sequential_edges():
    rng = np.random.default_rng(7)
    names = ["a", "b", "c", "d"]
    seq = InteractionModel(window_s=1.0)
    col = InteractionModel(window_s=1.0)
    t = 0.0
    for _ in range(20):
        burst = rng.integers(0, len(names), size=int(rng.integers(1, 30)))
        for i in burst:
            seq.record(names[int(i)], t)
        col.record_batch_columns(burst, names, t)
        t += float(rng.uniform(0.0, 2.0))
    assert dict(seq.edges) == dict(col.edges)
    assert seq._last == col._last
