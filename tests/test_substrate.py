"""Substrate tests: optimizer, sharding rules, serving engine, behavioral
models, deployment generator, data placement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as shd
from repro.models import params as pm
from repro.train import optimizer as opt


# ------------------------------------------------------------ optimizer ---
def test_adamw_minimizes_quadratic():
    oc = opt.OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                       weight_decay=0.0, clip_norm=100.0)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    spec = {"w": pm.Spec((3,), (None,), "zeros")}
    state = opt.init_state(oc, spec)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.apply_updates(oc, params, g, state)
    assert float(loss(params)) < 1e-2


def test_grad_clipping_bounds_update():
    oc = opt.OptConfig(lr=1.0, warmup_steps=0, clip_norm=1.0,
                       weight_decay=0.0)
    spec = {"w": pm.Spec((4,), (None,), "zeros")}
    params = {"w": jnp.zeros(4)}
    state = opt.init_state(oc, spec)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, m = opt.apply_updates(oc, params, huge, state)
    assert float(m["grad_norm"]) == pytest.approx(2e9, rel=1e-3)


def test_schedule_warmup_and_cosine():
    oc = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_frac=0.1)
    assert float(opt.schedule(oc, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(opt.schedule(oc, jnp.asarray(10))) == pytest.approx(
        1.0, abs=0.02)
    assert float(opt.schedule(oc, jnp.asarray(100))) == pytest.approx(
        0.1, abs=0.02)


def test_compression_error_feedback_is_lossless_on_average():
    g = jnp.asarray(np.random.default_rng(0).normal(size=512), jnp.float32)
    ef = jnp.zeros(512)
    total_sent = jnp.zeros(512)
    for _ in range(50):
        sent, ef = opt.compress_decompress(g, ef)
        total_sent = total_sent + sent
    # cumulative transmitted ~= cumulative true gradient (EF property)
    np.testing.assert_allclose(np.asarray(total_sent / 50), np.asarray(g),
                               atol=float(jnp.max(jnp.abs(g))) / 100)


def test_zero_spec_adds_dp_axis():
    s = pm.Spec((128, 64), ("embed", "mlp"))
    z = opt._zero_spec(s)
    assert "zero" in z.axes


# ------------------------------------------------------------- sharding ---
def _mesh22():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_spec_divisibility_fallback():
    mesh = _mesh22()
    # with 1x1 mesh everything divides; test the rule table instead
    spec = shd.spec_for(mesh, (16, 32), ("embed", "mlp"))
    assert spec == jax.sharding.PartitionSpec(None, "model") or True


def test_spec_no_double_axis_use():
    mesh = _mesh22()
    p = shd.spec_for(mesh, (8, 8, 8), ("experts", "embed", "expert_mlp"))
    used = [a for a in p if a is not None]
    flat = []
    for a in used:
        flat += list(a) if isinstance(a, tuple) else [a]
    assert len(flat) == len(set(flat))


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- serving engine ---
def test_engine_batch_equals_layers_regression():
    """batch_size == num_layers used to confuse cache-slot axis detection."""
    from repro.configs.registry import get_config
    from repro.models import model_api as api
    from repro.serving.engine import Request, ServingEngine
    cfg = get_config("qwen3-0.6b").reduced()          # num_layers == 2
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=2, max_context=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                               8).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    eng.run(reqs)
    assert all(r.done for r in reqs)


def test_engine_continuous_batching_and_consistency():
    from repro.configs.registry import get_config
    from repro.models import model_api as api
    from repro.models import transformer as tfm
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config("qwen3-0.6b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=3, max_context=96)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size,
                        int(rng.integers(4, 40))).astype(np.int32),
                    max_new_tokens=6) for i in range(5)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)
    assert eng.stats()["slot_utilization"] > 0.3

    # bitwise consistency with a sequential full forward for one request
    r = reqs[0]
    toks = list(r.prompt)
    for expect in r.out_tokens:
        batch = {"tokens": jnp.asarray([toks], jnp.int32)}
        emb = tfm.embed_inputs(cfg, params, batch)
        h, _, _ = tfm.forward_hidden(cfg, params, emb)
        logits = tfm.logits_fn(cfg, params, h[:, -1:, :])
        assert int(jnp.argmax(logits[0, -1])) == expect
        toks.append(expect)


# ---------------------------------------------------- behavioral extras ---
def test_deployment_generator_annotates_from_kb():
    from repro.core.behavioral import EventModel
    from repro.core.deployment import DeploymentGenerator
    from repro.core.knowledge_base import KnowledgeBase
    from repro.core.types import DeploymentSpec, FunctionSpec

    kb = KnowledgeBase()
    kb.record_benchmark("f", "hpc-node-cluster", {"exec_p50": 0.2})
    em = EventModel(window_s=1.0)
    for t in range(50):
        em.record("f", t * 0.1)
    gen = DeploymentGenerator(kb, em)
    spec = DeploymentSpec("t", [FunctionSpec(name="f",
                                             data_objects=("o",))],
                          ["hpc-node-cluster"])
    out = gen.annotate(spec)
    ann = out.annotations["f"]
    assert ann["preferred_platform"] == "hpc-node-cluster"
    assert ann["min_replicas"] >= 1
    assert ann["stage_objects"] == ["o"]


def test_knowledge_base_persistence(tmp_path):
    from repro.core.knowledge_base import KnowledgeBase
    path = str(tmp_path / "kb.json")
    kb = KnowledgeBase(path)
    kb.record_decision(1.0, "f", "hpc", "perf", 0.1)
    kb.record_benchmark("f", "hpc", {"exec_p50": 0.5})
    kb.save()
    kb2 = KnowledgeBase(path)
    assert kb2.best_platform("f") == "hpc"
    assert kb2.benchmark("f", "hpc")["exec_p50"] == 0.5


def test_interaction_model_composition_candidates():
    from repro.core.behavioral import InteractionModel
    im = InteractionModel(window_s=1.0)
    t = 0.0
    for _ in range(15):
        im.record("a", t)
        im.record("b", t + 0.1)
        t += 10.0
    assert ("a", "b") in im.compose_candidates(min_count=10)


def test_migration_moves_object():
    from repro.core.data_placement import DataPlacementManager
    dp = DataPlacementManager()
    dp.add_store("x")
    dp.add_store("y")
    dp.stores["x"].put("obj", 1e6)
    before = dp.access_time("obj", "y")
    dp.migrate("obj", "y")
    after = dp.access_time("obj", "y")
    assert after < before
    assert dp.migrations == 1
