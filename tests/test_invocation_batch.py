"""Struct-of-arrays admission (InvocationBatch): object/columnar parity.

The columnar path must be observationally identical to submitting the
materialized ``Invocation`` objects — same decisions, same queue timings,
same rejections, same report bytes — while creating Python objects only
for rows a replica actually starts (or a fault path touches).
"""
import json

import numpy as np
import pytest

from repro.core import FDNControlPlane, Gateway, InvocationBatch
from repro.core import profiles
from repro.core.types import FunctionSpec, Invocation
from repro.inspector import registry
from repro.inspector.scenario import run_scenario

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # optional extra
    HAVE_HYPOTHESIS = False


def _specs(n=3):
    return [FunctionSpec(name=f"f{i}", flops=1e6 * (i + 1),
                         memory_mb=64 * (i + 1)) for i in range(n)]


# ---------------------------------------------------------------------------
# Batch <-> object round trip
# ---------------------------------------------------------------------------

def test_from_invocations_round_trip_preserves_identity():
    specs = _specs()
    invs = [Invocation(specs[i % 3], 0.5 * i) for i in range(10)]
    b = InvocationBatch.from_invocations(invs)
    assert b.n == len(invs) == len(b)
    assert [s.name for s in b.specs] == ["f0", "f1", "f2"]
    assert b.to_invocations() == invs          # the very same objects
    np.testing.assert_array_equal(b.fn_idx, np.arange(10) % 3)
    np.testing.assert_array_equal(b.arrival_t, 0.5 * np.arange(10))


def test_deadline_column_defaults_to_spec_slo():
    specs = _specs()
    b = InvocationBatch(specs, np.array([0, 2, 1]), np.zeros(3))
    want = [specs[0].slo.p90_response_s, specs[2].slo.p90_response_s,
            specs[1].slo.p90_response_s]
    np.testing.assert_array_equal(b.deadline_s, want)


def test_materialize_caches_one_object_per_row():
    b = InvocationBatch(_specs(), np.array([1, 1]), np.array([3.0, 4.0]))
    inv = b.materialize(0)
    assert b.materialize(0) is inv
    assert inv.fn.name == "f1" and inv.arrival_t == 3.0
    assert len(b._objs) == 1                   # row 1 never materialized


def test_view_is_zero_copy_and_state_writes_propagate():
    b = InvocationBatch(_specs(), np.arange(6) % 3,
                        np.linspace(0.0, 1.0, 6))
    v = b.view(2, 5)
    assert v.n == 3
    assert v.fn_idx.base is b.fn_idx or \
        v.fn_idx.base is b.fn_idx.base         # shares memory
    v.state[:] = InvocationBatch.ADMITTED
    assert list(b.state) == [0, 0, 1, 1, 1, 0]


def test_present_fns_first_appearance_order():
    b = InvocationBatch(_specs(), np.array([2, 0, 2, 1, 0]), np.zeros(5))
    assert list(b.present_fns()) == [2, 0, 1]


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_round_trip_property():
    specs = _specs()

    @given(st.lists(st.tuples(st.integers(0, 2),
                              st.floats(0.0, 1e4, allow_nan=False)),
                    min_size=1, max_size=200))
    @settings(max_examples=50, deadline=None)
    def check(rows):
        invs = [Invocation(specs[i], t) for i, t in rows]
        b = InvocationBatch.from_invocations(invs)
        out = b.to_invocations()
        assert out == invs
        # columnarize -> view -> re-materialize agrees row for row
        lo, hi = 0, b.n
        v = b.view(lo, hi)
        for k in range(v.n):
            inv = v.materialize(k)
            assert inv.fn is specs[rows[k][0]]
            assert inv.arrival_t == float(v.arrival_t[k])

    check()


# ---------------------------------------------------------------------------
# Control-plane parity
# ---------------------------------------------------------------------------

def _cp():
    cp = FDNControlPlane()
    # decision-row logging forces the object-path fallback by design;
    # these tests exercise the columnar fast path (the production config)
    cp.kb.log_decisions = False
    for n in ("hpc-node-cluster", "edge-cluster"):
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    return cp


def test_columnar_submit_matches_object_submit():
    specs = _specs()
    results = []
    for columnar in (False, True):
        cp = _cp()
        for p in cp.platforms.values():
            for s in specs:
                p.deploy(s)
        times = np.linspace(0.0, 1.0, 40)
        fidx = np.arange(40) % 3
        if columnar:
            batch = InvocationBatch(specs, fidx, times)
            accepted = cp.submit_batch(batch)
            assert set(batch.state) == {InvocationBatch.ADMITTED}
        else:
            accepted = cp.submit_batch(
                [Invocation(specs[i], float(t))
                 for i, t in zip(fidx, times)])
        cp.clock.run_until(120.0)
        done = sorted((i.fn.name, round(i.arrival_t, 9), i.platform,
                       round(i.end_t, 9), round(i.exec_time, 9))
                      for i in cp.completed)
        results.append((accepted, cp.completed_count,
                        cp.kb.decision_count, done))
    assert results[0] == results[1]


def test_columnar_rejection_matches_object_path():
    specs = [FunctionSpec(name="huge", memory_mb=1 << 30)]
    outcomes = []
    for columnar in (False, True):
        cp = _cp()
        for p in cp.platforms.values():
            p.deploy(specs[0])
        if columnar:
            batch = InvocationBatch(specs, np.zeros(5, np.int32),
                                    np.zeros(5))
            accepted = cp.submit_batch(batch)
            assert set(batch.state) == {InvocationBatch.REJECTED}
        else:
            accepted = cp.submit_batch(
                [Invocation(specs[0], 0.0) for _ in range(5)])
        outcomes.append((accepted, cp.rejected_count, len(cp.rejected),
                         sorted(i.status for i in cp.rejected)))
    assert outcomes[0] == outcomes[1]
    assert outcomes[1][0] == 0 and outcomes[1][1] == 5


def test_columnar_platform_failure_materializes_queued_rows():
    cp = _cp()
    fn = FunctionSpec(name="slow", flops=5e11)    # long-running: queues
    for p in cp.platforms.values():
        p.deploy(fn)
    batch = InvocationBatch([fn], np.zeros(64, np.int32), np.zeros(64))
    accepted = cp.submit_batch(batch)
    assert accepted == 64
    cp.clock.step()
    failed_before = cp.rejected_count
    for p in cp.platforms.values():
        p.fail()
    # every admitted row travelled the failure path as a real object
    lost = [i for i in batch._objs.values() if i.status == "failed"]
    assert len(lost) > 0
    assert cp.redeliverer.redelivered >= 0       # redelivery saw objects
    assert failed_before == 0


def test_gateway_auth_failure_marks_batch_rejected():
    cp = _cp()
    gw = Gateway(cp)
    specs = _specs(1)
    for p in cp.platforms.values():
        p.deploy(specs[0])
    batch = InvocationBatch(specs, np.zeros(3, np.int32), np.zeros(3))
    assert gw.request_batch(batch, token="wrong") == 0
    assert gw.unauthorized == 3
    assert set(batch.state) == {InvocationBatch.REJECTED}


def test_gateway_lb_policy_falls_back_to_objects():
    from repro.core.scheduler import RoundRobinCollaboration
    cp = _cp()
    gw = Gateway(cp, lb_policy=RoundRobinCollaboration())
    specs = _specs(1)
    for p in cp.platforms.values():
        p.deploy(specs[0])
    batch = InvocationBatch(specs, np.zeros(4, np.int32),
                            np.linspace(0, 0.1, 4))
    assert gw.request_batch(batch) == 4
    cp.clock.run_until(60.0)
    assert cp.completed_count == 4


# ---------------------------------------------------------------------------
# Whole-scenario report parity (the tentpole's oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["smoke/tiny", "paper/fig10-weighted",
                                  "chains/etl-pipeline"])
def test_scenario_report_parity_columnar_vs_object(name):
    sc = registry.get(name)
    col = run_scenario(sc.replace(columnar=True)).to_dict()
    obj = run_scenario(sc.replace(columnar=False)).to_dict()
    col.pop("scenario")
    obj.pop("scenario")
    assert json.dumps(col, sort_keys=True) == json.dumps(obj,
                                                         sort_keys=True)
