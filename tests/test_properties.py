"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test extra (see pyproject.toml); without it
this module degrades to a skip instead of a collection error.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.behavioral import EWMA, EventModel, P2Quantile
from repro.core.data_placement import LRUCache
from repro.core.energy import EnergyMeter
from repro.core.monitoring import percentile
from repro.core.scheduler import WeightedCollaboration
from repro.core.simulator import SimClock
from repro.core.types import PlatformProfile

SETTINGS = dict(max_examples=50, deadline=None)


@given(st.lists(st.floats(0.001, 100.0), min_size=30, max_size=300))
@settings(**SETTINGS)
def test_p2_quantile_tracks_true_p90(xs):
    est = P2Quantile(0.9)
    for x in xs:
        est.add(x)
    true = float(np.percentile(xs, 90))
    lo, hi = float(np.min(xs)), float(np.max(xs))
    v = est.value()
    assert lo <= v <= hi
    spread = hi - lo
    if spread > 0 and len(xs) >= 50:
        assert abs(v - true) <= 0.5 * spread + 1e-9


@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=100),
       st.floats(0.01, 1.0))
@settings(**SETTINGS)
def test_ewma_stays_in_range(xs, alpha):
    e = EWMA(alpha)
    for x in xs:
        e.add(x)
    assert min(xs) - 1e-6 <= e.value() <= max(xs) + 1e-6


@given(st.lists(st.tuples(st.text(min_size=1, max_size=4),
                          st.floats(1.0, 1e8)), min_size=1, max_size=60),
       st.floats(1e3, 1e7))
@settings(**SETTINGS)
def test_lru_cache_never_exceeds_capacity(items, cap):
    c = LRUCache(cap)
    for k, size in items:
        c.put(k, size)
        assert c.used() <= cap + 1e-6


@given(st.integers(1, 20), st.integers(1, 20))
@settings(**SETTINGS)
def test_weighted_collaboration_exact_ratio(w1, w2):
    class FakePlatform:
        def __init__(self, name):
            self.prof = type("P", (), {"name": name,
                                       "total_memory_mb": 1 << 20})()
            self.failed = False
            self.deployed = {"f": object()}

    class FakeInv:
        fn = type("F", (), {"name": "f", "memory_mb": 128})()

    pol = WeightedCollaboration({"a": w1, "b": w2})
    plats = [FakePlatform("a"), FakePlatform("b")]
    n = (w1 + w2) * 3
    picks = [pol.choose(FakeInv(), plats).prof.name for _ in range(n)]
    assert picks.count("a") == 3 * w1
    assert picks.count("b") == 3 * w2


@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=30),
       st.lists(st.floats(0.1, 10.0), min_size=1, max_size=30))
@settings(**SETTINGS)
def test_energy_meter_monotone_nonnegative(utils, dts):
    m = EnergyMeter()
    prof = PlatformProfile(name="p", faas="openwhisk", nodes=2,
                           idle_w_per_node=1.0, loaded_w_per_node=5.0)
    m.register(prof)
    t, last = 0.0, 0.0
    for u, dt in zip(utils, dts):
        t += dt
        m.update("p", t, u)
        j = m.joules("p")
        assert j >= last - 1e-9
        # bounded by loaded power * elapsed
        assert j <= 2 * 5.0 * t + 1e-6
        assert j >= 2 * 1.0 * t - 1e-6
        last = j


@given(st.lists(st.floats(0.0, 1000.0), min_size=1, max_size=200),
       st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_percentile_bounds(vals, q):
    v = percentile(sorted(vals), q)
    assert min(vals) - 1e-9 <= v <= max(vals) + 1e-9


@given(st.integers(2, 64), st.integers(1, 32), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_masked_cache_update_equals_scatter(cap, b, kh):
    from repro.models.layers import masked_cache_update
    rng = np.random.default_rng(b * cap)
    cache = jnp.asarray(rng.normal(size=(b, cap, kh, 4)), jnp.float32)
    new = jnp.asarray(rng.normal(size=(b, 1, kh, 4)), jnp.float32)
    slot = jnp.asarray(rng.integers(0, cap, b), jnp.int32)
    got = masked_cache_update(cache, new, slot)
    want = cache.at[jnp.arange(b), slot].set(new[:, 0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@given(st.integers(4, 64), st.integers(1, 8), st.integers(1, 100))
@settings(max_examples=20, deadline=None)
def test_pack_cache_keeps_suffix(s, b, cap):
    from repro.models.transformer import pack_cache
    rng = np.random.default_rng(s * b)
    stack = jnp.asarray(rng.normal(size=(b, s, 2, 3)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    out = pack_cache(stack, lens, cap)
    for i in range(b):
        li = int(lens[i])
        keep = min(li, cap)
        start = max(li - cap, 0)
        np.testing.assert_allclose(np.asarray(out[i, :keep]),
                                   np.asarray(stack[i, start:start + keep]))


@given(st.lists(st.floats(0.0, 5.0), min_size=2, max_size=40))
@settings(**SETTINGS)
def test_sim_clock_monotonic(delays):
    clock = SimClock()
    seen = []
    for d in delays:
        clock.after(d, lambda: seen.append(clock.now()))
    clock.run()
    assert seen == sorted(seen)
    assert len(seen) == len(delays)


@given(st.integers(1, 50), st.integers(1, 20))
@settings(**SETTINGS)
def test_event_model_forecast_nonnegative(rate, windows):
    em = EventModel(window_s=1.0)
    t = 0.0
    for w in range(windows):
        for _ in range(rate):
            em.record("f", t)
            t += 1.0 / rate
    assert em.forecast_rate("f") >= 0.0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_data_pipeline_deterministic(seed):
    from repro.data.pipeline import DataConfig, TokenStream
    dc = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=seed)
    a = TokenStream(dc).batch(0)
    b = TokenStream(dc).batch(0)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    # labels are tokens shifted by one
    row = TokenStream(dc)._row(0, 0)
    np.testing.assert_array_equal(a["tokens"][0], row[:-1])
    np.testing.assert_array_equal(a["labels"][0], row[1:])


@given(st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_data_pipeline_host_sharding_disjoint(hosts):
    from repro.data.pipeline import DataConfig, TokenStream
    rows = []
    for h in range(hosts):
        dc = DataConfig(vocab_size=1000, seq_len=64, global_batch=4 * hosts,
                        seed=7, host_index=h, host_count=hosts)
        rows.append(TokenStream(dc).batch(0)["tokens"])
    full = np.concatenate(rows, axis=0)
    assert full.shape[0] == 4 * hosts
    # rows are distinct across hosts (w.h.p.)
    flat = {tuple(r) for r in full.tolist()}
    assert len(flat) == full.shape[0]


@given(st.lists(st.tuples(st.floats(0.0, 299.0, allow_nan=False),
                          st.integers(0, 4096)),
                min_size=1, max_size=400),
       st.integers(1, 64), st.booleans())
@settings(**SETTINGS)
def test_rollup_tier_merge_consistency(pairs, chunk, start_bulk):
    """Rollup cascade invariant (repro.obs.telemetry): 1 s tiers merged
    up to 60 s equal a direct 60 s rollup EXACTLY for ids / count / sum /
    min / max / bad — under any interleaving of scalar ``add`` and bulk
    ``add_many`` and any chunk size.  Values are dyadic (k/64) so float
    sums are associativity-proof; quantile sketches are approximate but
    must stay inside their bucket's exact [min, max]."""
    from repro.obs.telemetry import TelemetryConfig, TelemetryEngine

    ts = np.sort(np.array([t for t, _ in pairs]))
    vs = np.array([v for _, v in pairs], dtype=float) / 64.0

    def build(tiers):
        eng = TelemetryEngine(TelemetryConfig(
            tiers_s=tiers, capacity=512, auto_flush_samples=None))
        eng.set_slo("f", 8.0)
        bulk = start_bulk
        for i in range(0, len(ts), chunk):
            if bulk:
                eng.observe_many("p", "f", "response_time",
                                 ts[i:i + chunk], vs[i:i + chunk])
            else:
                for t, v in zip(ts[i:i + chunk], vs[i:i + chunk]):
                    eng.observe("p", "f", "response_time",
                                float(t), float(v))
            bulk = not bulk
        eng.finalize()
        return eng

    cascade = build((1.0, 10.0, 60.0))
    direct = build((60.0,))
    a = cascade.get_series("p", "f", "response_time", tier=2)
    b = direct.get_series("p", "f", "response_time", tier=0)
    for i, name in enumerate(("ids", "counts", "sums", "mins", "maxs",
                              "bad")):
        np.testing.assert_array_equal(a[i], b[i], err_msg=name)
    assert int(a[1].sum()) == len(ts)
    q = a[6]
    assert np.all((q >= a[3]) & (q <= a[4]))
