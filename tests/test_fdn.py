"""FDN core behaviour: scheduling policies, hierarchical decisions,
interference, collaboration, data locality, energy, monitoring."""
import pytest

from repro.core import (FDNControlPlane, Gateway, Invocation,
                        PerformanceRankedPolicy, UtilizationAwarePolicy,
                        RoundRobinCollaboration, WeightedCollaboration,
                        EnergyAwarePolicy, DataLocalityPolicy,
                        SLOCompositePolicy)
from repro.core import profiles, functions
from repro.core.loadgen import attach_completion_hooks, run_load, \
    run_open_loop
from repro.core.types import DeploymentSpec, FunctionSpec, SLO


def build(policy=None, names=None):
    cp = FDNControlPlane(policy=policy)
    for n in (names or list(profiles.PAPER_PLATFORMS)):
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = functions.paper_functions()
    functions.seed_object_stores(cp.placement, location="cloud-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()),
                             list(cp.platforms)))
    attach_completion_hooks(cp)
    return cp, fns


def test_performance_ranked_picks_fastest():
    cp, fns = build()
    pol = PerformanceRankedPolicy(cp.perf)
    inv = Invocation(fns["primes-python"], 0.0)
    chosen = pol.choose(inv, list(cp.platforms.values()))
    assert chosen.prof.name == "hpc-node-cluster"


def test_utilization_aware_avoids_loaded_platform():
    cp, fns = build(names=["hpc-node-cluster", "old-hpc-node-cluster"])
    pol = UtilizationAwarePolicy(cp.perf, cpu_threshold=0.5)
    cp.platforms["hpc-node-cluster"].bg_cpu = 0.9
    inv = Invocation(fns["primes-python"], 0.0)
    chosen = pol.choose(inv, list(cp.platforms.values()))
    assert chosen.prof.name == "old-hpc-node-cluster"


def test_round_robin_alternates():
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])
    pol = RoundRobinCollaboration()
    inv = Invocation(fns["nodeinfo"], 0.0)
    seq = [pol.choose(inv, list(cp.platforms.values())).prof.name
           for _ in range(4)]
    assert seq[0] != seq[1] and seq[0] == seq[2]


def test_weighted_ratio():
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])
    pol = WeightedCollaboration({"hpc-node-cluster": 5, "cloud-cluster": 1})
    inv = Invocation(fns["nodeinfo"], 0.0)
    seq = [pol.choose(inv, list(cp.platforms.values())).prof.name
           for _ in range(12)]
    assert seq.count("hpc-node-cluster") == 10
    assert seq.count("cloud-cluster") == 2


def test_energy_aware_prefers_edge_for_light_fn():
    cp, fns = build()
    pol = EnergyAwarePolicy(cp.perf)
    light = fns["JSON-loads"].replace(slo=SLO(p90_response_s=7.0))
    chosen = pol.choose(Invocation(light, 0.0),
                        list(cp.platforms.values()))
    assert chosen.prof.name == "edge-cluster"


def test_energy_aware_respects_slo():
    """With a tight SLO the slow edge platform must NOT be chosen."""
    cp, fns = build()
    # teach the model that edge is slow
    for _ in range(12):
        inv = Invocation(fns["primes-python"], 0.0)
        inv.platform = "edge-cluster"
        inv.exec_time = 5.0
        inv.end_t = 5.0
        cp.perf.observe(inv)
    pol = EnergyAwarePolicy(cp.perf)
    # SLO that the fast platforms can meet but edge's observed 5 s cannot
    strict = fns["primes-python"].replace(slo=SLO(p90_response_s=2.0))
    chosen = pol.choose(Invocation(strict, 0.0),
                        list(cp.platforms.values()))
    assert chosen.prof.name != "edge-cluster"


def test_data_locality_prefers_platform_near_data():
    cp, fns = build()
    pol = DataLocalityPolicy(cp.perf, cp.placement)
    # big object lives only on old-hpc; WAN to everyone else
    cp.placement.stores["old-hpc-node-cluster"].put("blob", 5e9)
    for other in cp.platforms:
        if other != "old-hpc-node-cluster":
            cp.placement.set_bandwidth(other, "old-hpc-node-cluster", 1e6)
    fn = fns["image-processing"].replace(data_objects=("blob",))
    chosen = pol.choose(Invocation(fn, 0.0), list(cp.platforms.values()))
    assert chosen.prof.name == "old-hpc-node-cluster"


def test_composite_policy_full_pipeline():
    cp, fns = build(policy=None)
    gw = Gateway(cp)
    res = run_load(cp.clock, lambda i: gw.request(i), fns["nodeinfo"],
                   vus=10, duration_s=30.0, sleep_s=0.05)
    assert len(res.completed) > 100
    assert len(cp.rejected) == 0
    assert len(cp.kb.decisions) == len(res.invocations)


def test_gateway_access_control():
    cp, fns = build()
    gw = Gateway(cp)
    inv = Invocation(fns["nodeinfo"], 0.0)
    assert not gw.request(inv, principal="intruder", token="nope")
    assert gw.unauthorized == 1


def test_sidecar_delegates_under_pressure():
    cp, fns = build(names=["hpc-node-cluster", "cloud-cluster"])
    sc = cp.sidecars["cloud-cluster"]
    cp.platforms["cloud-cluster"].bg_cpu = 1.0
    delegated = []
    inv = Invocation(fns["nodeinfo"], 0.0)
    sc.handle_local_trigger(inv, delegate=delegated.append)
    assert delegated, "sidecar should delegate when pressured"


def test_open_loop_energy_ratio_table4():
    """Condensed Table-4: >=8x CPU energy saving edge vs hpc at equal load."""
    joules = {}
    for pname in ("edge-cluster", "hpc-node-cluster"):
        cp, fns = build(names=[pname])
        res = run_open_loop(
            cp.clock, lambda i: cp.submit(i, platform_override=pname),
            fns["JSON-loads"], rps=40.0, duration_s=120.0)
        cp.run_until(cp.clock.now())
        assert len(res.completed) >= 0.95 * 40 * 120, pname
        assert res.p90_response() <= 7.0, pname
        joules[pname] = cp.energy.joules(pname)
    assert joules["hpc-node-cluster"] / joules["edge-cluster"] >= 8.0


def test_interference_cpu_and_memory():
    from repro.core.platform import Replica
    cp, fns = build(names=["old-hpc-node-cluster"])
    p = cp.platforms["old-hpc-node-cluster"]
    assert p._interference_factor() == 1.0
    # one running replica while the background load owns every core
    rep = Replica("nodeinfo")
    rep.busy = True
    p.replicas["nodeinfo"].append(rep)
    p._busy += 1                     # busy accounting is counter-based
    p.bg_cpu = 1.0
    assert p._interference_factor() == pytest.approx(2.0)
    p.bg_cpu = 0.5                       # fits on the free half -> no effect
    assert p._interference_factor() == 1.0
    p.bg_cpu = 0.0
    p.bg_mem = 1.01
    assert p._interference_factor() >= 7.0


def test_arm_platform_rejects_x86_images():
    cp, fns = build(names=["edge-cluster"])
    bad = FunctionSpec(name="x86-only", runtime="docker-x86")
    with pytest.raises(ValueError):
        cp.platforms["edge-cluster"].deploy(bad)
