"""Deployment recommendation (§3.6, now a performance-model method) +
bursty workload generator."""
import numpy as np

from repro.core import FDNControlPlane, Gateway
from repro.core import functions as fn_mod
from repro.core import profiles
from repro.core.loadgen import attach_completion_hooks, run_load
from repro.core.types import DeploymentSpec


def _loaded_cp():
    cp = FDNControlPlane()
    for n in ("hpc-node-cluster", "edge-cluster"):
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = fn_mod.paper_functions()
    fn_mod.seed_object_stores(cp.placement, location="hpc-node-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    gw = Gateway(cp)
    run_load(cp.clock, lambda i: gw.request(i), fns["nodeinfo"], vus=5,
             duration_s=20.0, sleep_s=0.05)
    return cp, fns


def test_recommend_tradeoff_and_history():
    cp, fns = _loaded_cp()
    profs = [p.prof for p in cp.platforms.values()]
    advice = cp.perf.recommend(fns["JSON-loads"], profs, kb=cp.kb)
    assert advice["latency_best"] == "hpc-node-cluster"
    assert advice["energy_best"] == "edge-cluster"
    assert advice["tradeoff"] is True
    advice2 = cp.perf.recommend(fns["nodeinfo"], profs, kb=cp.kb)
    assert advice2["historical"] in cp.platforms


def test_recommend_rejects_nonfitting():
    cp, fns = _loaded_cp()
    big = fns["nodeinfo"].replace(name="huge", memory_mb=1 << 30)
    advice = cp.perf.recommend(big,
                               [p.prof for p in cp.platforms.values()])
    assert advice.get("error") == "fits nowhere"


def test_recommend_matches_scalar_predictions():
    cp, fns = _loaded_cp()
    profs = [p.prof for p in cp.platforms.values()]
    advice = cp.perf.recommend(fns["nodeinfo"], profs)
    for p in profs:
        assert advice["predicted_exec_s"][p.name] == \
            round(cp.perf.predict_exec(fns["nodeinfo"], p), 4)
        assert advice["predicted_energy_j"][p.name] == \
            round(cp.perf.predict_energy(fns["nodeinfo"], p), 3)


def test_bursty_arrivals_shape():
    from repro.data.pipeline import bursty_arrival_times
    t = bursty_arrival_times(rate=10.0, duration_s=120.0,
                             burst_factor=4.0, period_s=30.0)
    assert np.all(np.diff(t) >= 0)
    assert 0 <= t.min() and t.max() <= 120.0
    # average rate between base and peak
    avg = len(t) / 120.0
    assert 10.0 * 0.8 <= avg <= 40.0
    # bursts exist: windowed rates vary by >1.5x
    hist, _ = np.histogram(t, bins=24)
    assert hist.max() >= 1.5 * max(hist.min(), 1)


def test_event_model_tracks_bursts():
    from repro.core.behavioral import EventModel
    from repro.data.pipeline import bursty_arrival_times
    em = EventModel(window_s=10.0)
    for t in bursty_arrival_times(20.0, 300.0, period_s=100.0):
        em.record("f", float(t))
    assert em.forecast_rate("f") > 0.0
