"""Live telemetry engine (repro.obs.telemetry / alerts): rollup tiers,
burn-rate SLO alerting and platform-health anomaly detection.

Load-bearing invariants pinned here:

  * cascade exactness — coarse tiers are *merges* of finer closed
    buckets, so 1 s rollups merged up to 60 s equal a direct 60 s rollup
    exactly for ids/count/sum/min/max/bad (quantiles stay in [min, max]);
  * bounded detection latency — ``telemetry/hpc-outage`` flags the t=40 s
    fault within 30 s, ``telemetry/overload-ramp`` flags queue growth
    before the SLO burn alert confirms it;
  * quiet baseline — ``telemetry/smoke-quiet`` emits ZERO alerts (the
    detectors are tuned against false positives, both directions pinned);
  * determinism — the alert log is byte-identical across runs;
  * non-perturbation — attaching telemetry changes nothing outside the
    added ``alerts`` section (the ``is None``-guard taps are pure reads).
"""
import itertools
import json

import numpy as np
import pytest

from repro.core import types as core_types
from repro.core.monitoring import percentile, percentile_unsorted
from repro.inspector import registry
from repro.inspector.registry import TELEMETRY_DEFAULTS
from repro.inspector.scenario import run_scenario
from repro.obs.telemetry import (NO_FN, TelemetryConfig, TelemetryEngine)
from repro.obs.alerts import (AlertConfig, BurnRule, evaluate_health,
                              evaluate_slo_burn)


def _run(name):
    # invocation ids come from a process-global counter; reset so every
    # run sees the id stream a fresh process would (byte-identical runs)
    core_types._inv_counter = itertools.count()
    return run_scenario(registry.get(name))


@pytest.fixture(scope="module")
def outage_report():
    return _run("telemetry/hpc-outage")


@pytest.fixture(scope="module")
def ramp_report():
    return _run("telemetry/overload-ramp")


# ---------------------------------------------------------------------------
# rollup engine units
# ---------------------------------------------------------------------------

def _feed(engine, ts, vs):
    engine.observe_many("p", "f", "response_time", ts, vs)
    engine.finalize()
    return engine


def test_cascade_merge_equals_direct_rollup():
    # dyadic values (k/64) make float sums exact under any association,
    # so the merge-vs-direct claim is array_equal, not allclose
    rng = np.random.default_rng(3)
    n = 20_000
    ts = np.sort(rng.uniform(0.0, 600.0, n))
    vs = rng.integers(0, 256, n).astype(float) / 64.0
    cascade = _feed(TelemetryEngine(TelemetryConfig(
        tiers_s=(1.0, 10.0, 60.0), capacity=1024,
        auto_flush_samples=None)), ts, vs)
    direct = _feed(TelemetryEngine(TelemetryConfig(
        tiers_s=(60.0,), capacity=1024, auto_flush_samples=None)), ts, vs)
    a = cascade.get_series("p", "f", "response_time", tier=2)
    b = direct.get_series("p", "f", "response_time", tier=0)
    for i, name in enumerate(("ids", "counts", "sums", "mins", "maxs",
                              "bad")):
        np.testing.assert_array_equal(a[i], b[i], err_msg=name)
    assert int(a[1].sum()) == n
    # P2 sketches are approximate but always bracketed by the exact
    # min/max of their own bucket
    assert np.all((a[6] >= a[3]) & (a[6] <= a[4]))


def test_slo_threshold_counts_bad_samples():
    eng = TelemetryEngine(TelemetryConfig(tiers_s=(1.0,),
                                          auto_flush_samples=None))
    eng.set_slo("f", 0.5)
    ts = np.arange(10, dtype=float) * 0.1
    vs = np.array([0.1] * 6 + [0.9] * 4)
    _feed(eng, ts, vs)
    ids, counts, _s, _mn, _mx, bad, _q = eng.get_series(
        "p", "f", "response_time")
    assert int(counts.sum()) == 10
    assert int(bad.sum()) == 4


def test_set_slo_retrofits_existing_series():
    eng = TelemetryEngine(TelemetryConfig(auto_flush_samples=None))
    eng.observe("p", "f", "response_time", 0.0, 2.0)
    eng.set_slo("f", 1.0)           # after the series already exists
    eng.observe("p", "f", "response_time", 0.5, 2.0)
    eng.finalize()
    bad = eng.get_series("p", "f", "response_time")[5]
    # classification happens at fold time, so the retrofit covers the
    # sample that was already pending as well as the one added after
    assert int(bad.sum()) == 2


def test_metric_filter_and_health_bypass():
    eng = TelemetryEngine(TelemetryConfig(metrics=("response_time",),
                                          auto_flush_samples=None))
    eng.observe("p", "f", "memory_mb", 0.0, 128.0)   # not subscribed
    eng.record_health("p", 0.0, 3.0, 0.5, 40.0)      # never filtered
    eng.finalize()
    keys = eng.keys()
    assert ("p", "f", "memory_mb") not in keys
    assert ("p", NO_FN, "queue_depth") in keys
    assert ("p", NO_FN, "utilization") in keys
    assert ("p", NO_FN, "watts") in keys


def test_ring_eviction_counts_dropped_late():
    eng = TelemetryEngine(TelemetryConfig(tiers_s=(1.0,), capacity=4,
                                          auto_flush_samples=None))
    eng.observe_many("p", "f", "response_time",
                     np.arange(16, dtype=float), np.ones(16))
    eng.flush()
    # a sample far in the past of the live window is dropped, not folded
    eng.observe("p", "f", "response_time", 0.5, 1.0)
    eng.flush()
    assert eng.dropped_late() == 1
    summary = eng.rollup_summary()
    assert summary["dropped_late"] == 1
    # "samples" counts everything pushed through the fold; drops are
    # tracked separately so the two reconcile: folded - dropped = kept
    assert summary["samples"] == 17


def test_auto_flush_keeps_pending_bounded():
    eng = TelemetryEngine(TelemetryConfig(tiers_s=(1.0,),
                                          auto_flush_samples=64))
    ts = np.linspace(0.0, 9.0, 100)
    eng.observe_many("p", "f", "response_time", ts, np.ones(100))
    assert eng.flushes >= 1          # crossed the 64-sample watermark
    eng.finalize()
    assert eng.rollup_summary()["samples"] == 100


def test_rollup_memory_is_capacity_bounded():
    cfg = TelemetryConfig(tiers_s=(1.0, 10.0, 60.0), capacity=64,
                          auto_flush_samples=4096)
    eng = TelemetryEngine(cfg)
    rng = np.random.default_rng(0)
    for start in range(0, 200_000, 10_000):
        ts = np.sort(rng.uniform(start, start + 10_000, 5_000))
        eng.observe_many("p", "f", "response_time", ts,
                         rng.exponential(0.2, 5_000))
    eng.finalize()
    sr = eng.series[("p", "f", "response_time")]
    for ring in sr.tiers:
        assert len(ring.ids) == 64   # grow-free: rings never resize
    assert eng.rollup_summary()["samples"] == 100_000


# ---------------------------------------------------------------------------
# percentile dedup (satellite: one shared interpolation definition)
# ---------------------------------------------------------------------------

def test_percentile_helpers_share_one_exact_definition():
    rng = np.random.default_rng(11)
    for n in (1, 2, 3, 7, 100, 1001):
        vals = rng.exponential(1.0, n)
        s = np.sort(vals)
        for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
            a = percentile(s, q)
            b = percentile_unsorted(vals, q)
            assert a == b            # bit-identical: same shared formula
            assert a == pytest.approx(float(np.percentile(vals, q * 100)),
                                      rel=1e-12, abs=1e-12)
    assert np.isnan(percentile([], 0.9))
    assert np.isnan(percentile_unsorted(np.array([]), 0.9))


# ---------------------------------------------------------------------------
# alert evaluation on synthetic series
# ---------------------------------------------------------------------------

def test_burn_rate_fires_on_sustained_budget_burn():
    cfg = AlertConfig(slo_target=0.9, rules=(
        BurnRule("fast", 5.0, 20.0, 4.0, "page"),), min_long_samples=5)
    eng = TelemetryEngine(TelemetryConfig(tiers_s=(1.0,),
                                          auto_flush_samples=None))
    eng.set_slo("f", 0.5)
    ts = np.arange(0.0, 60.0, 0.1)
    vs = np.where(ts < 30.0, 0.1, 2.0)   # all-bad from t=30 on
    _feed(eng, ts, vs)
    events = evaluate_slo_burn(eng, ["f"], cfg)
    fires = [e for e in events if e["kind"] == "fire"]
    assert fires and fires[0]["rule"] == "fast"
    # both windows must confirm: the fire lands after the long window
    # fills with burning samples, not at the first bad bucket
    assert 30.0 < fires[0]["t"] <= 55.0
    assert fires[0]["burn_short"] >= 4.0
    assert fires[0]["burn_long"] >= 4.0


def test_burn_rate_quiet_on_healthy_series():
    cfg = AlertConfig(slo_target=0.9, min_long_samples=5)
    eng = TelemetryEngine(TelemetryConfig(tiers_s=(1.0,),
                                          auto_flush_samples=None))
    eng.set_slo("f", 10.0)
    ts = np.arange(0.0, 120.0, 0.05)
    _feed(eng, ts, np.full(len(ts), 0.2))
    assert evaluate_slo_burn(eng, ["f"], cfg) == []


def test_health_detector_flags_level_shift_with_bounded_latency():
    cfg = AlertConfig(z_threshold=6.0, k_consecutive=3, warmup_buckets=8)
    eng = TelemetryEngine(TelemetryConfig(tiers_s=(1.0,),
                                          auto_flush_samples=None))
    rng = np.random.default_rng(5)
    for t in range(120):
        depth = 3.0 + rng.normal(0.0, 0.3) if t < 60 else 80.0
        eng.record_health("plat", float(t), depth, 0.4, 35.0)
    eng.finalize()
    events = evaluate_health(eng, cfg)
    fires = [e for e in events if e["kind"] == "fire"
             and e["metric"] == "queue_depth"]
    assert fires
    # k_consecutive=3 confirmation: flagged within ~5 buckets of the shift
    assert 60.0 <= fires[0]["t"] <= 66.0


def test_health_detector_quiet_on_stationary_noise():
    cfg = AlertConfig(z_threshold=6.0, k_consecutive=3, warmup_buckets=8)
    eng = TelemetryEngine(TelemetryConfig(tiers_s=(1.0,),
                                          auto_flush_samples=None))
    rng = np.random.default_rng(6)
    for t in range(200):
        eng.record_health("plat", float(t),
                          5.0 + rng.normal(0.0, 0.5),
                          0.5 + rng.normal(0.0, 0.02),
                          40.0 + rng.normal(0.0, 1.0))
    eng.finalize()
    assert evaluate_health(eng, cfg) == []


# ---------------------------------------------------------------------------
# scenario-level behavior (the registry's telemetry/* arms)
# ---------------------------------------------------------------------------

def test_telemetry_scenarios_registered():
    names = registry.names()
    for name in ("telemetry/hpc-outage", "telemetry/overload-ramp",
                 "telemetry/burst-storm", "telemetry/smoke-quiet"):
        assert name in names
        assert registry.get(name).telemetry is not None


def test_smoke_quiet_emits_zero_alerts():
    rep = _run("telemetry/smoke-quiet")
    a = rep.alerts
    assert a["enabled"] is True
    assert a["slo"]["fires"] == 0
    assert a["health"]["fires"] == 0
    assert a["slo"]["events"] == []
    assert a["health"]["events"] == []
    # the rollups still folded the whole run
    assert a["rollup"]["samples"] > 0
    assert a["rollup"]["dropped_late"] == 0


def test_outage_detected_within_bounded_window(outage_report):
    # hpc-node-cluster fails at t=40 s, recovers at t=80 s
    a = outage_report.alerts
    fires = [e for e in a["health"]["events"] if e["kind"] == "fire"]
    assert fires
    first = min(e["t"] for e in fires)
    assert 40.0 <= first <= 70.0     # detected within 30 s of the fault
    # the recovery transient is attributed to the failed platform itself
    assert any(e["platform"] == "hpc-node-cluster" for e in fires)


def test_ramp_overload_health_precedes_slo_burn(ramp_report):
    a = ramp_report.alerts
    slo_fires = [e for e in a["slo"]["events"] if e["kind"] == "fire"]
    hp_fires = [e for e in a["health"]["events"] if e["kind"] == "fire"]
    assert slo_fires and hp_fires
    sev = {e["severity"] for e in slo_fires}
    assert "ticket" in sev and "page" in sev
    # queue growth is the early-warning signal: the health detector
    # fires well before the burn-rate windows confirm the SLO breach
    first_hp = min(e["t"] for e in hp_fires
                   if e["metric"] == "queue_depth")
    first_slo = min(e["t"] for e in slo_fires)
    assert first_hp < first_slo - 30.0
    # burn alerts report both confirming windows above the rule threshold
    for e in slo_fires:
        assert e["burn_short"] >= 3.0 and e["burn_long"] >= 3.0


def test_alert_log_byte_identical_across_runs(outage_report):
    again = _run("telemetry/hpc-outage")
    a = json.dumps(outage_report.alerts, sort_keys=True)
    b = json.dumps(again.alerts, sort_keys=True)
    assert a == b


def test_telemetry_does_not_perturb_results():
    core_types._inv_counter = itertools.count()
    sc = registry.get("smoke/tiny")
    plain = json.loads(run_scenario(sc).to_json())
    core_types._inv_counter = itertools.count()
    tel = json.loads(run_scenario(sc.replace(
        telemetry=dict(TELEMETRY_DEFAULTS))).to_json())
    for rep in (plain, tel):
        rep.pop("alerts", None)
        rep.pop("scenario", None)    # echoes the telemetry config itself
    assert tel == plain


def test_report_alerts_section_schema(outage_report):
    a = outage_report.alerts
    assert set(a) >= {"enabled", "config", "rollup", "slo", "health"}
    assert a["config"]["slo_target"] == TELEMETRY_DEFAULTS["slo_target"]
    r = a["rollup"]
    assert r["tiers_s"] == TELEMETRY_DEFAULTS["tiers_s"]
    assert r["capacity"] == TELEMETRY_DEFAULTS["capacity"]
    assert r["samples"] > 0 and r["keys"] > 0
    for e in a["slo"]["events"]:
        assert set(e) == {"t", "kind", "fn", "rule", "severity",
                          "burn_short", "burn_long"}
    for e in a["health"]["events"]:
        assert set(e) == {"t", "kind", "platform", "metric", "z"}
    # every fire eventually has at most one matching resolve after it
    assert a["slo"]["fires"] == sum(
        1 for e in a["slo"]["events"] if e["kind"] == "fire")
    assert a["health"]["fires"] == sum(
        1 for e in a["health"]["events"] if e["kind"] == "fire")


# ---------------------------------------------------------------------------
# OpenMetrics exposition (repro.obs.export.to_openmetrics)
# ---------------------------------------------------------------------------

def _parse_openmetrics(text):
    """name{labels} -> float value for every sample line."""
    assert text.endswith("# EOF\n")
    samples = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        key, _, val = line.rpartition(" ")
        samples[key] = float(val)
    return samples


def test_openmetrics_roundtrip_burst_storm():
    """Every rollup the engine holds after ``telemetry/burst-storm``
    survives the text exposition exactly: the parsed-back count / sum /
    min / max / bad equal the engine's coarsest-tier aggregates
    bit-for-bit (repr-formatted floats round-trip float64)."""
    from repro.inspector.scenario import run_scenario_state
    from repro.obs import to_openmetrics

    core_types._inv_counter = itertools.count()
    _report, cp, _sink = run_scenario_state(
        registry.get("telemetry/burst-storm"))
    engine = cp.telemetry
    text = to_openmetrics(engine)
    samples = _parse_openmetrics(text)
    tier = len(engine.cfg.tiers_s) - 1
    q_label = repr(float(engine.cfg.quantile))
    checked = 0
    for (platform, fn, metric), sr in engine.series.items():
        ids, counts, sums, mins, maxs, bad, q = sr.series(tier)
        if not len(ids):
            continue
        labels = f'platform="{platform}",fn="{fn}"'
        name = f"fdn_{metric}"
        assert samples[f"{name}_count{{{labels}}}"] == int(counts.sum())
        assert samples[f"{name}_sum{{{labels}}}"] == float(sums.sum())
        assert samples[f"{name}_min{{{labels}}}"] == float(mins.min())
        assert samples[f"{name}_max{{{labels}}}"] == float(maxs.max())
        assert samples[f"{name}_bad_total{{{labels}}}"] == int(bad.sum())
        qv = samples[f'{name}{{{labels},quantile="{q_label}"}}']
        assert qv == float(q[-1])
        assert float(mins.min()) <= qv <= float(maxs.max())
        checked += 1
    assert checked > 0
    assert samples["fdn_telemetry_samples_total"] == engine.folded
    assert samples["fdn_telemetry_flushes_total"] == engine.flushes
    assert samples["fdn_telemetry_series"] == len(engine.series)


def test_openmetrics_escaping_and_sanitizing():
    """Label values escape backslash / quote / newline per the spec and
    metric names sanitize to [a-zA-Z0-9_:]."""
    from repro.obs import to_openmetrics

    engine = TelemetryEngine(TelemetryConfig(
        metrics=("weird.metric-name",)))
    engine.observe_many('p"1\\x', "f\nn", "weird.metric-name",
                        np.array([0.5, 1.0]), np.array([1.0, 2.0]))
    engine.finalize()
    text = to_openmetrics(engine)
    assert "fdn_weird_metric_name_count" in text
    assert 'platform="p\\"1\\\\x"' in text
    assert 'fn="f\\nn"' in text
    samples = _parse_openmetrics(text)
    assert samples[
        'fdn_weird_metric_name_count{platform="p\\"1\\\\x",fn="f\\nn"}'
    ] == 2
