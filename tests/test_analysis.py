"""Dry-run / roofline harness unit tests: HLO collective parsing, depth
control, analytic MODEL_FLOPS, and enc-dec/VLM decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.dryrun_lib import (_shape_bytes, collective_stats,
                                     full_depth_units, with_depth)

HLO_SNIPPET = """
HloModule test
fused_computation {
  ...
}
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(bf16[128,256]{1,0} %p0), dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), to_apply=%add
  %ar2.start = f32[64]{0} all-reduce-start(f32[64]{0} %y), to_apply=%add
  %ar2.done = f32[64]{0} all-reduce-done(f32[64]{0} %ar2.start)
  %rs = bf16[8,32]{1,0} reduce-scatter(bf16[128,32]{1,0} %z), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %w), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(f32[8,4]{1,0} %a, f32[4,8]{1,0} %b)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("pred[]") == 1 or _shape_bytes("pred[]") == 0


def test_collective_stats_parses_operand_bytes():
    st = collective_stats(HLO_SNIPPET)
    by = st["bytes_by_kind"]
    assert by["all-gather"] == 128 * 256 * 2          # operand, not output
    # all-reduce + all-reduce-start counted once each; -done skipped
    assert by["all-reduce"] == 16 * 128 * 4 + 64 * 4
    assert by["reduce-scatter"] == 128 * 32 * 2
    assert by["collective-permute"] == 4 * 4
    assert st["counts"]["all-reduce"] == 2
    assert st["total_bytes"] == sum(by.values())


@pytest.mark.parametrize("arch,units", [
    ("qwen3-1.7b", 28), ("llama3-405b", 126), ("recurrentgemma-9b", 12),
    ("whisper-small", 12), ("mamba2-2.7b", 64),
])
def test_full_depth_units(arch, units):
    assert full_depth_units(get_config(arch)) == units


def test_with_depth_family_semantics():
    rg = get_config("recurrentgemma-9b")
    assert with_depth(rg, 2).num_layers == 2 * 3 + 2   # supers + tail
    wh = get_config("whisper-small")
    c = with_depth(wh, 3)
    assert c.num_layers == 3 and c.n_enc_layers == 3
    assert with_depth(get_config("qwen3-0.6b"), 5).num_layers == 5


def test_model_flops_formulas():
    from benchmarks.roofline import model_flops
    from repro.configs.base import TRAIN_4K, DECODE_32K
    cfg = get_config("qwen3-1.7b")
    n = cfg.n_active_params()
    assert model_flops(cfg, TRAIN_4K) == 6.0 * n * TRAIN_4K.tokens
    assert model_flops(cfg, DECODE_32K) == 2.0 * n * DECODE_32K.global_batch
    moe = get_config("mixtral-8x7b")
    assert moe.n_active_params() < moe.n_params()      # top-2 of 8


def test_whisper_decode_matches_decode_train():
    from repro.models import model_api as api
    from repro.models import whisper as wh
    cfg = get_config("whisper-small").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.normal(size=(1, cfg.n_enc_frames, cfg.d_model))
                         * 0.02, jnp.bfloat16)
    toks = rng.integers(1, cfg.vocab_size, (1, 8)).astype(np.int32)
    logits, cache = api.prefill(cfg, params,
                                {"frames": frames,
                                 "tokens": jnp.asarray(toks)}, 24)
    enc = wh.encode(cfg, params, frames)
    seq = list(toks[0])
    for _ in range(3):
        nxt = int(jnp.argmax(logits[0, -1]))
        ref = wh.decode_train(cfg, params, jnp.asarray([seq], jnp.int32),
                              enc)
        assert int(jnp.argmax(ref[0, -1])) == nxt
        seq.append(nxt)
        logits, cache = api.decode_step(
            cfg, params, cache, {"token": jnp.asarray([[nxt]], jnp.int32)})


def test_vlm_decode_matches_full_forward():
    from repro.models import model_api as api
    from repro.models import transformer as tfm
    cfg = get_config("phi-3-vision-4.2b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.normal(size=(1, cfg.n_img_tokens, cfg.d_model))
                      * 0.02, jnp.bfloat16)
    toks = rng.integers(1, cfg.vocab_size, (1, 8)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "image_embeds": img}
    logits, cache = api.prefill(cfg, params, batch, 32)
    seq = list(toks[0])
    for _ in range(3):
        nxt = int(jnp.argmax(logits[0, -1]))
        full = {"tokens": jnp.asarray([seq], jnp.int32),
                "image_embeds": img}
        emb = tfm.embed_inputs(cfg, params, full)
        h, _, _ = tfm.forward_hidden(cfg, params, emb)
        ref = tfm.logits_fn(cfg, params, h[:, -1:, :])
        assert int(jnp.argmax(ref[0, -1])) == nxt
        seq.append(nxt)
        logits, cache = api.decode_step(
            cfg, params, cache, {"token": jnp.asarray([[nxt]], jnp.int32)})


def test_lower_cell_end_to_end_small_mesh():
    """The dry-run machinery itself, exercised on a reduced config and the
    local 1-device mesh: lower+compile succeeds and produces cost/memory/
    collective stats of the right shape."""
    from repro.configs.base import InputShape
    from repro.launch.dryrun_lib import lower_cell
    from repro.launch.mesh import make_local_mesh

    cfg = get_config("qwen3-0.6b").reduced()
    shape = InputShape("t", 64, 2, "train")
    res = lower_cell(cfg, shape, make_local_mesh(), microbatches=1)
    assert res.ok, res.error
    assert res.flops_per_dev > 0
    assert res.bytes_per_dev > 0
    assert res.mem is not None and res.mem["argument_bytes"] > 0
    assert res.coll_detail is not None

    dshape = InputShape("d", 64, 2, "decode")
    res2 = lower_cell(cfg, dshape, make_local_mesh())
    assert res2.ok, res2.error
    assert res2.kind == "decode"
