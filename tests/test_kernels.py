"""Pallas kernel validation: sweep shapes/dtypes, assert_allclose against
the pure-jnp oracles in kernels/ref.py (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def arr(*shape, dtype=jnp.float32, scale=0.3):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kh,d,qb,kb", [
    (1, 128, 4, 4, 32, 64, 64),       # MHA
    (2, 256, 8, 2, 64, 64, 128),      # GQA, rectangular blocks
    (1, 64, 4, 1, 32, 64, 32),        # MQA, single q block
])
def test_flash_attention_causal(dtype, b, s, h, kh, d, qb, kb):
    q, k, v = (arr(b, s, h, d, dtype=dtype), arr(b, s, kh, d, dtype=dtype),
               arr(b, s, kh, d, dtype=dtype))
    out = ops.flash_attention(q, k, v, causal=True, q_block=qb, kv_block=kb)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 96, 1024])
def test_flash_attention_windowed(window):
    q, k, v = arr(2, 256, 4, 32), arr(2, 256, 2, 32), arr(2, 256, 2, 32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              q_block=64, kv_block=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    q, k, v = arr(1, 128, 4, 32), arr(1, 128, 4, 32), arr(1, 128, 4, 32)
    out = ops.flash_attention(q, k, v, causal=False, q_block=64, kv_block=64)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,kh,d,splits", [
    (2, 256, 8, 4, 64, 4),
    (3, 512, 4, 1, 32, 8),
    (1, 128, 2, 2, 64, 1),
])
def test_decode_attention(dtype, b, t, h, kh, d, splits):
    q = arr(b, h, d, dtype=dtype)
    k, v = arr(b, t, kh, d, dtype=dtype), arr(b, t, kh, d, dtype=dtype)
    lengths = jnp.asarray(RNG.integers(1, t + 1, b), jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, splits=splits, kv_block=64)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 2, 16, 32),
    (1, 256, 8, 16, 1, 32, 64),
])
def test_ssd_scan(b, s, h, p, g, n, chunk):
    x = arr(b, s, h, p)
    dt = jnp.abs(arr(b, s, h)) * 0.1 + 0.01
    A = -jnp.abs(arr(h)) - 0.1
    Bm, Cm = arr(b, s, g, n), arr(b, s, g, n)
    y, fin = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yw, finw = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finw), atol=1e-4)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (algebraic identity)."""
    x = arr(1, 128, 2, 16)
    dt = jnp.abs(arr(1, 128, 2)) * 0.1 + 0.01
    A = -jnp.abs(arr(2)) - 0.1
    Bm, Cm = arr(1, 128, 1, 16), arr(1, 128, 1, 16)
    y32, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    y64, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y64), atol=1e-4)


@pytest.mark.parametrize("b,s,w,chunk,wb", [
    (1, 64, 32, 16, 32),
    (2, 128, 64, 32, 32),
    (1, 256, 128, 64, 128),
])
def test_rglru_scan(b, s, w, chunk, wb):
    a = jax.nn.sigmoid(arr(b, s, w)) * 0.98 + 0.01
    bb = arr(b, s, w)
    h = ops.rglru_scan(a, bb, chunk=chunk, width_block=wb)
    hw = ref.rglru_ref(a, bb)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hw), atol=2e-5,
                               rtol=2e-4)


def test_jnp_ssd_chunked_matches_oracle():
    """The model's ssd_chunked (non-Pallas path) against the sequential ref."""
    from repro.models.mamba2 import ssd_chunked
    x = arr(2, 64, 4, 16)
    dt = jnp.abs(arr(2, 64, 4)) * 0.1 + 0.01
    A = -jnp.abs(arr(4)) - 0.1
    Bm, Cm = arr(2, 64, 2, 8), arr(2, 64, 2, 8)
    y, fin = ssd_chunked(x, dt, A, Bm, Cm, 16, return_final_state=True)
    yw, finw = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finw), atol=1e-4)


def test_chunked_attention_matches_ref():
    """The model's chunked jnp attention against the flash oracle."""
    from repro.models.layers import chunked_attention
    q, k, v = arr(2, 128, 4, 32), arr(2, 128, 2, 32), arr(2, 128, 2, 32)
    out = chunked_attention(q, k, v, q_chunk=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
