"""Per-tenant QoS layer: DRR scalar/vectorized parity (hypothesis),
no-starvation and FIFO-recovery guarantees, platform drain integration,
admission-controller behavior (token buckets, shed / degrade / spillover
/ brownout), the unified ``admit()`` entry point, and the Scenario /
ScenarioRun API compatibility shims."""
import numpy as np
import pytest

from repro.core import (AdmissionRequest, FDNControlPlane, Invocation,
                        QosSpec, profiles, qos_id)
from repro.core import functions
from repro.core.invocation_batch import InvocationBatch
from repro.core.loadgen import ColumnarResultSink, attach_completion_hooks
from repro.core.qos import (N_QOS, QOS_BATCH, QOS_LATENCY_CRITICAL,
                            QOS_STANDARD, AdmissionController, TokenBuckets,
                            drr_commit, drr_drain_scalar, drr_plan)
from repro.core.types import DeploymentSpec

try:                 # hypothesis is an optional test extra; without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # the seeded exhaustive sweeps below still run
    HAVE_HYPOTHESIS = False

    def given(*a, **kw):
        return lambda fn: pytest.mark.skip("hypothesis not installed")(fn)

    def settings(*a, **kw):
        return lambda fn: fn

    class st:        # placeholder strategies so decorators still build
        @staticmethod
        def _none(*a, **kw):
            return None
        integers = lists = tuples = _none

SETTINGS = dict(max_examples=200, deadline=None)


def _vectorized_drain(backlogs, deficits, weights, capacity):
    """Serve order + final deficits via the vectorized plan/commit pair,
    mirroring what ``_drain_qos`` does."""
    b = np.asarray(backlogs, np.int64)
    d = np.asarray(deficits, np.int64)
    w = np.asarray(weights, np.int64)
    plan_cls, plan_rounds = drr_plan(b, d, w, capacity)
    n = min(int(plan_cls.size), int(capacity), int(b.sum()))
    served = np.bincount(plan_cls[:n], minlength=len(b))
    final = drr_commit(d, w, b, served, plan_cls, plan_rounds, n)
    return plan_cls[:n].tolist(), final.tolist()


drr_case = st.tuples(
    st.lists(st.integers(0, 40), min_size=N_QOS, max_size=N_QOS),
    st.lists(st.integers(0, 6), min_size=N_QOS, max_size=N_QOS),
    st.lists(st.integers(1, 9), min_size=N_QOS, max_size=N_QOS),
    st.integers(0, 120),
)


def _assert_drr_parity(backlogs, deficits, weights, capacity):
    # scalar reference never starts with credit on an empty class
    deficits = [d if b else 0 for d, b in zip(deficits, backlogs)]
    ref_order, ref_def = drr_drain_scalar(backlogs, deficits, weights,
                                          capacity)
    vec_order, vec_def = _vectorized_drain(backlogs, deficits, weights,
                                           capacity)
    assert vec_order == ref_order
    assert vec_def == ref_def


@given(drr_case)
@settings(**SETTINGS)
def test_drr_vectorized_matches_scalar(case):
    _assert_drr_parity(*case)


def test_drr_vectorized_matches_scalar_seeded_sweep():
    """Always-on twin of the hypothesis parity test: 2000 seeded random
    (backlogs, deficits, weights, capacity) cases, plus the boundary
    cases the closed-form plan is most likely to get wrong (capacity on
    a quantum edge, zero capacity, one-class-only backlogs)."""
    rng = np.random.default_rng(1234)
    for _ in range(2000):
        backlogs = rng.integers(0, 40, N_QOS).tolist()
        deficits = rng.integers(0, 7, N_QOS).tolist()
        weights = rng.integers(1, 10, N_QOS).tolist()
        capacity = int(rng.integers(0, 121))
        _assert_drr_parity(backlogs, deficits, weights, capacity)
    for cap in range(0, 22):             # quantum-edge capacities
        _assert_drr_parity([10, 10, 10], [0, 0, 0], [4, 2, 1], cap)
        _assert_drr_parity([0, 30, 0], [0, 3, 0], [4, 2, 1], cap)
        _assert_drr_parity([1, 1, 25], [2, 1, 0], [2, 2, 5], cap)


@given(st.lists(st.integers(1, 9), min_size=N_QOS, max_size=N_QOS),
       st.integers(1, 30))
@settings(**SETTINGS)
def test_drr_no_starvation_when_saturated(weights, rounds):
    """With every class backlogged past capacity, class c's share of a
    drain of S rows is within one quantum of w_c/W — no class starves
    however its competitors are weighted."""
    W = sum(weights)
    capacity = rounds * W
    backlogs = [capacity] * N_QOS
    order, _ = drr_drain_scalar(backlogs, [0] * N_QOS, weights, capacity)
    assert len(order) == capacity
    for c, w in enumerate(weights):
        assert order.count(c) >= w * (capacity // W) - w
        assert order.count(c) <= w * (capacity // W) + w


@given(st.lists(st.integers(0, 40), min_size=N_QOS, max_size=N_QOS),
       st.integers(1, 9), st.integers(0, 120))
@settings(**SETTINGS)
def test_drr_uniform_weights_serve_all_classes_evenly(backlogs, w, cap):
    """Equal weights degrade DRR to per-round round-robin: every
    backlogged class is served within one row of every other (until its
    backlog runs out) — the fairness face of FIFO recovery.  The
    *structural* recovery (uniform weights never build per-class queues
    at all) is asserted in test_platform_fifo_recovery_structural."""
    order, _ = drr_drain_scalar(backlogs, [0] * N_QOS, [w] * N_QOS, cap)
    served = [order.count(c) for c in range(N_QOS)]
    expect = min(cap, sum(backlogs))
    assert sum(served) == expect
    for c in range(N_QOS):
        fully_drained = served[c] == backlogs[c]
        for c2 in range(N_QOS):
            if not fully_drained and served[c2] > served[c]:
                assert served[c2] - served[c] <= w


def test_drr_fairness_bounds_seeded_sweep():
    """Always-on twins of the two hypothesis fairness properties."""
    rng = np.random.default_rng(7)
    for _ in range(300):
        weights = rng.integers(1, 10, N_QOS).tolist()
        W = sum(weights)
        capacity = int(rng.integers(1, 31)) * W
        order, _ = drr_drain_scalar([capacity] * N_QOS, [0] * N_QOS,
                                    weights, capacity)
        assert len(order) == capacity
        for c, w in enumerate(weights):
            assert abs(order.count(c) - w * (capacity // W)) <= w
    for _ in range(300):
        backlogs = rng.integers(0, 40, N_QOS).tolist()
        w = int(rng.integers(1, 10))
        cap = int(rng.integers(0, 121))
        order, _ = drr_drain_scalar(backlogs, [0] * N_QOS,
                                    [w] * N_QOS, cap)
        served = [order.count(c) for c in range(N_QOS)]
        assert sum(served) == min(cap, sum(backlogs))
        for c in range(N_QOS):
            if served[c] == backlogs[c]:
                continue
            for c2 in range(N_QOS):
                if served[c2] > served[c]:
                    assert served[c2] - served[c] <= w


# ---------------------------------------------------------------- platform --

def _build_cp(names=("cloud-cluster",), **cp_kw):
    cp = FDNControlPlane(**cp_kw)
    for n in names:
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = {k: f.replace(real_fn=None)
           for k, f in functions.paper_functions().items()}
    functions.seed_object_stores(cp.placement, location=names[0])
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    return cp, fns


def test_platform_drain_matches_scalar_reference():
    """A backlogged DRR platform serves per-class counts and commits
    deficits exactly as the scalar oracle with capacity = rows served."""
    spec = QosSpec(weights=(4, 2, 1))
    cp, fns = _build_cp()
    cp.attach_qos(spec)
    p = cp.platforms["cloud-cluster"]
    fn = fns["nodeinfo"]
    backlogs = (11, 7, 9)
    invs = []
    for c, n in enumerate(backlogs):
        for _ in range(n):
            invs.append(Invocation(fn, 0.0, qos=c))
    accepted = cp.submit_batch(invs)     # enqueues AND drains once
    assert accepted == sum(backlogs)
    served = [b - int(r) for b, r in zip(backlogs, p._crows)]
    n_served = sum(served)
    assert 0 < n_served < sum(backlogs)  # finite replicas: partial drain
    ref_order, ref_def = drr_drain_scalar(backlogs, [0] * N_QOS,
                                          spec.weights, n_served)
    assert served == [ref_order.count(c) for c in range(N_QOS)]
    assert [int(x) for x in p._deficit] == ref_def


def test_platform_fifo_recovery_structural():
    """Uniform weights never build per-class queues: every enqueue and
    drain stays on the single-FIFO fast path, so qos-off behavior (and
    its goldens) is recovered exactly, not approximately."""
    cp, fns = _build_cp()
    p = cp.platforms["cloud-cluster"]
    cp.attach_qos(QosSpec(weights=(1, 1, 1)))
    assert p._cqueues is None and p._deficit is None
    cp.attach_qos(QosSpec(weights=(5, 5, 5)))
    assert p._cqueues is None
    cp.attach_qos(QosSpec(weights=(4, 2, 1)))
    assert p._cqueues is not None and len(p._cqueues) == N_QOS


def test_platform_fail_flushes_class_queues():
    cp, fns = _build_cp()
    cp.attach_qos(QosSpec(weights=(4, 2, 1)))
    p = cp.platforms["cloud-cluster"]
    invs = [Invocation(fns["nodeinfo"], 0.0, qos=c % 3) for c in range(30)]
    cp.submit_batch(invs)
    assert int(p._crows.sum()) > 0 or p.queued_rows >= 0
    p.fail()
    assert int(p._crows.sum()) == 0
    assert all(not q for q in p._cqueues)
    p.recover()
    assert int(p._deficit.sum()) == 0


# ------------------------------------------------------- token buckets -----

def test_token_buckets_rate_and_burst():
    tb = TokenBuckets([10.0, None, 1.0], [5.0, 5.0, 2.0])
    got = tb.take(np.array([8, 8, 8]), now=0.0)
    # burst capacity bounds the initial grab; unlimited class passes all
    assert got.tolist() == [5, 8, 2]
    got = tb.take(np.array([8, 8, 8]), now=1.0)       # 1 s of refill
    assert got.tolist() == [5, 8, 1]
    got = tb.take(np.array([8, 0, 8]), now=1.0)       # no time, no tokens
    assert got.tolist() == [0, 0, 0]


def test_admission_token_bucket_sheds_tail_rows():
    cp, fns = _build_cp()
    adm = cp.attach_qos(QosSpec(rate_limits=(None, None, 2.0),
                                burst=(8.0, 8.0, 2.0)))
    fn = fns["nodeinfo"]
    invs = [Invocation(fn, 0.0, qos=QOS_BATCH, tenant=7)
            for _ in range(6)] + [Invocation(fn, 0.0)]
    accepted = cp.submit_batch(invs)
    assert accepted == 3                  # 2 batch tokens + 1 standard
    assert int(adm.token_shed[QOS_BATCH]) == 4
    assert adm.shed_by_tenant == {7: 4}
    assert cp.rejected_count == 4


# ------------------------------------------------ overload + brownout ------

def _columnar_burst(fn, qos, tenant=None):
    n = len(qos)
    return InvocationBatch([fn], np.zeros(n, np.int32), np.zeros(n),
                           qos=np.asarray(qos, np.int8),
                           tenant=tenant)


def _flood(cp, fn, rows=600):
    """Push the aggregate queue depth past any shed threshold."""
    cp._admit_objects([Invocation(fn, 0.0) for _ in range(rows)])


def test_overload_shed_drops_batch_then_standard():
    cp, fns = _build_cp()
    adm = cp.attach_qos(QosSpec(shed_queue_depth=50, shed_hard_factor=4.0))
    fn = fns["nodeinfo"]
    _flood(cp, fn, 100)                   # over soft, under hard (200)
    b = _columnar_burst(fn, [0, 1, 2, 2])
    accepted = cp.submit_batch(b)
    assert accepted == 2                  # batch shed, lc + standard kept
    assert int(adm.overload_shed[QOS_BATCH]) == 2
    assert int(adm.overload_shed[QOS_STANDARD]) == 0
    _flood(cp, fn, 200)                   # past hard threshold
    b = _columnar_burst(fn, [0, 1, 2])
    assert cp.submit_batch(b) == 1        # only latency_critical survives
    assert int(adm.overload_shed[QOS_STANDARD]) == 1
    assert int(adm.overload_shed[QOS_LATENCY_CRITICAL]) == 0


def test_overload_degrade_demotes_standard_in_place():
    cp, fns = _build_cp()
    adm = cp.attach_qos(QosSpec(shed_queue_depth=50,
                                overload_action="degrade"))
    fn = fns["nodeinfo"]
    _flood(cp, fn, 100)
    b = _columnar_burst(fn, [1, 1, 0])
    accepted = cp.submit_batch(b)
    assert accepted == 3                  # nothing dropped
    assert adm.degraded == 2
    assert b.qos.tolist() == [QOS_BATCH, QOS_BATCH, QOS_LATENCY_CRITICAL]


def test_overload_spillover_routes_to_least_loaded():
    cp, fns = _build_cp(("cloud-cluster", "edge-cluster"))
    adm = cp.attach_qos(QosSpec(shed_queue_depth=50,
                                overload_action="spillover"))
    fn = fns["nodeinfo"]
    # pile all load on cloud-cluster so edge is the obvious spill target
    for _ in range(4):
        cp._admit_objects([Invocation(fn, 0.0) for _ in range(50)],
                          platform_override="cloud-cluster")
    edge_before = cp.platforms["edge-cluster"].queued_rows + \
        cp.platforms["edge-cluster"].busy_replicas()
    b = _columnar_burst(fn, [2] * 10 + [0])
    accepted = cp.submit_batch(b)
    assert accepted == 11                 # spilled rows still admitted
    assert adm.spilled == 10
    edge_after = cp.platforms["edge-cluster"].queued_rows + \
        cp.platforms["edge-cluster"].busy_replicas()
    assert edge_after >= edge_before + 10
    assert cp.rejected_count == 0


def test_spillover_respects_data_gravity():
    """The spill-target score is transfer seconds + normalized load, so
    a platform already holding the spilled functions' hot objects beats
    a less-loaded one that would pull every byte over a slow WAN — and
    data-free functions still spill pure least-loaded."""
    cp, fns = _build_cp(("cloud-cluster", "edge-cluster"))
    adm = cp.attach_qos(QosSpec(shed_queue_depth=50,
                                overload_action="spillover"))
    # the sample objects are seeded on cloud-cluster; make the WAN link
    # to edge slow enough that staging 2 MB per invocation dwarfs a
    # real (multiple-rows) load gap
    cp.placement.set_bandwidth("cloud-cluster", "edge-cluster", 2e5)
    hot = fns["image-processing"]

    def load(name):
        p = cp.platforms[name]
        return (p.queued_rows + p.busy_replicas()) / \
            max(p.prof.total_replicas, 1)

    # pile load on cloud-cluster past the shed threshold: it is now
    # both overloaded and clearly the MORE loaded platform
    cp._admit_objects([Invocation(fns["nodeinfo"], 0.0)
                       for _ in range(70)],
                      platform_override="cloud-cluster")
    assert load("cloud-cluster") > load("edge-cluster") + 1.0
    # ...yet gravity still pins the hot-data function's spill there,
    # while the data-free function spills least-loaded as before
    assert adm._spill_target(cp, [(hot, 1)]) == "cloud-cluster"
    assert adm._spill_target(cp, [(fns["nodeinfo"], 1)]) == "edge-cluster"
    # end to end: overloaded standard rows of the hot function land on
    # the platform that holds their data
    cloud_before = cp.platforms["cloud-cluster"].queued_rows + \
        cp.platforms["cloud-cluster"].busy_replicas()
    b = _columnar_burst(hot, [2] * 8)
    assert cp.submit_batch(b) == 8
    assert adm.spilled == 8
    cloud_after = cp.platforms["cloud-cluster"].queued_rows + \
        cp.platforms["cloud-cluster"].busy_replicas()
    assert cloud_after >= cloud_before + 8
    assert cp.rejected_count == 0


def test_brownout_sheds_batch_on_energy_cap():
    cp, fns = _build_cp()
    # idle power of cloud-cluster alone exceeds a 1 W cap: brownout is on
    adm = cp.attach_qos(QosSpec(energy_cap_w=1.0))
    fn = fns["nodeinfo"]
    b = _columnar_burst(fn, [0, 1, 2, 2], tenant=[1, 1, 9, 9])
    accepted = cp.submit_batch(b)
    assert accepted == 2
    assert int(adm.brownout_shed[QOS_BATCH]) == 2
    assert adm.brownout_events == 1
    assert adm.shed_by_tenant == {9: 2}
    sec = adm.section()
    assert sec["shed_total"] == 2
    assert sec["shed_by_class"]["batch"] == 2
    assert sec["brownout_events"] == 1


def test_gate_objects_matches_gate_columns_counters():
    """The object-path gate twin sheds the same rows for the same load."""
    fn = None
    results = {}
    for mode in ("columns", "objects"):
        cp, fns = _build_cp()
        adm = cp.attach_qos(QosSpec(rate_limits=(None, 3.0, 1.0),
                                    burst=(8.0, 3.0, 1.0)))
        fn = fns["nodeinfo"]
        qos = [0, 1, 1, 1, 1, 2, 2]
        if mode == "columns":
            cp.submit_batch(_columnar_burst(fn, qos))
        else:
            cp.submit_batch([Invocation(fn, 0.0, qos=c) for c in qos])
        results[mode] = (adm.token_shed.tolist(), cp.rejected_count)
    assert results["columns"] == results["objects"]


# --------------------------------------------------- unified admission -----

def test_admit_request_is_the_single_entry_point():
    cp, fns = _build_cp()
    fn = fns["nodeinfo"]
    assert cp.admit(AdmissionRequest((Invocation(fn, 0.0),))) == 1
    assert cp.admit(AdmissionRequest(
        [Invocation(fn, 0.0), Invocation(fn, 0.0)])) == 2
    b = _columnar_burst(fn, [1, 1, 1])
    assert cp.admit(AdmissionRequest(b)) == 3
    assert cp.admit(AdmissionRequest(())) == 0
    # deprecated shims route through admit() and agree with it
    assert cp.submit(Invocation(fn, 0.0)) is True
    assert cp.submit_batch([Invocation(fn, 0.0)]) == 1


def test_admit_gates_every_legacy_entry_point():
    cp, fns = _build_cp()
    cp.attach_qos(QosSpec(rate_limits=(None, None, 0.0),
                          burst=(1.0, 1.0, 0.0)))
    fn = fns["nodeinfo"]
    assert cp.submit(Invocation(fn, 0.0, qos=QOS_BATCH)) is False
    assert cp.submit_batch([Invocation(fn, 0.0, qos=QOS_BATCH)]) == 0
    assert cp.submit_batch(_columnar_burst(fn, [2, 2])) == 0
    assert cp.rejected_count == 4


# ------------------------------------------------------- scenario API ------

def test_scenario_run_tuple_compat():
    from repro.inspector import registry
    from repro.inspector.scenario import ScenarioRun, run_scenario_state
    run = run_scenario_state(registry.get("smoke/tiny"))
    assert isinstance(run, ScenarioRun)
    report, cp, sink = run                 # legacy unpack
    assert run[0] is report is run.report
    assert run[1] is cp is run.control_plane
    assert run[2] is sink is run.sink
    assert len(run) == 3
    assert run.telemetry is None and run.recorder is None


def test_scenario_typed_subspecs_match_flat_fields():
    from repro.inspector.scenario import (AutoscaleSpec, Scenario,
                                          TracingSpec, Workload)
    wl = (Workload("nodeinfo", arrival={"kind": "poisson", "rps": 5.0}),)
    base = dict(name="t", platforms=("cloud-cluster",), workloads=wl,
                duration_s=1.0)
    flat = Scenario(trace=True, trace_sample=0.5,
                    autoscale={"policy": "ttl", "tick_s": 2.0,
                               "policy_kwargs": {"ttl_s": 30.0}}, **base)
    typed = Scenario(tracing=TracingSpec(enabled=True, sample=0.5),
                     autoscaling=AutoscaleSpec(
                         policy="ttl", tick_s=2.0,
                         policy_kwargs={"ttl_s": 30.0}), **base)
    assert flat.to_dict() == typed.to_dict()
    # QosSpec objects normalize to their dict form in the echo
    q = Scenario(qos=QosSpec(weights=(4, 2, 1)), **base)
    assert q.to_dict()["qos"] == QosSpec(weights=(4, 2, 1)).to_dict()
    assert q.qos_spec() == QosSpec(weights=(4, 2, 1))
    assert q.replace(duration_s=2.0).qos == q.qos


def test_qos_uniform_spec_keeps_report_metrics_identical():
    """A QoS spec with uniform weights and no shedding is a pure
    observer: every metric section matches the qos-less run exactly
    (the report only gains the qos section)."""
    from repro.inspector import registry, run_scenario
    sc = registry.get("smoke/tiny")
    base = run_scenario(sc).to_dict()
    spec = QosSpec(weights=(1, 1, 1), slo_multipliers=(1.0, 1.0, 1.0))
    wq = run_scenario(sc.replace(qos=spec)).to_dict()
    for section in ("totals", "per_platform", "per_function"):
        assert base[section] == wq[section]
    assert base["qos"] == {}
    assert wq["qos"]["fairness"]["drr_enabled"] is False
    assert wq["qos"]["admission"]["shed_total"] == 0


def test_qos_spec_validation():
    with pytest.raises(ValueError):
        QosSpec(weights=(4, 2))
    with pytest.raises(ValueError):
        QosSpec(weights=(4, 0.5, 1))
    with pytest.raises(ValueError):
        QosSpec(overload_action="explode")
    with pytest.raises(ValueError):
        qos_id("gold")
    with pytest.raises(ValueError):
        qos_id(7)
    assert qos_id("batch") == QOS_BATCH == qos_id(2)
    rt = QosSpec.from_dict(QosSpec(weights=(9, 3, 1),
                                   rate_limits=(None, 5.0, 1.0)).to_dict())
    assert rt.weights == (9, 3, 1) and rt.rate_limits == (None, 5.0, 1.0)


def test_qos_columns_flow_to_sink():
    cp, fns = _build_cp()
    sink = ColumnarResultSink().install(cp)
    fn = fns["nodeinfo"]
    cp.submit_batch(_columnar_burst(fn, [0, 2], tenant=[4, 5]))
    cp.clock.run_until(30.0)
    cols = sink.completion_columns()
    assert sorted(cols["qos"].tolist()) == [0, 2]
    assert sorted(cols["tenant"].tolist()) == [4, 5]
