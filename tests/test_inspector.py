"""FDNInspector scenario subsystem: report determinism (byte-identical
JSON), parity with the hand-wired benchmark harness, the columnar metrics
pipeline, fault schedules, and the scenario registry."""
import json

import numpy as np
import pytest

from repro.core import (FDNControlPlane, Gateway, Invocation,
                        MetricsRegistry)
from repro.core import functions as fn_mod
from repro.core import profiles as prof_mod
from repro.core.loadgen import (ColumnarResultSink, attach_completion_hooks,
                                run_load, run_open_loop)
from repro.core.monitoring import (ColumnarWindowSeries, WindowSeries,
                                   percentile, percentile_unsorted)
from repro.core.types import DeploymentSpec, FunctionSpec
from repro.inspector import (FaultEvent, Scenario, ScenarioReport,
                             Workload, registry, run_scenario)

PAIR = ("hpc-node-cluster", "cloud-cluster")


def tiny_scenario(**kw):
    base = dict(
        name="test/tiny",
        platforms=PAIR,
        workloads=(Workload("nodeinfo",
                            arrival={"kind": "poisson", "rps": 25.0}),
                   Workload("JSON-loads", mode="closed", vus=3,
                            sleep_s=0.05)),
        duration_s=8.0, drain_s=20.0)
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------- report --

def test_report_byte_identical_and_valid():
    a = run_scenario(tiny_scenario())
    b = run_scenario(tiny_scenario())
    ja, jb = a.to_json(), b.to_json()
    assert ja == jb
    ScenarioReport.validate(json.loads(ja))
    assert a.totals["completed"] > 0
    assert a.totals["submitted"] >= a.totals["completed"]


def test_report_sections_consistent():
    rep = run_scenario(tiny_scenario())
    per_p = sum(s["completed"] for s in rep.per_platform.values())
    per_f = sum(s["completed"] for s in rep.per_function.values())
    assert per_p == per_f == rep.totals["completed"]
    assert set(rep.per_platform) == set(PAIR)
    for s in rep.per_function.values():
        assert 0.0 <= s["slo_violation_rate"] <= 1.0
    assert rep.totals["energy_wh"] == pytest.approx(
        sum(s["energy_wh"] for s in rep.per_platform.values()))


def test_seed_changes_report():
    a = run_scenario(tiny_scenario())
    b = run_scenario(tiny_scenario(seed=43))
    assert a.to_json() != b.to_json()


def test_validate_rejects_drift():
    rep = run_scenario(registry.get("smoke/tiny"))
    d = json.loads(rep.to_json())
    ScenarioReport.validate(d)
    bad = dict(d, schema_version=99)
    with pytest.raises(ValueError):
        ScenarioReport.validate(bad)
    bad = {k: v for k, v in d.items() if k != "per_function"}
    with pytest.raises(ValueError):
        ScenarioReport.validate(bad)


# -------------------------------------------------------------- registry --

def test_registry_lists_and_builds():
    names = registry.names()
    assert len(names) >= 10
    sc = registry.get("mix/five-platform")
    assert isinstance(sc, Scenario) and len(sc.workloads) == 5
    with pytest.raises(KeyError):
        registry.get("does/not-exist")


def test_registry_builders_are_fresh():
    assert registry.get("smoke/tiny") == registry.get("smoke/tiny")


# ---------------------------------------------------- hand-wired parity ---

def _hand_wired_fdn(data_location="cloud-cluster"):
    """The pre-inspector benchmark harness, verbatim (fdn_common.build_fdn
    semantics with analytic functions)."""
    cp = FDNControlPlane()
    for name in prof_mod.PAPER_PLATFORMS:
        cp.create_platform(prof_mod.PAPER_PLATFORMS[name])
    fns = {k: f.replace(real_fn=None)
           for k, f in fn_mod.paper_functions().items()}
    fn_mod.seed_object_stores(cp.placement, location=data_location)
    cp.placement.add_store("gcp-us-east")
    fn_mod.seed_object_stores(cp.placement, location="gcp-us-east")
    for name in cp.platforms:
        cp.placement.set_bandwidth(name, "gcp-us-east", 2e6)
    cp.deploy(DeploymentSpec("hand", list(fns.values()),
                             list(cp.platforms)))
    attach_completion_hooks(cp)
    return cp, fns


def test_fig5_cell_matches_hand_wired_closed_loop():
    """A fig5 cell through the scenario runner must equal the hand-wired
    run_load drive exactly (same seeds, same clock, same decisions)."""
    duration, vus, pname = 30.0, 10, "hpc-node-cluster"
    cp, fns = _hand_wired_fdn()
    res = run_load(cp.clock,
                   lambda inv: cp.submit(inv, platform_override=pname),
                   fns["nodeinfo"], vus, duration, sleep_s=0.05, seed=42)
    comp = res.completed

    rep = run_scenario(registry.fig5_cell(pname, vus, duration,
                                          analytic=True))
    stats = rep.per_platform[pname]
    assert stats["completed"] == len(comp)
    assert stats["p90_s"] == pytest.approx(res.p90_response(), rel=1e-12)
    want_mean = sum(i.response_time for i in comp) / len(comp)
    assert stats["mean_s"] == pytest.approx(want_mean, rel=1e-12)


def test_table4_cell_matches_hand_wired_open_loop():
    """The table4 energy cell must reproduce the hand-wired run_open_loop
    numbers (served load, P90, energy) within tight tolerance."""
    duration, rps, pname = 60.0, 20.0, "edge-cluster"
    cp, fns = _hand_wired_fdn(data_location=pname)
    res = run_open_loop(cp.clock,
                        lambda inv: cp.submit(inv, platform_override=pname),
                        fns["JSON-loads"], rps, duration)
    cp.run_until(cp.clock.now())
    joules = cp.energy.joules(pname)

    rep = run_scenario(registry.table4_cell(pname, duration, rps,
                                            analytic=True))
    stats = rep.per_platform[pname]
    assert stats["completed"] == len(res.completed)
    assert stats["p90_s"] == pytest.approx(res.p90_response(), rel=1e-9)
    assert stats["energy_j"] == pytest.approx(joules, rel=0.02)


# ------------------------------------------------- columnar metrics path --

def _random_samples(n=4000, seed=3):
    rng = np.random.default_rng(seed)
    ts = rng.uniform(0.0, 200.0, n)
    vs = rng.exponential(0.5, n)
    return ts, vs


def test_columnar_window_series_matches_window_series():
    ts, vs = _random_samples()
    ws, cw = WindowSeries(10.0), ColumnarWindowSeries(10.0)
    for t, v in zip(ts[:100], vs[:100]):      # scalar path
        ws.add(t, v)
        cw.add(t, v)
    ws.add_many(ts[100:], vs[100:])           # bulk path
    cw.add_many(ts[100:], vs[100:])
    assert cw.count() == ws.count()
    assert cw.total() == pytest.approx(ws.total())
    assert cw.windows() == ws.windows()
    assert cw.p90() == pytest.approx(ws.p90())
    for agg in ("sum", "mean", "count", "p90"):
        a, b = cw.series(agg), ws.series(agg)
        assert len(a) == len(b)
        for (t1, v1), (t2, v2) in zip(a, b):
            assert t1 == t2 and v1 == pytest.approx(v2)
    assert sorted(cw.all_values()) == pytest.approx(
        sorted(ws.all_values()))


def test_percentile_unsorted_matches_percentile():
    rng = np.random.default_rng(1)
    for n in (1, 2, 7, 100):
        vals = rng.normal(size=n)
        for q in (0.0, 0.5, 0.9, 0.99, 1.0):
            assert percentile_unsorted(vals, q) == pytest.approx(
                percentile(np.sort(vals), q), abs=1e-12)
    assert np.isnan(percentile_unsorted(np.empty(0), 0.9))


def test_record_completions_matches_per_sample_record_completion():
    """Bulk sink ingest must produce the same registry state as the old
    per-invocation record_completion loop."""
    fns = [FunctionSpec(name="f1", flops=1e6, memory_mb=128),
           FunctionSpec(name="f2", flops=1e7, read_bytes=5e4,
                        memory_mb=256)]
    rng = np.random.default_rng(5)
    n = 500
    plat_names = ["pA", "pB"]
    sink = ColumnarResultSink()
    per_sample = MetricsRegistry(columnar=False)
    for i in range(n):
        inv = Invocation(fns[int(rng.integers(0, 2))],
                         float(rng.uniform(0, 100)))
        inv.platform = plat_names[int(rng.integers(0, 2))]
        inv.end_t = inv.arrival_t + float(rng.exponential(0.3))
        inv.exec_time = float(rng.uniform(0.01, 0.2))
        inv.cold_start = bool(rng.random() < 0.1)
        inv.status = "done"
        sink.record_completion(inv)
        per_sample.record_completion(inv, visible_infra=inv.platform ==
                                     "pA")
    bulk = MetricsRegistry()
    bulk.record_completions(sink, visible_infra={"pA": True, "pB": False})
    for p in plat_names:
        for f in ("f1", "f2"):
            for m in ("requests", "invocations", "cold_starts",
                      "exec_time", "memory_mb", "disk_io",
                      "response_time"):
                assert bulk.total(p, f, m) == pytest.approx(
                    per_sample.total(p, f, m)), (p, f, m)
        assert bulk.p90_response(p) == pytest.approx(
            per_sample.p90_response(p))
        assert bulk.requests_served(p) == per_sample.requests_served(p)


def test_deferred_metrics_report_equals_inline():
    """defer_metrics=True (bulk ingest at end of run) must not change the
    report relative to inline per-completion recording."""
    a = run_scenario(tiny_scenario())
    b = run_scenario(tiny_scenario(defer_metrics=False))
    da, db = json.loads(a.to_json()), json.loads(b.to_json())
    del da["scenario"], db["scenario"]        # spec differs by the flag
    assert da == db


def test_no_per_invocation_retention_on_hot_path():
    """With retain_objects=False (the default) the only per-invocation
    survivors of a run are the sink's NumPy columns: no completed-
    Invocation list, no knowledge-base decision rows — counters only."""
    from repro.inspector.scenario import assemble
    from repro.core.loadgen import run_arrivals, poisson_arrivals

    sc = tiny_scenario(workloads=(
        Workload("nodeinfo", arrival={"kind": "poisson", "rps": 30.0}),))
    cp, gw, fns, sink = assemble(sc)
    run_arrivals(cp.clock, gw.request_batch, fns["nodeinfo"],
                 poisson_arrivals(30.0, 8.0, seed=42), sink=sink)
    assert sink.completed > 0
    assert cp.completed == [] and cp.completed_count == sink.completed
    assert cp.rejected == [] and cp.rejected_count == 0
    assert cp.kb.decisions == []
    assert cp.kb.decision_count == sink.completed
    # registry series are NumPy-backed, not per-window Python lists
    for ws in cp.metrics._m.values():
        assert not hasattr(ws, "values")


# ----------------------------------------------------- faults & overrides -

def test_fault_schedule_survives_outage():
    rep = run_scenario(registry.get("faults/hpc-outage").replace(
        duration_s=60.0,
        faults=(FaultEvent(20.0, "hpc-node-cluster", "fail"),)))
    # the outage loses in-flight work but the FDN keeps serving
    assert rep.totals["completed"] > 0
    assert rep.per_platform["cloud-cluster"]["completed"] > 0
    # hpc took traffic before failing, then stopped
    assert rep.per_platform["hpc-node-cluster"]["completed"] > 0


def test_slo_override_applies():
    rep = run_scenario(tiny_scenario(
        slo_overrides={"nodeinfo": 0.001}))
    f = rep.per_function["nodeinfo"]
    assert f["slo_s"] == 0.001
    assert f["slo_violation_rate"] > 0.5


def test_platform_override_routes_exclusively():
    rep = run_scenario(tiny_scenario(
        platform_override="cloud-cluster",
        workloads=(Workload("nodeinfo",
                            arrival={"kind": "poisson", "rps": 10.0}),)))
    assert rep.per_platform["cloud-cluster"]["completed"] == \
        rep.totals["completed"] > 0
    assert rep.per_platform["hpc-node-cluster"]["completed"] == 0
