"""Threshold Tuning (§3.6) and function composition (§6.3)."""
import pytest

from repro.core import (FDNControlPlane, Gateway, SLOCompositePolicy)
from repro.core import functions as fn_mod
from repro.core import profiles
from repro.core.behavioral import InteractionModel
from repro.core.loadgen import attach_completion_hooks, run_load
from repro.core.tuning import (ThresholdTuner, compose_functions,
                               composition_plan)
from repro.core.types import DeploymentSpec, FunctionSpec, SLO


def _evaluate(thresholds):
    """One short FDNInspector replay; score = SLO-met fraction."""
    cp = FDNControlPlane()
    for n in ("hpc-node-cluster", "cloud-cluster", "edge-cluster"):
        cp.create_platform(profiles.PAPER_PLATFORMS[n])
    fns = fn_mod.paper_functions()
    fn_mod.seed_object_stores(cp.placement, location="hpc-node-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    cp.policy = SLOCompositePolicy(cp.perf, cp.placement, **thresholds)
    gw = Gateway(cp)
    res = run_load(cp.clock, lambda i: gw.request(i),
                   fns["primes-python"], vus=10, duration_s=20.0,
                   sleep_s=0.1)
    done = res.completed
    if not done:
        return 0.0
    met = sum(1 for i in done
              if i.response_time <= i.fn.slo.p90_response_s)
    return met / len(done)


def test_threshold_tuner_finds_best_setting():
    tuner = ThresholdTuner(grid={"cpu_threshold": (0.5, 0.9),
                                 "energy_weight": (0.0, 0.5)})
    result = tuner.tune(_evaluate)
    assert len(result.trials) == 4
    assert result.best in [t[0] for t in result.trials]
    assert result.score == max(s for _, s in result.trials)
    assert 0.0 <= result.score <= 1.0


def test_compose_functions_removes_internal_io():
    a = FunctionSpec(name="a", flops=1e6, read_bytes=100.0,
                     write_bytes=500.0, memory_mb=128, slo=SLO(5.0))
    b = FunctionSpec(name="b", flops=2e6, read_bytes=500.0,
                     write_bytes=50.0, memory_mb=256, slo=SLO(3.0))
    c = compose_functions(a, b)
    assert c.name == "a+b"
    assert c.flops == 3e6
    assert c.read_bytes == 100.0          # b's read of a's output is free
    assert c.write_bytes == 50.0
    assert c.memory_mb == 256
    assert c.slo.p90_response_s == 3.0


def test_composition_plan_from_interaction_model():
    im = InteractionModel(window_s=1.0)
    t = 0.0
    for _ in range(12):
        im.record("a", t)
        im.record("b", t + 0.1)
        t += 10.0
    fns = {"a": FunctionSpec(name="a"), "b": FunctionSpec(name="b")}
    plan = composition_plan(im, fns, min_count=10)
    assert [f.name for f in plan] == ["a+b"]
