"""Arrival forecasting for the warm-pool controller (repro.autoscale).

State is *columnar*: one row per managed (function, platform) pair, all
rows advanced together by one fused array pass per controller tick —
Holt-linear (EWMA level + trend) smoothing of per-tick arrival counts,
plus a log2-bucketed inter-arrival-gap histogram that turns observed
burstiness into an adaptive keep-alive TTL.  From those the predictive
prewarmer derives, per row,

  * ``desired`` — warm replicas to hold ready: Little's-law demand
    ``forecast rate x predicted exec seconds`` with head-room, ceil'd;
  * ``ttl``     — how long an idle replica stays warm: the gap histogram's
    ``quantile`` (next power-of-two ticks), i.e. "keep alive while the
    next arrival is probably closer than that".

NumPy is the reference backend (float64 host arrays); a ``jax.jit``
compiled mirror lives in ``repro.kernels.warm_forecast`` following the
``policy_score`` pattern — NumPy stays the fallback and the parity
oracle (tests pin byte-identical prewarm decisions from both backends),
so the backend choice is a throughput knob, not a semantic one.  ``auto``
uses NumPy below ``JAX_FORECAST_MIN`` rows (tiny states are dominated by
dispatch overhead) and jax above it (pod-scale registries).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

# Minimum row count at which "auto" switches to the jitted tick.
JAX_FORECAST_MIN = 256

_FORECAST_BACKEND = os.environ.get("FDN_FORECAST_BACKEND", "auto")


def set_forecast_backend(mode: str) -> None:
    """Select the forecaster backend: "numpy", "jax", or "auto"."""
    if mode not in ("numpy", "jax", "auto"):
        raise ValueError(f"unknown forecast backend {mode!r}")
    global _FORECAST_BACKEND
    _FORECAST_BACKEND = mode


def get_forecast_backend() -> str:
    return _FORECAST_BACKEND


_wf_mod = None
_wf_error: Optional[BaseException] = None


def _warm_forecast_mod():
    """The jitted forecast module, or None when jax is unavailable."""
    global _wf_mod, _wf_error
    if _wf_mod is None and _wf_error is None:
        try:
            from repro.kernels import warm_forecast as mod
            _wf_mod = mod
        except Exception as exc:           # missing/incompatible jax
            _wf_error = exc
    return _wf_mod


def _use_jax(n_rows: int, override: Optional[str]) -> bool:
    mode = override or _FORECAST_BACKEND
    if mode == "numpy":
        return False
    if mode == "auto" and n_rows < JAX_FORECAST_MIN:
        return False
    if _warm_forecast_mod() is None:
        if mode == "jax":
            raise RuntimeError(
                "forecast backend 'jax' requested but the jitted tick is "
                "unavailable") from _wf_error
        return False
    return True


@dataclass(frozen=True)
class ForecastParams:
    """Knobs of the predictive prewarmer (all rows share one set)."""
    alpha: float = 0.5          # Holt level smoothing
    beta: float = 0.3           # Holt trend smoothing
    headroom: float = 2.0       # demand safety multiplier (Poisson bursts)
    quantile: float = 0.9       # gap-histogram keep-alive quantile
    n_buckets: int = 12         # log2 gap buckets (ticks)
    min_demand: float = 0.05    # demand below this rounds to zero pool
    max_pool: int = 16          # per-row prewarm cap
    # hold at least one replica warm while the forecast rate says an
    # arrival is coming soon (>= hold_min_rps): for fast functions the
    # Little's-law demand rounds to zero even under steady traffic, but a
    # cold start would still hit every post-TTL arrival
    hold_min_rps: float = 0.05
    default_ttl_ticks: float = 30.0   # before the histogram has data
    min_ttl_ticks: float = 25.0       # keep-alive floor: surplus replicas
                                      # outlive short Poisson lulls
    max_ttl_ticks: float = 900.0
    min_gap_obs: int = 3        # histogram observations before trusting it


class ForecastState:
    """Growable columnar state: one row per (function, platform)."""

    __slots__ = ("level", "trend", "idle_ticks", "hist", "n")

    def __init__(self, n_buckets: int):
        self.n = 0
        self.level = np.zeros(0)
        self.trend = np.zeros(0)
        self.idle_ticks = np.zeros(0)
        self.hist = np.zeros((0, n_buckets))

    def resize(self, n: int) -> None:
        if n <= self.n:
            return
        grow = n - self.n
        self.level = np.concatenate([self.level, np.zeros(grow)])
        self.trend = np.concatenate([self.trend, np.zeros(grow)])
        self.idle_ticks = np.concatenate([self.idle_ticks, np.zeros(grow)])
        self.hist = np.concatenate(
            [self.hist, np.zeros((grow, self.hist.shape[1]))])
        self.n = n


def holt_zero_matrix(alpha: float, beta: float,
                     k: int) -> Tuple[float, float, float, float]:
    """``M^k`` for the Holt zero-observation step ``[l, t] <- M [l, t]``
    with ``M = [[1-a, 1-a], [-a*b, 1-a*b]]`` — the closed form that lets
    a run of ``k`` arrival-free ticks be applied in one vectorized pass
    (binary exponentiation over Python floats: deterministic).

    Policies use this to go *dormant* while no arrivals flow: cached
    decisions are returned instantly and the decayed state is caught up
    exactly when traffic resumes."""
    m = (1.0 - alpha, 1.0 - alpha, -alpha * beta, 1.0 - alpha * beta)
    r = (1.0, 0.0, 0.0, 1.0)
    while k:
        if k & 1:
            r = (r[0] * m[0] + r[1] * m[2], r[0] * m[1] + r[1] * m[3],
                 r[2] * m[0] + r[3] * m[2], r[2] * m[1] + r[3] * m[3])
        m = (m[0] * m[0] + m[1] * m[2], m[0] * m[1] + m[1] * m[3],
             m[2] * m[0] + m[3] * m[2], m[2] * m[1] + m[3] * m[3])
        k >>= 1
    return r


def ttl_from_hist(hist: np.ndarray, p: ForecastParams) -> np.ndarray:
    """Per-row keep-alive TTL in ticks: the next power of two above the
    gap histogram's ``quantile``; rows with too few observed gaps fall
    back to the default TTL."""
    total = hist.sum(axis=1)
    cum = np.cumsum(hist, axis=1)
    need = p.quantile * total
    b = np.argmax(cum >= need[:, None], axis=1)
    ttl = np.exp2(b + 1.0)
    ttl = np.where(total >= p.min_gap_obs, ttl, p.default_ttl_ticks)
    return np.clip(ttl, p.min_ttl_ticks, p.max_ttl_ticks)


def predictive_tick_numpy(state: ForecastState, counts: np.ndarray,
                          coeff: np.ndarray, p: ForecastParams,
                          has_arrivals: bool,
                          desired_out: np.ndarray,
                          scratch: np.ndarray,
                          ttl_cache: np.ndarray,
                          hold_buf: np.ndarray,
                          hold_thr: float = 0.0
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """One fused forecaster tick over all rows (reference backend).

    ``coeff`` is the precomputed ``exec_s * headroom / tick_s`` column, so
    ``demand = max(level + trend, 0) * coeff``; ``hold_thr`` is
    ``hold_min_rps * tick_s`` (the warm-floor threshold in forecast
    counts-per-tick units).  Zero-arrival ticks take
    the identical formulas (counts == 0 just decays level/trend and ages
    the idle counters); only the histogram/TTL work — a pure function of
    arrivals — is skipped, so the fast path is an optimization, not a
    semantic fork.  Everything is in-place over caller-owned buffers: the
    controller tick makes no allocations in steady state."""
    level, trend = state.level, state.trend
    pred = scratch
    # Holt: new_level = pred + a*err, new_trend = trend + a*b*err
    np.add(level, trend, out=pred)
    if has_arrivals:
        err = counts - pred
        np.add(pred, p.alpha * err, out=level)
        trend += (p.alpha * p.beta) * err
        # close inter-arrival gaps into the histogram
        gap_rows = np.flatnonzero((counts > 0.0) & (state.idle_ticks > 0.0))
        if gap_rows.size:
            gaps = state.idle_ticks[gap_rows]
            buckets = np.clip(np.floor(np.log2(gaps)).astype(np.int64), 0,
                              p.n_buckets - 1)
            np.add.at(state.hist, (gap_rows, buckets), 1.0)
            ttl_cache[:] = ttl_from_hist(state.hist, p)
        state.idle_ticks += 1.0
        state.idle_ticks[counts > 0.0] = 0.0
    else:                          # counts == 0 everywhere: err = -pred
        np.multiply(pred, 1.0 - p.alpha, out=level)
        np.multiply(pred, p.alpha * p.beta, out=pred)
        np.subtract(trend, pred, out=trend)
        state.idle_ticks += 1.0
    # demand -> desired pool (ceil with a dead-band below min_demand,
    # floored at one warm replica while arrivals are forecast soon)
    np.add(level, trend, out=pred)
    np.maximum(pred, 0.0, out=pred)
    np.greater_equal(pred, hold_thr, out=hold_buf)   # counts per tick
    np.multiply(pred, coeff, out=pred)
    np.subtract(pred, p.min_demand, out=pred)
    np.ceil(pred, out=pred)
    np.maximum(pred, hold_buf, out=pred)     # bool broadcast: floor of 1
    np.minimum(pred, float(p.max_pool), out=desired_out)
    return desired_out, ttl_cache


def predictive_tick_jax(state: ForecastState, counts: np.ndarray,
                        coeff: np.ndarray, p: ForecastParams,
                        desired_out: np.ndarray, ttl_cache: np.ndarray,
                        hold_thr: float = 0.0
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """The jitted mirror: one fused device call, state written back."""
    wf = _warm_forecast_mod()
    level, trend, idle, hist, desired, ttl = wf.predictive_tick(
        counts, state.level, state.trend, state.idle_ticks, state.hist,
        coeff, p.alpha, p.beta, p.min_demand, float(p.max_pool),
        p.quantile, p.default_ttl_ticks, p.min_ttl_ticks, p.max_ttl_ticks,
        float(p.min_gap_obs), hold_thr)
    state.level = np.asarray(level, dtype=np.float64)
    state.trend = np.asarray(trend, dtype=np.float64)
    state.idle_ticks = np.asarray(idle, dtype=np.float64)
    state.hist = np.asarray(hist, dtype=np.float64)
    desired_out[:] = np.asarray(desired, dtype=np.float64)
    ttl_cache[:] = np.asarray(ttl, dtype=np.float64)
    return desired_out, ttl_cache
