"""Keep-alive / prewarm policies for the warm-pool controller.

Each policy answers, per managed (function, platform) row and per tick:

  * ``desired`` — how many idle warm replicas to hold ready (the
    controller prewarms up to it);
  * ``ttl_s``   — how long an idle replica may stay warm past its last
    use before the controller retires it (the keep-alive).

All policies are columnar: one fused array pass per tick over every row.

  FixedTTLPolicy            classic FaaS keep-alive: no prewarming, idle
                            replicas die ``ttl_s`` after last use
                            (OpenWhisk's fixed keep-alive window).
  ScaleToZeroPolicy         aggressive idler: tiny TTL, pools drop to
                            zero between arrivals (faas-idler semantics;
                            minimum idle watts, maximum cold starts).
  ConcurrencyTargetPolicy   reactive: EWMA of observed arrival rate
                            sized by Little's law against a per-replica
                            concurrency target (OpenFaaS-style reactive
                            autoscaling, plus a fixed TTL).
  PredictivePolicy          the forecaster: Holt-linear rate forecast +
                            inter-arrival-gap histogram -> prewarm ahead
                            of predicted demand, keep alive for the gap
                            quantile (repro.autoscale.forecast; NumPy
                            reference + jax.jit backend, byte-identical
                            decisions pinned by tests).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import numpy as np

from repro.autoscale.forecast import (ForecastParams, ForecastState,
                                      _use_jax, holt_zero_matrix,
                                      predictive_tick_jax,
                                      predictive_tick_numpy)


class KeepAlivePolicy:
    """Base: fixed-size desired/TTL columns, grown with the row set."""

    name = "base"

    def __init__(self):
        self.n = 0
        self._desired = np.zeros(0)
        self._ttl = np.zeros(0)

    def resize(self, n: int) -> None:
        if n <= self.n:
            return
        grow = n - self.n
        self._desired = np.concatenate([self._desired, np.zeros(grow)])
        self._ttl = np.concatenate(
            [self._ttl, np.full(grow, self.default_ttl_s())])
        self.n = n

    def default_ttl_s(self) -> float:
        return 30.0

    def set_exec(self, exec_s: np.ndarray, tick_s: float) -> None:
        """Per-row predicted execution seconds (Little's-law input);
        refreshed by the controller as the perf model learns."""

    def tick(self, counts: np.ndarray, has_arrivals: bool
             ) -> Tuple[np.ndarray, np.ndarray]:
        """(desired warm replicas, keep-alive TTL seconds) per row."""
        raise NotImplementedError


class FixedTTLPolicy(KeepAlivePolicy):
    name = "ttl"

    def __init__(self, ttl_s: float = 30.0):
        self.ttl_s = float(ttl_s)
        super().__init__()

    def default_ttl_s(self) -> float:
        return self.ttl_s

    def tick(self, counts, has_arrivals):
        return self._desired, self._ttl


class ScaleToZeroPolicy(FixedTTLPolicy):
    name = "scale_to_zero"

    def __init__(self, idle_s: float = 1.0):
        super().__init__(ttl_s=idle_s)


class ConcurrencyTargetPolicy(KeepAlivePolicy):
    name = "concurrency"

    def __init__(self, target: float = 1.0, ttl_s: float = 30.0,
                 alpha: float = 0.3, min_demand: float = 0.05,
                 max_pool: int = 16):
        self.target = max(float(target), 1e-6)
        self.ttl_s = float(ttl_s)
        self.alpha = float(alpha)
        self.min_demand = float(min_demand)
        self.max_pool = float(max_pool)
        super().__init__()
        self._zero_run = 0
        self._level = np.zeros(0)
        self._coeff = np.zeros(0)
        self._scratch = np.zeros(0)

    def default_ttl_s(self) -> float:
        return self.ttl_s

    def resize(self, n: int) -> None:
        if n <= self.n:
            return
        grow = n - self.n
        self._level = np.concatenate([self._level, np.zeros(grow)])
        self._coeff = np.concatenate([self._coeff, np.zeros(grow)])
        self._scratch = np.concatenate([self._scratch, np.zeros(grow)])
        super().resize(n)

    def set_exec(self, exec_s, tick_s):
        np.multiply(exec_s, 1.0 / (self.target * tick_s), out=self._coeff)

    def tick(self, counts, has_arrivals):
        level, scratch = self._level, self._scratch
        if not has_arrivals:
            # dormant: decay is closed-form, decisions frozen until
            # traffic resumes (caught up exactly below)
            self._zero_run += 1
            return self._desired, self._ttl
        if self._zero_run:
            level *= (1.0 - self.alpha) ** self._zero_run
            self._zero_run = 0
        level += self.alpha * (counts - level)
        np.multiply(level, self._coeff, out=scratch)
        np.subtract(scratch, self.min_demand, out=scratch)
        np.ceil(scratch, out=scratch)
        np.maximum(scratch, 0.0, out=scratch)
        np.minimum(scratch, self.max_pool, out=self._desired)
        return self._desired, self._ttl


class PredictivePolicy(KeepAlivePolicy):
    name = "predictive"

    def __init__(self, params: Optional[ForecastParams] = None,
                 backend: Optional[str] = None, **param_overrides):
        self.params = params or ForecastParams(**param_overrides)
        self.backend = backend            # None: module-level setting
        self.state = ForecastState(self.params.n_buckets)
        self.tick_s = 1.0
        self._hold_thr = self.params.hold_min_rps * self.tick_s
        self._zero_run = 0
        super().__init__()
        self._coeff = np.zeros(0)
        self._scratch = np.zeros(0)
        self._hold_buf = np.zeros(0, dtype=bool)
        self._ttl_s_out = np.zeros(0)

    def default_ttl_s(self) -> float:
        # TTL columns are kept in *ticks* internally; converted on return
        p = self.params
        return float(np.clip(p.default_ttl_ticks, p.min_ttl_ticks,
                             p.max_ttl_ticks))

    def resize(self, n: int) -> None:
        if n <= self.n:
            return
        grow = n - self.n
        self._coeff = np.concatenate([self._coeff, np.zeros(grow)])
        self._scratch = np.concatenate([self._scratch, np.zeros(grow)])
        self._hold_buf = np.zeros(n, dtype=bool)
        self.state.resize(n)
        super().resize(n)
        self._ttl_s_out = self._ttl * self.tick_s

    def set_exec(self, exec_s, tick_s):
        self.tick_s = float(tick_s)
        self._hold_thr = self.params.hold_min_rps * self.tick_s
        np.multiply(exec_s, self.params.headroom / self.tick_s,
                    out=self._coeff)
        np.multiply(self._ttl, self.tick_s, out=self._ttl_s_out)

    def tick(self, counts, has_arrivals):
        if not has_arrivals:
            # dormant fast-forward: no arrivals means the only state
            # movement is Holt decay — closed-form (holt_zero_matrix),
            # applied exactly when traffic resumes; decisions stay frozen
            # meanwhile (retirement still proceeds on the armed TTLs)
            self._zero_run += 1
            return self._desired, self._ttl_s_out
        if self._zero_run:
            self._catch_up(self._zero_run)
            self._zero_run = 0
        if _use_jax(self.n, self.backend):
            predictive_tick_jax(self.state, counts, self._coeff,
                                self.params, self._desired, self._ttl,
                                hold_thr=self._hold_thr)
        else:
            predictive_tick_numpy(self.state, counts, self._coeff,
                                  self.params, True,
                                  self._desired, self._scratch, self._ttl,
                                  self._hold_buf, hold_thr=self._hold_thr)
        np.multiply(self._ttl, self.tick_s, out=self._ttl_s_out)
        return self._desired, self._ttl_s_out

    def _catch_up(self, k: int) -> None:
        s = self.state
        m00, m01, m10, m11 = holt_zero_matrix(self.params.alpha,
                                              self.params.beta, k)
        level = m00 * s.level + m01 * s.trend
        s.trend = m10 * s.level + m11 * s.trend
        s.level = level
        s.idle_ticks += float(k)


POLICY_KINDS: Dict[str, Type[KeepAlivePolicy]] = {
    cls.name: cls for cls in (FixedTTLPolicy, ScaleToZeroPolicy,
                              ConcurrencyTargetPolicy, PredictivePolicy)}


def make_policy(kind: str, **kwargs) -> KeepAlivePolicy:
    if kind not in POLICY_KINDS:
        raise KeyError(f"unknown keep-alive policy {kind!r}; "
                       f"known: {', '.join(sorted(POLICY_KINDS))}")
    cls = POLICY_KINDS[kind]
    if cls is not PredictivePolicy:
        kwargs.pop("backend", None)       # only the forecaster has one
    return cls(**kwargs)
