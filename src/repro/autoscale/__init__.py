"""Predictive autoscaling: warm-pool lifecycle, keep-alive policies, and
energy-aware prewarming (the replica-lifecycle control loop where the
FDN's SLO and energy objectives collide — keeping replicas warm burns
idle watts, letting them die costs cold starts).

  * ``WarmPoolController``  — per-(function, platform) control loop
    ticked on the SimClock (controller.py);
  * keep-alive policies     — fixed TTL, scale-to-zero, reactive
    concurrency target, predictive prewarmer (policies.py);
  * arrival forecasting     — columnar Holt-linear + inter-arrival-gap
    histogram, NumPy reference + ``jax.jit`` backend (forecast.py,
    ``repro.kernels.warm_forecast``).
"""
from repro.autoscale.controller import WarmPoolController
from repro.autoscale.forecast import (ForecastParams, ForecastState,
                                      get_forecast_backend,
                                      set_forecast_backend)
from repro.autoscale.policies import (POLICY_KINDS, ConcurrencyTargetPolicy,
                                      FixedTTLPolicy, KeepAlivePolicy,
                                      PredictivePolicy, ScaleToZeroPolicy,
                                      make_policy)

__all__ = [
    "WarmPoolController", "KeepAlivePolicy", "FixedTTLPolicy",
    "ScaleToZeroPolicy", "ConcurrencyTargetPolicy", "PredictivePolicy",
    "ForecastParams", "ForecastState", "POLICY_KINDS", "make_policy",
    "set_forecast_backend", "get_forecast_backend",
]
