"""WarmPoolController: the per-(function, platform) replica-lifecycle
control loop (repro.autoscale).

The controller owns every managed platform's warm pools.  On attach it
takes over keep-alive from the platform's own faas-idler
(``managed_keepalive``) and installs a per-platform admission counter the
platforms increment on enqueue (``autoscale_counts`` — one dict add per
admitted invocation, zero cost when autoscaling is off).  Every ``tick_s``
sim-seconds it then

  1. drains the admission counters into the columnar counts buffer (one
     row per managed (function, platform) pair),
  2. runs the keep-alive policy's fused array tick -> per-row ``desired``
     warm-pool size and keep-alive ``ttl_s``,
  3. grows pools below target (``platform.prewarm``) and TTL-sweeps pools
     above it (``platform.enforce_keepalive`` / ``retire``), both O(1)
     running-total transitions on the platform.

Idle pools are read back through the platforms' O(1) idle counters,
cached per platform and refreshed only when that platform's idle
generation moved, so a steady-state tick is a handful of fused array ops
plus one dict check per platform — ``benchmarks/bench_autoscale.py`` pins
the tick throughput.  Everything advances on the deterministic SimClock:
two runs of one seeded scenario make byte-identical prewarm/retire
decisions.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.autoscale.policies import KeepAlivePolicy
from repro.core.behavioral import FunctionPerformanceModel
from repro.core.platform import TargetPlatform
from repro.core.simulator import SimClock
from repro.core.types import FunctionSpec


class _PlatformRows:
    """Controller-side view of one platform's managed rows."""

    __slots__ = ("platform", "row_of", "fns", "gen")

    def __init__(self, platform: TargetPlatform):
        self.platform = platform
        self.row_of: Dict[str, int] = {}
        self.fns: Dict[str, FunctionSpec] = {}
        self.gen = -1                      # force first idle refresh


class WarmPoolController:
    def __init__(self, platforms: Dict[str, TargetPlatform],
                 perf: FunctionPerformanceModel, clock: SimClock,
                 policy: KeepAlivePolicy, tick_s: float = 1.0,
                 exec_refresh_ticks: int = 64):
        self.platforms = platforms          # live dict (control plane's)
        self.perf = perf
        self.clock = clock
        self.policy = policy
        self.tick_s = float(tick_s)
        self.exec_refresh_ticks = int(exec_refresh_ticks)
        self.ticks = 0
        self.prewarmed = 0
        self.retired = 0
        # flight recorder (repro.obs); set by the control plane's
        # attach_recorder / attach_autoscaler
        self.recorder = None
        self._plats: List[_PlatformRows] = []
        self._by_name: Dict[str, _PlatformRows] = {}
        self._rows = 0
        self._row_fn: List[FunctionSpec] = []
        self._row_platform: List[TargetPlatform] = []
        self._counts = np.zeros(0)
        self._idle = np.zeros(0)
        self._exec_s = np.zeros(0)
        self._need = np.zeros(0)
        self._next_sweep = np.zeros(0)
        self._sweep_mask = np.zeros(0, dtype=bool)
        self._touched: List[int] = []
        self._sweep_due = float("inf")
        self._started = False
        self._stopped = False

    # ----------------------------------------------------------- wiring ---
    def attach(self) -> "WarmPoolController":
        for p in list(self.platforms.values()):
            self.adopt(p)
        return self

    def adopt(self, platform: TargetPlatform) -> None:
        """Take over one platform's warm-pool lifecycle (elastic platforms
        may join mid-run)."""
        name = platform.prof.name
        if name in self._by_name:
            return
        platform.autoscale_counts = {}
        platform.managed_keepalive = True
        pv = _PlatformRows(platform)
        self._plats.append(pv)
        self._by_name[name] = pv
        self._sync_platform(pv)

    def _sync_platform(self, pv: _PlatformRows) -> None:
        for fn_name, spec in pv.platform.deployed.items():
            if fn_name not in pv.row_of:
                self._add_row(pv, fn_name, spec)

    def _add_row(self, pv: _PlatformRows, fn_name: str,
                 spec: FunctionSpec) -> int:
        row = self._rows
        pv.row_of[fn_name] = row
        pv.fns[fn_name] = spec
        pv.gen = -1                        # idle view must refresh
        self._row_fn.append(spec)
        self._row_platform.append(pv.platform)
        self._rows += 1
        for name in ("_counts", "_idle", "_exec_s", "_need",
                     "_next_sweep"):
            arr = getattr(self, name)
            grown = np.zeros(self._rows)
            grown[:row] = arr
            setattr(self, name, grown)
        self._sweep_mask = np.zeros(self._rows, dtype=bool)
        self.policy.resize(self._rows)
        # seed only the new row's Little's-law column (a full refresh per
        # added row would make attach quadratic in managed rows)
        self._exec_s[row] = self.perf.predict_exec(spec, pv.platform.prof)
        self.policy.set_exec(self._exec_s, self.tick_s)
        return row

    def _refresh_exec(self) -> None:
        """Re-pull predicted execution seconds (the Little's-law column)
        from the online perf model; called on row growth and every
        ``exec_refresh_ticks`` ticks."""
        perf, exec_s = self.perf, self._exec_s
        for r in range(self._rows):
            exec_s[r] = perf.predict_exec(self._row_fn[r],
                                          self._row_platform[r].prof)
        self.policy.set_exec(exec_s, self.tick_s)

    # ------------------------------------------------------------- tick ---
    def tick(self) -> None:
        """One control-loop pass; see the module docstring."""
        self.ticks += 1
        counts = self._counts
        touched = self._touched
        has_arrivals = False
        for pv in self._plats:
            c = pv.platform.autoscale_counts
            if c:
                row_of = pv.row_of
                for fn_name, n in c.items():
                    r = row_of.get(fn_name)
                    if r is None:          # deployed mid-run
                        spec = pv.platform.deployed.get(fn_name)
                        if spec is None:
                            continue
                        r = self._add_row(pv, fn_name, spec)
                        counts = self._counts
                    counts[r] = n
                    touched.append(r)
                c.clear()
                has_arrivals = True
        if self.ticks % self.exec_refresh_ticks == 0:
            self._refresh_exec()

        desired, ttl_s = self.policy.tick(counts, has_arrivals)

        if touched:
            for r in touched:
                counts[r] = 0.0
            touched.clear()

        # refresh the cached idle view only for platforms that moved
        # (an idle transition also re-arms the platform's sweep timers)
        idle = self._idle
        next_sweep = self._next_sweep
        moved = False
        for pv in self._plats:
            p = pv.platform
            g = p.idle_gen
            if g != pv.gen:
                pv.gen = g
                moved = True
                idle_warm = p.idle_warm
                for fn_name, r in pv.row_of.items():
                    idle[r] = idle_warm(fn_name)
                    next_sweep[r] = 0.0

        # quiet tick: decisions frozen (dormant policy), idle pools
        # untouched -> need is unchanged from its cached evaluation, so
        # the only possible action is a TTL expiry coming due
        now = self.clock.now()
        if not (has_arrivals or moved) or self._rows == 0:
            if now >= self._sweep_due:
                self._run_sweeps(now, desired, ttl_s)
            return
        need = self._need
        np.subtract(desired, idle, out=need)
        # grow pools below target ...
        if need.max() > 0.0:
            rec = self.recorder
            for r in np.flatnonzero(need > 0.0):
                n = int(need[r])
                self._row_platform[r].prewarm(self._row_fn[r].name, n)
                self.prewarmed += n
                if rec is not None:
                    rec.record_prewarm(self._row_platform[r].prof.name,
                                       self._row_fn[r].name, now, n)
        # ... and TTL-sweep pools above it, but only rows whose earliest
        # possible expiry has arrived (enforce_keepalive hands back the
        # next due time, so quiet pools are not re-scanned every tick)
        if need.min() < 0.0:
            self._run_sweeps(now, desired, ttl_s)
        else:
            self._sweep_due = float("inf")

    def _run_sweeps(self, now: float, desired: np.ndarray,
                    ttl_s: np.ndarray) -> None:
        """Sweep every surplus row whose earliest expiry has arrived and
        re-arm the cached next-due time."""
        next_sweep = self._next_sweep
        np.less(self._need, 0.0, out=self._sweep_mask)
        due = self._sweep_mask & (next_sweep <= now)
        rec = self.recorder
        for r in np.flatnonzero(due):
            n, nxt = self._row_platform[r].enforce_keepalive(
                self._row_fn[r].name, float(ttl_s[r]),
                keep=int(desired[r]))
            self.retired += n
            next_sweep[r] = nxt
            if n and rec is not None:
                rec.record_retire(self._row_platform[r].prof.name,
                                  self._row_fn[r].name, now, n)
        pending = next_sweep[self._sweep_mask]
        self._sweep_due = float(pending.min()) if pending.size \
            else float("inf")

    # -------------------------------------------------------- scheduling --
    def start(self) -> None:
        """Self-rescheduling tick on the sim clock (idempotent)."""
        if self._started:
            return
        self._started = True
        self._stopped = False

        def loop():
            if self._stopped:
                return
            self.tick()
            self.clock.after(self.tick_s, loop)

        self.clock.after(self.tick_s, loop)

    def stop(self) -> None:
        self._stopped = True
        self._started = False
