"""Decoder-only transformer families: dense (qwen3/yi/llama3), MoE
(mixtral/dbrx) and VLM (phi-3-vision backbone; stub image frontend).

Layers are stacked and scanned (``lax.scan``) so HLO size and compile time
are O(1) in depth. Decode uses either a full-length KV cache (dense archs)
or a rolling window buffer (SWA archs) — both position-mask based.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MOE, VLM
from repro.models import layers as nn
from repro.models import moe as moe_mod
from repro.models.params import Spec, stack
from repro.sharding import constrain, shard_map

# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    out: Dict[str, Any] = {
        "wq": Spec((d, cfg.q_dim), ("embed", "heads")),
        "wk": Spec((d, cfg.kv_dim), ("embed", "kv")),
        "wv": Spec((d, cfg.kv_dim), ("embed", "kv")),
        "wo": Spec((cfg.q_dim, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        out["q_norm"] = Spec((cfg.head_dim,), (None,), "zeros")
        out["k_norm"] = Spec((cfg.head_dim,), (None,), "zeros")
    return out


def mlp_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi": Spec((d, f), ("embed", "mlp")),
        "wg": Spec((d, f), ("embed", "mlp")),
        "wo": Spec((f, d), ("mlp", "embed")),
    }


def layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    out = {
        "ln1": Spec((cfg.d_model,), ("embed",), "zeros"),
        "ln2": Spec((cfg.d_model,), ("embed",), "zeros"),
        "attn": attn_specs(cfg),
    }
    if cfg.family == MOE:
        out["moe"] = moe_mod.moe_specs(cfg)
    else:
        out["mlp"] = mlp_specs(cfg)
    return out


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    out = {
        "embed": Spec((cfg.vocab_size, d), ("vocab", "embed"), "normal", 0.7),
        "layers": stack(cfg.num_layers, layer_specs(cfg)),
        "final_norm": Spec((d,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((d, cfg.vocab_size), ("embed", "vocab"))
    return out


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, p: Dict, h: jax.Array, positions):
    b, s, _ = h.shape
    q = (h @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (h @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.qk_norm(q, p["q_norm"])
        k = nn.qk_norm(k, p["k_norm"])
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(cfg: ModelConfig, p: Dict, x: jax.Array,
               positions: jax.Array) -> Tuple[jax.Array, Tuple]:
    """Self-attention over the in-context sequence (train / prefill)."""
    h = nn.rmsnorm(x, p["ln1"])
    q, k, v = _project_qkv(cfg, p["attn"], h, positions)
    q = constrain(q, "batch", None, "heads", None)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        blk = min(128, q.shape[1])
        ctx = kops.flash_attention(q, k, v, causal=cfg.causal,
                                   window=cfg.sliding_window,
                                   q_block=blk, kv_block=blk)
    else:
        ctx = nn.chunked_attention(q, k, v, causal=cfg.causal,
                                   window=cfg.sliding_window,
                                   q_chunk=cfg.attn_q_chunk,
                                   unroll=cfg.unroll_scans)
    b, s, _, _ = ctx.shape
    out = ctx.reshape(b, s, cfg.q_dim) @ p["attn"]["wo"]
    return x + out, (k, v)


def ffn_block(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array,
                                                                jax.Array]:
    h = nn.rmsnorm(x, p["ln2"])
    if cfg.family == MOE:
        out, aux = moe_mod.moe_block(cfg, p["moe"], h)
    else:
        out = nn.gated_mlp(h, **p["mlp"])
        aux = jnp.zeros((), jnp.float32)
    return x + out, aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.family == VLM:
        img = batch["image_embeds"].astype(tok.dtype)       # (B, Nimg, D)
        tok = jnp.concatenate([img, tok], axis=1)
    return constrain(tok, "batch", None, "embed")


def forward_hidden(cfg: ModelConfig, params: Dict, embeds: jax.Array, *,
                   collect_kv: bool = False, remat: bool = False):
    """Run the layer stack. Returns (hidden, kv_stack|None, aux_loss)."""
    b, s, _ = embeds.shape
    positions = jnp.arange(s)

    def body(x, p):
        x, kv = attn_block(cfg, p, x, positions)
        x, aux = ffn_block(cfg, p, x)
        seq_ax = "seq_sp" if cfg.seq_parallel else None
        x = constrain(x, "batch", seq_ax, "embed")
        return x, ((kv if collect_kv else None), aux)

    fn = _remat(cfg, body) if remat else body
    if cfg.scan_layers:
        x, (kvs, auxs) = jax.lax.scan(fn, embeds, params["layers"],
                                      unroll=cfg.unroll_scans)
        aux = jnp.sum(auxs)
    else:
        x, kvs_l, aux = embeds, [], jnp.zeros((), jnp.float32)
        leaves = jax.tree_util.tree_map(lambda a: list(a), params["layers"])
        for i in range(cfg.num_layers):
            p_i = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, (kv, a) = fn(x, p_i)
            kvs_l.append(kv)
            aux = aux + a
        kvs = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *kvs_l)
               if collect_kv else None)
    x = nn.rmsnorm(x, params["final_norm"])
    return x, kvs, aux


def logits_fn(cfg: ModelConfig, params: Dict, h: jax.Array) -> jax.Array:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    out = h @ head
    return constrain(out, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def cache_capacity(cfg: ModelConfig, context_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, context_len + 128)
    return context_len + 128


def cache_specs(cfg: ModelConfig, batch_size: int,
                context_len: int) -> Dict[str, Any]:
    """Declarative cache layout (Spec tree) — reused by input_specs().

    ``pos`` is PER ROW (B,), which is what allows the serving engine to run
    continuous batching (each slot at its own decode position).
    """
    cap = cache_capacity(cfg, context_len)
    seq_ax = "kv_seq" if cfg.decode_seq_shard else None
    kv = Spec((cfg.num_layers, batch_size, cap, cfg.n_kv_heads, cfg.head_dim),
              ("layers", "batch", seq_ax, None, None), "zeros")
    return {
        "k": kv,
        "v": kv,
        "k_pos": Spec((batch_size, cap), ("batch", None), "zeros"),
        "pos": Spec((batch_size,), ("batch",), "zeros"),
    }


def init_cache(cfg: ModelConfig, batch_size: int, context_len: int) -> Dict:
    from repro.models import params as pm
    tree = cache_specs(cfg, batch_size, context_len)
    cache = pm.tree_map(lambda s: jnp.zeros(s.shape, jnp.bfloat16), tree)
    cache["k_pos"] = jnp.full(tree["k_pos"].shape, -1, jnp.int32)
    cache["pos"] = jnp.zeros(tree["pos"].shape, jnp.int32)
    return cache


def pack_cache(stack: jax.Array, lens: jax.Array, cap: int) -> jax.Array:
    """Per-row gather of the last min(len_i, cap) entries of a (B,S,...) kv
    stack into a (B,cap,...) cache, right-padded prompts supported."""
    b, s = stack.shape[0], stack.shape[1]
    start = jnp.maximum(lens - cap, 0)                     # (B,)
    idx = start[:, None] + jnp.arange(cap)[None, :]        # (B,cap)
    idx = jnp.minimum(idx, s - 1)
    return jnp.take_along_axis(
        stack, idx.reshape(b, cap, *([1] * (stack.ndim - 2))), axis=1)


def prefill(cfg: ModelConfig, params: Dict, batch: Dict,
            context_len: Optional[int] = None):
    """Process the prompt; return (last-token logits, populated cache).

    ``batch["prompt_lens"]`` (B,) optionally marks right-padded prompts;
    defaults to the full sequence length for every row.
    """
    embeds = embed_inputs(cfg, params, batch)
    b, s, _ = embeds.shape
    context_len = context_len if context_len is not None else s
    raw_lens = batch.get("prompt_lens")
    lens = (jnp.full((b,), s, jnp.int32) if raw_lens is None
            else raw_lens.astype(jnp.int32))
    h, kvs, _ = forward_hidden(cfg, params, embeds, collect_kv=True)
    cache = init_cache(cfg, b, context_len)
    cap = cache["k"].shape[2]
    k_stack, v_stack = kvs                      # (L,B,S,KH,Dh)
    if raw_lens is None:
        # uniform prompt lengths (the pod-scale path): static slices only —
        # per-row gathers on a kv_seq-sharded cache force the SPMD
        # partitioner into full rematerialization.
        logits = logits_fn(cfg, params, h[:, -1:, :])
        keep = min(s, cap)
        cache["k"] = cache["k"].at[:, :, :keep].set(k_stack[:, :, s - keep:])
        cache["v"] = cache["v"].at[:, :, :keep].set(v_stack[:, :, s - keep:])
        pos = jnp.arange(s - keep, s, dtype=jnp.int32)
        cache["k_pos"] = cache["k_pos"].at[:, :keep].set(pos[None, :])
    else:
        # ragged prompts (serving engine): per-row gather
        last = jnp.take_along_axis(h, (lens - 1)[:, None, None], axis=1)
        logits = logits_fn(cfg, params, last)
        vm = jax.vmap(pack_cache, in_axes=(0, None, None))  # over layers
        cache["k"] = vm(k_stack, lens, cap)
        cache["v"] = vm(v_stack, lens, cap)
        start = jnp.maximum(lens - cap, 0)
        k_pos = start[:, None] + jnp.arange(cap)[None, :]
        cache["k_pos"] = jnp.where(k_pos < lens[:, None], k_pos,
                                   -1).astype(jnp.int32)
    cache["pos"] = lens
    return logits, cache


# ---------------------------------------------------------------------------
# §Perf: shard_mapped split-K flash decode.
#
# The GSPMD path updates the sequence-sharded cache with a masked select
# (a full read+write of the cache every step) and lets the partitioner pick
# the attention schedule. Under shard_map each "model" shard owns one cache
# slice: the token write is a LOCAL per-row scatter (no SPMD involvement),
# attention reduces its slice with online-softmax partials, and a tiny
# pmax/psum combine (the Pallas decode_attention kernel's split-K pattern
# lifted to the mesh) produces the context.
# ---------------------------------------------------------------------------


def _flash_decode_shmap(q, kc, vc, k_new, v_new, slot, pos, mesh):
    """q: (B,1,H,Dh); kc/vc: (B,T,KH,Dh) seq-sharded over "model";
    k_new/v_new: (B,1,KH,Dh); slot/pos: (B,). Returns (ctx, kc, vc).

    Only used for full (non-rolling) caches, where slot index == position.
    """
    from jax.sharding import PartitionSpec as P
    from repro import sharding as shd

    dp = shd.dp_axes(mesh)
    b, _, h, dh = q.shape
    kh = kc.shape[2]
    g = h // kh
    scale = dh ** -0.5

    def local(q, kc, vc, k_new, v_new, slot, pos):
        b_loc, t_loc = kc.shape[0], kc.shape[1]
        off = jax.lax.axis_index("model") * t_loc
        rows = jnp.arange(b_loc)
        slot_loc = slot - off
        own = (slot_loc >= 0) & (slot_loc < t_loc)
        idx = jnp.clip(slot_loc, 0, t_loc - 1)
        upd_k = jnp.where(own[:, None, None], k_new[:, 0], kc[rows, idx])
        upd_v = jnp.where(own[:, None, None], v_new[:, 0], vc[rows, idx])
        kc = kc.at[rows, idx].set(upd_k)
        vc = vc.at[rows, idx].set(upd_v)
        j = off + jnp.arange(t_loc)[None, :]                  # (1,T_loc)
        valid = j <= pos[:, None]                             # (B,T_loc)
        qr = q.reshape(b_loc, kh, g, dh)
        s = jnp.einsum("bkgd,btkd->bkgt", qr, kc,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)                # (B,KH,G,1)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bkgt,btkd->bkgd", p.astype(vc.dtype), vc)
        m_g = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_g)                                  # (B,KH,G,1)
        l_g = jax.lax.psum(l * w, "model")
        acc_g = jax.lax.psum(acc.astype(jnp.float32) * w, "model")
        out = acc_g / jnp.maximum(l_g, 1e-30)
        return out.reshape(b_loc, 1, h, dh).astype(q.dtype), kc, vc

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, "model", None, None),
                  P(dp, "model", None, None), P(dp, None, None, None),
                  P(dp, None, None, None), P(dp), P(dp)),
        out_specs=(P(dp, None, None, None), P(dp, "model", None, None),
                   P(dp, "model", None, None)),
        check_vma=False,
    )(q, kc, vc, k_new, v_new, slot, pos)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
    """One token for every row. batch: {"token": (B,1)}. Rows may sit at
    different positions (continuous batching)."""
    tok = batch["token"]
    x = jnp.take(params["embed"], tok, axis=0)          # (B,1,D)
    b = x.shape[0]
    pos = cache["pos"]                                   # (B,)
    positions = pos[:, None]
    cap = cache["k"].shape[2]
    slot = (pos % cap).astype(jnp.int32)                 # (B,)
    window = cfg.sliding_window
    k_pos = jnp.where(jnp.arange(cache["k_pos"].shape[1])[None, :]
                  == slot[:, None], pos[:, None], cache["k_pos"])

    from repro.sharding import current_mesh
    mesh = current_mesh()
    use_shmap = (cfg.decode_impl == "shmap_flash" and mesh is not None
                 and "model" in mesh.axis_names and window is None
                 and cfg.decode_seq_shard
                 and cap % mesh.shape["model"] == 0)

    def body(x, args):
        p, kc, vc = args
        h = nn.rmsnorm(x, p["ln1"])
        q, k, v = _project_qkv(cfg, p["attn"], h, positions)
        if use_shmap:
            ctx, kc, vc = _flash_decode_shmap(q, kc, vc, k, v, slot, pos,
                                              mesh)
        else:
            kc = nn.masked_cache_update(kc, k, slot)
            vc = nn.masked_cache_update(vc, v, slot)
            ctx = nn.attend(q, kc, vc, positions, k_pos,
                            causal=True, window=window)
        x = x + ctx.reshape(b, 1, cfg.q_dim) @ p["attn"]["wo"]
        x, _ = ffn_block(cfg, p, x)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x,
                                     (params["layers"], cache["k"],
                                      cache["v"]),
                                     unroll=cfg.unroll_scans)
    x = nn.rmsnorm(x, params["final_norm"])
    logits = logits_fn(cfg, params, x)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_new, v_new
    new_cache["k_pos"] = k_pos
    new_cache["pos"] = pos + 1
    return logits, new_cache
