"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA
attention in a repeating (rec, rec, attn) pattern, each followed by a gated
MLP.

TPU adaptation: the RG-LRU recurrence h_t = a_t*h_{t-1} + b_t is evaluated
with ``jax.lax.associative_scan`` (log-depth parallel scan over the sequence,
VPU-friendly) instead of a CUDA-style sequential linear-recurrence kernel.
Decode keeps O(1) recurrent state + a window-2048 rolling KV cache, which is
what makes the long_500k shape runnable for this family.

Layer stacking: the repeating 3-block pattern is scanned over ``num_layers //
3`` super-blocks; the remainder (38 % 3 = 2 recurrent blocks) is unrolled.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as nn
from repro.models import transformer as tfm
from repro.models.params import Spec, stack
from repro.sharding import constrain

C_RGLRU = 8.0  # Griffin's fixed gate sharpness


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _rec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, w, h = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.n_heads
    bw = w // h                       # block width for block-diagonal gates
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "wx": Spec((d, w), ("embed", "lru")),
        "wy": Spec((d, w), ("embed", "lru")),
        "conv_w": Spec((w, cfg.conv_width), ("lru", None)),
        "gate_a": Spec((h, bw, bw), ("heads", None, None)),
        "gate_a_b": Spec((w,), ("lru",), "zeros"),
        "gate_x": Spec((h, bw, bw), ("heads", None, None)),
        "gate_x_b": Spec((w,), ("lru",), "zeros"),
        "lam": Spec((w,), ("lru",), "lru_a"),
        "wo": Spec((w, d), ("lru", "embed")),
        "mlp_ln": Spec((d,), ("embed",), "zeros"),
        "mlp": tfm.mlp_specs(cfg),
    }


def _attn_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": Spec((cfg.d_model,), ("embed",), "zeros"),
        "attn": tfm.attn_specs(cfg),
        "ln2": Spec((cfg.d_model,), ("embed",), "zeros"),
        "mlp": tfm.mlp_specs(cfg),
    }


def _super_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"rec1": _rec_specs(cfg), "rec2": _rec_specs(cfg),
            "attn": _attn_specs(cfg)}


def n_super(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(cfg.block_pattern)


def n_tail(cfg: ModelConfig) -> int:
    return cfg.num_layers % len(cfg.block_pattern)


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    out: Dict[str, Any] = {
        "embed": Spec((cfg.vocab_size, d), ("vocab", "embed"), "normal", 0.7),
        "supers": stack(n_super(cfg), _super_specs(cfg)),
        "final_norm": Spec((d,), ("embed",), "zeros"),
    }
    for i in range(n_tail(cfg)):
        out[f"tail{i}"] = _rec_specs(cfg)
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((d, cfg.vocab_size), ("embed", "vocab"))
    return out


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _block_diag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,S,W); w: (H, W/H, W/H) block-diagonal projection."""
    b, s, width = x.shape
    h = w.shape[0]
    xr = x.reshape(b, s, h, width // h)
    return jnp.einsum("bshw,hwv->bshv", xr, w).reshape(b, s, width)


def rglru_gates(p: Dict, bx: jax.Array):
    """Compute (a, b) of h_t = a*h + b from the conv branch activation."""
    r = jax.nn.sigmoid(_block_diag(bx, p["gate_a"]).astype(jnp.float32)
                       + p["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(bx, p["gate_x"]).astype(jnp.float32)
                       + p["gate_x_b"].astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * bx.astype(jnp.float32))
    return a, b


def rglru_scan(a: jax.Array, b: jax.Array,
               use_pallas: bool = False) -> jax.Array:
    """Parallel linear recurrence h_t = a_t*h_{t-1} + b_t along axis 1."""
    if use_pallas:
        from repro.kernels import ops as kops
        s, w = a.shape[1], a.shape[2]
        return kops.rglru_scan(a, b, chunk=min(64, s),
                               width_block=min(128, w))

    def op(l, r):
        return (l[0] * r[0], l[1] * r[0] + r[1])

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def rec_block(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    h = nn.rmsnorm(x, p["ln"])
    bx = h @ p["wx"]
    by = jax.nn.gelu(h @ p["wy"])
    bx = nn.causal_conv1d(bx, p["conv_w"])
    bx = constrain(bx, "batch", None, "lru")
    a, b = rglru_gates(p, bx)
    hs = rglru_scan(a, b, cfg.use_pallas).astype(x.dtype)
    out = (hs * by) @ p["wo"]
    x = x + out
    h2 = nn.rmsnorm(x, p["mlp_ln"])
    return x + nn.gated_mlp(h2, act=jax.nn.gelu, **p["mlp"])


def attn_block(cfg: ModelConfig, p: Dict, x: jax.Array,
               positions: jax.Array) -> Tuple[jax.Array, Tuple]:
    acfg = cfg.replace(sliding_window=cfg.local_window, qk_norm=False)
    x, kv = tfm.attn_block(acfg, p, x, positions)
    h2 = nn.rmsnorm(x, p["ln2"])
    return x + nn.gated_mlp(h2, act=jax.nn.gelu, **p["mlp"]), kv


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_hidden(cfg: ModelConfig, params: Dict, embeds: jax.Array, *,
                   collect_state: bool = False, remat: bool = False):
    """Returns (hidden, per-super (kv, rec-states) | None)."""
    b, s, _ = embeds.shape
    positions = jnp.arange(s)
    kw = cfg.conv_width - 1

    def rec_with_state(p, x):
        # duplicated slice of rec_block that also extracts decode state
        h = nn.rmsnorm(x, p["ln"])
        bx_pre = h @ p["wx"]
        by = jax.nn.gelu(h @ p["wy"])
        bx = nn.causal_conv1d(bx_pre, p["conv_w"])
        a, bb = rglru_gates(p, bx)
        hs = rglru_scan(a, bb, cfg.use_pallas)
        out = (hs.astype(x.dtype) * by) @ p["wo"]
        x = x + out
        h2 = nn.rmsnorm(x, p["mlp_ln"])
        x = x + nn.gated_mlp(h2, act=jax.nn.gelu, **p["mlp"])
        state = {"h": hs[:, -1, :], "conv": bx_pre[:, -kw:, :]}
        return x, state

    def body(x, p):
        x, st1 = rec_with_state(p["rec1"], x)
        x, st2 = rec_with_state(p["rec2"], x)
        x, kv = attn_block(cfg, p["attn"], x, positions)
        x = constrain(x, "batch",
                      "seq_sp" if cfg.seq_parallel else None, "embed")
        st = ({"rec1": st1, "rec2": st2, "kv": kv}
              if collect_state else None)
        return x, st

    fn = tfm._remat(cfg, body) if remat else body
    x, states = jax.lax.scan(fn, embeds, params["supers"],
                             unroll=cfg.unroll_scans)
    tail_states = {}
    for i in range(n_tail(cfg)):
        x, st = rec_with_state(params[f"tail{i}"], x)
        tail_states[f"tail{i}"] = st
    x = nn.rmsnorm(x, params["final_norm"])
    st = (states, tail_states) if collect_state else None
    return x, st, jnp.zeros((), jnp.float32)


def prefill(cfg: ModelConfig, params: Dict, batch: Dict,
            context_len: Optional[int] = None):
    """Prompt processing with exact state handoff (LRU h, conv tail, KV)."""
    from repro.models import transformer as tfm
    tok = batch["tokens"]
    b, s = tok.shape
    context_len = context_len if context_len is not None else s
    embeds = jnp.take(params["embed"], tok, axis=0)
    x, (states, tail_states), _ = forward_hidden(cfg, params, embeds,
                                                 collect_state=True)
    logits = tfm.logits_fn(cfg, params, x[:, -1:, :])
    cache = init_cache(cfg, b, context_len)
    cap = cache["k"].shape[2]
    keep = min(s, cap)
    for r in ("rec1", "rec2"):
        cache[r]["h"] = states[r]["h"]
        cache[r]["conv"] = states[r]["conv"].astype(jnp.bfloat16)
    k_stack, v_stack = states["kv"]             # (NS,B,S,KH,Dh)
    cache["k"] = cache["k"].at[:, :, :keep].set(
        k_stack[:, :, s - keep:].astype(jnp.bfloat16))
    cache["v"] = cache["v"].at[:, :, :keep].set(
        v_stack[:, :, s - keep:].astype(jnp.bfloat16))
    pos = jnp.arange(s - keep, s, dtype=jnp.int32)
    cache["k_pos"] = cache["k_pos"].at[:, :keep].set(pos[None, :])
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    for i in range(n_tail(cfg)):
        cache[f"tail{i}"]["h"] = tail_states[f"tail{i}"]["h"]
        cache[f"tail{i}"]["conv"] = tail_states[f"tail{i}"]["conv"].astype(
            jnp.bfloat16)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch_size: int,
                context_len: int) -> Dict[str, Any]:
    w = cfg.lru_width or cfg.d_model
    kw = cfg.conv_width - 1
    cap = min(cfg.local_window, context_len + 128)
    ns = n_super(cfg)
    rec = {
        "h": Spec((ns, batch_size, w), ("layers", "batch", "lru"), "zeros"),
        "conv": Spec((ns, batch_size, kw, w),
                     ("layers", "batch", None, "lru"), "zeros"),
    }
    kvs = Spec((ns, batch_size, cap, cfg.n_kv_heads, cfg.head_dim),
               ("layers", "batch", None, None, None), "zeros")
    out: Dict[str, Any] = {
        "rec1": dict(rec), "rec2": dict(rec),
        "k": kvs, "v": kvs,
        "k_pos": Spec((batch_size, cap), ("batch", None), "zeros"),
        "pos": Spec((batch_size,), ("batch",), "zeros"),
    }
    for i in range(n_tail(cfg)):
        out[f"tail{i}"] = {
            "h": Spec((batch_size, w), ("batch", "lru"), "zeros"),
            "conv": Spec((batch_size, kw, w), ("batch", None, "lru"),
                         "zeros"),
        }
    return out


def init_cache(cfg: ModelConfig, batch_size: int, context_len: int) -> Dict:
    from repro.models import params as pm
    tree = cache_specs(cfg, batch_size, context_len)
    cache = pm.tree_map(lambda s: jnp.zeros(s.shape, jnp.bfloat16), tree)
    cache["k_pos"] = jnp.full(tree["k_pos"].shape, -1, jnp.int32)
    cache["pos"] = jnp.zeros(tree["pos"].shape, jnp.int32)
    # recurrent states carry f32 for numerical stability
    for key in ["rec1", "rec2"] + [f"tail{i}" for i in range(n_tail(cfg))]:
        cache[key]["h"] = jnp.zeros(tree[key]["h"].shape, jnp.float32)
    return cache


def _rec_step(cfg: ModelConfig, p: Dict, x: jax.Array, st: Dict):
    """x: (B,1,D). One-token recurrent block."""
    h = nn.rmsnorm(x, p["ln"])
    bx_pre = (h @ p["wx"])[:, 0, :]                       # (B,W)
    by = jax.nn.gelu(h @ p["wy"])[:, 0, :]
    bx, conv_buf = nn.conv1d_step(bx_pre, st["conv"], p["conv_w"])
    a, bb = rglru_gates(p, bx[:, None, :])
    a, bb = a[:, 0], bb[:, 0]
    h_new = a * st["h"] + bb
    out = (h_new.astype(x.dtype) * by) @ p["wo"]
    x = x + out[:, None, :]
    h2 = nn.rmsnorm(x, p["mlp_ln"])
    x = x + nn.gated_mlp(h2, act=jax.nn.gelu, **p["mlp"])
    return x, {"h": h_new, "conv": conv_buf}


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
    tok = batch["token"]
    x = jnp.take(params["embed"], tok, axis=0)
    b = x.shape[0]
    pos = cache["pos"]                                   # (B,)
    positions = pos[:, None]
    cap = cache["k"].shape[2]
    slot = (pos % cap).astype(jnp.int32)
    rows = jnp.arange(b)
    k_pos = jnp.where(jnp.arange(cache["k_pos"].shape[1])[None, :]
                  == slot[:, None], pos[:, None], cache["k_pos"])
    acfg = cfg.replace(sliding_window=cfg.local_window, qk_norm=False)

    def body(x, args):
        p, st1, st2, kc, vc = args
        x, st1 = _rec_step(cfg, p["rec1"], x, st1)
        x, st2 = _rec_step(cfg, p["rec2"], x, st2)
        pa = p["attn"]
        h = nn.rmsnorm(x, pa["ln1"])
        q, k, v = tfm._project_qkv(acfg, pa["attn"], h, positions)
        kc = nn.masked_cache_update(kc, k, slot)
        vc = nn.masked_cache_update(vc, v, slot)
        ctx = nn.attend(q, kc, vc, positions, k_pos, causal=True,
                        window=cfg.local_window)
        x = x + ctx.reshape(b, 1, cfg.q_dim) @ pa["attn"]["wo"]
        h2 = nn.rmsnorm(x, pa["ln2"])
        x = x + nn.gated_mlp(h2, act=jax.nn.gelu, **pa["mlp"])
        return x, (st1, st2, kc, vc)

    x, (st1, st2, k_new, v_new) = jax.lax.scan(
        body, x, (params["supers"], cache["rec1"], cache["rec2"],
                  cache["k"], cache["v"]), unroll=cfg.unroll_scans)
    new_cache = dict(cache)
    new_cache.update(rec1=st1, rec2=st2, k=k_new, v=v_new, k_pos=k_pos,
                     pos=pos + 1)
    for i in range(n_tail(cfg)):
        x, st = _rec_step(cfg, params[f"tail{i}"], x, cache[f"tail{i}"])
        new_cache[f"tail{i}"] = st
    x = nn.rmsnorm(x, params["final_norm"])
    logits = tfm.logits_fn(cfg, params, x)
    return logits, new_cache
