"""Mixture-of-Experts block (mixtral-8x7b top-2, dbrx top-4).

TPU-native capacity-based dispatch: tokens are grouped (one group per batch
row), routed with top-k, and dispatched to experts through one-hot einsums —
the all-to-all pattern XLA SPMD lowers for expert parallelism. Experts shard
over the "model" axis when the expert count divides it (dbrx: 16/16); when it
does not (mixtral: 8), the sharding rules fall back to tensor-parallel
experts (per-expert d_ff over "model") automatically.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import Spec
from repro.sharding import constrain, shard_map


def moe_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": Spec((d, e), ("embed", "experts")),
        "wi": Spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wg": Spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": Spec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def _capacity(cfg: ModelConfig, group_tokens: int) -> int:
    cap = int(group_tokens * cfg.top_k * cfg.capacity_factor
              // cfg.n_experts)
    return max(cap, cfg.top_k)


def moe_block(cfg: ModelConfig, p: Dict, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_load_balance_loss). Groups = batch rows."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, s)

    gate_logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(gate_logits, axis=-1)             # (B,S,E)
    top_p, top_i = jax.lax.top_k(probs, k)                   # (B,S,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize

    # Load-balancing auxiliary loss (Switch/Mixtral style).
    me = jnp.mean(probs, axis=(0, 1))                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    if cfg.moe_impl == "sorted":
        y = _sorted_dispatch(cfg, p, x, top_p, top_i, cap)
        return y, aux
    if cfg.moe_impl == "sorted_shmap":
        return _sorted_shard_map(cfg, p, x)

    # Position of each (token, choice) inside its expert's buffer.
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.int32)        # (B,S,k,E)
    flat = onehot.reshape(b, s * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat           # (B,S*k,E)
    pos_in_expert = pos_in_expert.reshape(b, s, k, e)
    within_cap = pos_in_expert < cap

    # dispatch: (B,S,E,C) one-hot; combine carries the gate weight.
    slot_oh = jax.nn.one_hot(pos_in_expert, cap, dtype=x.dtype)   # (B,S,k,E,C)
    sel = (onehot.astype(x.dtype) * within_cap.astype(x.dtype))[..., None]
    dispatch = jnp.sum(slot_oh * sel, axis=2)                     # (B,S,E,C)
    combine = jnp.sum(slot_oh * sel * top_p[..., None, None].astype(x.dtype),
                      axis=2)                                     # (B,S,E,C)

    xe = jnp.einsum("bsd,bsec->ebcd", x, dispatch)                # (E,B,C,D)
    xe = constrain(xe, "experts", "batch", None, "embed")
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, p["wi"]))
    h = h * jnp.einsum("ebcd,edf->ebcf", xe, p["wg"])
    h = constrain(h, "experts", "batch", None, "expert_mlp")
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["wo"])                 # (E,B,C,D)
    y = jnp.einsum("ebcd,bsec->bsd", ye, combine)
    return y, aux


# ---------------------------------------------------------------------------
# §Perf: sort-based dispatch — O(T·D) data movement instead of O(T·E·C·D)
# one-hot matmuls. Same group-local capacity/drop semantics as the einsum
# path (stable sort preserves token order within an expert).
# ---------------------------------------------------------------------------


def _group_sorted(cfg: ModelConfig, wi, wg, wo, xg, pg, ig, cap: int,
                  psum_axis=None):
    """One group's sorted dispatch. xg: (S,D); pg/ig: (S,k) -> (S,D).

    When the per-expert ffn dim is model-sharded (wi: (E,D,F_loc)), the
    caller passes psum_axis and the partial wo contraction is psum'ed.
    """
    s, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    n = s * k
    gate = pg.reshape(n)
    expert = ig.reshape(n)
    tok = jnp.repeat(jnp.arange(s), k)
    order = jnp.argsort(expert, stable=True)          # (n,)
    se, st, sg = expert[order], tok[order], gate[order]
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(n) - seg_start[se]
    slot = jnp.where(pos < cap, se * cap + pos, e * cap)   # drop -> tail
    buf = jnp.zeros((e * cap + 1, d), xg.dtype)
    buf = buf.at[slot].set(xg[st])
    xe = buf[:e * cap].reshape(e, cap, d)             # (E,C,D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wi))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wg)
    ye = jnp.einsum("ecf,efd->ecd", h, wo).reshape(e * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)])
    out_choice = ye[slot] * sg[:, None].astype(ye.dtype)
    y = jnp.zeros((s, d), xg.dtype).at[st].add(out_choice)
    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)
    return y


def _sorted_shard_map(cfg: ModelConfig, p: Dict, x: jax.Array):
    """§Perf: sorted dispatch under shard_map — every scatter/gather runs
    shard-LOCAL on the data-parallel shard, so GSPMD can never decide to
    replicate the dispatch buffers (the failure mode of the plain vmap
    version: an all-gathered f32[B, E*C, D] buffer on every device).

    Requires the mixtral-style layout (experts replicated, per-expert ffn
    dim sharded over "model"); falls back to the vmap path without a mesh
    or when the batch does not divide the dp axes.
    """
    from jax.sharding import PartitionSpec as P
    from repro import sharding as shd

    mesh = shd.current_mesh()
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, s)
    dp = shd.dp_axes(mesh) if mesh is not None else ()
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    experts_sharded = (mesh is not None and e % mesh.shape.get("model", 1)
                       == 0 and mesh.shape.get("model", 1) > 1)
    if mesh is None or b % max(dp_size, 1) != 0 or experts_sharded:
        # no mesh / ragged batch / EP layout: plain paths handle it
        gate_logits = (x.astype(jnp.float32)
                       @ p["router"].astype(jnp.float32))
        probs = jax.nn.softmax(gate_logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32),
                      axis=(0, 1))
        aux = cfg.router_aux_coef * e * jnp.sum(me * ce)
        return _sorted_dispatch(cfg, p, x, top_p, top_i, cap), aux

    def local(xl, router, wi, wg, wo):
        gate_logits = xl.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(gate_logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = (top_p / jnp.sum(top_p, axis=-1, keepdims=True)
                 ).astype(xl.dtype)
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32),
                      axis=(0, 1))
        aux_l = cfg.router_aux_coef * e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux_l, dp) if dp else aux_l
        y = jax.vmap(lambda xg, pg, ig: _group_sorted(
            cfg, wi, wg, wo, xg, pg, ig, cap))(xl, top_p, top_i)
        if "model" in mesh.axis_names:
            y = jax.lax.psum(y, "model")
        return y, aux

    wspec = P(None, None, "model")
    out = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), wspec, wspec,
                  P(None, "model", None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return out


def _sorted_dispatch(cfg: ModelConfig, p: Dict, x: jax.Array,
                     top_p: jax.Array, top_i: jax.Array,
                     cap: int) -> jax.Array:
    return jax.vmap(lambda xg, pg, ig: _group_sorted(
        cfg, p["wi"], p["wg"], p["wo"], xg, pg, ig, cap))(
            x, top_p.astype(x.dtype), top_i)
