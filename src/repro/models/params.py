"""Single-source-of-truth parameter declaration.

Each model family declares its parameters once as a pytree of ``Spec`` leaves
(shape + logical axes + initializer). From that single tree we derive:
  * ``abstract(tree)``  — ShapeDtypeStructs (dry-run, no allocation)
  * ``init(tree, rng)`` — materialized parameters (smoke tests / training)
  * ``shardings(tree, mesh)`` — NamedShardings via repro.sharding rules
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as shd


class Spec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | lru_a | pos
    scale: float = 1.0          # multiplier on fan-in-scaled normal


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def tree_map(f, tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_spec)


def stack(n: int, tree):
    """Prepend a scanned 'layers' dim of size n to every Spec in the tree."""
    return tree_map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        tree)


def abstract(tree, dtype=jnp.bfloat16):
    return tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)


def shardings(tree, mesh, dtype=jnp.bfloat16):
    return tree_map(lambda s: shd.named_sharding(mesh, s.shape, s.axes), tree)


def pspecs(tree, mesh):
    return tree_map(lambda s: shd.spec_for(mesh, s.shape, s.axes), tree)


def _init_leaf(s: Spec, key, dtype):
    if s.init == "zeros":
        return jnp.zeros(s.shape, dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, dtype)
    if s.init == "lru_a":
        # RG-LRU Lambda init: a in [0.9, 0.999] -> Lambda = softplus^-1 scheme
        u = jax.random.uniform(key, s.shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))  # inverse softplus
        return lam.astype(dtype)
    if s.init == "ssm_a":
        # A_log init: A in [1, 16) -> log
        u = jax.random.uniform(key, s.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if s.init == "ssm_dt":
        # dt bias: softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, s.shape, jnp.float32, math.log(1e-3),
                               math.log(1e-1))
        dt = jnp.exp(u)
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    if s.init == "pos":
        # sinusoid-free small normal for learned positional embeddings
        return (0.02 * jax.random.normal(key, s.shape, jnp.float32)
                ).astype(dtype)
    # fan-in scaled normal
    fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
    std = s.scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, s.shape, jnp.float32)).astype(dtype)


def init(tree, rng, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def count(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def fsdp_spec(s: Spec) -> Spec:
    """Add the data-parallel ("zero") axis to the largest effectively-
    replicated dim — FSDP-style parameter sharding (and the ZeRO-1 transform
    for optimizer states). Needed to FIT models like llama3-405b whose
    tensor-parallel-only shards exceed per-chip HBM."""
    shd.RULES.setdefault("zero", ("__dp__",))
    axes = list(s.axes)
    best, best_dim = None, 0
    for i, (d, a) in enumerate(zip(s.shape, axes)):
        replicated = a is None or not any(shd.RULES.get(a, ()))
        if replicated and d > best_dim:
            best, best_dim = i, d
    if best is not None:
        axes[best] = "zero"
    return Spec(s.shape, tuple(axes), s.init, s.scale)
