"""Unified model API: every architecture family behind one functional
interface, dispatched on ``cfg.family``.

  model_specs(cfg)                 -> Spec tree (single source of truth)
  abstract_params(cfg)             -> ShapeDtypeStructs (no allocation)
  init_params(cfg, rng)            -> materialized params
  param_shardings(cfg, mesh)       -> NamedSharding tree
  loss_fn(cfg, params, batch)      -> (scalar loss, metrics)
  prefill(cfg, params, batch)      -> (logits, cache)
  decode_step(cfg, params, cache, batch) -> (logits, cache)
  cache_specs / init_cache / abstract_cache
  make_batch(cfg, shape, rng)      -> concrete batch (smoke tests)
  input_specs(cfg, shape)          -> ShapeDtypeStruct batch (dry-run)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ModelConfig, InputShape, DENSE, MOE, HYBRID,
                                SSM, ENCDEC, VLM)
from repro.models import params as pm
from repro.models import transformer as tfm
from repro.models import rglru as rg
from repro.models import mamba2 as mb
from repro.models import whisper as wh
from repro.sharding import constrain

_FAMILY_MODULES = {DENSE: tfm, MOE: tfm, VLM: tfm, HYBRID: rg, SSM: mb,
                   ENCDEC: wh}


def _mod(cfg: ModelConfig):
    return _FAMILY_MODULES[cfg.family]


# ------------------------------------------------------------- params ------
def model_specs(cfg: ModelConfig):
    return _mod(cfg).model_specs(cfg)


def abstract_params(cfg: ModelConfig):
    return pm.abstract(model_specs(cfg), jnp.bfloat16)


def init_params(cfg: ModelConfig, rng):
    return pm.init(model_specs(cfg), rng, jnp.bfloat16)


def _sharding_specs(cfg: ModelConfig):
    tree = model_specs(cfg)
    if cfg.param_fsdp:
        tree = pm.tree_map(pm.fsdp_spec, tree)
    return tree


def param_shardings(cfg: ModelConfig, mesh):
    return pm.shardings(_sharding_specs(cfg), mesh)


def param_pspecs(cfg: ModelConfig, mesh):
    return pm.pspecs(_sharding_specs(cfg), mesh)


def param_count(cfg: ModelConfig) -> int:
    return pm.count(model_specs(cfg))


# --------------------------------------------------------------- loss ------
def _lm_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
             mask: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict,
            remat: bool = True) -> Tuple[jax.Array, Dict]:
    """Next-token CE (+ MoE aux) for every family."""
    if cfg.family == ENCDEC:
        logits = wh.decode_train(cfg, params, batch["tokens"],
                                 wh.encode(cfg, params, batch["frames"]),
                                 remat=remat)
        loss = _lm_loss(cfg, logits, batch["labels"], batch["mask"])
        return loss, {"ce": loss, "aux": 0.0}

    if cfg.family in (DENSE, MOE, VLM):
        embeds = tfm.embed_inputs(cfg, params, batch)
        h, _, aux = tfm.forward_hidden(cfg, params, embeds, remat=remat)
        if cfg.family == VLM:                    # loss over text positions
            h = h[:, cfg.n_img_tokens:, :]
        logits = tfm.logits_fn(cfg, params, h)
    elif cfg.family == HYBRID:
        embeds = jnp.take(params["embed"], batch["tokens"], axis=0)
        embeds = constrain(embeds, "batch", None, "embed")
        h, _, aux = rg.forward_hidden(cfg, params, embeds, remat=remat)
        logits = tfm.logits_fn(cfg, params, h)
    elif cfg.family == SSM:
        embeds = jnp.take(params["embed"], batch["tokens"], axis=0)
        embeds = constrain(embeds, "batch", None, "embed")
        h, _, aux = mb.forward_hidden(cfg, params, embeds, remat=remat)
        logits = tfm.logits_fn(cfg, params, h)
    else:
        raise ValueError(cfg.family)
    ce = _lm_loss(cfg, logits, batch["labels"], batch["mask"])
    return ce + aux, {"ce": ce, "aux": aux}


# -------------------------------------------------------------- serve ------
def prefill(cfg: ModelConfig, params: Dict, batch: Dict,
            context_len: Optional[int] = None):
    return _mod(cfg).prefill(cfg, params, batch, context_len)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
    return _mod(cfg).decode_step(cfg, params, cache, batch)


def cache_specs(cfg: ModelConfig, batch_size: int, context_len: int):
    return _mod(cfg).cache_specs(cfg, batch_size, context_len)


def init_cache(cfg: ModelConfig, batch_size: int, context_len: int):
    return _mod(cfg).init_cache(cfg, batch_size, context_len)


def abstract_cache(cfg: ModelConfig, batch_size: int, context_len: int):
    """ShapeDtypeStruct cache with the dtypes init_cache would produce."""
    concrete_dtypes = jax.eval_shape(
        lambda: init_cache(cfg, batch_size, context_len))
    return concrete_dtypes


def cache_shardings(cfg: ModelConfig, mesh, batch_size: int,
                    context_len: int):
    return pm.shardings(cache_specs(cfg, batch_size, context_len), mesh)


def cache_pspecs(cfg: ModelConfig, mesh, batch_size: int, context_len: int):
    return pm.pspecs(cache_specs(cfg, batch_size, context_len), mesh)


# ------------------------------------------------------------- inputs ------
def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len - cfg.n_img_tokens if cfg.family == VLM else seq_len


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, pm.Spec]:
    """Spec tree for a train/prefill batch (decode handled separately)."""
    b = shape.global_batch
    s = _text_len(cfg, shape.seq_len)
    out = {"tokens": pm.Spec((b, s), ("batch", None), "zeros")}
    if shape.kind == "train":
        out["labels"] = pm.Spec((b, s), ("batch", None), "zeros")
        out["mask"] = pm.Spec((b, s), ("batch", None), "ones")
    if cfg.family == VLM:
        out["image_embeds"] = pm.Spec((b, cfg.n_img_tokens, cfg.d_model),
                                      ("batch", None, "embed"))
    if cfg.family == ENCDEC:
        out["frames"] = pm.Spec((b, cfg.n_enc_frames, cfg.d_model),
                                ("batch", None, "embed"))
    return out


def decode_batch_specs(cfg: ModelConfig, shape: InputShape):
    return {"token": pm.Spec((shape.global_batch, 1), ("batch", None),
                             "zeros")}


_BATCH_DTYPES = {"tokens": jnp.int32, "labels": jnp.int32,
                 "token": jnp.int32, "mask": jnp.float32,
                 "image_embeds": jnp.bfloat16, "frames": jnp.bfloat16}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    tree = (decode_batch_specs(cfg, shape) if shape.kind == "decode"
            else batch_specs(cfg, shape))
    return {k: jax.ShapeDtypeStruct(s.shape, _BATCH_DTYPES[k])
            for k, s in tree.items()}


def batch_shardings(cfg: ModelConfig, mesh, shape: InputShape):
    tree = (decode_batch_specs(cfg, shape) if shape.kind == "decode"
            else batch_specs(cfg, shape))
    return pm.shardings(tree, mesh)


def make_batch(cfg: ModelConfig, shape: InputShape, rng=None,
               batch: Optional[int] = None, seq: Optional[int] = None
               ) -> Dict[str, jax.Array]:
    """Concrete random batch for smoke tests / real CPU execution."""
    rng = rng if rng is not None else np.random.default_rng(0)
    b = batch or shape.global_batch
    s = _text_len(cfg, seq or shape.seq_len)
    if shape.kind == "decode":
        return {"token": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)}
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                 jnp.int32)}
    if shape.kind == "train":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        out["mask"] = jnp.ones((b, s), jnp.float32)
    if cfg.family == VLM:
        out["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.family == ENCDEC:
        out["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_enc_frames, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return out
