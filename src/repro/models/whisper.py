"""Whisper-small backbone: transformer encoder over precomputed audio frame
embeddings (the conv frontend is a STUB per the assignment — ``input_specs``
supplies (B, n_enc_frames, d_model) tensors) + causal decoder with
cross-attention.

Deviation noted in DESIGN.md: the decoder uses RoPE instead of Whisper's
learned absolute positions so that the assigned decode_32k cache length is
well-defined; the encoder keeps learned positions over its fixed 1500 frames.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as nn
from repro.models import transformer as tfm
from repro.models.params import Spec, stack
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _mlp2_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    return {"wi": Spec((d, f), ("embed", "mlp")),
            "wo": Spec((f, d), ("mlp", "embed"))}


def _enc_layer(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": Spec((cfg.d_model,), ("embed",), "zeros"),
            "attn": tfm.attn_specs(cfg),
            "ln2": Spec((cfg.d_model,), ("embed",), "zeros"),
            "mlp": _mlp2_specs(cfg)}


def _dec_layer(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": Spec((cfg.d_model,), ("embed",), "zeros"),
            "self_attn": tfm.attn_specs(cfg),
            "ln_x": Spec((cfg.d_model,), ("embed",), "zeros"),
            "cross_attn": tfm.attn_specs(cfg),
            "ln2": Spec((cfg.d_model,), ("embed",), "zeros"),
            "mlp": _mlp2_specs(cfg)}


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "enc_pos": Spec((cfg.n_enc_frames, d), ("frames", "embed"), "pos"),
        "enc_layers": stack(cfg.n_enc_layers, _enc_layer(cfg)),
        "enc_norm": Spec((d,), ("embed",), "zeros"),
        "embed": Spec((cfg.vocab_size, d), ("vocab", "embed"), "normal", 0.7),
        "dec_layers": stack(cfg.num_layers, _dec_layer(cfg)),
        "final_norm": Spec((d,), ("embed",), "zeros"),
        "lm_head": Spec((d, cfg.vocab_size), ("embed", "vocab")),
    }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _mlp2(p: Dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


def _attn(cfg: ModelConfig, p: Dict, xq: jax.Array, xkv: jax.Array,
          q_pos, k_pos, causal: bool, rope: bool):
    b, sq, _ = xq.shape
    q = (xq @ p["wq"]).reshape(b, sq, cfg.n_heads, cfg.head_dim)
    k = (xkv @ p["wk"]).reshape(b, -1, cfg.n_kv_heads, cfg.head_dim)
    v = (xkv @ p["wv"]).reshape(b, -1, cfg.n_kv_heads, cfg.head_dim)
    if rope:
        q = nn.apply_rope(q, q_pos, cfg.rope_theta)
        k = nn.apply_rope(k, k_pos, cfg.rope_theta)
    ctx = nn.attend(q, k, v, q_pos, k_pos, causal=causal)
    return ctx.reshape(b, sq, cfg.q_dim) @ p["wo"], (k, v)


def encode(cfg: ModelConfig, params: Dict, frames: jax.Array) -> jax.Array:
    """frames: (B, F, D) precomputed embeddings (stub frontend)."""
    x = frames.astype(jnp.bfloat16) + params["enc_pos"][None].astype(
        jnp.bfloat16)
    x = constrain(x, "batch", None, "embed")
    f = x.shape[1]
    pos = jnp.arange(f)

    def body(x, p):
        h = nn.rmsnorm(x, p["ln1"])
        out, _ = _attn(cfg, p["attn"], h, h, pos, pos, causal=False,
                       rope=False)
        x = x + out
        h2 = nn.rmsnorm(x, p["ln2"])
        return x + _mlp2(p["mlp"], h2), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=cfg.unroll_scans)
    return nn.rmsnorm(x, params["enc_norm"])


def decode_train(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                 enc_out: jax.Array, remat: bool = False):
    b, s = tokens.shape
    f = enc_out.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", None, "embed")
    pos, fpos = jnp.arange(s), jnp.arange(f)

    def body(x, p):
        h = nn.rmsnorm(x, p["ln1"])
        out, _ = _attn(cfg, p["self_attn"], h, h, pos, pos, causal=True,
                       rope=True)
        x = x + out
        hx = nn.rmsnorm(x, p["ln_x"])
        out, _ = _attn(cfg, p["cross_attn"], hx, enc_out, pos, fpos,
                       causal=False, rope=False)
        x = x + out
        h2 = nn.rmsnorm(x, p["ln2"])
        return x + _mlp2(p["mlp"], h2), None

    fn = tfm._remat(cfg, body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["dec_layers"],
                        unroll=cfg.unroll_scans)
    x = nn.rmsnorm(x, params["final_norm"])
    return x @ params["lm_head"]


# ---------------------------------------------------------------------------
# Decode with caches
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch_size: int,
                context_len: int) -> Dict[str, Any]:
    cap = context_len + 128
    l, b = cfg.num_layers, batch_size
    kv = Spec((l, b, cap, cfg.n_kv_heads, cfg.head_dim),
              ("layers", "batch", "kv_seq" if cfg.decode_seq_shard else None,
               None, None), "zeros")
    xkv = Spec((l, b, cfg.n_enc_frames, cfg.n_kv_heads, cfg.head_dim),
               ("layers", "batch", None, None, None), "zeros")
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
            "k_pos": Spec((b, cap), ("batch", None), "zeros"),
            "pos": Spec((b,), ("batch",), "zeros")}


def init_cache(cfg: ModelConfig, batch_size: int, context_len: int) -> Dict:
    from repro.models import params as pm
    tree = cache_specs(cfg, batch_size, context_len)
    cache = pm.tree_map(lambda s: jnp.zeros(s.shape, jnp.bfloat16), tree)
    cache["k_pos"] = jnp.full(tree["k_pos"].shape, -1, jnp.int32)
    cache["pos"] = jnp.zeros(tree["pos"].shape, jnp.int32)
    return cache


def prefill(cfg: ModelConfig, params: Dict, batch: Dict,
            context_len: Optional[int] = None):
    """Encode frames, build the cross-attn cache, run decoder over prompt."""
    frames, tokens = batch["frames"], batch["tokens"]
    b, s = tokens.shape
    context_len = context_len if context_len is not None else s
    enc_out = encode(cfg, params, frames)
    f = enc_out.shape[1]
    cache = init_cache(cfg, b, context_len)
    x = jnp.take(params["embed"], tokens, axis=0)
    pos, fpos = jnp.arange(s), jnp.arange(f)

    def body(x, p):
        h = nn.rmsnorm(x, p["ln1"])
        out, kv = _attn(cfg, p["self_attn"], h, h, pos, pos, causal=True,
                        rope=True)
        x = x + out
        hx = nn.rmsnorm(x, p["ln_x"])
        out, xkv = _attn(cfg, p["cross_attn"], hx, enc_out, pos, fpos,
                         causal=False, rope=False)
        x = x + out
        h2 = nn.rmsnorm(x, p["ln2"])
        return x + _mlp2(p["mlp"], h2), (kv, xkv)

    x, ((ks, vs), (xks, xvs)) = jax.lax.scan(body, x, params["dec_layers"],
                                             unroll=cfg.unroll_scans)
    x = nn.rmsnorm(x, params["final_norm"])
    logits = x[:, -1:, :] @ params["lm_head"]
    cache["k"] = cache["k"].at[:, :, :s].set(ks)
    cache["v"] = cache["v"].at[:, :, :s].set(vs)
    cache["xk"], cache["xv"] = xks, xvs
    cache["k_pos"] = cache["k_pos"].at[:, :s].set(jnp.arange(s)[None])
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return logits, cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
    tok = batch["token"]
    x = jnp.take(params["embed"], tok, axis=0)
    b = x.shape[0]
    pos = cache["pos"]                                   # (B,)
    positions = pos[:, None]
    slot = pos.astype(jnp.int32)
    rows = jnp.arange(b)
    k_pos = jnp.where(jnp.arange(cache["k_pos"].shape[1])[None, :]
                  == slot[:, None], pos[:, None], cache["k_pos"])
    fpos = jnp.arange(cfg.n_enc_frames)

    def body(x, args):
        p, kc, vc, xk, xv = args
        h = nn.rmsnorm(x, p["ln1"])
        sa = p["self_attn"]
        q = (h @ sa["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = (h @ sa["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ sa["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
        kc = nn.masked_cache_update(kc, k, slot)
        vc = nn.masked_cache_update(vc, v, slot)
        ctx = nn.attend(q, kc, vc, positions, k_pos, causal=True)
        x = x + ctx.reshape(b, 1, cfg.q_dim) @ sa["wo"]
        hx = nn.rmsnorm(x, p["ln_x"])
        ca = p["cross_attn"]
        qx = (hx @ ca["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        ctx = nn.attend(qx, xk, xv, positions, fpos, causal=False)
        x = x + ctx.reshape(b, 1, cfg.q_dim) @ ca["wo"]
        h2 = nn.rmsnorm(x, p["ln2"])
        return x + _mlp2(p["mlp"], h2), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), unroll=cfg.unroll_scans)
    x = nn.rmsnorm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    new_cache = dict(cache)
    new_cache.update(k=k_new, v=v_new, k_pos=k_pos, pos=pos + 1)
    return logits, new_cache
