"""Core neural building blocks shared by every architecture family.

All functions are pure; activations enter/leave in the model compute dtype
(bf16) while softmax/normalization statistics are computed in f32.
Attention is *position-mask based* so the same kernel serves train, prefill,
full-cache decode and rolling-window-cache decode (positions array carries
slot validity for rolling buffers).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------- norms ----
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) or (B, S). NeoX half-split rotation."""
    d_half = x.shape[-1] // 2
    freq = theta ** (-jnp.arange(d_half, dtype=jnp.float32) / d_half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angle = positions[..., None].astype(jnp.float32) * freq  # (B,S,Dh)
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int]) -> jax.Array:
    """Additive mask. q_pos: (B?,Sq). k_pos: (T,) or (B,T); -1 = empty slot."""
    if k_pos.ndim == 1:
        k_pos = k_pos[None, :]
    if q_pos.ndim == 1:
        q_pos = q_pos[None, :]
    q = q_pos[:, :, None].astype(jnp.int32)          # (B,Sq,1)
    k = k_pos[:, None, :].astype(jnp.int32)          # (B,1,T)
    ok = k >= 0
    if causal:
        ok &= k <= q
    if window is not None:
        ok &= k > q - window
    return jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :]  # (B,1,1,Sq,T)


def attend(q: jax.Array, k: jax.Array, v: jax.Array,
           q_pos: jax.Array, k_pos: jax.Array, *,
           causal: bool = True, window: Optional[int] = None,
           softmax_scale: Optional[float] = None) -> jax.Array:
    """GQA attention. q: (B,Sq,H,D); k,v: (B,T,KH,D). Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    qr = q.reshape(b, sq, kh, g, d)
    scores = jnp.einsum("bqkgd,btkd->bkgqt", qr, k,
                        preferred_element_type=jnp.float32) * scale
    bias = _mask_bias(q_pos, k_pos, causal, window)  # (B,1,1,Sq,T)
    scores = scores + bias                           # broadcast over (KH,G)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgqt,btkd->bqkgd", probs.astype(v.dtype), v)
    return ctx.reshape(b, sq, h, d)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q0: int = 0, causal: bool = True,
                      window: Optional[int] = None,
                      q_chunk: int = 1024,
                      unroll: bool = False) -> jax.Array:
    """Scan over query blocks, touching only the kv range each block can see.

    q: (B,S,H,D) with absolute positions q0 + arange(S); k/v cover positions
    arange(T). Peak score memory is (B,KH,G,q_chunk,kv_width).

    §Perf: the kv range is restricted per query block — sliding-window
    attention reads a static window+q_chunk slice (scan-friendly dynamic
    slice), and pure-causal attention unrolls with exact [0,(i+1)*q_chunk)
    slices — cutting score FLOPs/bytes ~2x (causal) to ~T/(window+Cq)x
    (SWA) versus masking the full T.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    if s <= q_chunk:
        return attend(q, k, v, q0 + jnp.arange(s), jnp.arange(t),
                      causal=causal, window=window)
    assert s % q_chunk == 0, (s, q_chunk)
    nq = s // q_chunk
    qs = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)

    if causal and window is not None and window + q_chunk < t:
        # static-width kv slice ending at this block's last row
        w_kv = window + q_chunk

        def body(_, args):
            i, qc = args
            q_pos = q0 + i * q_chunk + jnp.arange(q_chunk)
            start = jnp.clip((i + 1) * q_chunk - w_kv, 0, t - w_kv)
            kc = jax.lax.dynamic_slice_in_dim(k, start, w_kv, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, w_kv, axis=1)
            k_pos = start + jnp.arange(w_kv)
            out = attend(qc, kc, vc, q_pos, k_pos, causal=True,
                         window=window)
            return None, out

        _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qs),
                               unroll=unroll)
        return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)

    if causal and q0 == 0 and t == s:
        # exact causal ranges; unrolled (layer stacks are scanned, so the
        # per-layer HLO stays modest)
        outs = []
        for i in range(nq):
            hi = (i + 1) * q_chunk
            q_pos = i * q_chunk + jnp.arange(q_chunk)
            out = attend(qs[i], k[:, :hi], v[:, :hi], q_pos,
                         jnp.arange(hi), causal=True, window=window)
            outs.append(out)
        return jnp.concatenate(outs, axis=1).reshape(b, s, h, d)

    k_pos = jnp.arange(t)

    def body(_, args):
        i, qc = args
        q_pos = q0 + i * q_chunk + jnp.arange(q_chunk)
        out = attend(qc, k, v, q_pos, k_pos, causal=causal, window=window)
        return None, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qs), unroll=unroll)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


# ------------------------------------------------------------------ mlp ----
def gated_mlp(x: jax.Array, wi: jax.Array, wg: jax.Array,
              wo: jax.Array, act=jax.nn.silu) -> jax.Array:
    h = act(x @ wi) * (x @ wg)
    h = constrain(h, "batch", None, "mlp")
    return h @ wo


# ------------------------------------------------------------- qk norm -----
def qk_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-head RMS norm over head_dim (qwen3 style). x: (B,S,H,D)."""
    return rmsnorm(x, scale)


# ---------------------------------------------------------- conv (SSM) -----
def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C); w: (C,K). Returns (B,S,C)."""
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w.T[:, None, :].astype(jnp.float32),        # (K,1,C) -> spec below
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out.astype(x.dtype)


def masked_cache_update(cache: jax.Array, new: jax.Array,
                        slot: jax.Array) -> jax.Array:
    """Write `new` (B,1,KH,D) into per-row slots of `cache` (B,T,KH,D).

    Implemented as a masked select rather than a scatter: per-row dynamic
    scatter indices on a sequence-sharded cache force the SPMD partitioner
    into full rematerialization (replicate + repartition), whereas an
    elementwise select keeps the "kv_seq" sharding intact on every shard.
    """
    t = cache.shape[1]
    mask = jnp.arange(t)[None, :] == slot[:, None]          # (B,T)
    return jnp.where(mask[:, :, None, None], new.astype(cache.dtype), cache)


def conv1d_step(x_t: jax.Array, buf: jax.Array,
                w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token causal conv with state buffer.

    x_t: (B,C); buf: (B,K-1,C) past inputs; w: (C,K).
    Returns (y_t (B,C), new_buf).
    """
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)    # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x_t.dtype)
    return y, window[:, 1:, :]
