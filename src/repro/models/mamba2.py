"""Mamba-2 SSD (state-space duality) — attention-free family.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the sequence is
processed in chunks; intra-chunk interactions are dense matmuls that map onto
the MXU, and inter-chunk state passing is a short ``lax.scan`` over chunk
states (nc = S/Q steps). Decode carries an O(1) state
(B, n_heads, headdim, d_state) — no KV cache — which is what makes the
long_500k shape runnable.

Projections are kept separate (wz/wx/wB/wC/wdt + per-stream depthwise convs)
so each stream shards cleanly: d_inner over "model", B/C streams replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as nn
from repro.models.params import Spec, stack
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    k = cfg.ssm_conv_width
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "wz": Spec((d, di), ("embed", "ssm_inner")),
        "wx": Spec((d, di), ("embed", "ssm_inner")),
        "wB": Spec((d, g * n), ("embed", None)),
        "wC": Spec((d, g * n), ("embed", None)),
        "wdt": Spec((d, nh), ("embed", "ssm_inner")),
        "conv_x": Spec((di, k), ("ssm_inner", None)),
        "conv_B": Spec((g * n, k), (None, None)),
        "conv_C": Spec((g * n, k), (None, None)),
        "A_log": Spec((nh,), ("ssm_inner",), "ssm_a"),
        "dt_bias": Spec((nh,), ("ssm_inner",), "ssm_dt"),
        "D": Spec((nh,), ("ssm_inner",), "ones"),
        "norm": Spec((di,), ("ssm_inner",), "zeros"),
        "wo": Spec((di, d), ("ssm_inner", "embed")),
    }


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    out = {
        "embed": Spec((cfg.vocab_size, d), ("vocab", "embed"), "normal", 0.7),
        "layers": stack(cfg.num_layers, layer_specs(cfg)),
        "final_norm": Spec((d,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((d, cfg.vocab_size), ("embed", "vocab"))
    return out


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                return_final_state: bool = False, unroll: bool = False):
    """SSD forward.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus, f32); A: (H,) negative f32;
    Bm/Cm: (B,S,G,N). Heads are grouped: H = G * heads_per_group.
    Returns y: (B,S,H,P) (f32).
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc, q = s // chunk, chunk
    hpg = h // g

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = Bm.astype(jnp.float32).reshape(b, nc, q, g, n)
    Cc = Cm.astype(jnp.float32).reshape(b, nc, q, g, n)

    dA = dtc * A[None, None, None, :]                    # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                         # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within chunk, MXU-friendly) ----
    # decay L[i,j] = exp(cum[i]-cum[j]) for i>=j
    li = cum[:, :, :, None, :]                           # (B,nc,Q,1,H)
    lj = cum[:, :, None, :, :]                           # (B,nc,1,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(li - lj), 0.0)           # (B,nc,Q,Q,H)
    cb = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)        # (B,nc,Q,Q,G)
    cb = jnp.repeat(cb, hpg, axis=-1)                    # (B,nc,Q,Q,H)
    w = cb * L * dtc[:, :, None, :, :]                   # weight over j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xf)

    # ---- chunk states ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,Q,H)
    xdt = xf * (dtc * decay_to_end)[..., None]           # (B,nc,Q,H,P)
    Bh = jnp.repeat(Bc, hpg, axis=3)                     # (B,nc,Q,H,N)
    states = jnp.einsum("bcqhn,bcqhp->bchnp", Bh, xdt)   # (B,nc,H,N,P)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def step(carry, args):
        st, dec = args                                   # (B,H,N,P),(B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                # emit PREVIOUS state

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=unroll)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B,nc,H,N,P)

    # ---- inter-chunk output ----
    Ch = jnp.repeat(Cc, hpg, axis=3)                     # (B,nc,Q,H,N)
    y_off = jnp.einsum("bcqhn,bchnp->bcqhp", Ch, prev_states)
    y_off = y_off * jnp.exp(cum)[..., None]
    y = (y_intra + y_off).reshape(b, s, h, p)
    if return_final_state:
        # cache layout is (B,H,P,N)
        return y, final_state.transpose(0, 1, 3, 2)
    return y


# ---------------------------------------------------------------------------
# Blocks / forward
# ---------------------------------------------------------------------------


def ssm_block(cfg: ModelConfig, p: Dict, x_in: jax.Array,
              collect_state: bool = False):
    b, s, _ = x_in.shape
    di, nh, pdim = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    kw = cfg.ssm_conv_width - 1
    h = nn.rmsnorm(x_in, p["ln"])
    z = h @ p["wz"]
    x_pre, B_pre, C_pre = h @ p["wx"], h @ p["wB"], h @ p["wC"]
    x = jax.nn.silu(nn.causal_conv1d(x_pre, p["conv_x"]))
    Bm = jax.nn.silu(nn.causal_conv1d(B_pre, p["conv_B"]))
    Cm = jax.nn.silu(nn.causal_conv1d(C_pre, p["conv_C"]))
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    x = constrain(x, "batch", None, "ssm_inner")
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    # pad the sequence to a chunk multiple; dt=0 on padding makes it inert
    # (decay exp(0)=1, contribution dt*x=0), so states/outputs are exact
    s_pad = -(-s // cfg.ssm_chunk) * cfg.ssm_chunk
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        x, Bm, Cm = (jnp.pad(t, pad) for t in (x, Bm, Cm))
        dt = jnp.pad(dt, pad)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        y, final = kops.ssd_scan(
            x.reshape(b, s_pad, nh, pdim), dt, A,
            Bm.reshape(b, s_pad, g, n), Cm.reshape(b, s_pad, g, n),
            chunk=min(cfg.ssm_chunk, s_pad))
    else:
        res = ssd_chunked(x.reshape(b, s_pad, nh, pdim), dt, A,
                          Bm.reshape(b, s_pad, g, n),
                          Cm.reshape(b, s_pad, g, n),
                          cfg.ssm_chunk, return_final_state=collect_state,
                          unroll=cfg.unroll_scans)
        y, final = res if collect_state else (res, None)
    y = y + (p["D"].astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32).reshape(b, s_pad, nh, pdim))
    y = y.reshape(b, s_pad, di)[:, :s].astype(x_in.dtype)
    y = nn.rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = x_in + y @ p["wo"]
    if collect_state:
        state = {"h": final,
                 "conv_x": x_pre[:, -kw:, :].astype(jnp.float32),
                 "conv_B": B_pre[:, -kw:, :].astype(jnp.float32),
                 "conv_C": C_pre[:, -kw:, :].astype(jnp.float32)}
        return out, state
    return out


def forward_hidden(cfg: ModelConfig, params: Dict, embeds: jax.Array, *,
                   collect_state: bool = False, remat: bool = False):
    from repro.models import transformer as tfm

    def body(x, p):
        x = ssm_block(cfg, p, x)
        seq_ax = "seq_sp" if cfg.seq_parallel else None
        return constrain(x, "batch", seq_ax, "embed"), None

    fn = tfm._remat(cfg, body) if remat else body
    x, _ = jax.lax.scan(fn, embeds, params["layers"],
                        unroll=cfg.unroll_scans)
    x = nn.rmsnorm(x, params["final_norm"])
    return x, None, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode — O(1) state
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch_size: int,
                context_len: int) -> Dict[str, Any]:
    del context_len                                      # O(1) state!
    l, b = cfg.num_layers, batch_size
    nh, pdim, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    kw = cfg.ssm_conv_width - 1
    gn = cfg.ssm_ngroups * n
    return {
        "h": Spec((l, b, nh, pdim, n),
                  ("layers", "batch", "ssm_inner", None, None), "zeros"),
        "conv_x": Spec((l, b, kw, cfg.d_inner),
                       ("layers", "batch", None, "ssm_inner"), "zeros"),
        "conv_B": Spec((l, b, kw, gn), ("layers", "batch", None, None),
                       "zeros"),
        "conv_C": Spec((l, b, kw, gn), ("layers", "batch", None, None),
                       "zeros"),
        "pos": Spec((b,), ("batch",), "zeros"),
    }


def init_cache(cfg: ModelConfig, batch_size: int, context_len: int) -> Dict:
    tree = cache_specs(cfg, batch_size, context_len)
    from repro.models import params as pm
    cache = pm.tree_map(lambda s: jnp.zeros(s.shape, jnp.float32), tree)
    cache["pos"] = jnp.zeros(tree["pos"].shape, jnp.int32)
    return cache


def prefill(cfg: ModelConfig, params: Dict, batch: Dict,
            context_len=None):
    """Prompt processing with exact decode-state handoff."""
    from repro.models import transformer as tfm
    tok = batch["tokens"]
    b, s = tok.shape
    embeds = jnp.take(params["embed"], tok, axis=0)

    def body(x, p):
        x, state = ssm_block(cfg, p, x, collect_state=True)
        seq_ax = "seq_sp" if cfg.seq_parallel else None
        return constrain(x, "batch", seq_ax, "embed"), state

    x, states = jax.lax.scan(body, embeds, params["layers"],
                             unroll=cfg.unroll_scans)
    x = nn.rmsnorm(x, params["final_norm"])
    logits = tfm.logits_fn(cfg, params, x[:, -1:, :])
    cache = dict(states)                        # (L, ...) stacked by scan
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return logits, cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
    from repro.models import transformer as tfm
    tok = batch["token"]
    x = jnp.take(params["embed"], tok, axis=0)           # (B,1,D)
    b = x.shape[0]
    di, nh, pdim = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state

    def body(x, args):
        p, hst, cx, cB, cC = args
        hh = nn.rmsnorm(x, p["ln"])[:, 0, :]             # (B,D)
        z = hh @ p["wz"]
        xs, cx = nn.conv1d_step(hh @ p["wx"], cx, p["conv_x"])
        Bs, cB = nn.conv1d_step(hh @ p["wB"], cB, p["conv_B"])
        Cs, cC = nn.conv1d_step(hh @ p["wC"], cC, p["conv_C"])
        xs, Bs, Cs = map(jax.nn.silu, (xs, Bs, Cs))
        dt = jax.nn.softplus((hh @ p["wdt"]).astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))  # (B,H)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        xh = xs.astype(jnp.float32).reshape(b, nh, pdim)
        Bh = jnp.repeat(Bs.astype(jnp.float32).reshape(b, g, n),
                        nh // g, axis=1)                 # (B,H,N)
        Ch = jnp.repeat(Cs.astype(jnp.float32).reshape(b, g, n),
                        nh // g, axis=1)
        decay = jnp.exp(dt * A)                          # (B,H)
        hst = (hst * decay[:, :, None, None]
               + (dt[:, :, None] * xh)[..., None] * Bh[:, :, None, :])
        y = jnp.einsum("bhpn,bhn->bhp", hst, Ch)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(b, di).astype(x.dtype)
        y = nn.rmsnorm(y * jax.nn.silu(z), p["norm"])
        x = x + (y @ p["wo"])[:, None, :]
        return x, (hst, cx, cB, cC)

    x, (h_new, cx, cB, cC) = jax.lax.scan(
        body, x, (params["layers"], cache["h"], cache["conv_x"],
                  cache["conv_B"], cache["conv_C"]), unroll=cfg.unroll_scans)
    x = nn.rmsnorm(x, params["final_norm"])
    logits = tfm.logits_fn(cfg, params, x)
    new_cache = dict(cache)
    new_cache.update(h=h_new, conv_x=cx, conv_B=cB, conv_C=cC,
                     pos=cache["pos"] + 1)
    return logits, new_cache
