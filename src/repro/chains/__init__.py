"""Function-chain subsystem (paper §3.1.3 collaborative execution +
§5.1.4 data localization): model an application as a DAG of functions
with typed data edges, plan placement for the whole chain with a
data-gravity cost model, and execute it collaboratively across target
platforms.

    from repro.chains import catalog, DataGravityPlanner, ChainExecutor

    tmpl = catalog.get("etl-pipeline")
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    plan = planner.plan(tmpl.chain, list(cp.platforms.values()))
    ex = ChainExecutor(cp, fns)
    inst = ex.launch(tmpl.chain, plan)
"""
from repro.chains.spec import EXTERNAL, Chain, DataEdge, Stage
from repro.chains.planner import (PLAN_MODES, ChainPlan,
                                  DataGravityPlanner)
from repro.chains.executor import ChainExecutor, ChainInstance
from repro.chains import catalog
from repro.chains.catalog import ChainInput, ChainTemplate

__all__ = [
    "EXTERNAL", "Chain", "DataEdge", "Stage",
    "PLAN_MODES", "ChainPlan", "DataGravityPlanner",
    "ChainExecutor", "ChainInstance",
    "catalog", "ChainInput", "ChainTemplate",
]
