"""Chain catalog: named multi-stage applications for the FDNInspector.

A ``ChainTemplate`` bundles the DAG with the stage functions it needs
deployed and the external input objects that give it data gravity (each
input may pin a location — the paper's "data lives somewhere" premise —
or default to the scenario's ``data_location``).

Templates:

  ``etl-pipeline``          extract -> transform (fan-out 4) -> aggregate
                            -> load; a linear ETL with one wide stage.
  ``ml-preprocess-serve``   image preprocess -> model serve -> respond,
                            built from the paper's Table-2 functions.
  ``ab-dual-source``        two gravity anchors (a 48 MB source pinned to
                            one platform, a small source pinned to
                            another) feeding a fan-in join — the chain the
                            split-vs-colocate A/B scenarios measure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.chains.spec import EXTERNAL, Chain, DataEdge, Stage
from repro.core.types import SLO, FunctionSpec


@dataclass(frozen=True)
class ChainInput:
    """One external object a chain reads: seeded before the run."""
    key: str
    size_bytes: float
    location: Optional[str] = None     # None -> scenario data_location


@dataclass(frozen=True)
class ChainTemplate:
    chain: Chain
    functions: Dict[str, FunctionSpec] = field(default_factory=dict)
    inputs: Tuple[ChainInput, ...] = ()


_BUILDERS: Dict[str, Callable[[], ChainTemplate]] = {}


def register(name: str, builder: Callable[[], ChainTemplate]) -> None:
    _BUILDERS[name] = builder


def names() -> List[str]:
    return sorted(_BUILDERS)


def get(name: str) -> ChainTemplate:
    if name not in _BUILDERS:
        raise KeyError(f"unknown chain {name!r}; "
                       f"registered: {', '.join(names())}")
    return _BUILDERS[name]()


# ---------------------------------------------------------------------------
# etl-pipeline
# ---------------------------------------------------------------------------

def etl_pipeline() -> ChainTemplate:
    fns = {
        "chain-extract": FunctionSpec(
            name="chain-extract", flops=4e8, read_bytes=8e6,
            write_bytes=6e6, memory_mb=256, slo=SLO(5.0)),
        "chain-transform": FunctionSpec(
            name="chain-transform", flops=2e9, read_bytes=6e6,
            write_bytes=5e5, memory_mb=512, slo=SLO(10.0)),
        "chain-aggregate": FunctionSpec(
            name="chain-aggregate", flops=5e8, read_bytes=2e6,
            write_bytes=5e5, memory_mb=256, slo=SLO(5.0)),
        "chain-load": FunctionSpec(
            name="chain-load", flops=2e7, read_bytes=5e5,
            write_bytes=1e5, memory_mb=128, slo=SLO(2.0)),
    }
    chain = Chain(
        name="etl-pipeline",
        stages=(Stage("extract", "chain-extract"),
                Stage("transform", "chain-transform", fan_out=4),
                Stage("aggregate", "chain-aggregate"),
                Stage("load", "chain-load")),
        edges=(DataEdge(EXTERNAL, "extract", "chains/etl/source", 8e6),
               DataEdge("extract", "transform", "records", 6e6),
               DataEdge("transform", "aggregate", "features", 2e6),
               DataEdge("aggregate", "load", "summary", 5e5)))
    return ChainTemplate(chain, fns,
                         (ChainInput("chains/etl/source", 8e6),))


# ---------------------------------------------------------------------------
# ml-preprocess-serve (reuses the paper's Table-2 functions as stages)
# ---------------------------------------------------------------------------

def ml_preprocess_serve() -> ChainTemplate:
    chain = Chain(
        name="ml-preprocess-serve",
        stages=(Stage("preprocess", "image-processing"),
                Stage("serve", "sentiment-analysis", fan_out=2,
                      slo_p90_s=8.0),
                Stage("respond", "JSON-loads")),
        edges=(DataEdge(EXTERNAL, "preprocess", "images/sample.jpg", 2e6),
               DataEdge("preprocess", "serve", "tensors", 3e6),
               DataEdge("serve", "respond", "scores", 1e5)))
    # stage functions are the already-deployed paper functions; only the
    # image input is (re)declared so standalone harnesses can seed it
    return ChainTemplate(chain, {},
                         (ChainInput("images/sample.jpg", 2e6),))


# ---------------------------------------------------------------------------
# ab-dual-source (split-vs-colocate A/B)
# ---------------------------------------------------------------------------

AB_BIG_HOME = "cloud-cluster"
AB_SMALL_HOME = "old-hpc-node-cluster"


def ab_dual_source() -> ChainTemplate:
    """Two data-gravity anchors: a 48 MB source pinned to the cloud
    cluster and a small source pinned to the old HPC cluster, feeding a
    fan-in join.  The shard stage is I/O-bound (prefers the old HPC's
    10 Gb/s store path), the join/report are compute-bound (prefer the
    cloud's faster replicas) — so a compute-greedy split lands the shard
    work off the colocation platform and the WAN price of that choice is
    exactly the 16 MB of shard features crossing platforms."""
    fns = {
        "chain-extract-big": FunctionSpec(
            name="chain-extract-big", flops=2e8, read_bytes=48e6,
            write_bytes=8e6, memory_mb=512, slo=SLO(20.0)),
        "chain-shard": FunctionSpec(
            name="chain-shard", flops=1e9, read_bytes=60e6,
            write_bytes=4e6, memory_mb=512, slo=SLO(20.0)),
        "chain-join": FunctionSpec(
            name="chain-join", flops=3e9, read_bytes=20e6,
            write_bytes=1e6, memory_mb=512, slo=SLO(20.0)),
        "chain-report": FunctionSpec(
            name="chain-report", flops=5e7, read_bytes=1e6,
            write_bytes=1e4, memory_mb=128, slo=SLO(20.0)),
    }
    chain = Chain(
        name="ab-dual-source",
        stages=(Stage("extract-big", "chain-extract-big"),
                Stage("shard", "chain-shard", fan_out=4),
                Stage("join", "chain-join"),
                Stage("report", "chain-report")),
        edges=(DataEdge(EXTERNAL, "extract-big", "chains/ab/big-source",
                        48e6),
               DataEdge(EXTERNAL, "shard", "chains/ab/small-source", 4e6),
               DataEdge("extract-big", "join", "big-features", 8e6),
               DataEdge("shard", "join", "small-features", 16e6),
               DataEdge("join", "report", "joined", 1e6)))
    return ChainTemplate(
        chain, fns,
        (ChainInput("chains/ab/big-source", 48e6, AB_BIG_HOME),
         ChainInput("chains/ab/small-source", 4e6, AB_SMALL_HOME)))


register("etl-pipeline", etl_pipeline)
register("ml-preprocess-serve", ml_preprocess_serve)
register("ab-dual-source", ab_dual_source)
