"""Chain executor: collaborative execution of planned chains on the FDN.

Built on ``FDNControlPlane.submit_batch``: stage releases are *batched* —
completions mark successors ready, and every stage that became ready in
the same batch window is admitted in one per-platform burst.  Intermediate
objects are recorded into the executing platform's object store, so a
downstream stage placed elsewhere physically pays the inter-platform
transfer through ``DataPlacementManager.access_time`` (the same machinery
single invocations use).  Bytes-moved and transfer-seconds are accounted
into the ``MetricsRegistry`` per chain label.

Optional proactive staging (§3.1.3 (2)): when a stage is admitted, the
*external* inputs of its successors are staged (``stage_for``) onto their
planned platforms, overlapping the pull with the predecessor's execution.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

from repro.chains.planner import ChainPlan
from repro.chains.spec import Chain, DataEdge, Stage
from repro.core.control_plane import FDNControlPlane
from repro.core.loadgen import attach_completion_hooks
from repro.core.types import SLO, FunctionSpec, Invocation


class ChainInstance:
    """One in-flight execution of a chain (a chain 'invocation')."""

    __slots__ = ("id", "label", "chain", "plan", "t0", "end_t", "status",
                 "remaining", "outstanding", "stages_done", "bytes_moved",
                 "transfer_s", "stage_ready")

    def __init__(self, iid: int, label: str, chain: Chain, plan: ChainPlan,
                 t0: float):
        self.id = iid
        self.label = label
        self.chain = chain
        self.plan = plan
        self.t0 = t0
        self.end_t: Optional[float] = None
        self.status = "running"               # running | done | failed
        # stage -> unfinished internal predecessors
        self.remaining: Dict[str, int] = {
            s.name: len(chain.preds(s.name)) for s in chain.stages}
        self.outstanding: Dict[str, int] = {}  # stage -> in-flight invs
        self.stages_done = 0
        self.bytes_moved = 0.0
        self.transfer_s = 0.0
        # stage -> ready instant; only filled when a flight recorder is
        # attached (the chain-stage spans' t0)
        self.stage_ready: Dict[str, float] = {}

    @property
    def latency(self) -> Optional[float]:
        return None if self.end_t is None else self.end_t - self.t0


class _StageSlot:
    """One stage-invocation completion slot.  The original invocation and
    any hedged duplicates the control plane spawns for it (batch-aware
    hedging arms one timer per released stage batch) all point at the
    same slot: the FIRST completion consumes it and advances the chain —
    so a winning speculative duplicate finishes the stage, at the
    platform it actually ran on.  ``carriers`` counts in-flight copies;
    the instance only fails when every carrier is exhausted."""

    __slots__ = ("inst", "stage", "consumed", "carriers")

    def __init__(self, inst: ChainInstance, stage: Stage):
        self.inst = inst
        self.stage = stage
        self.consumed = False
        self.carriers = 1


class ChainExecutor:
    """Drives chain instances over one control plane.

    ``sink`` (optional, a ``loadgen.ColumnarResultSink``) gets its
    ``submitted``/``rejected`` counters bumped for every stage invocation,
    keeping ScenarioReport totals consistent with the per-stage completion
    columns the sink already collects from the platforms.

    Stage releases ride ``FDNControlPlane.submit_batch``, so with hedging
    enabled each released stage batch arms one vectorized hedge timer per
    (fn, platform) group; ``HedgePolicy.on_duplicate`` wires the
    duplicates back into the originals' stage slots.
    """

    METRIC_SCOPE = "_chain"

    def __init__(self, cp: FDNControlPlane, fns: Dict[str, FunctionSpec],
                 sink=None, batch_window_s: float = 0.0,
                 proactive_staging: bool = True,
                 cleanup_intermediates: bool = True):
        self.cp = cp
        self.clock = cp.clock
        self.fns = dict(fns)
        self.sink = sink
        self.batch_window_s = batch_window_s
        self.proactive_staging = proactive_staging
        self.cleanup_intermediates = cleanup_intermediates
        attach_completion_hooks(cp)
        self._ids = itertools.count()
        # (instance, stage, platform) triples awaiting one batched release
        self._pending: List[Tuple[ChainInstance, Stage, str]] = []
        self._flush_scheduled = False
        # in-flight stage invocations (originals AND hedged duplicates)
        # -> their completion slot (failure tracking + first-wins)
        self._owner: Dict[int, _StageSlot] = {}
        for p in cp.platforms.values():
            p.on_fail.append(self._on_platform_fail)
        cp.hedge.on_duplicate.append(self._on_hedge_dup)
        self._spec_cache: Dict[Tuple[str, Tuple[str, ...],
                                     Optional[float]], FunctionSpec] = {}
        self.launched = 0
        self.launched_by_label: Dict[str, int] = {}
        self.completed = 0
        self.failed = 0
        self.plans: Dict[str, ChainPlan] = {}         # label -> plan
        # label -> [(t0, end_t, bytes_moved, transfer_s)]
        self.records: Dict[str, List[Tuple[float, float, float,
                                           float]]] = {}

    # ------------------------------------------------------------ keys ---
    @staticmethod
    def instance_key(inst: ChainInstance, edge: DataEdge) -> str:
        return f"chains/{inst.label}/{inst.id}/{edge.key}"

    def _input_keys(self, inst: ChainInstance,
                    stage: Stage) -> Tuple[str, ...]:
        return tuple(e.key if e.external else self.instance_key(inst, e)
                     for e in inst.chain.in_edges(stage.name))

    # ---------------------------------------------------------- launch ---
    def launch(self, chain: Chain, plan: ChainPlan,
               label: Optional[str] = None) -> ChainInstance:
        """Start one chain instance at the current sim time; its source
        stages join the next batched release."""
        label = label or chain.name
        inst = ChainInstance(next(self._ids), label, chain, plan,
                             self.clock.now())
        self.launched += 1
        self.launched_by_label[label] = \
            self.launched_by_label.get(label, 0) + 1
        self.plans.setdefault(label, plan)
        self.records.setdefault(label, [])
        for s in chain.stages:
            if inst.remaining[s.name] == 0:
                self._enqueue_stage(inst, s)
        return inst

    def _enqueue_stage(self, inst: ChainInstance, stage: Stage):
        pname = inst.plan.assignment[stage.name]
        inst.outstanding[stage.name] = stage.fan_out
        if self.cp.recorder is not None:
            inst.stage_ready[stage.name] = self.clock.now()
        if self.proactive_staging:
            # overlap successors' external pulls with this stage's run;
            # the replication is still a real transfer, so its bytes and
            # seconds are charged to this instance (later instances find
            # the replica already local and pay nothing)
            placement = self.cp.placement
            for succ in inst.chain.succs(stage.name):
                to = inst.plan.assignment[succ]
                staged = []
                for e in inst.chain.in_edges(succ):
                    if not e.external:
                        continue
                    src = placement.locate(e.key, origin=to)
                    if src is not None and src != to:
                        inst.bytes_moved += e.size_bytes
                        inst.transfer_s += placement.transfer_seconds(
                            e.size_bytes, src, to)
                    staged.append(e.key)
                if staged:
                    placement.stage_for(
                        inst.chain.stage(succ).function, staged, to)
        self._pending.append((inst, stage, pname))
        if not self._flush_scheduled:
            self._flush_scheduled = True
            self.clock.after(self.batch_window_s, self._flush)

    def _stage_fn(self, inst: ChainInstance, stage: Stage) -> FunctionSpec:
        """Per-stage spec: the deployed function with this instance's data
        objects (and the stage SLO, when set) attached.  Only stages whose
        inputs are all external are cached — their keys are instance-
        independent; internal edges carry per-instance keys and a cache
        over those would grow with every launch."""
        keys = self._input_keys(inst, stage)
        cacheable = all(e.external
                        for e in inst.chain.in_edges(stage.name))
        cache_key = (stage.function, keys, stage.slo_p90_s)
        if cacheable:
            spec = self._spec_cache.get(cache_key)
            if spec is not None:
                return spec
        spec = self.fns[stage.function]
        kw = {}
        if keys != spec.data_objects:
            kw["data_objects"] = keys
        if stage.slo_p90_s is not None:
            kw["slo"] = SLO(p90_response_s=stage.slo_p90_s)
        if kw:
            spec = spec.replace(**kw)
        if cacheable:
            self._spec_cache[cache_key] = spec
        return spec

    # ----------------------------------------------------------- flush ---
    def _flush(self):
        """One batched release: every stage that became ready inside the
        batch window is admitted through ``submit_batch``, grouped per
        planned platform."""
        self._flush_scheduled = False
        work, self._pending = self._pending, []
        groups: Dict[str, List[Invocation]] = {}
        now = self.clock.now()
        for inst, stage, pname in work:
            if inst.status != "running":     # failed earlier in this flush
                continue
            spec = self._stage_fn(inst, stage)
            self._account_transfers(inst, stage, pname)
            for _ in range(stage.fan_out):
                inv = Invocation(spec, now)
                self._attach_slot(_StageSlot(inst, stage), inv)
                groups.setdefault(pname, []).append(inv)
        for pname, invs in groups.items():
            # an earlier group's rejection may have failed an instance
            # this group also carries work for — drop those invocations
            live = []
            for inv in invs:
                slot = self._owner.get(inv.id)
                if slot is None or slot.inst.status != "running":
                    inv._on_done = None
                    self._owner.pop(inv.id, None)
                else:
                    live.append(inv)
            if not live:
                continue
            if self.sink is not None:
                self.sink.submitted += len(live)
            accepted = self.cp.submit_batch(live, platform_override=pname)
            if accepted == len(live):
                continue
            if self.sink is not None:
                self.sink.rejected += len(live) - accepted
            # a rejected admission never fires _on_done; fail the whole
            # instance so reports do not wait on it forever
            for inv in live:
                if inv.status == "failed":
                    inv._on_done = None
                    slot = self._owner.pop(inv.id, None)
                    self._fail_instance(slot.inst if slot else None)

    def _fail_instance(self, inst: Optional[ChainInstance]):
        if inst is not None and inst.status == "running":
            inst.status = "failed"
            self.failed += 1
            self._cleanup(inst)

    def _on_platform_fail(self, inv: Invocation):
        """Platform-level failure of a stage invocation.  Runs after the
        control plane's redelivery hook (callback registration order): a
        resubmitted invocation is back to 'pending' and may still
        complete, but one the Redeliverer exhausted stays 'failed'.  The
        instance only fails once the slot's LAST carrier (original or
        hedged duplicate) is exhausted and nothing completed it."""
        slot = self._owner.get(inv.id)
        if slot is None:
            return
        if inv.status == "failed":
            self._owner.pop(inv.id, None)
            if slot.consumed:
                return
            slot.carriers -= 1
            if slot.carriers <= 0:
                self._fail_instance(slot.inst)

    def _on_hedge_dup(self, orig: Invocation, dup: Invocation):
        """A speculative duplicate was spawned for one of our stage
        invocations: point it at the same completion slot, first-wins."""
        slot = self._owner.get(orig.id)
        if slot is None or slot.consumed:
            return
        slot.carriers += 1
        self._attach_slot(slot, dup)

    def _account_transfers(self, inst: ChainInstance, stage: Stage,
                           pname: str):
        """Estimate the bytes and seconds this stage pulls across platform
        boundaries (each of the ``fan_out`` invocations reads the inputs)."""
        placement = self.cp.placement
        for e in inst.chain.in_edges(stage.name):
            key = e.key if e.external else self.instance_key(inst, e)
            src = placement.locate(key, origin=pname)
            if src is None or src == pname:
                continue
            moved = e.size_bytes * stage.fan_out
            secs = placement.transfer_seconds(e.size_bytes, src, pname) * \
                stage.fan_out
            inst.bytes_moved += moved
            inst.transfer_s += secs

    # ------------------------------------------------------ completion ---
    def _attach_slot(self, slot: _StageSlot, inv: Invocation):
        self._owner[inv.id] = slot
        inv._on_done = lambda: self._slot_done(slot, inv)

    def _slot_done(self, slot: _StageSlot, completing: Invocation):
        """First completion (original or hedged duplicate) consumes the
        slot and advances the chain; later ones are no-ops."""
        completing._on_done = None
        self._owner.pop(completing.id, None)
        if slot.consumed:
            return
        slot.consumed = True
        self._stage_inv_done(slot.inst, slot.stage, completing)

    def _stage_inv_done(self, inst: ChainInstance, stage: Stage,
                        inv: Invocation):
        inst.outstanding[stage.name] -= 1
        if inst.outstanding[stage.name] > 0 or inst.status != "running":
            return
        # stage complete: record outputs where the stage actually ran
        loc = inv.platform or inst.plan.assignment[stage.name]
        stores = self.cp.placement.stores
        if loc in stores:
            for e in inst.chain.out_edges(stage.name):
                stores[loc].put(self.instance_key(inst, e), e.size_bytes)
        inst.stages_done += 1
        rec = self.cp.recorder
        if rec is not None:
            rec.record_chain_stage(
                inst.id, inv.id, stage.function, inv.platform,
                inst.stage_ready.get(stage.name, inst.t0),
                self.clock.now())
        for succ in inst.chain.succs(stage.name):
            inst.remaining[succ] -= 1
            if inst.remaining[succ] == 0:
                self._enqueue_stage(inst, inst.chain.stage(succ))
        if inst.stages_done == inst.chain.n_stages:
            self._instance_done(inst)

    def _instance_done(self, inst: ChainInstance):
        inst.end_t = self.clock.now()
        inst.status = "done"
        self.completed += 1
        self.records[inst.label].append(
            (inst.t0, inst.end_t, inst.bytes_moved, inst.transfer_s))
        m = self.cp.metrics
        m.add(self.METRIC_SCOPE, inst.label, "chain_latency", inst.end_t,
              inst.end_t - inst.t0)
        m.add(self.METRIC_SCOPE, inst.label, "bytes_moved", inst.end_t,
              inst.bytes_moved)
        m.add(self.METRIC_SCOPE, inst.label, "transfer_s", inst.end_t,
              inst.transfer_s)
        self._cleanup(inst)

    def _cleanup(self, inst: ChainInstance):
        """Drop the instance's intermediate objects (done OR failed runs —
        a failed chain's partial outputs must not leak into the stores)."""
        if not self.cleanup_intermediates:
            return
        for e in inst.chain.edges:
            if not e.external:
                key = self.instance_key(inst, e)
                for st in self.cp.placement.stores.values():
                    st.remove(key)
