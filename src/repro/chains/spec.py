"""Function-chain specification (paper §3.1.3 collaborative execution +
§5.1.4 data localization): an application modeled as a DAG of functions
with *typed data edges* — each edge names the object key and byte size
flowing between two stages — so placement can reason about data gravity
for the whole chain instead of one invocation at a time.

A ``Stage`` runs one deployed function (``fan_out`` parallel invocations
per chain instance, fan-in implied by multiple in-edges); a ``DataEdge``
either connects two stages (an *internal* intermediate object, written by
the producer's platform store and read by the consumer) or pulls an
*external* input (``src=EXTERNAL``) that pre-exists in some object store —
the anchor that gives a chain its data gravity.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Dict, List, Optional, Tuple

EXTERNAL = "__external__"


@dataclass(frozen=True)
class Stage:
    """One step of a chain: ``fan_out`` invocations of ``function``."""
    name: str
    function: str                    # deployed FunctionSpec name
    fan_out: int = 1                 # parallel invocations per instance
    slo_p90_s: Optional[float] = None  # per-stage SLO override


@dataclass(frozen=True)
class DataEdge:
    """A typed data dependency: ``size_bytes`` of object ``key`` flow from
    ``src`` (a stage name, or EXTERNAL for a pre-existing store object)
    into ``dst``."""
    src: str
    dst: str
    key: str
    size_bytes: float

    @property
    def external(self) -> bool:
        return self.src == EXTERNAL


@dataclass(frozen=True)
class Chain:
    """A DAG of stages joined by data edges (validated on construction)."""
    name: str
    stages: Tuple[Stage, ...]
    edges: Tuple[DataEdge, ...] = ()

    def __post_init__(self):
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"chain {self.name!r}: duplicate stage names")
        known = set(names)
        for e in self.edges:
            if e.dst not in known:
                raise ValueError(f"chain {self.name!r}: edge into unknown "
                                 f"stage {e.dst!r}")
            if not e.external and e.src not in known:
                raise ValueError(f"chain {self.name!r}: edge from unknown "
                                 f"stage {e.src!r}")
        self.topo_order()                  # raises on cycles

    # -------------------------------------------------------- structure ---
    @cached_property
    def _by_name(self) -> Dict[str, Stage]:
        return {s.name: s for s in self.stages}

    def stage(self, name: str) -> Stage:
        return self._by_name[name]

    @cached_property
    def _in_edges(self) -> Dict[str, Tuple[DataEdge, ...]]:
        out: Dict[str, List[DataEdge]] = {s.name: [] for s in self.stages}
        for e in self.edges:
            out[e.dst].append(e)
        return {k: tuple(v) for k, v in out.items()}

    @cached_property
    def _out_edges(self) -> Dict[str, Tuple[DataEdge, ...]]:
        out: Dict[str, List[DataEdge]] = {s.name: [] for s in self.stages}
        for e in self.edges:
            if not e.external:
                out[e.src].append(e)
        return {k: tuple(v) for k, v in out.items()}

    def in_edges(self, stage: str) -> Tuple[DataEdge, ...]:
        return self._in_edges[stage]

    def out_edges(self, stage: str) -> Tuple[DataEdge, ...]:
        return self._out_edges[stage]

    def preds(self, stage: str) -> Tuple[str, ...]:
        seen: List[str] = []
        for e in self._in_edges[stage]:
            if not e.external and e.src not in seen:
                seen.append(e.src)
        return tuple(seen)

    def succs(self, stage: str) -> Tuple[str, ...]:
        seen: List[str] = []
        for e in self._out_edges[stage]:
            if e.dst not in seen:
                seen.append(e.dst)
        return tuple(seen)

    def external_inputs(self) -> Tuple[DataEdge, ...]:
        return tuple(e for e in self.edges if e.external)

    def topo_order(self) -> Tuple[str, ...]:
        return self._topo

    @cached_property
    def _topo(self) -> Tuple[str, ...]:
        """Kahn's algorithm; deterministic (stage declaration order feeds
        the ready queue).  Raises ValueError on cycles."""
        indeg = {s.name: len(self.preds(s.name)) for s in self.stages}
        ready = [s.name for s in self.stages if indeg[s.name] == 0]
        order: List[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in self.succs(n):
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self.stages):
            raise ValueError(f"chain {self.name!r}: cycle detected")
        return tuple(order)

    def sinks(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.stages
                     if not self.succs(s.name))

    @property
    def n_stages(self) -> int:
        return len(self.stages)
