"""Data-gravity chain planner: place a whole chain, not one invocation.

The planner scores candidate platform assignments with a vectorized cost
model: one ``Policy.score`` call over all stages yields the (S, P)
compute/queue cost matrix from the columnar ``PlatformSnapshot``, and a
(P, P) seconds-per-byte transfer matrix (inverted
``DataPlacementManager.bandwidth_matrix``) prices every data edge, so the
whole plan is array ops — no per-stage platform scans.

The modes capture the paper's co-location vs. collaborative-execution
trade-off (§3.1.3, §5.1.4):

  ``colocate``  every stage on the single platform with the lowest
                estimated makespan *including* external-input transfer and
                a Graham-bound contention term (all the chain's work lands
                on one platform's replicas);
  ``split``     each stage greedily placed by compute/queue cost alone —
                maximal collaboration, blind to data gravity (what a
                per-invocation scheduler does today);
  ``gravity``   each stage greedily placed by compute cost + external data
                pull + inter-platform transfer from the already-placed
                predecessors (myopic data-gravity greedy);
  ``auto``      evaluate ``gravity`` and ``colocate``, keep the lower
                estimated makespan.

Estimates are planning heuristics — actual latencies come out of the
simulated execution; the FDNInspector A/B scenarios measure both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.chains.spec import Chain
from repro.core.data_placement import DataPlacementManager
from repro.core.scheduler import (PlatformSnapshot, PlatformsLike, Policy,
                                  as_snapshot)
from repro.core.types import FunctionSpec

PLAN_MODES = ("auto", "colocate", "split", "gravity")


@dataclass
class ChainPlan:
    """One platform assignment for a chain, with its cost estimates."""
    chain: str
    mode: str                                   # winning mode
    requested_mode: str                         # what the caller asked for
    assignment: Dict[str, str]                  # stage -> platform name
    est_makespan_s: float
    est_compute_s: float                        # summed landed stage cost
    est_transfer_s: float                       # inter-platform edge cost
    est_bytes_moved: float                      # bytes crossing platforms
    stage_cost_s: Dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"chain": self.chain, "mode": self.mode,
                "requested_mode": self.requested_mode,
                "assignment": dict(self.assignment),
                "est_makespan_s": self.est_makespan_s,
                "est_compute_s": self.est_compute_s,
                "est_transfer_s": self.est_transfer_s,
                "est_bytes_moved": self.est_bytes_moved}


class DataGravityPlanner:
    """Plans whole-chain placement against a platform snapshot.

    ``policy`` supplies the compute/queue cost term (stateless policies
    only: a stateful round-robin would consume rotation ticks per plan);
    ``placement`` supplies bandwidths and external-object locations;
    ``fns`` maps function names to deployed specs.
    """

    def __init__(self, policy: Policy, placement: DataPlacementManager,
                 fns: Dict[str, FunctionSpec]):
        self.policy = policy
        self.placement = placement
        self.fns = dict(fns)
        # data gravity enters through the chain's typed edges, so the
        # compute term scores data-stripped specs (no double counting of
        # fn.data_objects already expressed as external edges)
        self._stripped: Dict[str, FunctionSpec] = {}

    def stage_spec(self, function: str) -> FunctionSpec:
        s = self._stripped.get(function)
        if s is None:
            base = self.fns[function]
            s = base.replace(data_objects=()) if base.data_objects else base
            self._stripped[function] = s
        return s

    # ------------------------------------------------------ cost model ---
    def cost_matrices(self, chain: Chain, snap: PlatformSnapshot
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C, X, T): per-stage compute/queue cost (S, P), external data-
        pull seconds (S, P), seconds-per-byte transfer matrix (P, P)."""
        C = self.policy.score_specs(
            [self.stage_spec(st.function) for st in chain.stages], snap)
        X = np.zeros_like(C)
        for si, st in enumerate(chain.stages):
            for e in chain.in_edges(st.name):
                if e.external:
                    X[si] += [self.placement.access_time(e.key, nm)
                              for nm in snap.names]
        T = 1.0 / self.placement.bandwidth_matrix(snap.names)
        return C, X, T

    def plan(self, chain: Chain, platforms: PlatformsLike,
             mode: str = "auto") -> ChainPlan:
        if mode not in PLAN_MODES:
            raise ValueError(f"unknown plan mode {mode!r}; "
                             f"choose from {PLAN_MODES}")
        snap = as_snapshot(platforms)
        C, X, T = self.cost_matrices(chain, snap)
        if mode == "colocate":
            return self._colocate(chain, snap, C, X, mode)
        if mode in ("split", "gravity"):
            return self._greedy(chain, snap, C, X, T, mode,
                                gravity=(mode == "gravity"))
        g = self._greedy(chain, snap, C, X, T, mode, gravity=True)
        c = self._colocate(chain, snap, C, X, mode)
        return g if g.est_makespan_s <= c.est_makespan_s else c

    # ---------------------------------------------------------- greedy ---
    def _greedy(self, chain: Chain, snap: PlatformSnapshot, C: np.ndarray,
                X: np.ndarray, T: np.ndarray, requested: str,
                gravity: bool) -> ChainPlan:
        """Topological greedy: each stage takes the platform minimizing its
        own landed cost given the predecessors' choices.  ``gravity=False``
        ignores every data term (compute-only collaboration)."""
        names = snap.names
        sidx = {s.name: i for i, s in enumerate(chain.stages)}
        col: Dict[str, int] = {}
        est: Dict[str, float] = {}
        stage_cost: Dict[str, float] = {}
        total_cost = transfer_s = bytes_moved = 0.0
        for sname in chain.topo_order():
            si = sidx[sname]
            cost = C[si].copy()
            if gravity:
                cost += X[si]
                for e in chain.in_edges(sname):
                    if not e.external:
                        cost += e.size_bytes * T[col[e.src]]
            j = _argmin_finite(cost)
            if j is None:
                raise ValueError(f"chain {chain.name!r}: no feasible "
                                 f"platform for stage {sname!r}")
            col[sname] = j
            # landed cost always includes the data terms (a split plan
            # still *pays* gravity, it just doesn't optimize for it)
            landed = float(C[si, j] + X[si, j])
            transfer_s += float(X[si, j])
            for e in chain.in_edges(sname):
                if e.external:
                    src = self.placement.locate(e.key, origin=names[j])
                    if src is not None and src != names[j]:
                        bytes_moved += e.size_bytes
                elif (q := col[e.src]) != j:
                    hop = e.size_bytes * float(T[q, j])
                    landed += hop
                    transfer_s += hop
                    bytes_moved += e.size_bytes
            stage_cost[sname] = landed
            total_cost += landed
            start = max((est[p] for p in chain.preds(sname)), default=0.0)
            est[sname] = start + landed
        makespan = self._with_contention(chain, snap, C, col, est)
        return ChainPlan(
            chain=chain.name, mode="gravity" if gravity else "split",
            requested_mode=requested,
            assignment={s: names[j] for s, j in col.items()},
            est_makespan_s=makespan, est_compute_s=total_cost,
            est_transfer_s=transfer_s, est_bytes_moved=bytes_moved,
            stage_cost_s=stage_cost)

    # -------------------------------------------------------- colocate ---
    def _colocate(self, chain: Chain, snap: PlatformSnapshot, C: np.ndarray,
                  X: np.ndarray, requested: str) -> ChainPlan:
        """All stages on one platform, vectorized over candidates: per-
        platform critical path + external pulls, lower-bounded by the
        Graham work/replicas contention term."""
        S, P = C.shape
        landed = C + X                        # internal edges are local
        est = np.zeros((S, P))
        sidx = {s.name: i for i, s in enumerate(chain.stages)}
        for sname in chain.topo_order():
            si = sidx[sname]
            start = np.zeros(P)
            for p in chain.preds(sname):
                start = np.maximum(start, est[sidx[p]])
            est[si] = start + landed[si]
        sink_rows = [sidx[s] for s in chain.sinks()]
        critical = est[sink_rows].max(axis=0) if sink_rows else np.zeros(P)
        fan = np.array([float(s.fan_out) for s in chain.stages])
        replicas = self._replicas(snap)
        work = (landed * fan[:, None]).sum(axis=0) / replicas
        totals = np.maximum(critical, work)
        j = _argmin_finite(totals)
        if j is None:
            raise ValueError(f"chain {chain.name!r}: no single platform "
                             "can host every stage")
        home = snap.names[j]
        bytes_moved = sum(
            e.size_bytes for e in chain.external_inputs()
            if (src := self.placement.locate(e.key, origin=home))
            is not None and src != home)
        return ChainPlan(
            chain=chain.name, mode="colocate", requested_mode=requested,
            assignment={s.name: home for s in chain.stages},
            est_makespan_s=float(totals[j]),
            est_compute_s=float(landed[:, j].sum()),
            est_transfer_s=float(X[:, j].sum()),
            est_bytes_moved=float(bytes_moved),
            stage_cost_s={s.name: float(landed[sidx[s.name], j])
                          for s in chain.stages})

    def _with_contention(self, chain: Chain, snap: PlatformSnapshot,
                         C: np.ndarray, col: Dict[str, int],
                         est: Dict[str, float]) -> float:
        """max(critical path, per-platform work / replicas)."""
        sidx = {s.name: i for i, s in enumerate(chain.stages)}
        critical = max((est[s] for s in chain.sinks()), default=0.0)
        work = np.zeros(snap.n)
        for st in chain.stages:
            work[col[st.name]] += C[sidx[st.name], col[st.name]] * \
                st.fan_out
        load = work / self._replicas(snap)
        return float(max(critical, load.max() if load.size else 0.0))

    @staticmethod
    def _replicas(snap: PlatformSnapshot) -> np.ndarray:
        return np.array([max(pr.total_replicas, 1) for pr in snap.profs],
                        dtype=float)


def _argmin_finite(row: np.ndarray) -> Optional[int]:
    """First-lowest finite column (ties like ``Policy.choose_batch``)."""
    if not np.isfinite(row).any():
        return None
    return int(np.argmin(np.where(np.isfinite(row), row, np.inf)))
