"""Model configuration dataclasses for every assigned architecture family.

A ``ModelConfig`` fully determines a model: family, dimensions, attention
geometry, MoE/SSM/hybrid extras, and the knobs the perf loop turns
(remat policy, attention chunk sizes, sharding strategy overrides).

Every architecture in ``repro.configs`` is expressed as one of these; the
``reduced()`` method derives a CPU-smoke-test-sized config of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Families understood by the model zoo.
DENSE = "dense"
MOE = "moe"
HYBRID = "hybrid"   # RG-LRU + local attention (recurrentgemma)
SSM = "ssm"         # Mamba-2 SSD
ENCDEC = "encdec"   # whisper
VLM = "vlm"         # phi-3-vision: dense backbone + stub image frontend

FAMILIES = (DENSE, MOE, HYBRID, SSM, ENCDEC, VLM)


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str

    # core transformer dims
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention behaviour
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # SWA window; None = full attention
    causal: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "einsum"       # "einsum" (one-hot dispatch, paper-naive)
                                   # | "sorted" (argsort+scatter, §Perf)
                                   # | "sorted_shmap" (shard_map, §Perf)
    decode_impl: str = "gspmd"     # "gspmd" | "shmap_flash" (§Perf: split-K
                                   # flash-decode over the seq-sharded cache)

    # hybrid (RG-LRU): repeating block pattern, e.g. ("rec", "rec", "attn")
    block_pattern: Tuple[str, ...] = ()
    lru_width: Optional[int] = None
    conv_width: int = 4
    local_window: int = 2048

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_conv_width: int = 4

    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_enc_frames: int = 0          # encoder sequence length (precomputed frames)

    # vlm
    n_img_tokens: int = 0          # stub frontend supplies this many embeddings

    # numerics / perf knobs (hillclimbed in §Perf)
    dtype: str = "bfloat16"
    attn_q_chunk: int = 1024       # query-block size for chunked attention
    attn_kv_chunk: int = 2048      # kv-block size for chunked attention
    remat: str = "dots"            # "none" | "dots" | "full"
    tie_embeddings: bool = False
    param_fsdp: bool = False       # shard params over data axes too (FSDP);
                                   # required when TP-only shards overflow HBM
    seq_parallel: bool = True      # §Perf: shard layer-boundary activations
                                   # over "model" on the seq dim (Megatron
                                   # SP) — removes 16x-redundant norm/
                                   # residual work per model shard
    scan_layers: bool = True       # lax.scan over layer stack (keeps HLO small)
    use_pallas: bool = False       # route hot ops through Pallas kernels
    logits_chunk: int = 0          # >0: chunked loss over vocab (memory opt)
    decode_seq_shard: bool = True  # shard long KV caches over "model" axis
    unroll_scans: bool = False     # fully unroll lax.scan loops — used by
                                   # the roofline harness so XLA cost
                                   # analysis sees every iteration

    # ---- derived helpers -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """Mamba-2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode a 500k context without a full-length cache?"""
        if self.family in (SSM, HYBRID):
            return True
        return self.sliding_window is not None

    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        return _count_params(self)

    def n_active_params(self) -> int:
        """Active params per token (differs from n_params for MoE)."""
        return _count_params(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests (one fwd/train step)."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2 if not self.block_pattern
                           else len(self.block_pattern)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            attn_q_chunk=64,
            attn_kv_chunk=64,
            local_window=32,
            scan_layers=self.scan_layers,
        )
        if self.sliding_window is not None:
            kw["sliding_window"] = 64
        if self.family == MOE:
            # generous capacity so the toy config never drops tokens and
            # train/prefill/decode agree exactly (drop behaviour is covered
            # at the full configs / property tests)
            kw.update(n_experts=4, top_k=2, capacity_factor=8.0)
        if self.family == HYBRID:
            kw.update(lru_width=128)
        if self.family == SSM:
            kw.update(d_model=64, ssm_state=16, ssm_headdim=16, ssm_chunk=16)
        if self.family == ENCDEC:
            kw.update(n_enc_layers=2, n_enc_frames=32)
        if self.family == VLM:
            kw.update(n_img_tokens=8)
        return self.replace(**kw)


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic per-family parameter count (embedding + blocks + head)."""
    d, L = cfg.d_model, cfg.num_layers
    n = cfg.vocab_size * d                      # embedding
    if not cfg.tie_embeddings:
        n += d * cfg.vocab_size                 # lm head

    def attn_params() -> int:
        return d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d

    def mlp_params() -> int:
        return 3 * d * cfg.d_ff                 # gated (wi, wg, wo)

    if cfg.family in (DENSE, VLM):
        n += L * (attn_params() + mlp_params() + 2 * d) + d
    elif cfg.family == MOE:
        e = cfg.top_k if active_only else cfg.n_experts
        n += L * (attn_params() + e * 3 * d * cfg.d_ff
                  + d * cfg.n_experts + 2 * d) + d
    elif cfg.family == HYBRID:
        w = cfg.lru_width or d
        pat = cfg.block_pattern or ("rec",)
        n_attn = sum(1 for i in range(L) if pat[i % len(pat)] == "attn")
        n_rec = L - n_attn
        rec = 2 * d * w + w * cfg.conv_width + 3 * w + w * d  # branches+conv+lru
        n += n_rec * (rec + mlp_params() + 2 * d)
        n += n_attn * (attn_params() + mlp_params() + 2 * d) + d
    elif cfg.family == SSM:
        di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
        g = cfg.ssm_ngroups
        in_proj = d * (2 * di + 2 * g * ds + nh)
        conv = (di + 2 * g * ds) * cfg.ssm_conv_width
        n += L * (in_proj + conv + 2 * nh + di + di * d + 2 * d) + d
    elif cfg.family == ENCDEC:
        enc = cfg.n_enc_layers * (attn_params() + mlp_params() + 2 * d)
        dec = L * (2 * attn_params() + mlp_params() + 3 * d)
        n += enc + dec + 2 * d
    else:
        raise ValueError(cfg.family)
    return n


@dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) cell plus its step kind."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runnable, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
