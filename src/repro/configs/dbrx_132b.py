"""dbrx-132b — fine-grained MoE, 16 experts top-4.
[hf:databricks/dbrx-base; unverified]

16 experts divide the 16-way model axis exactly -> clean expert parallelism.
"""
from repro.configs.base import ModelConfig, MOE

CONFIG = ModelConfig(
    name="dbrx-132b",
    family=MOE,
    num_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100_352,
    head_dim=128,
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
    param_fsdp=True,      # 264 GB bf16 / 16-way TP is borderline for HBM
)
