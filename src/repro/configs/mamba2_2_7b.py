"""mamba2-2.7b — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]

SSM family: chunked SSD forward (intra-chunk on the MXU, inter-chunk state
scan), O(1)-state decode -> runs long_500k.
"""
from repro.configs.base import ModelConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family=SSM,
    num_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    head_dim=0,
    ssm_state=128,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    ssm_expand=2,
    tie_embeddings=True,
)
