"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (n_img_tokens, d_model) which are
concatenated ahead of the text tokens. kv=32 == n_heads -> plain MHA.
"""
from repro.configs.base import ModelConfig, VLM

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family=VLM,
    num_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    head_dim=96,
    n_img_tokens=576,       # one 336px CLIP tile -> 24x24 patches
    rope_theta=10_000.0,
)
