"""llama3-405b — dense GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="llama3-405b",
    family=DENSE,
    num_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    # TP-only param shards (810 GB / 16 = 50 GB) overflow a v5e's 16 GB HBM:
    # FSDP-shard params over the data axes and recompute activations fully.
    param_fsdp=True,
    remat="full",
)
