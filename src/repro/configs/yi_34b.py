"""yi-34b — llama-arch dense GQA. [arXiv:2403.04652; hf]

56 query heads are not divisible by the 16-way model axis; projections stay
2-D (d_model, n_heads*head_dim) and shard on the flattened output dim
(7168 / 16 = 448). See DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="yi-34b",
    family=DENSE,
    num_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    head_dim=128,
    rope_theta=5_000_000.0,
)
