"""whisper-small — encoder/decoder, conv frontend (stub).
[arXiv:2212.04356; unverified]

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (n_enc_frames, d_model). Decode shapes use the
decoder's self-attention KV cache at the stated sequence length plus the
fixed-length cross-attention cache.
"""
from repro.configs.base import ModelConfig, ENCDEC

CONFIG = ModelConfig(
    name="whisper-small",
    family=ENCDEC,
    num_layers=12,            # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    head_dim=64,
    n_enc_layers=12,
    n_enc_frames=1500,
    causal=True,
    rope_theta=10_000.0,      # (whisper uses learned abs pos; rope unused in enc)
)
