"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ModelConfig, InputShape, ALL_SHAPES,
                                SHAPES_BY_NAME, shape_applicable)
from repro.configs import (qwen3_1_7b, qwen3_0_6b, yi_34b, llama3_405b,
                           mixtral_8x7b, dbrx_132b, recurrentgemma_9b,
                           phi3_vision_4_2b, mamba2_2_7b, whisper_small)

_CONFIGS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen3_1_7b, qwen3_0_6b, yi_34b, llama3_405b, mixtral_8x7b,
              dbrx_132b, recurrentgemma_9b, phi3_vision_4_2b, mamba2_2_7b,
              whisper_small)
}

ARCH_IDS: List[str] = sorted(_CONFIGS)


def get_config(arch: str) -> ModelConfig:
    if arch not in _CONFIGS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _CONFIGS[arch]


def get_shape(name: str) -> InputShape:
    return SHAPES_BY_NAME[name]


def all_cells(include_skipped: bool = False):
    """Yield (config, shape, runnable, reason) for the 10x4 assignment grid."""
    for arch in ARCH_IDS:
        cfg = _CONFIGS[arch]
        for shape in ALL_SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield cfg, shape, ok, reason
