"""recurrentgemma-9b — RG-LRU + local attention, 2 recurrent : 1 attention.
[arXiv:2402.19427; unverified]

Hybrid family: O(1)-state decode (RG-LRU state + window-2048 local cache),
so the long_500k shape runs. The RG-LRU recurrence is computed with
jax.lax.associative_scan (TPU-native parallel scan) rather than a CUDA-style
sequential kernel — see DESIGN.md hardware-adaptation notes.
"""
from repro.configs.base import ModelConfig, HYBRID

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family=HYBRID,
    num_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,           # MQA in the local-attention blocks
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    conv_width=4,
    local_window=2048,
    tie_embeddings=True,
)
