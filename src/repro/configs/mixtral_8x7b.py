"""mixtral-8x7b — MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

SWA (window 4096) makes decode caches O(window), so this arch runs the
long_500k shape with a rolling KV buffer.
"""
from repro.configs.base import ModelConfig, MOE

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family=MOE,
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    head_dim=128,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
)
