"""Continuous-batching serving engine.

A fixed decode batch of B slots over a shared KV cache; finished slots are
refilled from the waiting queue without stopping the other rows (per-row
cache positions — see models/transformer.cache_specs). Prefill runs at
bucketed prompt lengths to bound recompilation, and the resulting
single-request cache is scattered into the live batch cache.

This engine is what an FDN TargetPlatform runs when it executes `serve-*`
functions for real; the FDN layers (scheduler, monitoring, energy) sit on
top and deliver requests to engines on different platforms.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model_api as api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.done_s is not None


def _buckets(max_len: int) -> List[int]:
    out, b = [], 16
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int = 4,
                 max_context: int = 256, greedy: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.cap = api.cache_specs(cfg, batch_size, max_context)
        self.max_context = max_context
        self.clock = clock
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.cache = api.init_cache(cfg, batch_size, max_context)
        self._steps = 0
        self._generated = 0
        self.buckets = _buckets(max_context)

        self._decode = jax.jit(
            lambda p, c, b: api.decode_step(cfg, p, c, b))
        self._prefill = jax.jit(
            lambda p, b: api.prefill(cfg, p, b, max_context))
        self._slot_tokens = np.zeros((batch_size, 1), np.int32)

    # ------------------------------------------------------------ intake --
    def submit(self, req: Request):
        req.submitted_s = self.clock()
        self.queue.append(req)

    def _bucket_len(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _admit(self):
        for slot in range(self.B):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            n = len(req.prompt)
            pad = self._bucket_len(n)
            tokens = np.zeros((1, pad), np.int32)
            tokens[0, :n] = req.prompt
            batch = {"tokens": jnp.asarray(tokens),
                     "prompt_lens": jnp.asarray([n], np.int32)}
            logits, small = self._prefill(self.params, batch)
            tok = int(jnp.argmax(logits[0, -1]))
            self._insert_cache(slot, small)
            req.out_tokens.append(tok)
            req.first_token_s = self.clock()
            self._slot_tokens[slot, 0] = tok
            self.slots[slot] = req

    def _insert_cache(self, slot: int, small):
        """Scatter a batch=1 cache into batch slot `slot`."""
        def ins(big, small_leaf):
            # find the batch axis: big is B there, small is 1, and every
            # other dim matches (k/v/(h) carry layers first; k_pos/pos are
            # batch-leading — shape-based detection handles both)
            for ax in range(big.ndim):
                if (big.shape[ax] == self.B and small_leaf.shape[ax] == 1
                        and big.shape[:ax] == small_leaf.shape[:ax]
                        and big.shape[ax + 1:] == small_leaf.shape[ax + 1:]):
                    idx = [slice(None)] * big.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return big.at[tuple(idx)].set(
                        small_leaf.astype(big.dtype))
            raise ValueError((big.shape, small_leaf.shape))

        self.cache = jax.tree_util.tree_map(ins, self.cache, small)

    # ------------------------------------------------------------- churn --
    def step(self) -> int:
        """One engine iteration: admit, decode, retire. Returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        batch = {"token": jnp.asarray(self._slot_tokens)}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        self._steps += 1
        toks = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1),
                          np.int32)
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self._generated += 1
            self._slot_tokens[i, 0] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                req.done_s = self.clock()
                self.slots[i] = None       # slot freed; next step refills
        return len(active)

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return requests

    # ------------------------------------------------------------ stats ---
    def stats(self) -> Dict[str, float]:
        return {"decode_steps": self._steps,
                "tokens_generated": self._generated,
                "slot_utilization": self._generated /
                max(self._steps * self.B, 1)}
