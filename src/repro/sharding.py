"""Logical-axis sharding rules -> NamedSharding / PartitionSpec.

Every parameter and activation in the model zoo is annotated with *logical*
axis names ("vocab", "mlp", "heads", ...). This module maps logical names to
mesh axes with divisibility-checked fallback (replicate when a dim does not
divide), so the same model code lowers on a 1-device CPU mesh, the 16x16
single-pod mesh, and the 2x16x16 multi-pod mesh.

DP  = "batch"   -> ("pod", "data") when the mesh has a pod axis, else ("data",)
TP  = width-ish -> "model" (heads / flattened q-kv dims / mlp / vocab / lru /
                   ssm inner dim)
EP  = "experts" -> "model" when the expert count divides it (dbrx), else the
                   per-expert ffn dim takes "model" (mixtral)
SP  = "kv_seq"  -> "model" for long decode caches (flash-decode style split-K)
ZeRO-1: optimizer states additionally shard a replicated dim over "data"
        (see train/optimizer.py).
"""
from __future__ import annotations

import contextvars
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Ordered candidates per logical axis name. "batch" is special-cased.
RULES = {
    "batch":     ("__dp__",),
    "vocab":     ("model",),
    "mlp":       ("model",),
    "heads":     ("model",),     # flattened n_heads*head_dim output dim
    "kv":        ("model",),     # flattened n_kv_heads*head_dim output dim
    "experts":   ("model",),
    "expert_mlp": ("model",),    # per-expert ffn dim (used when EP impossible)
    "lru":       ("model",),     # RG-LRU width
    "ssm_inner": ("model",),     # mamba d_inner / heads*headdim
    "ssm_state": (),
    "kv_seq":    ("model",),     # sequence-sharded decode caches
    "embed":     (),
    "seq":       (),
    "seq_sp":    ("model",),   # Megatron-style sequence parallelism
    "layers":    (),
    "frames":    (),
    None:        (),
}


def shard_map(f, mesh: Mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compat ``shard_map``: newer JAX exposes ``jax.shard_map``
    with a ``check_vma`` flag; older releases only have
    ``jax.experimental.shard_map.shard_map`` where the same knob is called
    ``check_rep``.  All model code routes through this wrapper."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _mesh_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def spec_for(mesh: Mesh, dims: Sequence[Optional[int]],
             axes: Sequence[Optional[str]]) -> P:
    """Build a PartitionSpec for `dims` annotated with logical `axes`.

    A mesh axis is assigned at most once per tensor; a logical axis falls back
    to replication when its dim does not divide the mesh axis size.
    `dims[i]` may be None to skip the divisibility check (e.g. activations
    whose dim is unknown here).
    """
    assert len(dims) == len(axes), (dims, axes)
    used = set()
    out = []
    for dim, name in zip(dims, axes):
        assigned = None
        for cand in RULES.get(name, ()):
            mesh_ax = dp_axes(mesh) if cand == "__dp__" else cand
            if not mesh_ax:
                continue
            flat = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            if any(a not in mesh.axis_names or a in used for a in flat):
                continue
            if dim is not None and dim % _mesh_size(mesh, flat) != 0:
                continue
            assigned = mesh_ax
            used.update(flat)
            break
        out.append(assigned)
    # PartitionSpec drops trailing Nones automatically
    return P(*out)


def named_sharding(mesh: Mesh, dims, axes) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, dims, axes))


# --------------------------------------------------------------------------
# Activation-constraint context. Model code calls constrain(x, ...axes) and
# the launcher installs the mesh; on a bare CPU test no mesh is installed and
# constrain() is the identity.
# --------------------------------------------------------------------------
_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


class use_mesh:
    """Context manager installing the mesh used by constrain()."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh
        self._token = None

    def __enter__(self):
        self._token = _MESH.set(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _MESH.reset(self._token)
        return False


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op without mesh)."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    spec = spec_for(mesh, x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
