"""Decision journal: columnar provenance for the admission fast path.

``DecisionJournal`` records one row per fused ``fn_decisions`` decision
(one per distinct function per admitted burst) into grow-by-doubling
NumPy columns — the flight-recorder discipline: every tap site guards
with ``if journal is not None``, so the provenance-off path costs one
attribute read per burst and the pinned 1.6M decisions/s columnar floor
holds.

Each row snapshots the *full* standard feature set the stateless policy
cascades are pure functions of (``repro.core.scheduler
.decision_features``): per-candidate exec/data/P90/energy predictions,
warm-pool, utilization and cold-start columns, the function's SLO — plus
the decision itself: chosen platform slot, runner-up slot and cost
margin, and the per-candidate filter-kill bitmask (``KILL_DEAD`` /
``KILL_UTIL`` / ``KILL_SLO``; 0 == feasible after graceful degrade).
Because the features are policy-agnostic, an offline what-if replay
(``repro.obs.whatif``) can re-score the journaled columns under *any*
stateless policy or alternate QoS config; re-scoring under the same
policy reproduces the original choices byte-identically (the
correctness oracle — the cascades mirror ``fn_cost_matrix`` op for op).

The journal row id is stamped onto every invocation the decision routed
(``Invocation.decision`` / ``InvocationBatch.decision`` ->
``ColumnarResultSink._decision``), so joining journal rows to sink
completions is direct fancy indexing — the calibration analyzer
(``decision_provenance_section``) computes per-(function, platform)
predicted-vs-realized latency error, per-filter kill counts, decision
regret and policy-churn stats fully vectorized.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import (KILL_DEAD, KILL_SLO, KILL_UTIL,
                                  decision_features)

# 2D float feature columns, (rows, Pmax), NaN-padded past each row's
# platform-set size.  Order is the .npz layout contract.
FEATURE_COLS = ("exec_s", "data_s", "p90_s", "energy_j", "warm_free",
                "cold_start_s", "cpu_util", "mem_util")

# kill value for padding slots past a row's platform-set size (all bits
# set: a pad slot is never alive, never feasible)
KILL_PAD = 255

KILL_NAMES = {KILL_DEAD: "dead", KILL_UTIL: "utilization",
              KILL_SLO: "slo"}

_1D = ("_t", "_fn", "_count", "_pset", "_choice", "_runner", "_margin",
       "_slo_s")


class DecisionJournal:
    """Grow-by-doubling decision provenance columns.

    1D columns (one per journaled decision row):
      * ``t``      (f8)    — decision sim-time
      * ``fn``     (int32) — interned function-name id (``fn_names``)
      * ``count``  (int32) — invocations this decision routed
      * ``pset``   (int32) — interned platform-set id (``pset_names``,
        candidate order == snapshot order == slot order)
      * ``choice`` (int16) — chosen platform *slot* (-1 == infeasible)
      * ``runner`` (int16) — runner-up slot (-1 when < 2 feasible)
      * ``margin`` (f8)    — runner-up cost minus chosen cost (inf when
        no runner-up)
      * ``slo_s``  (f8)    — the function's P90 SLO budget

    2D columns (rows x Pmax, NaN / False / ``KILL_PAD`` padded): the
    ``FEATURE_COLS`` feature matrices, the ``alive`` mask and the
    ``kill`` bitmask.

    The hot-path ``record`` only *appends*: features, liveness and the
    backend's choice.  The derived columns — per-candidate ``kill``
    bits, runner-up slot and cost margin — are pure functions of the
    journaled features (the policy cascade re-run), so they are
    computed lazily in one vectorized pass the first time ``columns``
    is read, keeping per-burst recording cost inside the 15%
    provenance-overhead gate.
    """

    def __init__(self, capacity: int = 1024):
        cap = max(int(capacity), 1)
        self._n = 0
        self._pmax = 0
        self._t = np.empty(cap)
        self._fn = np.empty(cap, np.int32)
        self._count = np.empty(cap, np.int32)
        self._pset = np.empty(cap, np.int32)
        self._choice = np.empty(cap, np.int16)
        self._runner = np.empty(cap, np.int16)
        self._margin = np.empty(cap)
        self._slo_s = np.empty(cap)
        self._f2: Dict[str, np.ndarray] = \
            {name: np.empty((cap, 0)) for name in FEATURE_COLS}
        self._alive = np.zeros((cap, 0), bool)
        self._kill = np.empty((cap, 0), np.uint8)
        self._derived_n = 0        # rows with kill/runner/margin computed
        self._fn_ids: Dict[str, int] = {}
        self.fn_names: List[str] = []
        self._pset_ids: Dict[tuple, int] = {}
        self.pset_names: List[tuple] = []
        # bound by ControlPlane.attach_provenance
        self.perf = None
        self.placement = None
        self.policy_name: Optional[str] = None
        self.params: Dict[str, float] = {}
        self._cascade = None

    # ----------------------------------------------------------- wiring --
    def bind(self, policy, perf, placement) -> "DecisionJournal":
        """Bind the live policy + models (called at attach time).  The
        policy must be stateless (expose ``cascade``); rotation policies
        take the object fallback and are never journaled."""
        self.policy_name = policy.name
        self.params = dict(policy.cascade_params())
        self._cascade = type(policy).cascade
        self.perf = perf
        self.placement = placement
        return self

    @property
    def n(self) -> int:
        return self._n

    def __len__(self) -> int:
        return self._n

    # ----------------------------------------------------------- growth --
    def _grow_rows(self, need: int):
        cap = max(self._t.size * 2, need)
        n, P = self._n, self._pmax
        for name in _1D:
            a = getattr(self, name)
            b = np.empty(cap, a.dtype)
            b[:n] = a[:n]
            setattr(self, name, b)
        for name, a in self._f2.items():
            b = np.full((cap, P), np.nan)
            b[:n] = a[:n]
            self._f2[name] = b
        b = np.zeros((cap, P), bool)
        b[:n] = self._alive[:n]
        self._alive = b
        b = np.full((cap, P), KILL_PAD, np.uint8)
        b[:n] = self._kill[:n]
        self._kill = b

    def _grow_width(self, P: int):
        cap, n = self._t.size, self._n
        for name, a in self._f2.items():
            b = np.full((cap, P), np.nan)
            b[:n, :self._pmax] = a[:n]
            self._f2[name] = b
        b = np.zeros((cap, P), bool)
        b[:n, :self._pmax] = self._alive[:n]
        self._alive = b
        b = np.full((cap, P), KILL_PAD, np.uint8)
        b[:n, :self._pmax] = self._kill[:n]
        self._kill = b
        self._pmax = P

    # ----------------------------------------------------------- record --
    def record(self, t: float, fns: Sequence, snap, choice: np.ndarray,
               ok: np.ndarray, counts: np.ndarray) -> np.ndarray:
        """Journal one fused decision burst: ``F = len(fns)`` rows,
        ``choice``/``ok`` straight from ``Policy.fn_decisions`` (so the
        journaled choice IS the routing decision, whatever backend made
        it), ``counts[g]`` the number of invocations routed by row
        ``g``.  Returns the journal row ids, one per function group.

        Append-only: no cascade runs here — the feature matrices are
        already in the snapshot's per-function cache (``fn_decisions``
        computed them), so this is interning plus column writes."""
        F, P = len(fns), snap.n
        feats = decision_features(fns, snap, self.perf, self.placement)

        key = tuple(snap.names)
        pid = self._pset_ids.get(key)
        if pid is None:
            pid = len(self.pset_names)
            self._pset_ids[key] = pid
            self.pset_names.append(key)

        need = self._n + F
        if need > self._t.size:
            self._grow_rows(need)
        if P > self._pmax:
            self._grow_width(P)
        lo, hi = self._n, need
        self._t[lo:hi] = t
        for g, fn in enumerate(fns):
            name = fn.name
            fid = self._fn_ids.get(name)
            if fid is None:
                fid = len(self.fn_names)
                self._fn_ids[name] = fid
                self.fn_names.append(name)
            self._fn[lo + g] = fid
        self._count[lo:hi] = np.asarray(counts, np.int32)
        self._pset[lo:hi] = pid
        self._choice[lo:hi] = np.where(np.asarray(ok), np.asarray(choice),
                                       -1).astype(np.int16)
        self._slo_s[lo:hi] = feats["slo_s"]
        for name in FEATURE_COLS:
            self._f2[name][lo:hi, :P] = feats[name]  # (P,) rows broadcast
        self._alive[lo:hi, :P] = feats["alive"]
        self._n = need
        return np.arange(lo, hi, dtype=np.int64)

    # ------------------------------------------------- derived columns --
    def _derive(self):
        """Fill kill/runner/margin for rows appended since the last
        read: one vectorized cascade re-run per platform set — a pure
        function of the journaled features, so the result is identical
        to (and far cheaper than) computing it per recorded burst."""
        lo, n = self._derived_n, self._n
        if lo == n:
            return
        pset = self._pset[lo:n]
        for pid in np.unique(pset):
            P = len(self.pset_names[int(pid)])
            sel = np.nonzero(pset == pid)[0] + lo
            feats = {name: self._f2[name][sel, :P]
                     for name in FEATURE_COLS}
            feats["alive"] = self._alive[sel, :P]
            feats["slo_s"] = self._slo_s[sel]
            cost, kill = self._cascade(feats, self.params)
            masked = np.where((kill == 0) & np.isfinite(cost), cost,
                              np.inf)
            ch = self._choice[sel]
            rest = masked.copy()
            rr = np.nonzero(ch >= 0)[0]
            rest[rr, ch[rr]] = np.inf
            best2 = rest.min(axis=1) if P else \
                np.full(sel.size, np.inf)
            has2 = np.isfinite(best2)
            runner = np.where(has2, np.argmin(rest, axis=1), -1) \
                .astype(np.int16)
            chosen = masked[np.arange(sel.size), np.maximum(ch, 0)]
            self._runner[sel] = runner
            self._margin[sel] = np.where(has2 & (ch >= 0),
                                         best2 - chosen, np.inf)
            self._kill[sel, :P] = kill
            if P < self._pmax:
                self._kill[sel, P:] = KILL_PAD
        self._derived_n = n

    # ---------------------------------------------------------- columns --
    def columns(self) -> Dict[str, np.ndarray]:
        """Trimmed views (not copies) of the journal columns."""
        self._derive()
        n = self._n
        out = {"t": self._t[:n], "fn": self._fn[:n],
               "count": self._count[:n], "pset": self._pset[:n],
               "choice": self._choice[:n], "runner": self._runner[:n],
               "margin": self._margin[:n], "slo_s": self._slo_s[:n],
               "alive": self._alive[:n], "kill": self._kill[:n]}
        for name in FEATURE_COLS:
            out[name] = self._f2[name][:n]
        return out

    def platform_of(self, row: int) -> Optional[str]:
        """Chosen platform name for one journal row (None if infeasible)."""
        ch = int(self._choice[row])
        if ch < 0:
            return None
        return self.pset_names[int(self._pset[row])][ch]

    # ------------------------------------------------------ persistence --
    def save(self, path: str):
        """Write the journal as a .npz archive (CI artifact / offline
        analysis).  ``load_journal`` round-trips it."""
        cols = self.columns()
        meta = {"policy": self.policy_name, "params": self.params,
                "fn_names": self.fn_names,
                "pset_names": [list(p) for p in self.pset_names]}
        np.savez(path, meta=np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), np.uint8), **cols)


def load_journal(path: str) -> DecisionJournal:
    """Rebuild a (read-only) ``DecisionJournal`` from ``save`` output.
    The perf/placement/cascade bindings are not restored — replay takes
    the policy explicitly (or from ``policy_name``/``params``)."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"]).decode())
        j = DecisionJournal(capacity=max(int(z["t"].size), 1))
        n = int(z["t"].size)
        j._n = n
        j._pmax = int(z["kill"].shape[1]) if z["kill"].ndim == 2 else 0
        j._t[:n] = z["t"]
        j._fn[:n] = z["fn"]
        j._count[:n] = z["count"]
        j._pset[:n] = z["pset"]
        j._choice[:n] = z["choice"]
        j._runner[:n] = z["runner"]
        j._margin[:n] = z["margin"]
        j._slo_s[:n] = z["slo_s"]
        j._alive = np.asarray(z["alive"], bool).reshape(n, j._pmax)
        j._kill = np.asarray(z["kill"], np.uint8).reshape(n, j._pmax)
        j._f2 = {name: np.asarray(z[name]).reshape(n, j._pmax)
                 for name in FEATURE_COLS}
        j._derived_n = n           # save() derived before writing
    j.policy_name = meta["policy"]
    j.params = dict(meta["params"])
    j.fn_names = list(meta["fn_names"])
    j._fn_ids = {f: i for i, f in enumerate(j.fn_names)}
    j.pset_names = [tuple(p) for p in meta["pset_names"]]
    j._pset_ids = {p: i for i, p in enumerate(j.pset_names)}
    return j


# ---------------------------------------------------------------------------
# Calibration analyzer: journal rows x sink completions
# ---------------------------------------------------------------------------

def _stats(a: np.ndarray) -> Dict[str, float]:
    if a.size == 0:
        return {"count": 0, "mean_s": float("nan"), "p90_s": float("nan")}
    return {"count": int(a.size), "mean_s": float(a.mean()),
            "p90_s": float(np.percentile(a, 90.0))}


def decision_provenance_section(journal: DecisionJournal,
                                cols: Dict) -> Dict:
    """The ``decision_provenance`` section of ``ScenarioReport``: the
    vectorized join of journal rows to sink completion columns via the
    stamped ``decision`` row ids.

    * ``calibration``: per-(function, platform) predicted-vs-realized
      exec-latency error (mean abs/rel, signed bias) — how good the perf
      model that drove routing actually was.
    * ``kill_counts``: invocation-weighted per-filter candidate kills.
    * ``regret``: realized response minus the best *feasible alternative*
      latency estimate (exec + data of the best non-chosen candidate) —
      positive regret marks decisions a different feasible platform
      would (per the model) have served faster.
    * ``churn``: per-function rate of consecutive decisions switching
      platform.
    """
    n = journal.n
    jc = journal.columns()
    kill, counts = jc["kill"], jc["count"]
    real = ~np.equal(kill, KILL_PAD)
    killed = {}
    for bit, name in KILL_NAMES.items():
        hit = ((kill & bit) != 0) & real
        killed[name] = int((hit.sum(axis=1) * counts).sum()) if n else 0

    fin = np.isfinite(jc["margin"])
    margin = {"mean_s": float(jc["margin"][fin].mean())
              if fin.any() else float("nan"),
              "p90_s": float(np.percentile(jc["margin"][fin], 90.0))
              if fin.any() else float("nan"),
              "no_runner_up": int(n - fin.sum())}

    # churn: consecutive same-function decisions switching platform
    churn: Dict[str, float] = {}
    switches = transitions = 0
    for fid, fname in enumerate(journal.fn_names):
        rows = np.nonzero(jc["fn"] == fid)[0]
        if rows.size < 2:
            churn[fname] = 0.0
            continue
        key = jc["pset"][rows].astype(np.int64) * 1024 + jc["choice"][rows]
        ch = int(np.count_nonzero(key[1:] != key[:-1]))
        churn[fname] = ch / (rows.size - 1)
        switches += ch
        transitions += rows.size - 1

    # ---- join to completions over the stamped decision row ids --------
    d = np.asarray(cols.get("decision", np.empty(0, np.int64)))
    valid = (d >= 0) & (d < n)
    rows = d[valid]
    ch = jc["choice"][rows]
    good = ch >= 0
    rows, ch = rows[good], ch[good]
    matched = int(rows.size)
    ridx = np.arange(d.size)[valid][good]

    calibration: Dict[str, Dict[str, Dict[str, float]]] = {}
    regret = _stats(np.empty(0))
    regret["positive_rate"] = float("nan")
    if matched:
        pred_exec = jc["exec_s"][rows, ch]
        real_exec = np.asarray(cols["exec"])[ridx]
        err = pred_exec - real_exec
        fkey = jc["fn"][rows]
        pkey = jc["pset"][rows].astype(np.int64) * 1024 + ch
        for pk in np.unique(pkey):
            pname = journal.pset_names[int(pk) // 1024][int(pk) % 1024]
            psel = pkey == pk
            for fk in np.unique(fkey[psel]):
                sel = psel & (fkey == fk)
                e, r = err[sel], real_exec[sel]
                fname = journal.fn_names[int(fk)]
                calibration.setdefault(fname, {})[pname] = {
                    "count": int(sel.sum()),
                    "predicted_mean_s": float(pred_exec[sel].mean()),
                    "realized_mean_s": float(r.mean()),
                    "mean_abs_err_s": float(np.abs(e).mean()),
                    "mean_rel_err": float(
                        (np.abs(e) / np.maximum(r, 1e-9)).mean()),
                    "bias_s": float(e.mean()),
                }
        # regret vs the best feasible *alternative* estimate
        est = jc["exec_s"][rows] + jc["data_s"][rows]
        feasible = np.equal(jc["kill"][rows], 0)
        alt = np.where(feasible, est, np.inf)
        alt[np.arange(rows.size), ch] = np.inf
        best_alt = alt.min(axis=1)
        has_alt = np.isfinite(best_alt)
        resp = (np.asarray(cols["end"]) - np.asarray(cols["arrival"]))[ridx]
        reg = resp[has_alt] - best_alt[has_alt]
        regret = _stats(reg)
        regret["positive_rate"] = \
            float((reg > 0).mean()) if reg.size else float("nan")

    return {
        "policy": journal.policy_name,
        "params": {k: float(v) for k, v in sorted(journal.params.items())},
        "decisions": int(n),
        "invocations": int(counts.sum()) if n else 0,
        "matched_completions": matched,
        "infeasible_decisions": int((jc["choice"] < 0).sum()) if n else 0,
        "kill_counts": killed,
        "margin": margin,
        "churn": {"per_fn": churn,
                  "overall": (switches / transitions) if transitions
                  else 0.0},
        "calibration": calibration,
        "regret": regret,
    }
