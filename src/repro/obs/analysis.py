"""Vectorized latency decomposition over flight-recorder span columns.

``decompose`` folds the lifecycle spans of each traced invocation into a
``(n, 6)`` segment matrix whose rows sum *exactly* to the invocation's
response time: ingress, queue, cold start, prewarm start and data staging
are taken from the recorded intervals, and execution is defined as the
residual ``response - sum(others)``.  The recorder stores EXEC end times
bit-identical to the clock-scheduled completion instants, so the residual
differs from the raw recorded exec duration only by float re-association
(reported as ``exec_residual_err`` and pinned tiny by test) — while the
reconciliation against the result sink's ``end - arrival`` is bitwise.

On top of the decomposition: ``slo_attribution`` names the dominant
segment of every SLO-violating invocation (the paper's "why did p90
blow" question), ``chain_critical_paths`` chains chain-stage spans
backwards through completion==ready edges, and
``latency_breakdown_section`` packages everything as a plain-JSON report
section.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs.recorder import (CHAIN_STAGE, EXEC, INGRESS, LIFECYCLE,
                                SEGMENT_NAMES, FlightRecorder)


@dataclass
class Decomposition:
    """Per-invocation segment matrix for every *completed* traced row.

    ``segments[i]`` sums exactly to ``response[i]`` (exec is the
    residual); ``attempts[i]`` is the launch attempt the segments came
    from (redelivered invocations keep only their final attempt).
    """
    inv: np.ndarray            # int64 invocation ids, sorted ascending
    fn: np.ndarray             # int32 recorder fn ids
    platform: np.ndarray       # int16 recorder platform ids
    arrival: np.ndarray        # float64 arrival instants
    response: np.ndarray       # float64 end - arrival (bitwise vs sink)
    segments: np.ndarray       # (n, LIFECYCLE) float64, rows sum == response
    attempts: np.ndarray       # int64
    exec_residual_err: float   # max |residual - recorded exec duration|


def decompose(rec: FlightRecorder) -> Decomposition:
    cols = rec.spans.columns()
    kind = cols["kind"]
    inv = cols["inv"]
    mask = (kind < LIFECYCLE) & (inv >= 0)
    if not mask.any():
        z = np.empty(0)
        return Decomposition(np.empty(0, np.int64), np.empty(0, np.int32),
                             np.empty(0, np.int16), z, z,
                             np.empty((0, LIFECYCLE)),
                             np.empty(0, np.int64), 0.0)
    inv = inv[mask]
    kind = kind[mask].astype(np.int64)
    t0 = cols["t0"][mask]
    t1 = cols["t1"][mask]
    plat = cols["platform"][mask]
    fn = cols["fn"][mask]
    att = cols["link"][mask]

    uids, inverse = np.unique(inv, return_inverse=True)
    n = uids.size
    # Redelivered invocations launch more than once; keep only the spans
    # of the final attempt so segments describe the completing run.
    maxatt = np.full(n, np.iinfo(np.int64).min, np.int64)
    np.maximum.at(maxatt, inverse, att)
    keep = att == maxatt[inverse]
    inverse = inverse[keep]
    kind = kind[keep]
    t0 = t0[keep]
    t1 = t1[keep]
    plat = plat[keep]
    fn = fn[keep]
    att = att[keep]

    seg = np.bincount(inverse * LIFECYCLE + kind, weights=t1 - t0,
                      minlength=n * LIFECYCLE).reshape(n, LIFECYCLE)

    arrival = np.zeros(n)
    end = np.zeros(n)
    row_fn = np.zeros(n, np.int32)
    row_plat = np.zeros(n, np.int16)
    row_att = np.zeros(n, np.int64)
    has_ing = np.zeros(n, bool)
    has_exec = np.zeros(n, bool)
    ing = kind == INGRESS
    arrival[inverse[ing]] = t0[ing]
    has_ing[inverse[ing]] = True
    ex = kind == EXEC
    end[inverse[ex]] = t1[ex]
    row_fn[inverse[ex]] = fn[ex]
    row_plat[inverse[ex]] = plat[ex]
    row_att[inverse[ex]] = att[ex]
    has_exec[inverse[ex]] = True

    complete = has_ing & has_exec
    uids = uids[complete]
    seg = seg[complete]
    arrival = arrival[complete]
    end = end[complete]
    row_fn = row_fn[complete]
    row_plat = row_plat[complete]
    row_att = row_att[complete]

    response = end - arrival
    # Exec becomes the residual so rows reconcile with response exactly;
    # the recorded exec interval is kept only to bound the substitution.
    raw_exec = seg[:, EXEC].copy()
    others = seg.copy()
    others[:, EXEC] = 0.0
    seg[:, EXEC] = response - others.sum(axis=1)
    err = float(np.abs(seg[:, EXEC] - raw_exec).max()) if uids.size else 0.0
    return Decomposition(uids, row_fn, row_plat, arrival, response, seg,
                         row_att, err)


def reconcile(decomp: Decomposition, sink_cols: Dict[str, Any]
              ) -> Dict[str, Any]:
    """Join decomposition rows to the result sink by invocation id and
    compare the traced ``end - arrival`` to the sink's — bitwise."""
    inv_id = np.asarray(sink_cols["inv_id"], np.int64)
    rt_sink = (np.asarray(sink_cols["end"], float)
               - np.asarray(sink_cols["arrival"], float))
    order = np.argsort(inv_id, kind="stable")
    pos = np.searchsorted(inv_id[order], decomp.inv)
    pos = np.clip(pos, 0, max(inv_id.size - 1, 0))
    if inv_id.size:
        hit = inv_id[order][pos] == decomp.inv
    else:
        hit = np.zeros(decomp.inv.size, bool)
    rt = rt_sink[order][pos]
    matched = int(hit.sum())
    if matched:
        diff = np.abs(decomp.response[hit] - rt[hit])
        exact = int((decomp.response[hit] == rt[hit]).sum())
        max_err = float(diff.max())
    else:
        exact, max_err = 0, 0.0
    return {"traced": int(decomp.inv.size), "matched": matched,
            "exact": exact, "max_err_s": max_err}


def slo_attribution(decomp: Decomposition, rec: FlightRecorder,
                    fns: Dict[str, Any]) -> Dict[str, Any]:
    """For each traced invocation violating its function's p90-response
    SLO, name the dominant latency segment — the "why" behind the
    report's violation counts."""
    fn_names = rec.fn_names()
    thr = np.full(len(fn_names), np.inf)
    for i, name in enumerate(fn_names):
        fn = fns.get(name)
        if fn is not None:
            thr[i] = fn.slo.p90_response_s
    if decomp.inv.size == 0 or not fn_names:
        return {"violations": 0, "dominant_segment": {}, "per_function": {}}
    viol = decomp.response > thr[decomp.fn]
    dom = np.argmax(decomp.segments, axis=1)
    counts = np.bincount(dom[viol], minlength=LIFECYCLE)
    per_fn: Dict[str, Any] = {}
    for i, name in enumerate(fn_names):
        m = viol & (decomp.fn == i)
        nv = int(m.sum())
        if nv == 0:
            continue
        fdom = np.bincount(dom[m], minlength=LIFECYCLE)
        per_fn[name] = {"violations": nv,
                        "dominant": SEGMENT_NAMES[int(fdom.argmax())]}
    return {
        "violations": int(viol.sum()),
        "dominant_segment": {SEGMENT_NAMES[k]: int(counts[k])
                             for k in range(LIFECYCLE) if counts[k]},
        "per_function": per_fn,
    }


def chain_critical_paths(rec: FlightRecorder, tol: float = 1e-6
                         ) -> Dict[str, Any]:
    """Chain-stage spans record ``[ready, completed)`` per stage, and the
    executor releases a stage exactly at its last predecessor's completion
    instant — so walking backwards from the final completion through
    ``|pred.t1 - cur.t0| <= tol`` edges recovers each instance's critical
    path."""
    cols = rec.spans.columns()
    m = cols["kind"] == CHAIN_STAGE
    if not m.any():
        return {"instances": 0, "mean_critical_s": 0.0, "stage_counts": {}}
    t0 = cols["t0"][m]
    t1 = cols["t1"][m]
    fn = cols["fn"][m]
    link = cols["link"][m]
    fn_names = rec.fn_names()
    insts = np.unique(link)
    crit_total = 0.0
    stage_counts: Dict[str, int] = {}
    for inst in insts:
        rows = np.flatnonzero(link == inst)
        it0, it1, ifn = t0[rows], t1[rows], fn[rows]
        cur = int(np.argmax(it1))
        crit = 0.0
        visited = set()
        while True:
            visited.add(cur)
            crit += it1[cur] - it0[cur]
            name = fn_names[ifn[cur]] if 0 <= ifn[cur] < len(fn_names) \
                else str(int(ifn[cur]))
            stage_counts[name] = stage_counts.get(name, 0) + 1
            preds = np.flatnonzero(np.abs(it1 - it0[cur]) <= tol)
            preds = [p for p in preds if p not in visited]
            if not preds:
                break
            cur = max(preds, key=lambda p: it1[p])
        crit_total += crit
    return {"instances": int(insts.size),
            "mean_critical_s": float(crit_total / insts.size),
            "stage_counts": dict(sorted(stage_counts.items()))}


def latency_breakdown_section(rec: Optional[FlightRecorder],
                              sink_cols: Dict[str, Any],
                              fns: Dict[str, Any]) -> Dict[str, Any]:
    """The ``latency_breakdown`` block of ``ScenarioReport`` — native
    Python scalars only, so the canonical-JSON bytes stay stable."""
    if rec is None:
        return {}
    decomp = decompose(rec)
    rc = reconcile(decomp, sink_cols)
    totals = decomp.segments.sum(axis=0) if decomp.inv.size \
        else np.zeros(LIFECYCLE)
    grand = float(totals.sum())
    section: Dict[str, Any] = {
        "enabled": True,
        "sample": float(rec.sample),
        "spans": int(rec.spans.n),
        "traced_invocations": rc["traced"],
        "matched_completions": rc["matched"],
        "exact_reconciled": rc["exact"],
        "max_reconcile_err_s": rc["max_err_s"],
        "exec_residual_err_s": float(decomp.exec_residual_err),
        "segment_totals_s": {SEGMENT_NAMES[k]: float(totals[k])
                             for k in range(LIFECYCLE)},
        "segment_share": {SEGMENT_NAMES[k]:
                          (float(totals[k]) / grand if grand > 0.0 else 0.0)
                          for k in range(LIFECYCLE)},
        "slo_attribution": slo_attribution(decomp, rec, fns),
    }
    cp = chain_critical_paths(rec)
    if cp["instances"]:
        section["chain_critical_path"] = cp
    return section
