"""Live telemetry engine: multi-resolution rollups over the columnar
metrics path.

The FDN's monitoring loop (paper §3.1.2) continuously scrapes
per-platform metrics; PR 7's flight recorder answers *why* a run was
slow after the fact, but nothing watches the system *while it runs*.
This module is the online half: a :class:`TelemetryEngine` subscribes to
every ``MetricsRegistry`` ingest site (one ``is None`` check per burst,
same discipline as the flight recorder) and folds each
(platform, fn, metric) sample stream into ring-buffered, grow-free
multi-resolution tiers — 1s/10s/60s by default — holding exact
sum/count/min/max plus a mergeable P² quantile sketch per bucket
(reusing the perf model's ``QuantileState`` discipline from
``core.behavioral``).

Memory is O(tiers x capacity) regardless of stream length: a 14-day
streaming replay keeps the same footprint as a 60-second smoke run.
Two structural invariants make the state exactly reproducible:

* **cascade merging** — raw samples fold only into the *finest* tier;
  every coarser tier is produced by merging closed finer buckets upward
  (``child_id // ratio``).  Folding through 1s and merging to 60s is
  therefore *identical* (not just close) to folding straight into 60s
  for sum/count/min/max, which the tier-consistency property test pins.
* **deterministic sketch feeds** — each closed bucket contributes at
  most ``sketch_samples`` evenly-strided time-ordered samples to its
  tier sketch, and merges feed marker heights in a fixed order, so the
  quantile state is a pure function of the input stream.

``alerts.py`` consumes the rollups: burn-rate SLO windows and platform
health detectors both read closed buckets, never raw samples.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.behavioral import QuantileState, _p2_update, _q_value

__all__ = ["TelemetryConfig", "TierRing", "SeriesRollup", "TelemetryEngine",
           "HEALTH_METRICS"]

# Platform-health series recorded by the control-plane taps (per-platform,
# fn slot "-"): queue depth in rows, busy-replica utilization 0..1, and
# instantaneous watts from the energy meter.  cold_start_rate is derived
# at alert-evaluation time from the cold_starts / response_time rollups.
HEALTH_METRICS = ("queue_depth", "utilization", "watts")

# fn-slot placeholder for per-platform (fn-less) health series
NO_FN = "-"


def _q_add_many(qs: QuantileState, slot: int, xs, q: float) -> None:
    """Feed a whole bucket's samples into one P² cell with a single
    load/store of the marker state.  Bit-identical to looping
    ``behavioral._q_add`` (the cells round-trip through float64, which
    is lossless) but ~10x cheaper per sample — the per-call array
    round-trip dominated streaming-replay folds."""
    c = int(qs.count[slot, 0])
    n = len(xs)
    if n == 0:
        return
    qs.count[slot, 0] = c + n
    i = 0
    while c < 5 and i < n:
        qs.buf[slot, 0, c] = xs[i]
        c += 1
        i += 1
        if c == 5:
            s = sorted(float(v) for v in qs.buf[slot, 0])
            qs.heights[slot, 0] = s
            qs.pos[slot, 0] = (0, 1, 2, 3, 4)
            qs.want[slot, 0] = (0, 2 * q, 4 * q, 2 + 2 * q, 4)
    if i >= n:
        return
    h = [float(v) for v in qs.heights[slot, 0]]
    pos = [int(v) for v in qs.pos[slot, 0]]
    want = [float(v) for v in qs.want[slot, 0]]
    while i < n:
        _p2_update(h, pos, want, q, float(xs[i]))
        i += 1
    qs.heights[slot, 0] = h
    qs.pos[slot, 0] = pos
    qs.want[slot, 0] = want


def _q_add_block(qs: QuantileState, slots: np.ndarray, X: np.ndarray,
                 L: np.ndarray, q: float) -> None:
    """Feed MANY P² cells at once: lane ``b`` consumes ``X[b, :L[b]]``
    into cell ``slots[b]``.  Cells are independent, so the inherently
    sequential per-sample marker update runs as a loop over sample
    *columns*, each step vectorized across lanes — the expression order
    inside a lane mirrors ``_p2_update`` exactly (same float64 IEEE ops),
    so results are bit-identical to looping ``_q_add_many`` per lane.
    ``slots`` must be distinct (one bucket per lane)."""
    B = len(slots)
    if B == 0:
        return
    K = int(X.shape[1])
    c = qs.count[slots, 0].copy()
    qs.count[slots, 0] = c + L
    buf = qs.buf[slots, 0]           # fancy indexing: working copies
    h = qs.heights[slots, 0]
    pos = qs.pos[slots, 0]
    want = qs.want[slots, 0]
    want_add = np.array([0.0, q / 2, q, (1 + q) / 2, 1.0])
    want_init = np.array([0.0, 2 * q, 4 * q, 2 + 2 * q, 4.0])
    with np.errstate(divide="ignore", invalid="ignore"):
        for j in range(K):
            act = j < L
            if not act.any():
                break
            x = X[:, j]
            pre_post = c >= 5
            # bootstrap lanes: fill buf; sort into markers at the 5th
            bl = np.flatnonzero(act & ~pre_post)
            if len(bl):
                buf[bl, c[bl]] = x[bl]
                c[bl] += 1
                done = bl[c[bl] == 5]
                if len(done):
                    h[done] = np.sort(buf[done], axis=1)
                    pos[done] = np.arange(5)
                    want[done] = want_init
            # post-bootstrap lanes: one vectorized _p2_update step
            p = np.flatnonzero(act & pre_post)
            if not len(p):
                continue
            hp5, np5, ns5 = h[p], pos[p], want[p]
            xv = x[p]
            lo = xv < hp5[:, 0]
            hi = xv >= hp5[:, 4]
            hp5[lo, 0] = xv[lo]
            hp5[hi, 4] = xv[hi]
            # k = the marker interval holding x (heights stay sorted, so
            # counting h[i] <= x over i in 0..3 matches the scalar scan)
            k = np.where(lo, 0, np.where(
                hi, 3, np.sum(hp5[:, :4] <= xv[:, None], axis=1) - 1))
            np5 += np.arange(5)[None, :] > k[:, None]
            ns5 += want_add
            for i in (1, 2, 3):
                d = ns5[:, i] - np5[:, i]
                gp = np5[:, i + 1] - np5[:, i]
                gm = np5[:, i - 1] - np5[:, i]
                move = ((d >= 1) & (gp > 1)) | ((d <= -1) & (gm < -1))
                ds = np.where(d > 0, 1, -1)
                # parabolic, mirroring the scalar expression order
                hpar = hp5[:, i] + ds / (np5[:, i + 1] - np5[:, i - 1]) * (
                    (np5[:, i] - np5[:, i - 1] + ds)
                    * (hp5[:, i + 1] - hp5[:, i]) / gp
                    + (np5[:, i + 1] - np5[:, i] - ds)
                    * (hp5[:, i] - hp5[:, i - 1]) / (-gm))
                h_adj = np.where(ds > 0, hp5[:, i + 1], hp5[:, i - 1])
                n_adj = np.where(ds > 0, np5[:, i + 1], np5[:, i - 1])
                hlin = hp5[:, i] + ds * (h_adj - hp5[:, i]) \
                    / (n_adj - np5[:, i])
                use_lin = ~((hp5[:, i - 1] < hpar) & (hpar < hp5[:, i + 1]))
                hnew = np.where(use_lin, hlin, hpar)
                hp5[:, i] = np.where(move, hnew, hp5[:, i])
                np5[:, i] += np.where(move, ds, 0)
            h[p], pos[p], want[p] = hp5, np5, ns5
    qs.buf[slots, 0] = buf
    qs.heights[slots, 0] = h
    qs.pos[slots, 0] = pos
    qs.want[slots, 0] = want


@dataclass(frozen=True)
class TelemetryConfig:
    """Engine knobs.  ``tiers_s`` must be ascending and each coarser tier
    an integer multiple of the previous (cascade merging requires aligned
    bucket boundaries)."""

    tiers_s: Tuple[float, ...] = (1.0, 10.0, 60.0)
    capacity: int = 512                # ring slots per tier
    quantile: float = 0.9              # sketch target quantile
    sketch_samples: int = 16           # max raw feeds per closed bucket
    auto_flush_samples: Optional[int] = 1 << 18   # None = manual flush
    metrics: Tuple[str, ...] = ("response_time", "cold_starts")

    def __post_init__(self):
        tiers = tuple(float(t) for t in self.tiers_s)
        if not tiers or any(t <= 0 for t in tiers):
            raise ValueError(f"bad tiers_s: {self.tiers_s}")
        for a, b in zip(tiers, tiers[1:]):
            ratio = b / a
            if ratio < 2 or abs(ratio - round(ratio)) > 1e-9:
                raise ValueError(
                    f"tier {b}s must be an integer multiple of {a}s")
        object.__setattr__(self, "tiers_s", tiers)
        object.__setattr__(self, "metrics", tuple(self.metrics))

    @staticmethod
    def from_dict(d: Dict) -> "TelemetryConfig":
        keys = {f.name for f in
                TelemetryConfig.__dataclass_fields__.values()}  # type: ignore
        kw = {k: v for k, v in d.items() if k in keys}
        if "tiers_s" in kw:
            kw["tiers_s"] = tuple(kw["tiers_s"])
        if "metrics" in kw:
            kw["metrics"] = tuple(kw["metrics"])
        return TelemetryConfig(**kw)


class TierRing:
    """One resolution tier of one series: a fixed-capacity ring of
    bucket aggregates keyed by absolute bucket id (``floor(t / bucket_s)``).

    Slots are addressed ``id % capacity``; an incoming id evicts whatever
    older bucket occupied its slot (the ring keeps the most recent
    ``capacity`` buckets of *timeline*, not of data).  ``bad`` counts
    samples above the series' violation threshold — the SLO burn-rate
    numerator — and rides the same reduceat pass as the other aggregates.
    """

    __slots__ = ("bucket_s", "cap", "ids", "counts", "sums", "mins",
                 "maxs", "bad", "sketch", "newest", "merged_upto",
                 "dropped_late", "quantile")

    def __init__(self, bucket_s: float, capacity: int, quantile: float):
        self.bucket_s = float(bucket_s)
        self.cap = int(capacity)
        self.quantile = float(quantile)
        self.ids = np.full(self.cap, -1, np.int64)
        self.counts = np.zeros(self.cap, np.int64)
        self.sums = np.zeros(self.cap)
        self.mins = np.zeros(self.cap)
        self.maxs = np.zeros(self.cap)
        self.bad = np.zeros(self.cap, np.int64)
        # one P² estimator per ring slot: (cap, 1) grid, cell (slot, 0)
        self.sketch = QuantileState.alloc(self.cap, 1)
        self.newest = -1          # largest bucket id ever opened
        self.merged_upto = 0      # ids < this were cascaded to the parent
        self.dropped_late = 0     # samples for already-cascaded buckets

    # -- slot lifecycle -----------------------------------------------

    def _reset_slot(self, slot: int, bid: int) -> None:
        self.ids[slot] = bid
        self.counts[slot] = 0
        self.sums[slot] = 0.0
        self.mins[slot] = np.inf
        self.maxs[slot] = -np.inf
        self.bad[slot] = 0
        self.sketch.count[slot, 0] = 0

    def slot_for(self, bid: int) -> int:
        """Return the (possibly freshly reset) slot for bucket ``bid``,
        or -1 when the bucket is too old to accept data."""
        if bid < self.merged_upto or bid <= self.newest - self.cap:
            self.dropped_late += 1
            return -1
        slot = bid % self.cap
        if self.ids[slot] != bid:
            self._reset_slot(slot, bid)
        if bid > self.newest:
            self.newest = bid
        return slot

    # -- accumulation -------------------------------------------------

    def accumulate(self, bid: int, count: int, total: float, lo: float,
                   hi: float, bad: int, q_feed: Iterable[float]) -> bool:
        slot = self.slot_for(bid)
        if slot < 0:
            return False
        self.counts[slot] += count
        self.sums[slot] += total
        if lo < self.mins[slot]:
            self.mins[slot] = lo
        if hi > self.maxs[slot]:
            self.maxs[slot] = hi
        self.bad[slot] += bad
        _q_add_many(self.sketch, slot, q_feed, self.quantile)
        return True

    def accumulate_block(self, bids: np.ndarray, counts: np.ndarray,
                         totals: np.ndarray, los: np.ndarray,
                         his: np.ndarray, bads: np.ndarray,
                         X: np.ndarray, L: np.ndarray,
                         drop_weights: Optional[np.ndarray] = None,
                         sum_chunks: Optional[np.ndarray] = None,
                         chunk_len: Optional[np.ndarray] = None) -> None:
        """Vectorized ``accumulate`` over a batch of DISTINCT ascending
        bucket ids spanning less than ``cap`` (so no lane evicts
        another's slot mid-batch).  ``drop_weights`` is what each dropped
        bucket adds to ``dropped_late`` (the cascade passes its per-
        parent child counts so the counter matches the scalar path).
        ``sum_chunks``/``chunk_len`` carry the unreduced per-child sums:
        adding them left-to-right keeps the float association of the
        one-at-a-time path, so merged sums stay bit-identical."""
        keep = ~((bids < self.merged_upto)
                 | (bids <= self.newest - self.cap))
        if not keep.all():
            d = ~keep
            self.dropped_late += int(d.sum() if drop_weights is None
                                     else drop_weights[d].sum())
            bids, counts, totals = bids[keep], counts[keep], totals[keep]
            los, his, bads = los[keep], his[keep], bads[keep]
            X, L = X[keep], L[keep]
            if sum_chunks is not None:
                sum_chunks, chunk_len = sum_chunks[keep], chunk_len[keep]
            if len(bids) == 0:
                return
        slots = bids % self.cap
        stale = self.ids[slots] != bids
        if stale.any():
            s = slots[stale]
            self.ids[s] = bids[stale]
            self.counts[s] = 0
            self.sums[s] = 0.0
            self.mins[s] = np.inf
            self.maxs[s] = -np.inf
            self.bad[s] = 0
            self.sketch.count[s, 0] = 0
        if bids[-1] > self.newest:
            self.newest = int(bids[-1])
        self.counts[slots] += counts
        if sum_chunks is None:
            self.sums[slots] += totals
        else:
            for g in range(sum_chunks.shape[1]):
                m = chunk_len > g
                if not m.any():
                    break
                self.sums[slots[m]] += sum_chunks[m, g]
        self.mins[slots] = np.minimum(self.mins[slots], los)
        self.maxs[slots] = np.maximum(self.maxs[slots], his)
        self.bad[slots] += bads
        _q_add_block(self.sketch, slots, X, L, self.quantile)

    # -- reads --------------------------------------------------------

    def live_order(self) -> np.ndarray:
        """Slots holding buckets still on the ring timeline, ascending
        by bucket id."""
        m = np.flatnonzero(self.ids > self.newest - self.cap)
        m = m[self.ids[m] >= 0]
        return m[np.argsort(self.ids[m], kind="stable")]

    def quantile_value(self, slot: int) -> float:
        return _q_value(self.sketch, int(slot), 0, self.quantile)

    def sketch_feed(self, slot: int) -> List[float]:
        """Deterministic upward-merge feed for one closed bucket: the
        exact bootstrap values while the cell is in bootstrap, else each
        marker height repeated in proportion to the observation count
        (capped so a merge costs O(1))."""
        s = int(slot)
        c = int(self.sketch.count[s, 0])
        if c == 0:
            return []
        if c < 5:
            return sorted(float(v) for v in self.sketch.buf[s, 0, :c])
        reps = max(1, min(c // 5, 8))
        out: List[float] = []
        for h in self.sketch.heights[s, 0]:
            out.extend([float(h)] * reps)
        return out


class SeriesRollup:
    """All tiers of one (platform, fn, metric) series plus its pending
    sample buffer.  Raw samples land in ``pend_*``; ``fold`` drains them
    into the finest tier and cascades closed buckets upward."""

    __slots__ = ("tiers", "thr", "pend_t", "pend_v", "pend_n")

    def __init__(self, cfg: TelemetryConfig, thr: float = np.inf):
        self.tiers = [TierRing(b, cfg.capacity, cfg.quantile)
                      for b in cfg.tiers_s]
        self.thr = float(thr)          # violation threshold (SLO numerator)
        self.pend_t = np.empty(1024)
        self.pend_v = np.empty(1024)
        self.pend_n = 0

    # -- ingest -------------------------------------------------------

    def add(self, t: float, v: float) -> None:
        n = self.pend_n
        if n == len(self.pend_t):
            self._grow(n + 1)
        self.pend_t[n] = t
        self.pend_v[n] = v
        self.pend_n = n + 1

    def add_many(self, ts: np.ndarray, vs: np.ndarray) -> None:
        k = len(ts)
        if k == 0:
            return
        n = self.pend_n
        if n + k > len(self.pend_t):
            self._grow(n + k)
        self.pend_t[n:n + k] = ts
        self.pend_v[n:n + k] = vs
        self.pend_n = n + k

    def _grow(self, need: int) -> None:
        cap = len(self.pend_t)
        while cap < need:
            cap *= 2
        for name in ("pend_t", "pend_v"):
            old = getattr(self, name)
            new = np.empty(cap)
            new[:self.pend_n] = old[:self.pend_n]
            setattr(self, name, new)

    # -- fold + cascade -----------------------------------------------

    def fold(self, sketch_samples: int) -> int:
        """Drain pending samples into the finest tier, then cascade every
        newly-closed bucket up the tier chain.  Returns samples folded."""
        n = self.pend_n
        if n == 0:
            return 0
        ts = self.pend_t[:n]
        vs = self.pend_v[:n]
        t0 = self.tiers[0]
        bids = np.floor_divide(ts, t0.bucket_s).astype(np.int64)
        order = np.argsort(bids, kind="stable")
        bids = bids[order]
        vs_s = vs[order]
        uniq, starts = np.unique(bids, return_index=True)
        ends = np.append(starts[1:], n)
        sums = np.add.reduceat(vs_s, starts)
        mins = np.minimum.reduceat(vs_s, starts)
        maxs = np.maximum.reduceat(vs_s, starts)
        if np.isfinite(self.thr):
            bads = np.add.reduceat(
                (vs_s > self.thr).astype(np.int64), starts)
        else:
            bads = np.zeros(len(uniq), np.int64)
        # span-grouping: one batch may cover more timeline than the ring
        # holds (a 1h streaming chunk vs a 512 x 1s ring).  Cascade after
        # every <capacity span of bucket ids so no bucket is slot-evicted
        # before its aggregates reached the parent tier.
        counts = ends - starts
        # feed index matrix, replicating np.linspace(a, b-1, m) exactly
        # (m = min(count, sketch_samples)): arange * step + start, with
        # the endpoint pinned — raw runs (m == count) degenerate to
        # consecutive indices, so one formula covers both cases
        k = sketch_samples
        m = np.minimum(counts, k)
        a = starts.astype(np.float64)
        bm1 = (ends - 1).astype(np.float64)
        step = np.where(m > 1, (bm1 - a) / np.maximum(m - 1, 1), 0.0)
        idx = a[:, None] + np.arange(k)[None, :] * step[:, None]
        idx[np.arange(len(m)), m - 1] = bm1
        feed_idx = np.minimum(idx.astype(np.int64), n - 1)
        X = vs_s[feed_idx]
        g0 = 0
        for i in range(len(uniq)):
            if uniq[i] - uniq[g0] >= t0.cap:
                t0.accumulate_block(uniq[g0:i], counts[g0:i], sums[g0:i],
                                    mins[g0:i], maxs[g0:i], bads[g0:i],
                                    X[g0:i], m[g0:i])
                self._cascade(closed_only=True)
                g0 = i
        t0.accumulate_block(uniq[g0:], counts[g0:], sums[g0:], mins[g0:],
                            maxs[g0:], bads[g0:], X[g0:], m[g0:])
        self.pend_n = 0
        self._cascade(closed_only=True)
        return n

    def _cascade(self, closed_only: bool) -> None:
        """Merge finished finer buckets into their parent tiers.  With
        ``closed_only`` the still-open newest bucket of each tier stays;
        ``finalize`` passes False to push everything up."""
        for child, parent in zip(self.tiers, self.tiers[1:]):
            frontier = child.newest if closed_only else child.newest + 1
            # every occupied, not-yet-merged slot below the frontier —
            # including stragglers that already fell off the timeline
            todo = np.flatnonzero((child.ids >= child.merged_upto)
                                  & (child.ids < frontier))
            todo = todo[np.argsort(child.ids[todo], kind="stable")]
            ratio = int(round(parent.bucket_s / child.bucket_s))
            if len(todo):
                self._merge_block(child, parent, todo, ratio)
            if frontier > child.merged_upto:
                child.merged_upto = frontier

    @staticmethod
    def _merge_block(child: TierRing, parent: TierRing,
                     todo: np.ndarray, ratio: int) -> None:
        """Merge a batch of closed child slots (ascending by bucket id)
        into their parents in one block: aggregates reduce per parent
        group, and each child's deterministic ``sketch_feed`` lands in
        its parent's concatenated feed row in child order — the same
        per-parent sample sequence the one-at-a-time path produced."""
        cbids = child.ids[todo]
        pbids = cbids // ratio            # non-decreasing: groups contiguous
        # child feed matrix: bootstrap cells contribute their sorted
        # raw buf, mature cells each marker height x reps (capped)
        ccnt = child.sketch.count[todo, 0]
        reps = np.clip(ccnt // 5, 1, 8)
        clen = np.where(ccnt < 5, ccnt, 5 * reps)
        CF = np.zeros((len(todo), 40))
        for c in (1, 2, 3, 4):
            lanes = np.flatnonzero(ccnt == c)
            if len(lanes):
                CF[lanes[:, None], np.arange(c)[None, :]] = np.sort(
                    child.sketch.buf[todo[lanes], 0, :c], axis=1)
        mature = ccnt >= 5
        for r in np.unique(reps[mature]) if mature.any() else ():
            lanes = np.flatnonzero(mature & (reps == r))
            CF[lanes[:, None], np.arange(5 * r)[None, :]] = np.repeat(
                child.sketch.heights[todo[lanes], 0], r, axis=1)
        gstart = np.flatnonzero(np.diff(pbids, prepend=pbids[0] - 1))
        uniq = pbids[gstart]
        counts = np.add.reduceat(child.counts[todo], gstart)
        sums = np.add.reduceat(child.sums[todo], gstart)
        mins = np.minimum.reduceat(child.mins[todo], gstart)
        maxs = np.maximum.reduceat(child.maxs[todo], gstart)
        bads = np.add.reduceat(child.bad[todo], gstart)
        gsizes = np.diff(np.append(gstart, len(todo)))
        # per-child sums kept unreduced so the parent adds them in child
        # order (float association matches the scalar merge exactly)
        SC = np.zeros((len(uniq), int(gsizes.max())))
        rank = np.arange(len(todo)) - np.repeat(gstart, gsizes)
        pidx = np.repeat(np.arange(len(uniq)), gsizes)
        SC[pidx, rank] = child.sums[todo]
        # scatter child feeds into per-parent rows at running offsets
        PL = np.add.reduceat(clen, gstart)
        cum = np.cumsum(clen) - clen      # global feed offset per child
        off = cum - (np.cumsum(PL) - PL)[pidx]
        tot = int(clen.sum())
        X = np.zeros((len(uniq), int(PL.max()) if len(PL) else 0))
        if tot:
            flat_child = np.repeat(np.arange(len(todo)), clen)
            within = np.arange(tot) - np.repeat(cum, clen)
            X[pidx[flat_child], np.repeat(off, clen) + within] = \
                CF[flat_child, within]
        parent.accumulate_block(uniq, counts, sums, mins, maxs, bads,
                                X, PL, drop_weights=gsizes,
                                sum_chunks=SC, chunk_len=gsizes)

    def finalize(self, sketch_samples: int) -> None:
        self.fold(sketch_samples)
        self._cascade(closed_only=False)

    # -- reads --------------------------------------------------------

    def series(self, tier: int):
        """(ids, counts, sums, mins, maxs, bad, q) of live buckets of one
        tier, ascending by bucket id."""
        ring = self.tiers[tier]
        slots = ring.live_order()
        q = np.array([ring.quantile_value(s) for s in slots])
        return (ring.ids[slots].copy(), ring.counts[slots].copy(),
                ring.sums[slots].copy(), ring.mins[slots].copy(),
                ring.maxs[slots].copy(), ring.bad[slots].copy(), q)


class TelemetryEngine:
    """The live subscriber.  ``observe``/``observe_many`` are the ingest
    taps (called under an ``is None`` guard from ``MetricsRegistry``);
    ``record_health`` is the platform-side tap.  Metrics outside
    ``cfg.metrics`` are filtered here in O(1) so hot ingest paths never
    buffer series nobody reads."""

    def __init__(self, cfg: Optional[TelemetryConfig] = None):
        self.cfg = cfg or TelemetryConfig()
        self._want = frozenset(self.cfg.metrics)
        self.series: Dict[Tuple[str, str, str], SeriesRollup] = {}
        self.slo_thr: Dict[str, float] = {}   # fn -> response-time SLO
        self._pending = 0                     # samples since last flush
        self.folded = 0                       # lifetime samples folded
        self.flushes = 0

    # -- subscription surface -----------------------------------------

    def set_slo(self, fn: str, threshold_s: float) -> None:
        """Register a function's SLO threshold; response_time buckets
        then count ``bad`` samples (> threshold) for burn-rate math."""
        self.slo_thr[fn] = float(threshold_s)
        for (p, f, m), sr in self.series.items():
            if f == fn and m == "response_time":
                sr.thr = float(threshold_s)

    def _series(self, platform: str, fn: str,
                metric: str) -> SeriesRollup:
        key = (platform, fn, metric)
        sr = self.series.get(key)
        if sr is None:
            thr = (self.slo_thr.get(fn, np.inf)
                   if metric == "response_time" else np.inf)
            sr = SeriesRollup(self.cfg, thr)
            self.series[key] = sr
        return sr

    def observe(self, platform: str, fn: str, metric: str,
                t: float, v: float) -> None:
        if metric not in self._want:
            return
        self._series(platform, fn, metric).add(t, v)
        self._pending += 1
        self._maybe_flush()

    def observe_many(self, platform: str, fn: str, metric: str,
                     ts: np.ndarray, vs: np.ndarray) -> None:
        if metric not in self._want:
            return
        self._series(platform, fn, metric).add_many(ts, vs)
        self._pending += len(ts)
        self._maybe_flush()

    def record_health(self, platform: str, t: float, queue_rows: float,
                      utilization: float, watts: float) -> None:
        """Platform drain/heartbeat tap: per-platform health samples on
        the fn-less ``'-'`` slot."""
        sr = self._series(platform, NO_FN, "queue_depth")
        sr.add(t, float(queue_rows))
        sr = self._series(platform, NO_FN, "utilization")
        sr.add(t, float(utilization))
        sr = self._series(platform, NO_FN, "watts")
        sr.add(t, float(watts))
        self._pending += 3
        self._maybe_flush()

    # -- folding ------------------------------------------------------

    def _maybe_flush(self) -> None:
        lim = self.cfg.auto_flush_samples
        if lim is not None and self._pending >= lim:
            self.flush()

    def flush(self) -> int:
        """Fold every pending buffer into the tier rings.  Bounded work:
        O(pending) plus O(live buckets) cascade."""
        folded = 0
        k = self.cfg.sketch_samples
        for sr in self.series.values():
            folded += sr.fold(k)
        self._pending = 0
        self.folded += folded
        self.flushes += 1
        return folded

    def finalize(self) -> None:
        """End-of-run flush that also cascades the still-open buckets so
        coarse tiers cover the full horizon."""
        k = self.cfg.sketch_samples
        for sr in self.series.values():
            self.folded += sr.pend_n
            sr.finalize(k)
        self._pending = 0
        self.flushes += 1

    # -- reads --------------------------------------------------------

    def keys(self) -> List[Tuple[str, str, str]]:
        return sorted(self.series.keys())

    def get_series(self, platform: str, fn: str, metric: str,
                   tier: int = 0):
        sr = self.series.get((platform, fn, metric))
        if sr is None:
            return None
        return sr.series(tier)

    def dropped_late(self) -> int:
        return sum(t.dropped_late for sr in self.series.values()
                   for t in sr.tiers)

    def rollup_summary(self) -> Dict:
        """Canonical-JSON-friendly summary for the report section."""
        return {
            "tiers_s": [float(t) for t in self.cfg.tiers_s],
            "capacity": int(self.cfg.capacity),
            "keys": len(self.series),
            "samples": int(self.folded),
            "flushes": int(self.flushes),
            "dropped_late": int(self.dropped_late()),
        }
