"""Chrome trace-event export: open any traced run in Perfetto.

``write_chrome_trace`` serializes the recorder's span columns as the
Chrome trace-event JSON format (``{"traceEvents": [...]}``, complete
``"X"`` events with microsecond ``ts``/``dur``).  Platforms map to
processes and invocations to tracks, so a scenario's queue waits, cold
starts, data staging and executions line up visually per platform —
load ``chrome://tracing`` or https://ui.perfetto.dev and drop the file.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.recorder import KIND_NAMES, LIFECYCLE, FlightRecorder


def chrome_trace_events(rec: FlightRecorder) -> List[Dict[str, Any]]:
    events: List[Dict[str, Any]] = []
    pnames = rec.platform_names()
    fnames = rec.fn_names()
    events.append({"name": "process_name", "ph": "M", "pid": 0,
                   "args": {"name": "(control)"}})
    for pid, pname in enumerate(pnames):
        events.append({"name": "process_name", "ph": "M", "pid": pid + 1,
                       "args": {"name": pname}})
    cols = rec.spans.columns()
    inv = cols["inv"]
    kind = cols["kind"]
    t0 = cols["t0"]
    t1 = cols["t1"]
    plat = cols["platform"]
    fn = cols["fn"]
    link = cols["link"]
    for i in range(inv.size):
        k = int(kind[i])
        fid = int(fn[i])
        events.append({
            "name": KIND_NAMES[k],
            "ph": "X",
            "ts": float(t0[i]) * 1e6,
            "dur": (float(t1[i]) - float(t0[i])) * 1e6,
            "pid": int(plat[i]) + 1,
            "tid": int(inv[i]) if inv[i] >= 0 else 0,
            "cat": "lifecycle" if k < LIFECYCLE else "control",
            "args": {"fn": fnames[fid] if 0 <= fid < len(fnames) else "",
                     "link": int(link[i])},
        })
    return events


def write_chrome_trace(rec: FlightRecorder, path: str) -> int:
    """Write the trace file; returns the number of events written."""
    events = chrome_trace_events(rec)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)
