"""Chrome trace-event export: open any traced run in Perfetto.

``write_chrome_trace`` serializes the recorder's span columns as the
Chrome trace-event JSON format (``{"traceEvents": [...]}``, complete
``"X"`` events with microsecond ``ts``/``dur``).  Platforms map to
processes and invocations to tracks, so a scenario's queue waits, cold
starts, data staging and executions line up visually per platform —
load ``chrome://tracing`` or https://ui.perfetto.dev and drop the file.

``alert_annotation_events`` overlays the live-telemetry alert log
(repro.obs.alerts) as instant events: SLO burn alerts land on the
control track (pid 0, they aggregate across platforms) and platform
health anomalies on their platform's track, so a queue-depth anomaly
lines up with the queue spans that caused it.

``to_openmetrics`` renders a ``TelemetryEngine``'s rollups as an
OpenMetrics text exposition — the lingua franca of Prometheus scrapes —
so any run's telemetry can feed an external dashboard without bespoke
glue.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.recorder import KIND_NAMES, LIFECYCLE, FlightRecorder


def alert_annotation_events(slo_events: Sequence[Dict[str, Any]],
                            health_events: Sequence[Dict[str, Any]],
                            pnames: Sequence[str]
                            ) -> List[Dict[str, Any]]:
    """Alert log entries as Chrome instant events ("i", process scope).

    ``pnames`` is the recorder's platform order — the same pid mapping
    (platform index + 1) the span events use; health events for
    platforms the recorder never saw fall back to the control track."""
    pid_of = {name: i + 1 for i, name in enumerate(pnames)}
    events: List[Dict[str, Any]] = []
    for e in slo_events:
        events.append({
            "name": f"slo:{e['rule']}:{e['kind']}",
            "ph": "i", "s": "p",
            "ts": float(e["t"]) * 1e6,
            "pid": 0, "tid": 0,
            "cat": "alert",
            "args": {"fn": e["fn"], "severity": e["severity"],
                     "burn_short": e["burn_short"],
                     "burn_long": e["burn_long"]},
        })
    for e in health_events:
        events.append({
            "name": f"health:{e['metric']}:{e['kind']}",
            "ph": "i", "s": "p",
            "ts": float(e["t"]) * 1e6,
            "pid": pid_of.get(e["platform"], 0), "tid": 0,
            "cat": "alert",
            "args": {"platform": e["platform"], "z": e["z"]},
        })
    return events


def chrome_trace_events(rec: FlightRecorder) -> List[Dict[str, Any]]:
    events: List[Dict[str, Any]] = []
    pnames = rec.platform_names()
    fnames = rec.fn_names()
    events.append({"name": "process_name", "ph": "M", "pid": 0,
                   "args": {"name": "(control)"}})
    for pid, pname in enumerate(pnames):
        events.append({"name": "process_name", "ph": "M", "pid": pid + 1,
                       "args": {"name": pname}})
    cols = rec.spans.columns()
    inv = cols["inv"]
    kind = cols["kind"]
    t0 = cols["t0"]
    t1 = cols["t1"]
    plat = cols["platform"]
    fn = cols["fn"]
    link = cols["link"]
    for i in range(inv.size):
        k = int(kind[i])
        fid = int(fn[i])
        events.append({
            "name": KIND_NAMES[k],
            "ph": "X",
            "ts": float(t0[i]) * 1e6,
            "dur": (float(t1[i]) - float(t0[i])) * 1e6,
            "pid": int(plat[i]) + 1,
            "tid": int(inv[i]) if inv[i] >= 0 else 0,
            "cat": "lifecycle" if k < LIFECYCLE else "control",
            "args": {"fn": fnames[fid] if 0 <= fid < len(fnames) else "",
                     "link": int(link[i])},
        })
    return events


def write_chrome_trace(rec: FlightRecorder, path: str,
                       alerts: Optional[Dict[str, Any]] = None) -> int:
    """Write the trace file; returns the number of events written.

    ``alerts`` is a ScenarioReport ``alerts`` section: its SLO and
    health event logs become instant-event annotations on the matching
    tracks."""
    events = chrome_trace_events(rec)
    if alerts and alerts.get("enabled"):
        events += alert_annotation_events(
            alerts.get("slo", {}).get("events", []),
            alerts.get("health", {}).get("events", []),
            rec.platform_names())
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)


# ---------------------------------------------------------------------------
# OpenMetrics text exposition


_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _om_name(metric: str) -> str:
    return "fdn_" + _NAME_BAD.sub("_", metric)


def _om_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _om_float(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    # repr round-trips float64 exactly, so a parse-back compares equal
    return repr(float(v))


def to_openmetrics(engine, tier: Optional[int] = None) -> str:
    """Render a ``TelemetryEngine``'s rollups as OpenMetrics text.

    Each (platform, fn, metric) series aggregates its live buckets of
    one tier — by default the coarsest, which after ``finalize`` covers
    the whole run horizon — into one summary family ``fdn_<metric>``
    (``_count``/``_sum`` plus the sketch quantile of the newest live
    bucket), min/max gauges and an SLO-violation ``_bad`` counter.
    Engine totals ride along as ``fdn_telemetry_*``.  Floats are
    ``repr``-formatted so a parse-back compares exactly equal."""
    if tier is None:
        tier = len(engine.cfg.tiers_s) - 1
    q_label = _om_float(float(engine.cfg.quantile))
    # (metric -> [(labels, count, sum, min, max, bad, quantile)])
    per_metric: Dict[str, List] = {}
    for (platform, fn, metric) in engine.keys():
        sr = engine.series[(platform, fn, metric)]
        ids, counts, sums, mins, maxs, bad, q = sr.series(tier)
        if len(ids) == 0:
            continue
        labels = (f'platform="{_om_label(platform)}",'
                  f'fn="{_om_label(fn)}"')
        per_metric.setdefault(metric, []).append(
            (labels, int(counts.sum()), float(sums.sum()),
             float(mins.min()), float(maxs.max()), int(bad.sum()),
             float(q[-1])))
    out: List[str] = []
    for metric in sorted(per_metric):
        name = _om_name(metric)
        rows = per_metric[metric]
        out.append(f"# TYPE {name} summary")
        out.append(f"# HELP {name} rollup of the {metric} series "
                   f"(tier {tier})")
        for labels, cnt, tot, _lo, _hi, _bad, qv in rows:
            out.append(f"{name}_count{{{labels}}} {cnt}")
            out.append(f"{name}_sum{{{labels}}} {_om_float(tot)}")
            out.append(f"{name}{{{labels},quantile=\"{q_label}\"}} "
                       f"{_om_float(qv)}")
        out.append(f"# TYPE {name}_min gauge")
        for labels, _cnt, _tot, lo, _hi, _bad, _qv in rows:
            out.append(f"{name}_min{{{labels}}} {_om_float(lo)}")
        out.append(f"# TYPE {name}_max gauge")
        for labels, _cnt, _tot, _lo, hi, _bad, _qv in rows:
            out.append(f"{name}_max{{{labels}}} {_om_float(hi)}")
        out.append(f"# TYPE {name}_bad counter")
        out.append(f"# HELP {name}_bad samples above the series' "
                   f"violation threshold")
        for labels, _cnt, _tot, _lo, _hi, nbad, _qv in rows:
            out.append(f"{name}_bad_total{{{labels}}} {nbad}")
    out.append("# TYPE fdn_telemetry_samples counter")
    out.append(f"fdn_telemetry_samples_total {int(engine.folded)}")
    out.append("# TYPE fdn_telemetry_flushes counter")
    out.append(f"fdn_telemetry_flushes_total {int(engine.flushes)}")
    out.append("# TYPE fdn_telemetry_dropped_late counter")
    out.append(f"fdn_telemetry_dropped_late_total "
               f"{int(engine.dropped_late())}")
    out.append("# TYPE fdn_telemetry_series gauge")
    out.append(f"fdn_telemetry_series {len(engine.series)}")
    out.append("# EOF")
    return "\n".join(out) + "\n"
