"""Chrome trace-event export: open any traced run in Perfetto.

``write_chrome_trace`` serializes the recorder's span columns as the
Chrome trace-event JSON format (``{"traceEvents": [...]}``, complete
``"X"`` events with microsecond ``ts``/``dur``).  Platforms map to
processes and invocations to tracks, so a scenario's queue waits, cold
starts, data staging and executions line up visually per platform —
load ``chrome://tracing`` or https://ui.perfetto.dev and drop the file.

``alert_annotation_events`` overlays the live-telemetry alert log
(repro.obs.alerts) as instant events: SLO burn alerts land on the
control track (pid 0, they aggregate across platforms) and platform
health anomalies on their platform's track, so a queue-depth anomaly
lines up with the queue spans that caused it.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.recorder import KIND_NAMES, LIFECYCLE, FlightRecorder


def alert_annotation_events(slo_events: Sequence[Dict[str, Any]],
                            health_events: Sequence[Dict[str, Any]],
                            pnames: Sequence[str]
                            ) -> List[Dict[str, Any]]:
    """Alert log entries as Chrome instant events ("i", process scope).

    ``pnames`` is the recorder's platform order — the same pid mapping
    (platform index + 1) the span events use; health events for
    platforms the recorder never saw fall back to the control track."""
    pid_of = {name: i + 1 for i, name in enumerate(pnames)}
    events: List[Dict[str, Any]] = []
    for e in slo_events:
        events.append({
            "name": f"slo:{e['rule']}:{e['kind']}",
            "ph": "i", "s": "p",
            "ts": float(e["t"]) * 1e6,
            "pid": 0, "tid": 0,
            "cat": "alert",
            "args": {"fn": e["fn"], "severity": e["severity"],
                     "burn_short": e["burn_short"],
                     "burn_long": e["burn_long"]},
        })
    for e in health_events:
        events.append({
            "name": f"health:{e['metric']}:{e['kind']}",
            "ph": "i", "s": "p",
            "ts": float(e["t"]) * 1e6,
            "pid": pid_of.get(e["platform"], 0), "tid": 0,
            "cat": "alert",
            "args": {"platform": e["platform"], "z": e["z"]},
        })
    return events


def chrome_trace_events(rec: FlightRecorder) -> List[Dict[str, Any]]:
    events: List[Dict[str, Any]] = []
    pnames = rec.platform_names()
    fnames = rec.fn_names()
    events.append({"name": "process_name", "ph": "M", "pid": 0,
                   "args": {"name": "(control)"}})
    for pid, pname in enumerate(pnames):
        events.append({"name": "process_name", "ph": "M", "pid": pid + 1,
                       "args": {"name": pname}})
    cols = rec.spans.columns()
    inv = cols["inv"]
    kind = cols["kind"]
    t0 = cols["t0"]
    t1 = cols["t1"]
    plat = cols["platform"]
    fn = cols["fn"]
    link = cols["link"]
    for i in range(inv.size):
        k = int(kind[i])
        fid = int(fn[i])
        events.append({
            "name": KIND_NAMES[k],
            "ph": "X",
            "ts": float(t0[i]) * 1e6,
            "dur": (float(t1[i]) - float(t0[i])) * 1e6,
            "pid": int(plat[i]) + 1,
            "tid": int(inv[i]) if inv[i] >= 0 else 0,
            "cat": "lifecycle" if k < LIFECYCLE else "control",
            "args": {"fn": fnames[fid] if 0 <= fid < len(fnames) else "",
                     "link": int(link[i])},
        })
    return events


def write_chrome_trace(rec: FlightRecorder, path: str,
                       alerts: Optional[Dict[str, Any]] = None) -> int:
    """Write the trace file; returns the number of events written.

    ``alerts`` is a ScenarioReport ``alerts`` section: its SLO and
    health event logs become instant-event annotations on the matching
    tracks."""
    events = chrome_trace_events(rec)
    if alerts and alerts.get("enabled"):
        events += alert_annotation_events(
            alerts.get("slo", {}).get("events", []),
            alerts.get("health", {}).get("events", []),
            rec.platform_names())
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)
