"""Counterfactual what-if replay over journaled decision columns.

``replay`` re-scores a ``DecisionJournal``'s snapshot feature columns
offline — no simulation re-run — under any stateless registry policy
and/or alternate cascade params / scaled SLOs.  The policy ``cascade``
staticmethods are pure functions of exactly the journaled features and
mirror the live ``fn_cost_matrix`` arithmetic op for op, so replaying
under the *same* policy and params reproduces the original (numpy-
backend) choices byte-identically — ``replay_matches`` is the
correctness oracle pinned by tests and the ``run.py explain`` flow.

Journal rows are grouped by platform-set id; each group replays as one
dense (rows, P) cascade + masked argmin, first-lowest tie-break —
identical to the live ``fn_decisions`` host path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.scheduler import POLICIES
from repro.obs.provenance import FEATURE_COLS, DecisionJournal


@dataclass
class WhatIfConfig:
    """An alternate universe to re-score the journal under."""
    policy: str
    params: Dict[str, float] = field(default_factory=dict)
    slo_scale: float = 1.0

    @classmethod
    def parse(cls, text: str) -> "WhatIfConfig":
        """``policy=NAME[,key=value...]`` (``slo_scale`` is recognized as
        a config key; everything else is a cascade param override)."""
        policy, params, slo_scale = None, {}, 1.0
        for part in text.split(","):
            k, _, v = part.partition("=")
            k = k.strip()
            if k == "policy":
                policy = v.strip()
            elif k == "slo_scale":
                slo_scale = float(v)
            else:
                params[k] = float(v)
        if policy is None:
            raise ValueError(f"--whatif needs policy=NAME, got {text!r}")
        return cls(policy, params, slo_scale)


@dataclass
class ReplayResult:
    policy: str
    params: Dict[str, float]
    slo_scale: float
    choice: np.ndarray          # (n,) int16 chosen slot, -1 infeasible
    ok: np.ndarray              # (n,) bool
    est_s: np.ndarray           # (n,) chosen exec+data estimate (NaN if -1)

    def matches(self, journal: DecisionJournal) -> bool:
        """The byte-identical same-policy oracle."""
        return bool(np.array_equal(self.choice,
                                   journal.columns()["choice"]))


def _resolve(journal: DecisionJournal, cfg: Optional[WhatIfConfig]):
    if cfg is None:
        name = journal.policy_name
        params = dict(journal.params)
        slo_scale = 1.0
    else:
        name = cfg.policy
        cls = POLICIES.get(name)
        if cls is None:
            raise ValueError(f"unknown policy {name!r}")
        params = {**cls.CASCADE_PARAMS, **cfg.params}
        slo_scale = cfg.slo_scale
    cascade = getattr(POLICIES[name], "cascade", None)
    if cascade is None:
        raise ValueError(
            f"policy {name!r} is stateful (no cascade) — not replayable")
    return name, params, slo_scale, cascade


def replay(journal: DecisionJournal,
           cfg: Optional[WhatIfConfig] = None) -> ReplayResult:
    """Re-score every journal row.  ``cfg=None`` replays under the
    journaled policy + params (the oracle configuration)."""
    name, params, slo_scale, cascade = _resolve(journal, cfg)
    n = journal.n
    jc = journal.columns()
    choice = np.full(n, -1, np.int16)
    ok = np.zeros(n, bool)
    est_out = np.full(n, np.nan)
    for pid in np.unique(jc["pset"]) if n else ():
        mask = jc["pset"] == pid
        P = len(journal.pset_names[int(pid)])
        feats = {name2: jc[name2][mask][:, :P] for name2 in FEATURE_COLS}
        feats["alive"] = jc["alive"][mask][:, :P]
        feats["slo_s"] = jc["slo_s"][mask] * slo_scale
        cost, kill = cascade(feats, params)
        masked = np.where((kill == 0) & np.isfinite(cost), cost, np.inf)
        finite = np.isfinite(masked)
        any_ok = finite.any(axis=1)
        ch = np.argmin(masked, axis=1).astype(np.int16)
        ch = np.where(any_ok, ch, -1).astype(np.int16)
        est = feats["exec_s"] + feats["data_s"]
        chosen_est = est[np.arange(ch.size), np.maximum(ch, 0)]
        choice[mask] = ch
        ok[mask] = any_ok
        est_out[mask] = np.where(ch >= 0, chosen_est, np.nan)
    return ReplayResult(name, params, slo_scale, choice, ok, est_out)


def replay_matches(journal: DecisionJournal) -> bool:
    """Same-policy replay oracle: True iff re-scoring the journal under
    its own policy reproduces every journaled choice byte-identically."""
    return replay(journal).matches(journal)


def whatif_section(journal: DecisionJournal, base: ReplayResult,
                   alt: ReplayResult) -> Dict:
    """Counterfactual summary: how the alternate config's choices differ
    from the journaled ones, invocation-weighted."""
    jc = journal.columns()
    counts = jc["count"].astype(np.int64)
    n = journal.n
    changed = alt.choice != jc["choice"]
    base_est = base.est_s
    both = ~np.isnan(base_est) & ~np.isnan(alt.est_s)
    delta = alt.est_s[both] - base_est[both]
    w = counts[both]

    def shift(res_choice: np.ndarray) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for pid in np.unique(jc["pset"]) if n else ():
            names = journal.pset_names[int(pid)]
            mask = jc["pset"] == pid
            for slot in range(len(names)):
                c = int(counts[mask & (res_choice == slot)].sum())
                if c:
                    out[names[slot]] = out.get(names[slot], 0) + c
        return out

    return {
        "policy": alt.policy,
        "params": {k: float(v) for k, v in sorted(alt.params.items())},
        "slo_scale": float(alt.slo_scale),
        "decisions": int(n),
        "changed_decisions": int(changed.sum()),
        "changed_invocations": int(counts[changed].sum()),
        "changed_rate": float(changed.mean()) if n else 0.0,
        "platform_share_before": shift(jc["choice"]),
        "platform_share_after": shift(alt.choice),
        "est_latency_delta_mean_s":
            float((delta * w).sum() / w.sum()) if w.sum() else 0.0,
        "infeasible_after": int((alt.choice < 0).sum()),
    }
