"""Observability (repro.obs): the FDN's flight recorder.

The paper's FDN stands on monitoring (§3.1.2) — but windowed metrics say
*that* p90 blew the SLO, never *why*.  This package records per-invocation
lifecycle segments into struct-of-arrays span columns (``recorder``),
decomposes response time into exactly-reconciling segments and attributes
SLO violations to their dominant segment (``analysis``), and exports any
run as Chrome trace-event JSON openable in Perfetto (``export``).

Disabled, the recorder costs one ``is None`` check per admission burst;
enabled, deterministic head-based sampling keeps million-invocation runs
in budget.

The live half (``telemetry`` / ``alerts``) watches the system while it
runs: multi-resolution rollup tiers over the columnar metrics path,
multi-window burn-rate SLO alerting and EWMA+MAD platform-health
anomaly detection — same ``is None``-guard discipline, O(tiers) memory
on streams of any length.

``provenance`` / ``whatif`` answer *why this platform*: a columnar
decision journal tapped at the fused ``fn_decisions`` fast path records
per-candidate filter-kill bits, score columns, chosen slot and
runner-up margin; the journal joins to sink completions for
predicted-vs-realized calibration and decision regret, and replays
offline under alternate policies — same-policy replay reproduces the
original choices byte-identically.
"""
from repro.obs.recorder import (ADMIT, CHAIN_STAGE, COLD_START, DATA, EXEC,
                                HEDGE, INGRESS, KIND_NAMES, LIFECYCLE,
                                POOL_PREWARM, POOL_RETIRE, PREWARM_START,
                                QUEUE, REJECT, SEGMENT_NAMES, FlightRecorder,
                                SpanBuffer)
from repro.obs.analysis import (Decomposition, chain_critical_paths,
                                decompose, latency_breakdown_section,
                                reconcile, slo_attribution)
from repro.obs.export import (alert_annotation_events, chrome_trace_events,
                              to_openmetrics, write_chrome_trace)
from repro.obs.telemetry import (TelemetryConfig, TelemetryEngine, TierRing,
                                 SeriesRollup)
from repro.obs.alerts import (AlertConfig, BurnRule, alerts_section,
                              evaluate_health, evaluate_slo_burn)
from repro.obs.provenance import (DecisionJournal, decision_provenance_section,
                                  load_journal)
from repro.obs.whatif import (ReplayResult, WhatIfConfig, replay,
                              replay_matches, whatif_section)

__all__ = [
    "SpanBuffer", "FlightRecorder", "KIND_NAMES", "SEGMENT_NAMES",
    "LIFECYCLE", "INGRESS", "QUEUE", "COLD_START", "PREWARM_START", "DATA",
    "EXEC", "ADMIT", "REJECT", "HEDGE", "CHAIN_STAGE", "POOL_PREWARM",
    "POOL_RETIRE",
    "Decomposition", "decompose", "reconcile", "slo_attribution",
    "chain_critical_paths", "latency_breakdown_section",
    "chrome_trace_events", "write_chrome_trace", "alert_annotation_events",
    "TelemetryConfig", "TelemetryEngine", "TierRing", "SeriesRollup",
    "AlertConfig", "BurnRule", "alerts_section", "evaluate_health",
    "evaluate_slo_burn",
    "to_openmetrics",
    "DecisionJournal", "decision_provenance_section", "load_journal",
    "ReplayResult", "WhatIfConfig", "replay", "replay_matches",
    "whatif_section",
]
