"""Online alerting over telemetry rollups: multi-window multi-burn-rate
SLO alerts plus EWMA+MAD platform-health anomaly detection.

Both evaluators read *closed rollup buckets* from a
:class:`~repro.obs.telemetry.TelemetryEngine` — never raw samples — so
their cost is O(live buckets) per evaluation and their output is a pure
function of the rollup state: the alert event log is byte-identical
across runs of the same seeded scenario.

SLO alerting follows the Google-SRE multi-window multi-burn-rate
recipe: a rule fires only when the error-budget burn rate exceeds its
threshold over BOTH a short window (fast detection) and a long window
(flapping suppression).  The classic production windows (14.4x over
5m+1h pages, 3x over 1h+6h tickets) are the defaults; registry
scenarios shrink them to match their 2-minute horizons.

Health detection runs an EWMA baseline per (platform, health-metric)
series with a median-absolute-deviation scale estimated from the
EWMA residuals; ``k_consecutive`` buckets beyond ``z_threshold`` robust
z-scores raise an anomaly.  The MAD scale has a relative floor so
flat-line series (constant watts on an idle platform) don't alarm on
float noise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.telemetry import HEALTH_METRICS, NO_FN, TelemetryEngine

__all__ = ["BurnRule", "AlertConfig", "evaluate_slo_burn",
           "evaluate_health", "alerts_section", "DEFAULT_RULES"]


@dataclass(frozen=True)
class BurnRule:
    """One multi-window burn-rate rule.  ``burn`` is the error-budget
    consumption multiple: burn 14.4 on a 99.9% SLO eats a 30-day budget
    in ~50 hours."""

    name: str
    short_s: float
    long_s: float
    burn: float
    severity: str          # "page" | "ticket"


# Google-SRE production defaults (5m/1h page at 14.4x, 1h/6h ticket at 3x)
DEFAULT_RULES: Tuple[BurnRule, ...] = (
    BurnRule("fast_burn", 300.0, 3600.0, 14.4, "page"),
    BurnRule("slow_burn", 3600.0, 21600.0, 3.0, "ticket"),
)


@dataclass(frozen=True)
class AlertConfig:
    """Evaluation knobs.  ``slo_target`` sets the error budget
    (budget = 1 - target); ``eval_tier`` picks which rollup tier the
    windows are measured on (window seconds are converted to bucket
    counts on that tier)."""

    slo_target: float = 0.99
    eval_tier: int = 0
    rules: Tuple[BurnRule, ...] = DEFAULT_RULES
    min_long_samples: int = 8        # long window needs this many samples
    # health detector
    ewma_alpha: float = 0.25
    z_threshold: float = 6.0
    k_consecutive: int = 3
    warmup_buckets: int = 8
    mad_floor_frac: float = 0.05     # scale floor as a fraction of |mean|

    @staticmethod
    def from_dict(d: Dict) -> "AlertConfig":
        keys = {f.name for f in
                AlertConfig.__dataclass_fields__.values()}  # type: ignore
        kw = {k: v for k, v in d.items() if k in keys}
        if "rules" in kw:
            kw["rules"] = tuple(
                r if isinstance(r, BurnRule) else BurnRule(**r)
                for r in kw["rules"])
        return AlertConfig(**kw)


def _dense_series(engine: TelemetryEngine, keys, tier: int):
    """Aggregate several (platform, fn, metric) series onto one dense
    bucket timeline: returns (ids, counts, sums, bad) with zero-filled
    gaps, or None when no key has data."""
    parts = []
    for key in keys:
        sr = engine.series.get(key)
        if sr is None:
            continue
        ids, counts, sums, _mins, _maxs, bad, _q = sr.series(tier)
        if len(ids):
            parts.append((ids, counts, sums, bad))
    if not parts:
        return None
    lo = min(int(p[0][0]) for p in parts)
    hi = max(int(p[0][-1]) for p in parts)
    n = hi - lo + 1
    counts = np.zeros(n, np.int64)
    sums = np.zeros(n)
    bad = np.zeros(n, np.int64)
    for ids, c, s, b in parts:
        idx = ids - lo
        np.add.at(counts, idx, c)
        np.add.at(sums, idx, s)
        np.add.at(bad, idx, b)
    return np.arange(lo, hi + 1, dtype=np.int64), counts, sums, bad


def _window_sums(x: np.ndarray, w: int) -> np.ndarray:
    """Trailing-window sums: out[i] = sum(x[max(0, i-w+1) .. i])."""
    c = np.cumsum(x, dtype=np.float64)
    out = c.copy()
    if w < len(x):
        out[w:] = c[w:] - c[:-w]
    return out


def evaluate_slo_burn(engine: TelemetryEngine, fns: Sequence[str],
                      cfg: AlertConfig) -> List[Dict]:
    """Burn-rate evaluation for each function's response_time series,
    aggregated across platforms.  Emits deterministic fire/resolve
    events ordered by (time, fn, rule)."""
    tier_s = engine.cfg.tiers_s[cfg.eval_tier]
    budget = max(1.0 - cfg.slo_target, 1e-9)
    platforms = sorted({p for (p, f, m) in engine.series
                        if m == "response_time"})
    events: List[Dict] = []
    for fn in sorted(fns):
        dense = _dense_series(
            engine, [(p, fn, "response_time") for p in platforms],
            cfg.eval_tier)
        if dense is None:
            continue
        ids, counts, _sums, bad = dense
        for rule in cfg.rules:
            ws = max(1, int(round(rule.short_s / tier_s)))
            wl = max(1, int(round(rule.long_s / tier_s)))
            tot_s = _window_sums(counts.astype(np.float64), ws)
            tot_l = _window_sums(counts.astype(np.float64), wl)
            bad_s = _window_sums(bad.astype(np.float64), ws)
            bad_l = _window_sums(bad.astype(np.float64), wl)
            burn_s = bad_s / np.maximum(tot_s, 1.0) / budget
            burn_l = bad_l / np.maximum(tot_l, 1.0) / budget
            # a window only counts once the timeline covers it — a 60 s
            # burn window evaluated 5 s into a run would alert on the
            # cold-start transient of an otherwise healthy scenario
            covered = np.arange(1, len(ids) + 1) >= wl
            active = (covered & (burn_s >= rule.burn)
                      & (burn_l >= rule.burn)
                      & (tot_l >= cfg.min_long_samples))
            prev = False
            for i in range(len(ids)):
                cur = bool(active[i])
                if cur != prev:
                    events.append({
                        "t": round(float((ids[i] + 1) * tier_s), 6),
                        "kind": "fire" if cur else "resolve",
                        "fn": fn,
                        "rule": rule.name,
                        "severity": rule.severity,
                        "burn_short": round(float(burn_s[i]), 6),
                        "burn_long": round(float(burn_l[i]), 6),
                    })
                prev = cur
    events.sort(key=lambda e: (e["t"], e["fn"], e["rule"], e["kind"]))
    return events


def _health_points(engine: TelemetryEngine, platform: str, metric: str,
                   tier: int):
    """Per-bucket mean series for one platform-health metric, or the
    derived cold-start rate (cold starts per completion)."""
    if metric == "cold_start_rate":
        fns = sorted({f for (p, f, m) in engine.series
                      if p == platform and m == "response_time"
                      and f != NO_FN})
        comp = _dense_series(
            engine, [(platform, f, "response_time") for f in fns], tier)
        if comp is None:
            return None
        ids, counts, _sums, _bad = comp
        cold = _dense_series(
            engine, [(platform, f, "cold_starts") for f in fns], tier)
        rate = np.zeros(len(ids))
        if cold is not None:
            cids, ccounts, csums, _cb = cold
            idx = cids - int(ids[0])
            ok = (idx >= 0) & (idx < len(ids))
            rate[idx[ok]] = csums[ok]
        return ids, rate / np.maximum(counts, 1)
    sr = engine.series.get((platform, NO_FN, metric))
    if sr is None:
        return None
    ids, counts, sums, _mins, _maxs, _bad, _q = sr.series(tier)
    if not len(ids):
        return None
    return ids, sums / np.maximum(counts, 1)


def evaluate_health(engine: TelemetryEngine, cfg: AlertConfig
                    ) -> List[Dict]:
    """EWMA+MAD robust z-score sweep over each platform's health series.
    Sequential over <= capacity points per series — cheap and exactly
    deterministic."""
    tier_s = engine.cfg.tiers_s[cfg.eval_tier]
    platforms = sorted({p for (p, f, m) in engine.series
                        if f == NO_FN and m in HEALTH_METRICS})
    events: List[Dict] = []
    metrics = list(HEALTH_METRICS) + ["cold_start_rate"]
    for platform in platforms:
        for metric in metrics:
            pts = _health_points(engine, platform, metric, cfg.eval_tier)
            if pts is None:
                continue
            ids, vals = pts
            mu = float(vals[0])
            resid: List[float] = []
            streak = 0
            active = False
            for i in range(1, len(vals)):
                x = float(vals[i])
                r = x - mu
                if len(resid) >= max(2, cfg.warmup_buckets):
                    mad = float(np.median(np.abs(np.asarray(resid))))
                    scale = max(1.4826 * mad,
                                cfg.mad_floor_frac * abs(mu), 1e-9)
                    z = max(-9999.0, min(9999.0, r / scale))
                    if abs(z) >= cfg.z_threshold:
                        streak += 1
                    else:
                        streak = 0
                        if active:
                            active = False
                            events.append({
                                "t": round(float((ids[i] + 1) * tier_s), 6),
                                "kind": "resolve",
                                "platform": platform,
                                "metric": metric,
                                "z": round(z, 4),
                            })
                    if streak >= cfg.k_consecutive and not active:
                        active = True
                        events.append({
                            "t": round(float((ids[i] + 1) * tier_s), 6),
                            "kind": "fire",
                            "platform": platform,
                            "metric": metric,
                            "z": round(z, 4),
                        })
                # anomalous points don't poison the baseline: only track
                # the EWMA/residuals while the detector is quiet
                if streak == 0:
                    resid.append(r)
                    if len(resid) > 4 * max(2, cfg.warmup_buckets):
                        resid.pop(0)
                    mu = mu + cfg.ewma_alpha * r
    events.sort(key=lambda e: (e["t"], e["platform"], e["metric"],
                               e["kind"]))
    return events


def alerts_section(engine: Optional[TelemetryEngine],
                   fns: Sequence[str],
                   cfg: Optional[AlertConfig] = None) -> Dict:
    """The canonical-JSON ``alerts`` ScenarioReport section."""
    if engine is None:
        return {"enabled": False}
    cfg = cfg or AlertConfig()
    engine.finalize()
    slo_events = evaluate_slo_burn(engine, fns, cfg)
    health_events = evaluate_health(engine, cfg)
    by_sev: Dict[str, int] = {}
    for e in slo_events:
        if e["kind"] == "fire":
            by_sev[e["severity"]] = by_sev.get(e["severity"], 0) + 1
    by_metric: Dict[str, int] = {}
    for e in health_events:
        if e["kind"] == "fire":
            by_metric[e["metric"]] = by_metric.get(e["metric"], 0) + 1
    return {
        "enabled": True,
        "config": {
            "slo_target": cfg.slo_target,
            "eval_tier_s": float(engine.cfg.tiers_s[cfg.eval_tier]),
            "rules": [{"name": r.name, "short_s": r.short_s,
                       "long_s": r.long_s, "burn": r.burn,
                       "severity": r.severity} for r in cfg.rules],
            "z_threshold": cfg.z_threshold,
            "k_consecutive": cfg.k_consecutive,
        },
        "rollup": engine.rollup_summary(),
        "slo": {"events": slo_events,
                "fires": sum(1 for e in slo_events if e["kind"] == "fire"),
                "by_severity": by_sev},
        "health": {"events": health_events,
                   "fires": sum(1 for e in health_events
                                if e["kind"] == "fire"),
                   "by_metric": by_metric},
    }
