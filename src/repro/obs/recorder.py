"""Flight recorder: array-native invocation-lifecycle tracing.

``SpanBuffer`` is the storage — grow-by-doubling NumPy columns, one row
per recorded span: invocation id (``-1`` for aggregate/control spans),
segment kind, ``[t0, t1)`` sim-time bounds, interned platform and function
ids, and a generic ``link`` column (attempt index for lifecycle spans,
the original invocation for hedge duplicates, the chain-instance id for
chain-stage spans, the group size for admission/pool spans).

``FlightRecorder`` is the tap surface the core calls into.  Every tap
site guards with ``if recorder is not None`` — the disabled path costs
one attribute read per admission burst, nothing per invocation.  All
per-invocation lifecycle segments are recorded from the single launch
tap (``TargetPlatform._launch``), where arrival, queue-entry, startup,
data-staging and execution times are all known at once, so the object
and columnar admission paths produce identical traces.

Sampling is deterministic and head-based: an invocation is traced iff a
multiplicative hash of its id falls under ``sample`` — every segment of
one invocation is kept or dropped together, and two runs of one seeded
scenario record byte-identical span columns.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

# lifecycle segment kinds (the latency decomposition, exclusive intervals)
INGRESS, QUEUE, COLD_START, PREWARM_START, DATA, EXEC = range(6)
LIFECYCLE = 6                       # kinds < LIFECYCLE decompose response
# control/aggregate kinds
ADMIT, REJECT, HEDGE, CHAIN_STAGE, POOL_PREWARM, POOL_RETIRE = range(6, 12)

KIND_NAMES = ("ingress", "queue", "cold_start", "prewarm_start", "data",
              "exec", "admit", "reject", "hedge", "chain_stage",
              "pool_prewarm", "pool_retire")
SEGMENT_NAMES = KIND_NAMES[:LIFECYCLE]

_HASH_MULT = np.uint64(2654435761)          # Knuth multiplicative hash
_HASH_MASK = np.uint64(0xFFFFFFFF)


class SpanBuffer:
    """Grow-by-doubling span columns (struct-of-arrays, PR-6 discipline)."""

    __slots__ = ("_inv", "_kind", "_t0", "_t1", "_platform", "_fn",
                 "_link", "_n")

    def __init__(self, capacity: int = 1024):
        capacity = max(int(capacity), 1)
        self._inv = np.empty(capacity, np.int64)
        self._kind = np.empty(capacity, np.int8)
        self._t0 = np.empty(capacity)
        self._t1 = np.empty(capacity)
        self._platform = np.empty(capacity, np.int16)
        self._fn = np.empty(capacity, np.int32)
        self._link = np.empty(capacity, np.int64)
        self._n = 0

    @property
    def n(self) -> int:
        return self._n

    def _grow(self, need: int):
        cap = max(self._inv.size * 2, need)
        for name in ("_inv", "_kind", "_t0", "_t1", "_platform", "_fn",
                     "_link"):
            a = getattr(self, name)
            b = np.empty(cap, a.dtype)
            b[:self._n] = a[:self._n]
            setattr(self, name, b)

    def add(self, inv: int, kind: int, t0: float, t1: float,
            platform: int, fn: int, link: int):
        i = self._n
        if i == self._inv.size:
            self._grow(i + 1)
        self._inv[i] = inv
        self._kind[i] = kind
        self._t0[i] = t0
        self._t1[i] = t1
        self._platform[i] = platform
        self._fn[i] = fn
        self._link[i] = link
        self._n = i + 1

    def add_many(self, inv, kind, t0, t1, platform, fn, link):
        """Bulk append of parallel span columns (one slice copy each)."""
        inv = np.asarray(inv, np.int64)
        k = inv.size
        if k == 0:
            return
        need = self._n + k
        if need > self._inv.size:
            self._grow(need)
        lo, hi = self._n, need
        self._inv[lo:hi] = inv
        self._kind[lo:hi] = kind
        self._t0[lo:hi] = t0
        self._t1[lo:hi] = t1
        self._platform[lo:hi] = platform
        self._fn[lo:hi] = fn
        self._link[lo:hi] = link
        self._n = need

    def columns(self) -> Dict[str, np.ndarray]:
        """Trimmed views (not copies) of the recorded spans."""
        n = self._n
        return {"inv": self._inv[:n], "kind": self._kind[:n],
                "t0": self._t0[:n], "t1": self._t1[:n],
                "platform": self._platform[:n], "fn": self._fn[:n],
                "link": self._link[:n]}


class FlightRecorder:
    """The tap surface: interned ids + sampling over one ``SpanBuffer``."""

    def __init__(self, sample: float = 1.0, capacity: int = 1024):
        self.sample = min(max(float(sample), 0.0), 1.0)
        self._threshold = np.uint64(int(self.sample * float(2 ** 32)))
        self.spans = SpanBuffer(capacity)
        self._pids: Dict[str, int] = {}
        self._fids: Dict[str, int] = {}

    # ----------------------------------------------------------- intern ---
    def platform_id(self, name: Optional[str]) -> int:
        if name is None:
            return -1
        pid = self._pids.get(name)
        if pid is None:
            pid = len(self._pids)
            self._pids[name] = pid
        return pid

    def fn_id(self, name: Optional[str]) -> int:
        if name is None:
            return -1
        fid = self._fids.get(name)
        if fid is None:
            fid = len(self._fids)
            self._fids[name] = fid
        return fid

    def platform_names(self) -> List[str]:
        return list(self._pids)

    def fn_names(self) -> List[str]:
        return list(self._fids)

    # --------------------------------------------------------- sampling ---
    def keep_mask(self, ids: np.ndarray) -> np.ndarray:
        """Deterministic head-based sampling decision per invocation id."""
        if self.sample >= 1.0:
            return np.ones(ids.size, bool)
        h = (ids.astype(np.uint64) * _HASH_MULT) & _HASH_MASK
        return h < self._threshold

    def keep(self, inv_id: int) -> bool:
        if self.sample >= 1.0:
            return True
        h = (np.uint64(inv_id) * _HASH_MULT) & _HASH_MASK
        return bool(h < self._threshold)

    def traced_invocations(self) -> int:
        """Distinct invocations with at least one lifecycle span."""
        cols = self.spans.columns()
        mask = (cols["kind"] < LIFECYCLE) & (cols["inv"] >= 0)
        return int(np.unique(cols["inv"][mask]).size)

    # ------------------------------------------------------ launch tap  ---
    def record_launch(self, invs: Sequence, fns: Sequence, pname: str,
                      now: float, startups, data_ts, end_ts, colds):
        """The one tap that yields the whole per-invocation decomposition
        (called from ``TargetPlatform._launch``, scalar and vectorized
        paths alike).  ``end_ts`` must be the exact values the finish
        callbacks are scheduled at — ``inv.end_t`` bit-for-bit — so the
        recorded segments reconcile exactly with ``response_time``.

        Segments per started row: ingress ``[arrival, scheduled)``, queue
        ``[scheduled, now)``, cold/prewarm start ``[now, now+startup)``
        when a container had to start, data staging and execution filling
        ``[now+startup, end)``.
        """
        n = len(invs)
        ids = np.fromiter((inv.id for inv in invs), np.int64, n)
        keep = self.keep_mask(ids)
        if not keep.any():
            return
        idx = np.flatnonzero(keep)
        ids = ids[idx]
        startup = np.asarray(startups, float)[idx]
        data = np.asarray(data_ts, float)[idx]
        end = np.asarray(end_ts, float)[idx]
        cold = np.asarray(colds, bool)[idx]
        arrival = np.fromiter((invs[i].arrival_t for i in idx),
                              float, idx.size)
        sched = np.fromiter(
            (invs[i].scheduled_t if invs[i].scheduled_t is not None
             else now for i in idx), float, idx.size)
        att = np.fromiter((invs[i].attempts for i in idx),
                          np.int64, idx.size)
        fid = np.fromiter((self.fn_id(fns[i].name) for i in idx),
                          np.int32, idx.size)
        pid = self.platform_id(pname)
        k = idx.size
        start = now + startup
        dstop = start + data

        inv_cols = [ids, ids, ids]
        kind_cols = [np.full(k, INGRESS, np.int8),
                     np.full(k, QUEUE, np.int8),
                     np.full(k, EXEC, np.int8)]
        t0_cols = [arrival, sched, dstop]
        t1_cols = [sched, np.full(k, now), end]
        fn_cols = [fid, fid, fid]
        link_cols = [att, att, att]
        su = np.flatnonzero(startup > 0.0)
        if su.size:
            inv_cols.append(ids[su])
            kind_cols.append(np.where(cold[su], COLD_START,
                                      PREWARM_START).astype(np.int8))
            t0_cols.append(np.full(su.size, now))
            t1_cols.append(start[su])
            fn_cols.append(fid[su])
            link_cols.append(att[su])
        da = np.flatnonzero(data > 0.0)
        if da.size:
            inv_cols.append(ids[da])
            kind_cols.append(np.full(da.size, DATA, np.int8))
            t0_cols.append(start[da])
            t1_cols.append(dstop[da])
            fn_cols.append(fid[da])
            link_cols.append(att[da])
        self.spans.add_many(np.concatenate(inv_cols),
                            np.concatenate(kind_cols),
                            np.concatenate(t0_cols),
                            np.concatenate(t1_cols),
                            pid,
                            np.concatenate(fn_cols),
                            np.concatenate(link_cols))

    # ------------------------------------------------- control-path taps --
    def record_admit(self, fn_name: str, pname: str, t: float, count: int):
        """One admission-decision span per (fn, platform) group — both the
        object and the columnar submit paths record groups, keeping their
        traces aligned.  ``link`` carries the group size."""
        self.spans.add(-1, ADMIT, t, t, self.platform_id(pname),
                       self.fn_id(fn_name), count)

    def record_reject(self, fn_name: Optional[str], pname: Optional[str],
                      t: float, count: int):
        self.spans.add(-1, REJECT, t, t, self.platform_id(pname),
                       self.fn_id(fn_name), count)

    def record_hedge(self, dup, orig, t: float):
        """Speculative duplicate spawned: the dup's lifecycle spans appear
        at its own launch; this span links it back to the original."""
        self.spans.add(dup.id, HEDGE, t, t, -1,
                       self.fn_id(dup.fn.name), orig.id)

    def record_chain_stage(self, inst_id: int, inv_id: int, fn_name: str,
                           pname: Optional[str], t0: float, t1: float):
        """One span per completed chain stage: ``[ready, completed)``,
        linked to the chain instance — the edges the critical-path
        extraction chains backwards through."""
        self.spans.add(inv_id, CHAIN_STAGE, t0, t1,
                       self.platform_id(pname), self.fn_id(fn_name),
                       inst_id)

    def record_prewarm(self, pname: str, fn_name: str, t: float, n: int):
        self.spans.add(-1, POOL_PREWARM, t, t, self.platform_id(pname),
                       self.fn_id(fn_name), n)

    def record_retire(self, pname: str, fn_name: str, t: float, n: int):
        self.spans.add(-1, POOL_RETIRE, t, t, self.platform_id(pname),
                       self.fn_id(fn_name), n)
