"""AdamW with ZeRO-1 optimizer-state sharding, global-norm clipping,
cosine LR schedule, and optional int8 gradient compression (error feedback).

States (m, v) are f32 and additionally sharded over the data-parallel axes
("zero" logical axis): GSPMD then lowers the update into the classic ZeRO-1
reduce-scatter(grads) -> local update -> all-gather(params) schedule without
hand-written collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.models import params as pm

# register the ZeRO logical axis
shd.RULES.setdefault("zero", ("__dp__",))


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False     # int8 all-reduce with error feedback


def schedule(oc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    t = (step - oc.warmup_steps) / jnp.maximum(
        oc.total_steps - oc.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


# ---------------------------------------------------------------------------
# State declaration (Spec trees -> shardings reuse the params machinery)
# ---------------------------------------------------------------------------


def _zero_spec(s: pm.Spec) -> pm.Spec:
    """ZeRO-1: optimizer state sharded over the data axes on the largest
    effectively-replicated dim (see params.fsdp_spec)."""
    z = pm.fsdp_spec(s)
    return pm.Spec(z.shape, z.axes, "zeros")


def state_specs(model_spec_tree) -> Dict[str, Any]:
    mv = pm.tree_map(_zero_spec, model_spec_tree)
    ef = pm.tree_map(lambda s: pm.Spec(s.shape, s.axes, "zeros"),
                     model_spec_tree)
    return {"m": mv, "v": jax.tree_util.tree_map(
        lambda x: x, mv, is_leaf=pm.is_spec), "ef": ef,
        "step": pm.Spec((), (), "zeros")}


def init_state(oc: OptConfig, model_spec_tree) -> Dict[str, Any]:
    spec = state_specs(model_spec_tree)
    zeros = lambda t: pm.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.float32), t)
    out = {"m": zeros(spec["m"]), "v": zeros(spec["v"]),
           "step": jnp.zeros((), jnp.int32)}
    if oc.compress_grads:
        out["ef"] = zeros(spec["ef"])
    return out


def state_shardings(oc: OptConfig, model_spec_tree, mesh):
    spec = state_specs(model_spec_tree)
    out = {"m": pm.shardings(spec["m"], mesh),
           "v": pm.shardings(spec["v"], mesh),
           "step": shd.named_sharding(mesh, (), ())}
    if oc.compress_grads:
        out["ef"] = pm.shardings(spec["ef"], mesh)
    return out


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback) — beyond-paper distributed
# optimization trick, toggled by OptConfig.compress_grads.
# ---------------------------------------------------------------------------


def compress_decompress(g: jax.Array, ef: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Quantize g+ef to int8 per-tensor scale, return (g_hat, new_ef)."""
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, gf - g_hat


# ---------------------------------------------------------------------------
# Update
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(oc: OptConfig, params, grads, state
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = schedule(oc, step)

    if oc.compress_grads:
        pairs = jax.tree_util.tree_map(compress_decompress, grads,
                                       state["ef"])
        grads = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                        is_leaf=lambda x: isinstance(x, tuple))

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - oc.b1 ** step.astype(jnp.float32)
    b2c = 1 - oc.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = oc.b1 * m + (1 - oc.b1) * g
        v = oc.b2 * v + (1 - oc.b2) * jnp.square(g)
        mhat, vhat = m / b1c, v / b2c
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + \
            oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    triples = jax.tree_util.tree_map(upd, params, grads, state["m"],
                                     state["v"])
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3 and not pm.is_spec(x)
    new_p = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=is3)
    new_m = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=is3)
    new_v = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=is3)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if oc.compress_grads:
        new_state["ef"] = new_ef
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
