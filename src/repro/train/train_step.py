"""Training / serving step builders — the jit-able functions the launcher,
dry-run and FDN platforms all share.

``train_step``: fwd + bwd (+ optional microbatch grad accumulation via scan)
+ AdamW update. ``prefill_step`` / ``serve_step``: inference entry points.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, InputShape
from repro.models import model_api as api
from repro.train import optimizer as opt


def _split_microbatches(batch: Dict, n: int) -> Dict:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, oc: opt.OptConfig,
                    num_microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics)."""

    def loss(params, mb):
        l, metrics = api.loss_fn(cfg, params, mb, remat=True)
        return l, metrics

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if num_microbatches > 1:
            mbs = _split_microbatches(batch, num_microbatches)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(acc, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / num_microbatches, grads)
            l = lsum / num_microbatches
        else:
            (l, _), grads = grad_fn(params, batch)
        new_params, new_state, om = opt.apply_updates(oc, params, grads,
                                                      opt_state)
        metrics = {"loss": l, **om}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, context_len: Optional[int] = None):
    def prefill_step(params, batch):
        return api.prefill(cfg, params, batch, context_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: new token for every sequence, cache in/out."""
    def serve_step(params, cache, batch):
        return api.decode_step(cfg, params, cache, batch)
    return serve_step


def default_microbatches(cfg: ModelConfig, shape: InputShape,
                         n_chips: int) -> int:
    """Activation-memory heuristic: keep saved layer inputs under ~2 GiB/chip.

    With remat='dots', per-layer live activations ~= batch*seq*d_model*2B
    (+ MoE dispatch buffers); we bound sum over layers / chips.
    """
    if shape.kind != "train":
        return 1
    depth = cfg.num_layers
    bytes_per_layer = shape.global_batch * shape.seq_len * cfg.d_model * 2
    total = bytes_per_layer * max(depth, 1)
    budget = 2 * (1 << 30) * n_chips
    n = max(1, int(-(-total // budget)))
    # round to a divisor of global_batch
    while shape.global_batch % n:
        n += 1
    return min(n, shape.global_batch)
