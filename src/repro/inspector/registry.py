"""Scenario registry: the paper's figure/table experiments re-expressed as
declarative FDNInspector scenarios, plus scenarios the hand-wired
benchmarks could not express (multi-function mixes across five platforms,
energy sweeps under diurnal load, MMPP burst storms, mid-run platform
outages, overload ramps, Azure-style minute-count replay).

``get(name)`` builds a fresh ``Scenario``; ``names()`` lists everything
registered.  The parameterized ``fig5_cell`` / ``fig7_cell`` /
``fig10_scenario`` / ``table4_cell`` builders are what the migrated
``benchmarks/fig*.py`` modules iterate over.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.inspector import traces
from repro.inspector.scenario import (IMAGE_KEY, REMOTE_STORE, FaultEvent,
                                      Scenario, Workload)

PAPER_FIVE = ("hpc-node-cluster", "old-hpc-node-cluster", "cloud-cluster",
              "google-cloud-cluster", "edge-cluster")

_BUILDERS: Dict[str, Callable[[], Scenario]] = {}


def register(name: str, builder: Callable[[], Scenario]) -> None:
    _BUILDERS[name] = builder


def names() -> List[str]:
    return sorted(_BUILDERS)


def get(name: str) -> Scenario:
    if name not in _BUILDERS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {', '.join(names())}")
    return _BUILDERS[name]()


# ---------------------------------------------------------------------------
# Paper experiments as scenario families (benchmarks/fig*.py iterate these)
# ---------------------------------------------------------------------------

def fig5_cell(platform: str, vus: int, duration_s: float = 120.0,
              analytic: bool = False) -> Scenario:
    """Fig. 5: nodeinfo, exclusive on one platform, closed-loop VUs."""
    return Scenario(
        name=f"fig5/nodeinfo/{platform}/vus{vus}",
        platforms=PAPER_FIVE,
        workloads=(Workload("nodeinfo", mode="closed", vus=vus,
                            sleep_s=0.05),),
        duration_s=duration_s, platform_override=platform,
        analytic=analytic)


def fig7_cell(platform: str, function: str, duration_s: float = 120.0,
              analytic: bool = False) -> Scenario:
    """Fig. 7: function heterogeneity at 30 VUs on one platform."""
    return Scenario(
        name=f"fig7/{function}/{platform}/vus30",
        platforms=PAPER_FIVE,
        workloads=(Workload(function, mode="closed", vus=30,
                            sleep_s=0.2),),
        duration_s=duration_s, platform_override=platform,
        analytic=analytic)


def fig10_scenario(mode: str, duration_s: float = 120.0,
                   analytic: bool = False) -> Scenario:
    """Fig. 10: primes-python at 40 VUs over old-hpc + cloud — exclusive
    arms or gateway collaboration (round-robin / weighted 5:1)."""
    pair = ("old-hpc-node-cluster", "cloud-cluster")
    wl = (Workload("primes-python", mode="closed", vus=40, sleep_s=0.05),)
    base = dict(platforms=pair, workloads=wl, duration_s=duration_s,
                analytic=analytic)
    if mode in pair:
        return Scenario(name=f"fig10/exclusive/{mode}",
                        platform_override=mode, **base)
    if mode == "round_robin":
        return Scenario(name="fig10/round_robin", lb_policy="round_robin",
                        **base)
    if mode == "weighted":
        return Scenario(name="fig10/weighted_5to1", lb_policy="weighted",
                        lb_kwargs={"weights": {"old-hpc-node-cluster": 5,
                                               "cloud-cluster": 1}},
                        **base)
    raise KeyError(f"unknown fig10 mode {mode!r}")


def fig6_cell(platform: str, duration_s: float = 120.0,
              analytic: bool = False) -> Scenario:
    """Fig. 6: nodeinfo at 20 VUs, exclusive on one platform — the Table-1
    metric-detail run (same drive as ``fig5_cell`` at 20 VUs; the fig6
    benchmark reads the metric *series* behind the report via
    ``run_scenario_state``)."""
    return Scenario(
        name=f"fig6/nodeinfo/{platform}/vus20",
        platforms=PAPER_FIVE,
        workloads=(Workload("nodeinfo", mode="closed", vus=20,
                            sleep_s=0.05),),
        duration_s=duration_s, platform_override=platform,
        analytic=analytic)


def fig8_cell(bg_cpu: float, duration_s: float = 120.0,
              analytic: bool = False) -> Scenario:
    """Fig. 8: image-processing at 40 VUs on old-hpc with background CPU
    load in {0%, 50%, 100%} (the §5.1.2 interference knob)."""
    platform = "old-hpc-node-cluster"
    return Scenario(
        name=f"fig8/image-processing/bg_cpu{int(bg_cpu * 100)}",
        platforms=PAPER_FIVE,
        workloads=(Workload("image-processing", mode="closed", vus=40,
                            sleep_s=0.5),),
        duration_s=duration_s, platform_override=platform,
        data_location=platform, bg_cpu={platform: bg_cpu},
        analytic=analytic)


def fig9_cell(bg_mem: float, duration_s: float = 120.0,
              analytic: bool = False) -> Scenario:
    """Fig. 9: image-processing at 40 VUs on old-hpc with background
    MEMORY load in {0%, 50%, 100%} — the swap-cliff twin of fig8."""
    platform = "old-hpc-node-cluster"
    return Scenario(
        name=f"fig9/image-processing/bg_mem{int(bg_mem * 100)}",
        platforms=PAPER_FIVE,
        workloads=(Workload("image-processing", mode="closed", vus=40,
                            sleep_s=0.5),),
        duration_s=duration_s, platform_override=platform,
        data_location=platform, bg_mem={platform: bg_mem},
        analytic=analytic)


FIG11_ARMS = {
    # variant -> (compute platform, data location, pre-run migrations)
    "cloud-local-minio": ("cloud-cluster", "cloud-cluster", ()),
    "cloud-remote-minio": ("cloud-cluster", REMOTE_STORE, ()),
    "gcf-near-data": ("google-cloud-cluster", REMOTE_STORE, ()),
    "cloud-after-migration": ("cloud-cluster", REMOTE_STORE,
                              ((IMAGE_KEY, "cloud-cluster"),)),
}


def fig11_cell(variant: str, duration_s: float = 120.0,
               analytic: bool = False) -> Scenario:
    """Fig. 11: image-processing at 20 VUs — local vs remote MinIO vs
    compute-near-data vs migrate-then-run (§5.1.4 adaptive data
    management).  With ``data_location=REMOTE_STORE`` the runner seeds the
    object at the remote store ONLY, so the remote arms read across the
    WAN by construction."""
    platform, data_loc, migrations = FIG11_ARMS[variant]
    return Scenario(
        name=f"fig11/{variant}",
        platforms=PAPER_FIVE,
        workloads=(Workload("image-processing", mode="closed", vus=20,
                            sleep_s=0.2),),
        duration_s=duration_s, platform_override=platform,
        data_location=data_loc, migrate_objects=migrations,
        analytic=analytic)


SWEEP_POLICIES = ("perf_ranked", "utilization_aware", "round_robin",
                  "energy_aware", "slo_composite")
SWEEP_FNS = ("nodeinfo", "primes-python", "JSON-loads", "image-processing")


def policy_sweep_cell(policy: str, duration_s: float = 90.0,
                      analytic: bool = True) -> Scenario:
    """One arm of the all-policy head-to-head: four closed-loop function
    streams over the five platforms under ``policy`` (deterministic
    per-stream seeds come from the runner — the old hand-wired sweep
    seeded VU pools with salted ``hash(fn)``)."""
    return Scenario(
        name=f"sweep/{policy}",
        platforms=PAPER_FIVE,
        workloads=tuple(Workload(fn, mode="closed", vus=8, sleep_s=0.1)
                        for fn in SWEEP_FNS),
        duration_s=duration_s, policy=policy, analytic=analytic)


def policy_sweep_open_loop(duration_s: float = 90.0,
                           rps: float = 60.0) -> Scenario:
    """The sweep's open-loop arm: Poisson nodeinfo through the batched
    gateway path under the composite policy (burst admission must hold
    the SLO too)."""
    return Scenario(
        name="sweep/slo_composite-open-loop",
        platforms=PAPER_FIVE,
        workloads=(Workload("nodeinfo",
                            arrival={"kind": "poisson", "rps": rps}),),
        duration_s=duration_s, batch_window_s=0.1)


def table4_cell(platform: str, duration_s: float = 600.0, rps: float = 40.0,
                analytic: bool = False) -> Scenario:
    """Table 4: JSON-loads at a fixed open-loop arrival rate, exclusive on
    one platform, data local to that platform (energy comparison)."""
    return Scenario(
        name=f"table4/JSON-loads/{platform}",
        platforms=PAPER_FIVE,
        workloads=(Workload("JSON-loads", mode="open",
                            arrival={"kind": "uniform", "rps": rps}),),
        duration_s=duration_s, platform_override=platform,
        data_location=platform, batch_window_s=0.0, drain_s=60.0,
        analytic=analytic)


register("paper/fig5-hpc-vus20",
         lambda: fig5_cell("hpc-node-cluster", 20, analytic=True))
register("paper/fig7-primes-gcf",
         lambda: fig7_cell("google-cloud-cluster", "primes-python",
                           analytic=True))
register("paper/fig10-weighted",
         lambda: fig10_scenario("weighted", analytic=True))
register("paper/table4-edge",
         lambda: table4_cell("edge-cluster", analytic=True))
register("paper/table4-hpc",
         lambda: table4_cell("hpc-node-cluster", analytic=True))


# ---------------------------------------------------------------------------
# Beyond the hand-wired benchmarks
# ---------------------------------------------------------------------------

def five_platform_mix(duration_s: float = 120.0) -> Scenario:
    """All five Table-2 functions as concurrent Poisson streams over all
    five platforms under the production policy — the cross-function
    interference case no per-figure benchmark could express."""
    return Scenario(
        name="mix/five-platform",
        platforms=PAPER_FIVE,
        workloads=(
            Workload("nodeinfo",
                     arrival={"kind": "poisson", "rps": 40.0}),
            Workload("JSON-loads",
                     arrival={"kind": "poisson", "rps": 25.0}),
            Workload("image-processing",
                     arrival={"kind": "poisson", "rps": 6.0}),
            Workload("sentiment-analysis",
                     arrival={"kind": "poisson", "rps": 4.0}),
            Workload("primes-python",
                     arrival={"kind": "poisson", "rps": 2.0}),
        ),
        duration_s=duration_s)


def edge_vs_cloud_energy(duration_s: float = 600.0) -> Scenario:
    """Table-4's question under realistic load: a diurnal JSON-loads cycle
    over edge + hpc with the energy-aware policy free to choose."""
    return Scenario(
        name="energy/edge-vs-cloud-diurnal",
        platforms=("edge-cluster", "hpc-node-cluster"),
        workloads=(
            Workload("JSON-loads",
                     arrival={"kind": "diurnal", "mean_rps": 25.0,
                              "period_s": 600.0, "peak_frac": 0.8}),
            Workload("nodeinfo",
                     arrival={"kind": "diurnal", "mean_rps": 10.0,
                              "period_s": 600.0, "peak_frac": 0.8}),
        ),
        duration_s=duration_s, policy="energy_aware",
        data_location="hpc-node-cluster")


def burst_storm(duration_s: float = 120.0) -> Scenario:
    """MMPP burst storm against ``submit_batch``: quiet baseline
    punctuated by 600 rps bursts, admitted in 50 ms batched windows."""
    return Scenario(
        name="burst/mmpp-storm",
        platforms=PAPER_FIVE,
        workloads=(
            Workload("nodeinfo",
                     arrival={"kind": "mmpp", "base_rps": 30.0,
                              "burst_rps": 600.0, "mean_quiet_s": 15.0,
                              "mean_burst_s": 3.0}),
            Workload("JSON-loads",
                     arrival={"kind": "mmpp", "base_rps": 15.0,
                              "burst_rps": 300.0, "mean_quiet_s": 20.0,
                              "mean_burst_s": 2.0}),
        ),
        duration_s=duration_s)


def platform_outage(duration_s: float = 120.0) -> Scenario:
    """Mid-run outage of the fastest platform: hpc fails at t=40 s and
    recovers at t=80 s while a mixed load keeps arriving (redelivery +
    failure detector + elastic re-admission, §3.1.3)."""
    return Scenario(
        name="faults/hpc-outage",
        platforms=("hpc-node-cluster", "cloud-cluster", "edge-cluster"),
        workloads=(
            Workload("nodeinfo",
                     arrival={"kind": "poisson", "rps": 30.0}),
            Workload("JSON-loads",
                     arrival={"kind": "poisson", "rps": 10.0}),
        ),
        duration_s=duration_s,
        faults=(FaultEvent(40.0, "hpc-node-cluster", "fail"),
                FaultEvent(80.0, "hpc-node-cluster", "recover")))


def ramp_overload(duration_s: float = 120.0) -> Scenario:
    """Linear overload ramp on the two weakest platforms: the
    sentiment-analysis arrival rate climbs past their aggregate capacity
    (~70 rps), exposing queueing growth and the SLO-violation knee."""
    return Scenario(
        name="ramp/overload",
        platforms=("cloud-cluster", "edge-cluster"),
        workloads=(
            Workload("sentiment-analysis",
                     arrival={"kind": "ramp", "start_rps": 5.0,
                              "end_rps": 160.0}),
        ),
        duration_s=duration_s,
        slo_overrides={"sentiment-analysis": 2.0})


def azure_replay(duration_s: float = 300.0) -> Scenario:
    """Azure-Functions-style minute-count replay: three synthetic
    per-minute count rows (diurnal-shaped, seeded) expanded to arrivals
    and time-dilated so a 60-minute trace plays in 300 s."""
    counts = traces.synthetic_azure_counts(
        ["nodeinfo", "JSON-loads", "image-processing"], minutes=60,
        mean_rpm=240.0, seed=11)
    scale = duration_s / 3600.0
    return Scenario(
        name="azure/minute-replay",
        platforms=PAPER_FIVE,
        workloads=tuple(
            Workload(fn, arrival={"kind": "azure",
                                  "counts": counts[fn].tolist(),
                                  "time_scale": scale,
                                  "duration_s": duration_s})
            for fn in counts),
        duration_s=duration_s)


def million_burst(n_target: int = 1_000_000) -> Scenario:
    """Scale demonstration: ~10^6 invocations through the columnar
    pipeline (Poisson mix at ~1700 rps over 600 s across five platforms).
    Per-invocation survivors of the run are NumPy columns only — no
    completed-Invocation list, no decision rows (``retain_objects`` stays
    False).  Takes a minute or two of wall time; not part of CI."""
    duration = 600.0
    total_rps = n_target / duration
    return Scenario(
        name="scale/million-burst",
        platforms=PAPER_FIVE,
        workloads=(
            Workload("nodeinfo",
                     arrival={"kind": "poisson",
                              "rps": 0.7 * total_rps}),
            Workload("JSON-loads",
                     arrival={"kind": "mmpp",
                              "base_rps": 0.2 * total_rps,
                              "burst_rps": 0.6 * total_rps,
                              "mean_quiet_s": 20.0, "mean_burst_s": 5.0}),
        ),
        duration_s=duration)


def smoke_tiny() -> Scenario:
    """CI smoke: a 10-second two-platform mixed scenario (closed + open)
    exercising every runner path in well under a second."""
    return Scenario(
        name="smoke/tiny",
        platforms=("hpc-node-cluster", "cloud-cluster"),
        workloads=(
            Workload("nodeinfo",
                     arrival={"kind": "poisson", "rps": 20.0}),
            Workload("JSON-loads", mode="closed", vus=4, sleep_s=0.05),
        ),
        duration_s=10.0, drain_s=30.0)


# ---------------------------------------------------------------------------
# Function chains (collaborative execution + data gravity, repro.chains)
# ---------------------------------------------------------------------------

def chain_etl(duration_s: float = 120.0) -> Scenario:
    """ETL chain instances (extract -> 4x transform -> aggregate -> load)
    planned by the data-gravity planner over the five platforms, riding
    alongside plain nodeinfo traffic."""
    return Scenario(
        name="chains/etl-pipeline",
        platforms=PAPER_FIVE,
        workloads=(
            Workload(mode="chain", chain="etl-pipeline",
                     arrival={"kind": "poisson", "rps": 2.0}),
            Workload("nodeinfo",
                     arrival={"kind": "poisson", "rps": 20.0}),
        ),
        duration_s=duration_s)


def chain_ml(duration_s: float = 120.0) -> Scenario:
    """Preprocess -> serve -> respond over the Table-2 functions: the
    paper's image/sentiment workloads composed into one application."""
    return Scenario(
        name="chains/ml-inference-preprocess-serve",
        platforms=PAPER_FIVE,
        workloads=(
            Workload(mode="chain", chain="ml-preprocess-serve",
                     arrival={"kind": "poisson", "rps": 3.0}),
            Workload("JSON-loads",
                     arrival={"kind": "poisson", "rps": 10.0}),
        ),
        duration_s=duration_s)


AB_PAIR = ("cloud-cluster", "old-hpc-node-cluster")


def split_vs_colocate(wan_bw: float = 2e9, duration_s: float = 120.0,
                      rps: float = 3.0, suffix: str = "") -> Scenario:
    """Collaborative split vs forced co-location A/B on the dual-source
    chain: both arms share the platform pair, the inter-platform
    bandwidth is the swept knob.  With a fast interconnect the split arm
    wins end-to-end p90 (the co-located arm queues on one platform); with
    a slow WAN the 16 MB of features crossing platforms flips the order.
    """
    return Scenario(
        name=f"chains/split-vs-colocate-ab{suffix}",
        platforms=AB_PAIR,
        policy="perf_ranked",
        bandwidths=((AB_PAIR[0], AB_PAIR[1], wan_bw),),
        workloads=(
            Workload(mode="chain", chain="ab-dual-source",
                     plan_mode="colocate", label="ab@colocate",
                     arrival={"kind": "poisson", "rps": rps}),
            Workload(mode="chain", chain="ab-dual-source",
                     plan_mode="split", label="ab@split",
                     arrival={"kind": "poisson", "rps": rps}),
        ),
        duration_s=duration_s)


# ---------------------------------------------------------------------------
# Prewarm-policy studies (warm-pool lifecycle, repro.autoscale)
# ---------------------------------------------------------------------------

AUTOSCALE_PLATFORM = "cloud-cluster"
KEEPALIVE_W = 2.0                      # watts per idle warm replica

# one deep diurnal cycle every 600 s: the trough (rate -> 0) is where a
# fixed keep-alive must choose between dying (cold starts at the ramp)
# and idling (watts); ~6000 invocations over two cycles
DIURNAL_TRACE = {"kind": "diurnal", "mean_rps": 5.0, "period_s": 600.0,
                 "peak_frac": 1.0}
# sparse: one arrival every ~12 s — keep-alive is almost pure idle cost
SPARSE_TRACE = {"kind": "poisson", "rps": 0.08}
# MMPP burst storm: quiet baseline punctuated by short bursts, the
# recurrence-gap case the predictive TTL histogram is built to learn
BURST_TRACE = {"kind": "mmpp", "base_rps": 0.5, "burst_rps": 40.0,
               "mean_quiet_s": 45.0, "mean_burst_s": 3.0}

AUTOSCALE_POLICIES = {
    "ttl": {"policy": "ttl", "policy_kwargs": {"ttl_s": 60.0}},
    "ttl-short": {"policy": "ttl", "policy_kwargs": {"ttl_s": 15.0}},
    "scale-to-zero": {"policy": "scale_to_zero",
                      "policy_kwargs": {"idle_s": 2.0}},
    "concurrency": {"policy": "concurrency"},
    "predictive": {"policy": "predictive"},
}


def autoscale_cell(trace_name: str, policy_key: str,
                   duration_s: float) -> Scenario:
    """One arm of a prewarm-policy A/B: a single exclusive platform (so
    cold-start and idle-Wh effects are not confounded by routing), one
    trace, one keep-alive policy, idle keep-alive watts charged."""
    traces_by_name = {"diurnal": DIURNAL_TRACE, "sparse": SPARSE_TRACE,
                      "burst": BURST_TRACE}
    return Scenario(
        name=f"autoscale/{trace_name}-{policy_key}",
        platforms=(AUTOSCALE_PLATFORM,),
        platform_override=AUTOSCALE_PLATFORM,
        workloads=(Workload("nodeinfo",
                            arrival=dict(traces_by_name[trace_name])),),
        duration_s=duration_s, drain_s=30.0,
        keepalive_w_per_replica=KEEPALIVE_W,
        autoscale=dict(AUTOSCALE_POLICIES[policy_key]))


for _trace, _dur in (("diurnal", 1200.0), ("sparse", 600.0),
                     ("burst", 600.0)):
    for _pol in AUTOSCALE_POLICIES:
        register(f"autoscale/{_trace}-{_pol}",
                 lambda t=_trace, p=_pol, d=_dur: autoscale_cell(t, p, d))


register("chains/etl-pipeline", chain_etl)
register("chains/ml-inference-preprocess-serve", chain_ml)
register("chains/split-vs-colocate-ab", lambda: split_vs_colocate(2e9))
# slow WAN: 1 rps keeps both arms stable, so the p90 flip measures the
# transfer cost of gravity-blind splitting rather than queue collapse
register("chains/split-vs-colocate-ab-slowwan",
         lambda: split_vs_colocate(3e6, rps=1.0, suffix="-slowwan"))
register("mix/five-platform", five_platform_mix)
register("energy/edge-vs-cloud-diurnal", edge_vs_cloud_energy)
register("burst/mmpp-storm", burst_storm)
register("faults/hpc-outage", platform_outage)
register("ramp/overload", ramp_overload)
register("azure/minute-replay", azure_replay)
register("scale/million-burst", million_burst)
register("smoke/tiny", smoke_tiny)

# ---------------------------------------------------------------------------
# Flight-recorder A/B arms (repro.obs): the outage, burst-storm and
# overload scenarios re-examined through latency decomposition — the
# report's latency_breakdown section attributes each arm's SLO violations
# to its dominant segment (queue growth under overload, cold starts after
# recovery, ingress batching under bursts).
# ---------------------------------------------------------------------------

register("trace/hpc-outage",
         lambda: platform_outage().replace(name="trace/hpc-outage",
                                           trace=True))
register("trace/burst-storm",
         lambda: burst_storm().replace(name="trace/burst-storm",
                                       trace=True))
register("trace/overload-ramp",
         lambda: ramp_overload().replace(name="trace/overload-ramp",
                                         trace=True))

# ---------------------------------------------------------------------------
# Live-telemetry arms (repro.obs.telemetry/alerts): the same stress
# scenarios watched *online* — multi-resolution rollups feed burn-rate
# SLO alerts and platform-health detectors, and the report gains an
# ``alerts`` section.  Burn windows are shrunk from the SRE production
# defaults (5m/1h, 1h/6h) to match these 2-minute horizons; the health
# thresholds are tuned so ``telemetry/smoke-quiet`` emits zero events
# (tests pin both directions).
# ---------------------------------------------------------------------------

TELEMETRY_DEFAULTS: Dict[str, object] = {
    "tiers_s": [1.0, 10.0, 60.0],
    "capacity": 512,
    "slo_target": 0.9,                 # 10% error budget
    "eval_tier": 0,                    # evaluate on the 1 s tier
    "rules": [
        {"name": "fast_burn", "short_s": 10.0, "long_s": 60.0,
         "burn": 8.0, "severity": "page"},
        {"name": "slow_burn", "short_s": 30.0, "long_s": 120.0,
         "burn": 3.0, "severity": "ticket"},
    ],
    "min_long_samples": 20,
    "z_threshold": 6.0,
    "k_consecutive": 3,
    "warmup_buckets": 8,
}


def _with_telemetry(sc: Scenario, name: str) -> Scenario:
    return sc.replace(name=name, telemetry=dict(TELEMETRY_DEFAULTS))


# ---------------------------------------------------------------------------
# Per-tenant QoS + overload resilience (repro.core.qos): multi-class
# mixes through the unified admission gate — DRR queue draining vs plain
# FIFO, shed vs degrade vs spillover under an overload ramp, and a
# brownout arm where an energy cap degrades the batch class first.  The
# report gains a ``qos`` section (per-class/per-tenant stats, fairness
# shares, admission counters); benchmarks/bench_qos.py asserts the
# DRR-vs-FIFO A/B headline.
# ---------------------------------------------------------------------------

QOS_PAIR = ("cloud-cluster", "edge-cluster")

# three tenants, three classes: interactive traffic that must stay fast,
# a rampable standard stream, and throughput-oriented batch filler
QOS_SPEC_BASE: Dict[str, object] = {
    "weights": [8, 3, 1],
    "slo_multipliers": [0.5, 1.0, 4.0],
    "shed_queue_depth": 300,
    "shed_hard_factor": 2.0,
}


def _qos_mix(ramp_end_rps: float) -> tuple:
    return (
        Workload("nodeinfo", qos_class="latency_critical", tenant=1,
                 arrival={"kind": "poisson", "rps": 25.0}),
        Workload("sentiment-analysis", qos_class="standard", tenant=2,
                 arrival={"kind": "ramp", "start_rps": 5.0,
                          "end_rps": ramp_end_rps}),
        Workload("JSON-loads", qos_class="batch", tenant=3,
                 arrival={"kind": "poisson", "rps": 40.0}),
    )


def qos_overload(action: str, duration_s: float = 120.0) -> Scenario:
    """Shed / degrade / spillover A/B: the ``ramp/overload`` pressure
    pattern re-run with three tenants in three classes, identical except
    for the admission controller's overload action."""
    spec = dict(QOS_SPEC_BASE)
    spec["overload_action"] = action
    return Scenario(
        name=f"qos/overload-{action}",
        platforms=QOS_PAIR,
        workloads=_qos_mix(120.0),
        duration_s=duration_s,
        slo_overrides={"sentiment-analysis": 2.0},
        qos=spec)


def qos_burst_storm(drr: bool, duration_s: float = 120.0) -> Scenario:
    """DRR-vs-FIFO A/B under an MMPP burst storm: same three-class mix,
    same admission spec, but the FIFO arm runs uniform weights — which
    structurally disables the per-class queues (every enqueue stays on
    the single-FIFO fast path), so the only difference is drain order."""
    spec = dict(QOS_SPEC_BASE)
    spec.pop("shed_queue_depth")       # isolate drain order from shedding
    if not drr:
        spec["weights"] = [1, 1, 1]
    arm = "drr" if drr else "fifo"
    return Scenario(
        name=f"qos/burst-storm-{arm}",
        platforms=QOS_PAIR,
        workloads=(
            Workload("nodeinfo", qos_class="latency_critical", tenant=1,
                     arrival={"kind": "mmpp", "base_rps": 20.0,
                              "burst_rps": 150.0, "mean_quiet_s": 15.0,
                              "mean_burst_s": 3.0}),
            Workload("sentiment-analysis", qos_class="standard", tenant=2,
                     arrival={"kind": "poisson", "rps": 20.0}),
            Workload("JSON-loads", qos_class="batch", tenant=3,
                     arrival={"kind": "mmpp", "base_rps": 30.0,
                              "burst_rps": 300.0, "mean_quiet_s": 20.0,
                              "mean_burst_s": 3.0}),
        ),
        duration_s=duration_s,
        qos=spec)


def qos_brownout(duration_s: float = 120.0) -> Scenario:
    """Brownout: a fleet-power cap trips mid-ramp and the controller
    sheds the batch class first, keeping interactive tenants served
    while total watts stay bounded."""
    spec = dict(QOS_SPEC_BASE)
    spec.pop("shed_queue_depth")       # brownout is the only shedder here
    spec["energy_cap_w"] = 135.0
    return Scenario(
        name="qos/brownout-energy-cap",
        platforms=QOS_PAIR,
        workloads=_qos_mix(90.0),
        duration_s=duration_s,
        slo_overrides={"sentiment-analysis": 2.0},
        qos=spec)


for _action in ("shed", "degrade", "spillover"):
    register(f"qos/overload-{_action}",
             lambda a=_action: qos_overload(a))
register("qos/burst-storm-drr", lambda: qos_burst_storm(True))
register("qos/burst-storm-fifo", lambda: qos_burst_storm(False))
register("qos/brownout-energy-cap", qos_brownout)

register("telemetry/hpc-outage",
         lambda: _with_telemetry(platform_outage(),
                                 "telemetry/hpc-outage"))
register("telemetry/overload-ramp",
         lambda: _with_telemetry(ramp_overload(),
                                 "telemetry/overload-ramp"))
register("telemetry/burst-storm",
         lambda: _with_telemetry(burst_storm(),
                                 "telemetry/burst-storm"))
register("telemetry/smoke-quiet",
         lambda: _with_telemetry(smoke_tiny(), "telemetry/smoke-quiet"))

# ---------------------------------------------------------------------------
# Decision-provenance arms (repro.obs.provenance/whatif): the same
# scenarios with the decision journal attached — the report gains a
# ``decision_provenance`` section (perf-model calibration, filter kill
# counts, regret, churn) and ``run.py explain <arm> [--whatif ...]``
# renders kill-reason / counterfactual summaries over the journal.
# ---------------------------------------------------------------------------

register("prov/smoke-tiny",
         lambda: smoke_tiny().replace(name="prov/smoke-tiny",
                                      provenance=True))
register("prov/etl-pipeline",
         lambda: chain_etl().replace(name="prov/etl-pipeline",
                                     provenance=True))
register("prov/burst-storm-drr",
         lambda: qos_burst_storm(True).replace(name="prov/burst-storm-drr",
                                               provenance=True))
