"""FDNInspector (paper §5): the benchmarking subsystem that turns
"benchmark the FDN" into data.

    from repro.inspector import registry, run_scenario

    report = run_scenario(registry.get("mix/five-platform"))
    print(report.to_json())

``scenario`` — declarative Scenario spec + runner + versioned
ScenarioReport; ``traces`` — FaaS trace library (Azure minute counts,
diurnal / MMPP / ramp generators, WorkloadMix); ``streaming`` — chunked
columnar replay of Azure-scale traces in bounded memory; ``registry`` —
named scenarios: the paper's figures/tables re-expressed, plus mixes the
hand-wired benchmarks could not express.
"""
from repro.inspector.scenario import (SCHEMA_VERSION, AutoscaleSpec,
                                      FaultEvent, Scenario,
                                      ScenarioReport, ScenarioRun,
                                      TracingSpec, Workload, assemble,
                                      build_report, run_scenario,
                                      run_scenario_state)
from repro.inspector.streaming import StreamStats, stream_replay
from repro.inspector.traces import (WorkloadMix, build_arrivals,
                                    counts_to_arrivals, diurnal_arrivals,
                                    load_azure_invocations_csv,
                                    mmpp_arrivals, ramp_arrivals,
                                    synthetic_azure_counts)
from repro.inspector import registry

__all__ = [
    "SCHEMA_VERSION", "AutoscaleSpec", "FaultEvent", "Scenario",
    "ScenarioReport", "ScenarioRun", "TracingSpec", "Workload",
    "assemble", "build_report", "run_scenario", "run_scenario_state",
    "StreamStats", "stream_replay",
    "WorkloadMix", "build_arrivals", "counts_to_arrivals",
    "diurnal_arrivals", "load_azure_invocations_csv", "mmpp_arrivals",
    "ramp_arrivals", "synthetic_azure_counts", "registry",
]
