"""FDNInspector scenarios: "benchmark the FDN" as data (paper §5).

A ``Scenario`` is a declarative spec — platforms, per-function workload
mix (closed-loop VUs and/or open-loop arrival streams), scheduling policy,
SLO overrides, fault schedule, seed, duration — and ``run_scenario``
assembles the control plane, drives everything on one SimClock, and emits
a versioned ``ScenarioReport``: per-platform / per-function p50/p90/p99,
SLO-violation rate, cold starts, energy, decisions per simulated second.

Reports are reproducible artifacts: with ``analytic=True`` (the default;
execution cost from the analytic model, no wall-clock measurement) two
runs of the same scenario produce byte-identical canonical JSON on any
machine.  Completions stream into a ``ColumnarResultSink`` and are bulk-
ingested into the metrics registry at the end of the run
(``MetricsRegistry.record_completions``), so a 10^6-invocation scenario
never touches a per-sample Python hot path.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import InitVar, dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core import functions as fn_mod
from repro.core import profiles as prof_mod
from repro.core.control_plane import FDNControlPlane
from repro.core.qos import N_QOS, QOS_NAMES, QosSpec, qos_id
from repro.core.gateway import Gateway
from repro.core.loadgen import (ColumnarResultSink, attach_completion_hooks,
                                schedule_arrival_mix, spawn_vus)
from repro.core.monitoring import percentile_unsorted
from repro.core.scheduler import (DataLocalityPolicy, EnergyAwarePolicy,
                                  PerformanceRankedPolicy,
                                  RoundRobinCollaboration,
                                  SLOCompositePolicy,
                                  UtilizationAwarePolicy,
                                  WarmAwarePolicy,
                                  WeightedCollaboration)
from repro.core.types import SLO, DeploymentSpec, Invocation
from repro.chains import catalog as chain_catalog
from repro.chains.executor import ChainExecutor  # noqa: F401 (type hints)
from repro.chains.planner import DataGravityPlanner
from repro.inspector import traces

SCHEMA_VERSION = 1

REMOTE_STORE = "gcp-us-east"
REMOTE_BW = 2e6                 # WAN Germany <-> us-east (Fig. 11)

IMAGE_KEY = "images/sample.jpg"
JSON_KEY = "json/coords.json"


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Workload:
    """One load stream of the mix.

    ``mode="open"``: ``arrival`` is a ``traces.build_arrivals`` spec dict
    (seeded per workload: scenario seed + stream index).
    ``mode="closed"``: ``vus`` k6-style virtual users with ``sleep_s``
    think time.
    ``mode="chain"``: ``chain`` names a ``repro.chains.catalog`` template;
    each arrival launches one chain instance, planned once per workload by
    the data-gravity planner in ``plan_mode`` and reported under
    ``label`` (default ``"<chain>@<plan_mode>"``).

    ``qos_class`` / ``tenant`` tag every invocation of the stream with a
    QoS class (``latency_critical`` | ``standard`` | ``batch``) and a
    tenant id — the columns the DRR queues drain by and the report's
    fairness sections aggregate over."""
    function: str = ""
    mode: str = "open"                       # "open" | "closed" | "chain"
    arrival: Optional[Dict[str, Any]] = None
    vus: int = 0
    sleep_s: float = 0.0
    jitter: float = 0.05
    chain: Optional[str] = None              # chains.catalog name
    plan_mode: str = "auto"                  # chains.planner.PLAN_MODES
    label: Optional[str] = None              # per_chain report key
    qos_class: str = "standard"              # repro.core.qos class name
    tenant: int = 0

    def __post_init__(self):
        if self.mode == "chain":
            if not self.chain:
                raise ValueError(
                    "chain workload needs chain=<catalog name>")
        elif not self.function:
            raise ValueError(
                f"{self.mode!r} workload needs a function name")
        qos_id(self.qos_class)               # validate early


@dataclass(frozen=True)
class FaultEvent:
    """Scheduled platform outage / recovery (§3.1.3 fault tolerance)."""
    t: float
    platform: str
    action: str                              # "fail" | "recover"


@dataclass(frozen=True)
class TracingSpec:
    """Typed form of the flight-recorder knobs (``trace`` /
    ``trace_sample``).  Passed as ``Scenario(tracing=...)`` it normalizes
    into the flat fields, so the serialized spec — and every golden —
    stays byte-identical with the legacy constructor."""
    enabled: bool = True
    sample: float = 1.0


@dataclass(frozen=True)
class AutoscaleSpec:
    """Typed form of the ``autoscale`` config dict (policy, tick, backend,
    policy kwargs).  ``to_dict`` emits exactly the keys ``assemble``
    consumes, omitting unset ones so the scenario echo matches a
    hand-written dict."""
    policy: str = "predictive"
    tick_s: float = 1.0
    backend: Optional[str] = None
    policy_kwargs: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"policy": self.policy,
                               "tick_s": float(self.tick_s)}
        if self.backend is not None:
            out["backend"] = self.backend
        if self.policy_kwargs is not None:
            out["policy_kwargs"] = dict(self.policy_kwargs)
        return out


@dataclass(frozen=True)
class Scenario:
    name: str
    platforms: Tuple[str, ...]
    workloads: Tuple[Workload, ...]
    duration_s: float
    policy: str = "slo_composite"            # scheduler.POLICIES key
    policy_kwargs: Dict[str, Any] = field(default_factory=dict)
    lb_policy: Optional[str] = None          # collaboration at the gateway
    lb_kwargs: Dict[str, Any] = field(default_factory=dict)
    platform_override: Optional[str] = None  # exclusive per-platform runs
    data_location: str = "cloud-cluster"
    # extra inter-location bandwidth pins, (loc_a, loc_b, bytes/s): the
    # WAN-speed knob the chain split-vs-colocate A/Bs sweep
    bandwidths: Tuple[Tuple[str, str, float], ...] = ()
    seed: int = 42
    analytic: bool = True                    # strip real JAX callables
    batch_window_s: float = 0.05
    # admit open-loop arrivals as struct-of-arrays InvocationBatch chunks
    # (lazy Invocation materialization); False replays the object path —
    # decisions and timings are identical either way (tests pin it)
    columnar: bool = True
    drain_s: float = 120.0
    faults: Tuple[FaultEvent, ...] = ()
    slo_overrides: Dict[str, float] = field(default_factory=dict)
    defer_metrics: bool = True               # bulk-ingest completions
    retain_objects: bool = False             # keep per-invocation lists
    enable_hedging: bool = False
    predictive_prewarm: bool = False
    # warm-pool lifecycle (repro.autoscale): {"policy": "ttl" |
    # "scale_to_zero" | "concurrency" | "predictive", "tick_s": ...,
    # "backend": ..., "policy_kwargs": {...}}; None leaves platforms on
    # their own faas-idler
    autoscale: Optional[Dict[str, Any]] = None
    # keep-alive watts charged per idle warm replica (0 keeps the
    # historical accounting; the prewarm-policy studies set it)
    keepalive_w_per_replica: float = 0.0
    # background CPU load per platform (§5.1.2 interference knob)
    bg_cpu: Dict[str, float] = field(default_factory=dict)
    # background MEMORY load per platform (Fig. 9's swap-cliff knob)
    bg_mem: Dict[str, float] = field(default_factory=dict)
    # (object key, destination store) pairs migrated before load starts —
    # the §5.1.4 adaptive data-management move the fig11 arms A/B
    migrate_objects: Tuple[Tuple[str, str], ...] = ()
    # flight recorder (repro.obs): per-invocation lifecycle tracing and
    # the report's latency_breakdown section; trace_sample < 1 keeps a
    # deterministic head-based subset of invocations
    trace: bool = False
    trace_sample: float = 1.0
    # live telemetry (repro.obs.telemetry): multi-resolution rollups,
    # burn-rate SLO alerting and platform-health anomaly detection.  A
    # dict mixing TelemetryConfig and AlertConfig keys (each picks the
    # keys it knows), or None to leave the engine off
    telemetry: Optional[Dict[str, Any]] = None
    # per-tenant QoS + overload resilience (repro.core.qos): a QosSpec or
    # its dict form — class weights (DRR queue draining), per-class SLO
    # multipliers, token-bucket rate limits, load-shedding / brownout
    # thresholds.  None leaves admission and queues exactly as before
    qos: Optional[Union[QosSpec, Dict[str, Any]]] = None
    # decision provenance (repro.obs.provenance): journal every fused
    # fn_decisions admission (feature snapshot, filter-kill bitmask,
    # runner-up margin), stamp journal row ids onto invocations, and
    # surface the calibration/regret analysis as the report's
    # decision_provenance section.  Off by default (zero per-burst cost)
    provenance: bool = False
    # typed-spec constructor aliases (normalized into the flat fields
    # above, so the serialized spec and goldens are identical either way)
    tracing: InitVar[Optional[TracingSpec]] = None
    autoscaling: InitVar[Optional[AutoscaleSpec]] = None

    def __post_init__(self, tracing: Optional[TracingSpec],
                      autoscaling: Optional[AutoscaleSpec]):
        if tracing is not None:
            object.__setattr__(self, "trace", bool(tracing.enabled))
            object.__setattr__(self, "trace_sample",
                               float(tracing.sample))
        if autoscaling is not None:
            object.__setattr__(self, "autoscale", autoscaling.to_dict())
        if isinstance(self.qos, QosSpec):
            object.__setattr__(self, "qos", self.qos.to_dict())

    def qos_spec(self) -> Optional[QosSpec]:
        return None if self.qos is None else QosSpec.from_dict(self.qos)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


def _make_policy(name: str, kwargs: Dict[str, Any], cp: FDNControlPlane):
    kw = dict(kwargs or {})
    if name == "perf_ranked":
        return PerformanceRankedPolicy(cp.perf)
    if name == "utilization_aware":
        return UtilizationAwarePolicy(cp.perf, **kw)
    if name == "round_robin":
        return RoundRobinCollaboration()
    if name == "weighted":
        return WeightedCollaboration(kw.get("weights", {}))
    if name == "data_locality":
        return DataLocalityPolicy(cp.perf, cp.placement)
    if name == "warm_aware":
        return WarmAwarePolicy(cp.perf, cp.placement)
    if name == "energy_aware":
        return EnergyAwarePolicy(cp.perf)
    if name == "slo_composite":
        return SLOCompositePolicy(cp.perf, cp.placement, **kw)
    raise KeyError(f"unknown policy {name!r}")


PLATFORM_CATALOG: Dict[str, Any] = {**prof_mod.PAPER_PLATFORMS,
                                    **prof_mod.TPU_PLATFORMS}


def assemble(sc: Scenario):
    """Build the control plane a scenario describes (mirrors the harness
    every hand-wired benchmark used to copy: five-platform FDN, Table-2
    functions, seeded MinIO stores, remote us-east replica)."""
    cp = FDNControlPlane(enable_hedging=sc.enable_hedging,
                         predictive_prewarm=sc.predictive_prewarm,
                         retain_completions=sc.retain_objects)
    # without retain_objects the only per-invocation survivors of a run
    # are the sink's NumPy columns (no completed-Invocation list, no
    # knowledge-base decision rows — counters only)
    cp.kb.log_decisions = sc.retain_objects
    cp.policy = _make_policy(sc.policy, sc.policy_kwargs, cp)
    for name in sc.platforms:
        prof = PLATFORM_CATALOG[name]
        if sc.keepalive_w_per_replica > 0.0:
            prof = dataclasses.replace(
                prof, warm_w_per_replica=sc.keepalive_w_per_replica)
        cp.create_platform(prof)
    for name, bg in sc.bg_cpu.items():
        cp.platforms[name].bg_cpu = float(bg)
    for name, bg in sc.bg_mem.items():
        cp.platforms[name].bg_mem = float(bg)
    fns = fn_mod.paper_functions(IMAGE_KEY, JSON_KEY)
    if sc.analytic:
        fns = {k: f.replace(real_fn=None) for k, f in fns.items()}
    # chain workloads bring their own stage functions and data anchors
    for w in sc.workloads:
        if w.mode != "chain":
            continue
        tmpl = chain_catalog.get(w.chain)
        for fname, spec in tmpl.functions.items():
            if sc.analytic:
                spec = spec.replace(real_fn=None)
            fns.setdefault(fname, spec)
        for inp in tmpl.inputs:
            loc = inp.location or sc.data_location
            if loc not in cp.placement.stores:
                cp.placement.add_store(loc)
            cp.placement.stores[loc].put(inp.key, inp.size_bytes)
    for fname, p90_s in sc.slo_overrides.items():
        fns[fname] = fns[fname].replace(slo=SLO(p90_response_s=p90_s))
    fn_mod.seed_object_stores(cp.placement, IMAGE_KEY, JSON_KEY,
                              location=sc.data_location)
    cp.placement.add_store(REMOTE_STORE)
    fn_mod.seed_object_stores(cp.placement, IMAGE_KEY, JSON_KEY,
                              location=REMOTE_STORE)
    for name in sc.platforms:
        cp.placement.set_bandwidth(name, REMOTE_STORE, REMOTE_BW)
    for a, b, bw in sc.bandwidths:
        cp.placement.set_bandwidth(a, b, float(bw))
    for key, dest in sc.migrate_objects:
        cp.placement.migrate(key, dest)
    cp.deploy(DeploymentSpec(sc.name, list(fns.values()),
                             list(sc.platforms)))
    if sc.autoscale is not None:
        kw = dict(sc.autoscale)
        cp.attach_autoscaler(
            policy=kw.pop("policy", "predictive"),
            tick_s=float(kw.pop("tick_s", 1.0)),
            backend=kw.pop("backend", None),
            policy_kwargs=kw.pop("policy_kwargs", None))
        if kw:
            raise ValueError(f"unknown autoscale keys: {sorted(kw)}")
    if sc.trace:
        from repro.obs import FlightRecorder
        cp.attach_recorder(FlightRecorder(sample=sc.trace_sample))
    if sc.telemetry is not None:
        from repro.obs.telemetry import TelemetryConfig, TelemetryEngine
        engine = cp.attach_telemetry(
            TelemetryEngine(TelemetryConfig.from_dict(sc.telemetry)))
        for fn in fns.values():
            engine.set_slo(fn.name, fn.slo.p90_response_s)
    if sc.qos is not None:
        # after telemetry: the admission controller's burn-rate overload
        # signal reads cp.telemetry rollups when configured
        cp.attach_qos(sc.qos_spec())
    if sc.provenance:
        from repro.obs.provenance import DecisionJournal
        cp.attach_provenance(DecisionJournal())
    attach_completion_hooks(cp)
    gw = Gateway(cp)
    if sc.lb_policy is not None:
        gw.lb_policy = _make_policy(sc.lb_policy, sc.lb_kwargs, cp)
    sink = ColumnarResultSink().install(cp)
    if sc.defer_metrics:
        cp.metrics.defer_completions = True
    return cp, gw, fns, sink


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

@dataclass
class ScenarioReport:
    schema_version: int
    scenario: Dict[str, Any]
    totals: Dict[str, Any]
    per_platform: Dict[str, Dict[str, Any]]
    per_function: Dict[str, Dict[str, Any]]
    # chain workloads only: per-label end-to-end latency percentiles,
    # bytes moved between platforms, and the planner's placement decision
    per_chain: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    # flight-recorder runs only: segment decomposition totals, exact-
    # reconciliation counters, and SLO-violation attribution (repro.obs)
    latency_breakdown: Dict[str, Any] = field(default_factory=dict)
    # telemetry runs only: rollup summary, burn-rate SLO alert events and
    # platform-health anomalies (repro.obs.telemetry / repro.obs.alerts)
    alerts: Dict[str, Any] = field(default_factory=dict)
    # QoS runs only: per-class / per-tenant latency + class-adjusted SLO
    # stats, DRR fairness shares and the admission controller's shed /
    # degrade / spillover / brownout counters (repro.core.qos)
    qos: Dict[str, Any] = field(default_factory=dict)
    # provenance runs only: decision-journal calibration (predicted-vs-
    # realized latency error), filter kill counts, regret and policy
    # churn (repro.obs.provenance)
    decision_provenance: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        """Canonical serialization: sorted keys, no whitespace — two runs
        of one scenario must produce byte-identical strings."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    REQUIRED_TOTALS = ("submitted", "completed", "rejected", "cold_starts",
                       "cold_start_rate", "idle_wh",
                       "idle_wh_per_completion",
                       "slo_violations", "slo_violation_rate", "decisions",
                       "decisions_per_sim_s", "sim_duration_s",
                       "energy_wh")
    REQUIRED_STATS = ("completed", "mean_s", "p50_s", "p90_s", "p99_s")
    REQUIRED_CHAIN = ("launched", "completed", "p50_s", "p90_s", "p99_s",
                      "bytes_moved", "transfer_s", "placement", "mode")

    @classmethod
    def validate(cls, d: Dict[str, Any]) -> None:
        """Schema check for CI smoke tests; raises ValueError on drift."""
        if d.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(f"schema_version != {SCHEMA_VERSION}: "
                             f"{d.get('schema_version')!r}")
        for section in ("scenario", "totals", "per_platform",
                        "per_function"):
            if not isinstance(d.get(section), dict):
                raise ValueError(f"missing section {section!r}")
        for k in cls.REQUIRED_TOTALS:
            if k not in d["totals"]:
                raise ValueError(f"totals missing {k!r}")
        for section in ("per_platform", "per_function"):
            for name, stats in d[section].items():
                for k in cls.REQUIRED_STATS:
                    if k not in stats:
                        raise ValueError(
                            f"{section}[{name!r}] missing {k!r}")
        # per_chain is additive (pre-chain reports omit it entirely)
        for name, stats in d.get("per_chain", {}).items():
            for k in cls.REQUIRED_CHAIN:
                if k not in stats:
                    raise ValueError(f"per_chain[{name!r}] missing {k!r}")
        # latency_breakdown is additive too ({} on untraced runs)
        lb = d.get("latency_breakdown", {})
        if not isinstance(lb, dict):
            raise ValueError("latency_breakdown must be a dict")
        if lb:
            for k in ("segment_totals_s", "slo_attribution",
                      "exact_reconciled"):
                if k not in lb:
                    raise ValueError(f"latency_breakdown missing {k!r}")
        # alerts is additive too ({} when the telemetry engine is off)
        al = d.get("alerts", {})
        if not isinstance(al, dict):
            raise ValueError("alerts must be a dict")
        if al:
            for k in ("enabled", "rollup", "slo", "health"):
                if k not in al:
                    raise ValueError(f"alerts missing {k!r}")
        # qos is additive too ({} when no QosSpec is attached)
        q = d.get("qos", {})
        if not isinstance(q, dict):
            raise ValueError("qos must be a dict")
        if q:
            for k in ("per_class", "per_tenant", "fairness", "admission"):
                if k not in q:
                    raise ValueError(f"qos missing {k!r}")
        # decision_provenance is additive too ({} when the journal is off)
        dp = d.get("decision_provenance", {})
        if not isinstance(dp, dict):
            raise ValueError("decision_provenance must be a dict")
        if dp:
            for k in ("policy", "decisions", "kill_counts", "calibration",
                      "regret", "churn"):
                if k not in dp:
                    raise ValueError(f"decision_provenance missing {k!r}")


def _pct_stats(rt: np.ndarray, duration_s: float) -> Dict[str, Any]:
    return {
        "completed": int(rt.size),
        "mean_s": float(rt.mean()) if rt.size else float("nan"),
        "p50_s": percentile_unsorted(rt, 0.50),
        "p90_s": percentile_unsorted(rt, 0.90),
        "p99_s": percentile_unsorted(rt, 0.99),
        "rps": rt.size / max(duration_s, 1e-9),
    }


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

class ScenarioRun:
    """Everything behind a scenario run, by name: ``.report``,
    ``.control_plane``, ``.sink``, plus the attached ``.telemetry`` engine
    and flight ``.recorder`` (None when the scenario left them off).

    Iterates and indexes as the historical ``(report, control_plane,
    sink)`` 3-tuple, so ``report, cp, sink = run_scenario_state(sc)`` and
    ``run_scenario_state(sc)[0]`` keep working unchanged."""

    __slots__ = ("report", "control_plane", "sink", "telemetry",
                 "recorder", "journal")

    def __init__(self, report: ScenarioReport, control_plane:
                 FDNControlPlane, sink: ColumnarResultSink):
        self.report = report
        self.control_plane = control_plane
        self.sink = sink
        self.telemetry = control_plane.telemetry
        self.recorder = control_plane.recorder
        self.journal = control_plane.journal

    def _as_tuple(self):
        return (self.report, self.control_plane, self.sink)

    def __iter__(self):
        return iter(self._as_tuple())

    def __getitem__(self, i):
        return self._as_tuple()[i]

    def __len__(self) -> int:
        return 3


def run_scenario(sc: Scenario) -> ScenarioReport:
    return run_scenario_state(sc).report


def run_scenario_state(sc: Scenario) -> "ScenarioRun":
    """``run_scenario`` returning a ``ScenarioRun`` — for callers (fig6/
    fig8 benchmarks, tests) that need the metric series or platform state
    behind the report, not just the canonical summary.  Unpacks as the
    legacy ``(report, control_plane, sink)`` tuple."""
    cp, gw, fns, sink = assemble(sc)
    clock = cp.clock

    for ev in sc.faults:
        p = cp.platforms[ev.platform]
        clock.schedule(ev.t, p.fail if ev.action == "fail" else p.recover)

    if sc.platform_override is not None:
        po = sc.platform_override

        def submit(inv: Invocation) -> bool:
            return cp.submit(inv, platform_override=po)

        def submit_batch(invs: List[Invocation]) -> int:
            return cp.submit_batch(invs, platform_override=po)
    else:
        submit, submit_batch = gw.request, gw.request_batch

    # one derived seed per load stream: deterministic, decorrelated
    closed_out: List[Invocation] = []
    mix = traces.WorkloadMix()
    chain_exec: Optional[ChainExecutor] = None
    planner: Optional[DataGravityPlanner] = None
    last_chain_t = 0.0
    for i, w in enumerate(sc.workloads):
        stream_seed = sc.seed + 7919 * i
        if w.mode == "closed":
            spawn_vus(clock, submit, fns[w.function], w.vus,
                      t_end=sc.duration_s, sleep_s=w.sleep_s,
                      seed=stream_seed, jitter=w.jitter, out=closed_out,
                      qos=qos_id(w.qos_class), tenant=w.tenant)
        elif w.mode == "open":
            if w.arrival is None:
                raise ValueError(f"open workload {w.function!r} "
                                 "needs an arrival spec")
            mix.add(w.function,
                    traces.build_arrivals(w.arrival, sc.duration_s,
                                          seed=stream_seed),
                    qos=qos_id(w.qos_class), tenant=w.tenant)
        elif w.mode == "chain":
            if w.chain is None or w.arrival is None:
                raise ValueError("chain workload needs a chain name and "
                                 "an arrival spec")
            if chain_exec is None:
                chain_exec = cp.chain_executor(
                    fns, sink=sink, batch_window_s=sc.batch_window_s)
                planner = DataGravityPlanner(cp.policy, cp.placement, fns)
            chain = chain_catalog.get(w.chain).chain
            plan = planner.plan(chain,
                                [cp.platforms[n] for n in sc.platforms],
                                mode=w.plan_mode)
            label = w.label or f"{w.chain}@{w.plan_mode}"
            arr = traces.build_arrivals(w.arrival, sc.duration_s,
                                        seed=stream_seed)
            if arr.size:
                last_chain_t = max(last_chain_t, float(arr[-1]))
                clock.schedule_many(
                    arr.tolist(),
                    [lambda c=chain, p=plan, l=label:
                     chain_exec.launch(c, p, label=l)] * arr.size)
        else:
            raise ValueError(f"unknown workload mode {w.mode!r}")

    times, fn_idx, names, qos_col, tenant_col = mix.merge_tagged()
    specs = [fns[n] for n in names]
    schedule_arrival_mix(clock, submit_batch, specs, times, fn_idx,
                         sc.batch_window_s, sink, columnar=sc.columnar,
                         qos=qos_col, tenant=tenant_col)

    t_end = max(sc.duration_s,
                float(times[-1]) if times.size else 0.0,
                last_chain_t)
    clock.run_until(t_end)
    clock.run_until(t_end + sc.drain_s)      # gracefulStop
    cp.run_until(clock.now())                # flush energy integrators

    visible = {name: p.prof.infra_metrics_visible
               for name, p in cp.platforms.items()}
    if sc.defer_metrics:
        cp.metrics.defer_completions = False
        cp.metrics.record_completions(sink, visible_infra=visible)

    report = build_report(sc, cp, fns, sink,
                          closed_submitted=len(closed_out),
                          chain_exec=chain_exec)
    return ScenarioRun(report, cp, sink)


def build_report(sc: Scenario, cp: FDNControlPlane, fns,
                 sink: ColumnarResultSink,
                 closed_submitted: int = 0,
                 chain_exec: Optional[ChainExecutor] = None
                 ) -> ScenarioReport:
    cols = sink.completion_columns()
    rt = cols["end"] - cols["arrival"]
    plat_col, fn_col, cold = cols["platform"], cols["fn"], cols["cold"]

    # SLO thresholds broadcast per completion via the fn-id column
    slo_by_fid = np.full(max(len(cols["fn_ids"]), 1), np.inf)
    for fname, fid in cols["fn_ids"].items():
        slo_by_fid[fid] = fns[fname].slo.p90_response_s
    violated = rt > slo_by_fid[fn_col] if rt.size else \
        np.empty(0, bool)

    per_platform: Dict[str, Dict[str, Any]] = {}
    for pname in sc.platforms:
        pid = cols["platform_ids"].get(pname)
        mask = (plat_col == pid) if pid is not None else \
            np.zeros(rt.size, bool)
        stats = _pct_stats(rt[mask], sc.duration_s)
        n_cold = int(cold[mask].sum())
        n_done = int(mask.sum())
        stats["cold_starts"] = n_cold
        stats["cold_start_rate"] = n_cold / n_done if n_done else 0.0
        stats["slo_violations"] = int(violated[mask].sum())
        joules = cp.energy.joules(pname)
        idle_j = cp.energy.keepalive_joules(pname)
        stats["energy_j"] = float(joules)
        stats["energy_wh"] = float(joules) / 3600.0
        stats["idle_wh"] = float(idle_j) / 3600.0
        stats["idle_wh_per_completion"] = \
            float(idle_j) / 3600.0 / n_done if n_done else 0.0
        per_platform[pname] = stats

    per_function: Dict[str, Dict[str, Any]] = {}
    for fname, fid in cols["fn_ids"].items():
        mask = fn_col == fid
        stats = _pct_stats(rt[mask], sc.duration_s)
        n_cold = int(cold[mask].sum())
        stats["cold_starts"] = n_cold
        stats["cold_start_rate"] = (n_cold / int(mask.sum())
                                    if mask.any() else 0.0)
        n_violated = int(violated[mask].sum())
        stats["slo_violations"] = n_violated
        stats["slo_violation_rate"] = (n_violated / int(mask.sum())
                                       if mask.any() else 0.0)
        stats["slo_s"] = float(fns[fname].slo.p90_response_s)
        per_function[fname] = stats

    submitted = sink.submitted + closed_submitted
    rejected = cp.rejected_count
    n_violations = int(violated.sum()) + rejected
    decisions = cp.kb.decision_count
    idle_wh = float(sum(p["idle_wh"] for p in per_platform.values()))
    totals = {
        "submitted": submitted,
        "completed": sink.completed,
        "rejected": rejected,
        "cold_starts": int(cold.sum()),
        "cold_start_rate": (int(cold.sum()) / sink.completed
                            if sink.completed else 0.0),
        "slo_violations": n_violations,
        "slo_violation_rate": n_violations / max(submitted, 1),
        "decisions": decisions,
        "decisions_per_sim_s": decisions / max(sc.duration_s, 1e-9),
        "sim_duration_s": float(sc.duration_s),
        "energy_wh": float(sum(p["energy_wh"]
                               for p in per_platform.values())),
        "idle_wh": idle_wh,
        "idle_wh_per_completion": (idle_wh / sink.completed
                                   if sink.completed else 0.0),
        "redelivered": cp.redeliverer.redelivered,
        "hedges_sent": cp.hedge.hedges_sent,
    }
    totals.update(_pct_stats(rt, sc.duration_s))
    if cp.autoscaler is not None:
        totals["autoscale"] = {
            "policy": cp.autoscaler.policy.name,
            "ticks": cp.autoscaler.ticks,
            "prewarmed": cp.autoscaler.prewarmed,
            "retired": cp.autoscaler.retired,
        }

    per_chain: Dict[str, Dict[str, Any]] = {}
    if chain_exec is not None:
        for label, recs in chain_exec.records.items():
            lat = np.array([r[1] - r[0] for r in recs])
            plan = chain_exec.plans[label]
            stats = _pct_stats(lat, sc.duration_s)
            stats["launched"] = chain_exec.launched_by_label.get(label, 0)
            stats["bytes_moved"] = float(sum(r[2] for r in recs))
            stats["transfer_s"] = float(sum(r[3] for r in recs))
            stats["mode"] = plan.mode
            stats["requested_mode"] = plan.requested_mode
            stats["placement"] = dict(plan.assignment)
            stats["est_makespan_s"] = plan.est_makespan_s
            per_chain[label] = stats
        totals["chains_launched"] = chain_exec.launched
        totals["chains_completed"] = chain_exec.completed
        totals["chains_failed"] = chain_exec.failed

    latency_breakdown: Dict[str, Any] = {}
    if cp.recorder is not None:
        from repro.obs.analysis import latency_breakdown_section
        latency_breakdown = latency_breakdown_section(cp.recorder, cols,
                                                      fns)

    alerts: Dict[str, Any] = {}
    if cp.telemetry is not None:
        from repro.obs.alerts import AlertConfig, alerts_section
        alerts = alerts_section(cp.telemetry, sorted(fns),
                                AlertConfig.from_dict(sc.telemetry or {}))

    qos_section: Dict[str, Any] = {}
    qspec = sc.qos_spec()
    if qspec is not None:
        qos_section = _qos_section(qspec, cp, cols, rt, slo_by_fid,
                                   sc.duration_s)

    provenance: Dict[str, Any] = {}
    if cp.journal is not None:
        from repro.obs.provenance import decision_provenance_section
        provenance = decision_provenance_section(cp.journal, cols)

    return ScenarioReport(schema_version=SCHEMA_VERSION,
                          scenario=sc.to_dict(), totals=totals,
                          per_platform=per_platform,
                          per_function=per_function,
                          per_chain=per_chain,
                          latency_breakdown=latency_breakdown,
                          alerts=alerts,
                          qos=qos_section,
                          decision_provenance=provenance)


def _qos_section(spec: QosSpec, cp: FDNControlPlane,
                 cols: Dict[str, Any], rt: np.ndarray,
                 slo_by_fid: np.ndarray,
                 duration_s: float) -> Dict[str, Any]:
    """Per-class / per-tenant latency and class-adjusted SLO stats.

    A class's effective deadline is the function SLO scaled by its
    multiplier (latency_critical tightens it, batch relaxes it), so the
    violation counts here answer "did each class meet *its own* bar",
    not the flat per-function question ``totals`` already answers."""
    qcol, tcol, fn_col = cols["qos"], cols["tenant"], cols["fn"]
    mults = np.asarray(spec.slo_multipliers, np.float64)
    adj_violated = (rt > slo_by_fid[fn_col] * mults[qcol]) if rt.size \
        else np.empty(0, bool)
    total = max(int(rt.size), 1)

    per_class: Dict[str, Dict[str, Any]] = {}
    share: Dict[str, float] = {}
    for c in range(N_QOS):
        mask = qcol == c
        n = int(mask.sum())
        stats = _pct_stats(rt[mask], duration_s)
        n_viol = int(adj_violated[mask].sum())
        stats["slo_multiplier"] = float(mults[c])
        stats["slo_violations"] = n_viol
        stats["slo_violation_rate"] = n_viol / n if n else 0.0
        stats["weight"] = int(spec.weights[c])
        stats["served_share"] = n / total
        per_class[QOS_NAMES[c]] = stats
        share[QOS_NAMES[c]] = n / total

    per_tenant: Dict[str, Dict[str, Any]] = {}
    for t in (np.unique(tcol) if tcol.size else ()):
        mask = tcol == t
        n = int(mask.sum())
        per_tenant[str(int(t))] = {
            "completed": n,
            "served_share": n / total,
            "p99_s": percentile_unsorted(rt[mask], 0.99),
            "slo_violations": int(adj_violated[mask].sum()),
        }

    adm = cp.admission.section() if cp.admission is not None else {}
    return {
        "per_class": per_class,
        "per_tenant": per_tenant,
        "fairness": {"weights": [int(w) for w in spec.weights],
                     "drr_enabled": spec.drr_enabled(),
                     "served_share": share},
        "admission": adm,
    }
