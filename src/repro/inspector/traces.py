"""FaaS trace library for open-loop replay (ROADMAP item; paper §4.3).

Every generator returns a flat NumPy array of arrival timestamps in
``[t0, t0 + duration_s)`` and is deterministic under its seed, so a trace
is a replayable artifact: the same spec always produces byte-identical
arrivals on any machine.

  * Azure-Functions-style traces: per-minute per-function invocation
    counts (the public Azure 2019 dataset format) expanded into arrival
    timestamps, plus a CSV loader for the real dataset.
  * Synthetic processes: diurnal (sinusoidal-rate Poisson via thinning),
    bursty MMPP (two-state Markov-modulated Poisson), linear ramp.
  * ``WorkloadMix``: interleaves per-function arrival streams into ONE
    sorted admission stream tagged by function index — the shape
    ``loadgen.run_arrival_mix`` consumes.

``build_arrivals`` dispatches a declarative spec dict (``{"kind": ...}``)
so FDNInspector scenarios can carry workloads as data.
"""
from __future__ import annotations

import csv
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.loadgen import (poisson_arrivals, trace_arrivals,
                                uniform_arrivals)


# ---------------------------------------------------------------------------
# Azure Functions minute-count traces
# ---------------------------------------------------------------------------

def counts_to_arrivals(counts: Sequence[float], minute_s: float = 60.0,
                       seed: int = 0, t0: float = 0.0,
                       time_scale: float = 1.0) -> np.ndarray:
    """Expand per-minute invocation counts into arrival timestamps.

    Within minute m with count c, the c arrivals land uniformly at random
    (seeded) inside ``[m * minute_s, (m+1) * minute_s)`` — the standard
    open-loop replay of the Azure Functions 2019 dataset, which records
    counts, not timestamps.  ``time_scale`` dilates the replay (0.1 plays
    a day-long trace in 2.4 hours)."""
    counts = np.asarray(counts)
    rng = np.random.default_rng(seed)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0)
    minute_of = np.repeat(np.arange(counts.size), counts.astype(np.int64))
    offsets = rng.random(total)
    t = (minute_of + offsets) * minute_s
    t.sort(kind="stable")
    return t0 + t * time_scale


def load_azure_invocations_csv(path: str) -> Dict[str, np.ndarray]:
    """Load an Azure-Functions invocations-per-minute CSV.

    Format (the public ``invocations_per_function_md.anon`` schema):
    identifying columns (HashOwner/HashApp/HashFunction/Trigger) followed
    by one column per minute ("1", "2", ...).  Returns per-function
    minute-count arrays keyed by the function hash."""
    out: Dict[str, np.ndarray] = {}
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        minute_cols = [c for c in (reader.fieldnames or [])
                       if c.strip().isdigit()]
        minute_cols.sort(key=int)
        for row in reader:
            name = (row.get("HashFunction") or row.get("function")
                    or f"fn{len(out)}")
            counts = np.array([float(row[c] or 0) for c in minute_cols])
            out[name] = out[name] + counts if name in out else counts
    return out


def synthetic_azure_counts(functions: Sequence[str], minutes: int = 60,
                           mean_rpm: float = 60.0, seed: int = 0
                           ) -> Dict[str, np.ndarray]:
    """Deterministic stand-in for the public dataset: per-function
    per-minute Poisson counts shaped by a diurnal curve (the repo ships no
    real trace; tests and registry scenarios replay these)."""
    rng = np.random.default_rng(seed)
    phase = np.linspace(0.0, 2.0 * np.pi, minutes, endpoint=False)
    shape = 1.0 + 0.5 * np.sin(phase - np.pi / 2)
    return {name: rng.poisson(mean_rpm * shape * (0.5 + rng.random()))
            for name in functions}


# ---------------------------------------------------------------------------
# Synthetic arrival processes
# ---------------------------------------------------------------------------

def _thinned_poisson(rate_fn, rate_max: float, duration_s: float,
                     seed: int, t0: float) -> np.ndarray:
    """Inhomogeneous Poisson via thinning: draw at the envelope rate,
    accept each arrival with probability rate(t) / rate_max."""
    if rate_max <= 0 or duration_s <= 0:
        return np.empty(0)
    rng = np.random.default_rng(seed)
    n = max(int(rate_max * duration_s * 1.2) + 16, 16)
    gaps = rng.exponential(1.0 / rate_max, size=n)
    t = np.cumsum(gaps)
    while t[-1] < duration_s:
        more = rng.exponential(1.0 / rate_max, size=n)
        t = np.concatenate([t, t[-1] + np.cumsum(more)])
    t = t[t < duration_s]
    keep = rng.random(t.size) * rate_max < rate_fn(t)
    return t0 + t[keep]


def diurnal_arrivals(mean_rps: float, duration_s: float, seed: int = 0,
                     t0: float = 0.0, period_s: float = 86400.0,
                     peak_frac: float = 0.6) -> np.ndarray:
    """Sinusoidal daily cycle: rate(t) swings ``mean * (1 +/- peak_frac)``
    with the trough at t=0 (night) and the peak at half period (midday)."""
    peak_frac = min(max(peak_frac, 0.0), 1.0)

    def rate(t):
        return mean_rps * (1.0 + peak_frac *
                           np.sin(2.0 * np.pi * t / period_s - np.pi / 2))

    return _thinned_poisson(rate, mean_rps * (1.0 + peak_frac),
                            duration_s, seed, t0)


def mmpp_arrivals(base_rps: float, burst_rps: float, duration_s: float,
                  seed: int = 0, t0: float = 0.0,
                  mean_quiet_s: float = 20.0,
                  mean_burst_s: float = 5.0) -> np.ndarray:
    """Two-state Markov-modulated Poisson process: exponential-duration
    quiet/burst phases at ``base_rps`` / ``burst_rps`` — the classic bursty
    FaaS arrival model (burst storms against ``submit_batch``)."""
    if duration_s <= 0:
        return np.empty(0)
    rng = np.random.default_rng(seed)
    chunks: List[np.ndarray] = []
    t, burst = 0.0, False
    while t < duration_s:
        mean_len = mean_burst_s if burst else mean_quiet_s
        seg = min(float(rng.exponential(mean_len)), duration_s - t)
        rate = burst_rps if burst else base_rps
        if rate > 0 and seg > 0:
            n = rng.poisson(rate * seg)
            if n:
                chunks.append(t + np.sort(rng.random(n)) * seg)
        t += seg
        burst = not burst
    if not chunks:
        return np.empty(0)
    return t0 + np.concatenate(chunks)


def ramp_arrivals(start_rps: float, end_rps: float, duration_s: float,
                  seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """Linear rate ramp (load staircase / overload probes)."""
    def rate(t):
        return start_rps + (end_rps - start_rps) * t / max(duration_s, 1e-9)

    return _thinned_poisson(rate, max(start_rps, end_rps), duration_s,
                            seed, t0)


# ---------------------------------------------------------------------------
# Declarative dispatch + multi-function mixes
# ---------------------------------------------------------------------------

ARRIVAL_KINDS = ("poisson", "uniform", "diurnal", "mmpp", "ramp", "trace",
                 "azure")


def build_arrivals(spec: Mapping, duration_s: float, seed: int = 0,
                   t0: float = 0.0) -> np.ndarray:
    """Materialize a declarative arrival spec: ``{"kind": ..., ...}``.

    ``duration_s``/``seed`` are scenario-level defaults a spec may
    override; everything else is kind-specific parameters."""
    kind = spec.get("kind", "poisson")
    duration_s = float(spec.get("duration_s", duration_s))
    seed = int(spec.get("seed", seed))
    if kind == "poisson":
        return poisson_arrivals(spec["rps"], duration_s, seed=seed, t0=t0)
    if kind == "uniform":
        return uniform_arrivals(spec["rps"], duration_s, t0=t0)
    if kind == "diurnal":
        return diurnal_arrivals(
            spec["mean_rps"], duration_s, seed=seed, t0=t0,
            period_s=float(spec.get("period_s", 86400.0)),
            peak_frac=float(spec.get("peak_frac", 0.6)))
    if kind == "mmpp":
        return mmpp_arrivals(
            spec["base_rps"], spec["burst_rps"], duration_s, seed=seed,
            t0=t0, mean_quiet_s=float(spec.get("mean_quiet_s", 20.0)),
            mean_burst_s=float(spec.get("mean_burst_s", 5.0)))
    if kind == "ramp":
        return ramp_arrivals(spec["start_rps"], spec["end_rps"],
                             duration_s, seed=seed, t0=t0)
    if kind == "trace":
        return trace_arrivals(spec["times"], t0=t0,
                              time_scale=float(spec.get("time_scale", 1.0)))
    if kind == "azure":
        return counts_to_arrivals(
            spec["counts"], minute_s=float(spec.get("minute_s", 60.0)),
            seed=seed, t0=t0,
            time_scale=float(spec.get("time_scale", 1.0)))
    raise KeyError(f"unknown arrival kind {kind!r} "
                   f"(expected one of {ARRIVAL_KINDS})")


class WorkloadMix:
    """Interleave per-function arrival streams into one admission stream.

    ``merge`` returns ``(times, fn_idx, names)``: the globally sorted
    timestamps, a parallel index into ``names`` per arrival, and the
    distinct function names in first-added order.  The sort is stable, so
    simultaneous arrivals keep stream-insertion order; per-function counts
    are preserved exactly.  Streams may be tagged with a QoS class and a
    tenant; ``merge_tagged`` additionally returns the per-arrival qos /
    tenant columns aligned with ``times``."""

    def __init__(self):
        self._streams: List[Tuple[str, np.ndarray, int, int]] = []

    def add(self, fn_name: str, arrivals: np.ndarray,
            qos: int = 1, tenant: int = 0) -> "WorkloadMix":
        self._streams.append((fn_name,
                              np.asarray(arrivals, dtype=float),
                              int(qos), int(tenant)))
        return self

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for name, arr, _q, _t in self._streams:
            out[name] = out.get(name, 0) + int(arr.size)
        return out

    @property
    def total(self) -> int:
        return sum(arr.size for _, arr, _q, _t in self._streams)

    def merge(self) -> Tuple[np.ndarray, np.ndarray, List[str]]:
        times, idx, names, _qos, _tenant = self.merge_tagged()
        return times, idx, names

    def merge_tagged(self) -> Tuple[np.ndarray, np.ndarray, List[str],
                                    np.ndarray, np.ndarray]:
        names: List[str] = []
        ids: Dict[str, int] = {}
        times_parts: List[np.ndarray] = []
        idx_parts: List[np.ndarray] = []
        qos_parts: List[np.ndarray] = []
        ten_parts: List[np.ndarray] = []
        for name, arr, q, t in self._streams:
            fid = ids.get(name)
            if fid is None:
                fid = len(names)
                ids[name] = fid
                names.append(name)
            times_parts.append(arr)
            idx_parts.append(np.full(arr.size, fid, np.int64))
            qos_parts.append(np.full(arr.size, q, np.int8))
            ten_parts.append(np.full(arr.size, t, np.int32))
        if not times_parts:
            return (np.empty(0), np.empty(0, np.int64), names,
                    np.empty(0, np.int8), np.empty(0, np.int32))
        times = np.concatenate(times_parts)
        idx = np.concatenate(idx_parts)
        qos = np.concatenate(qos_parts)
        tenant = np.concatenate(ten_parts)
        order = np.argsort(times, kind="stable")
        return (times[order], idx[order], names,
                qos[order], tenant[order])
