"""Chunked streaming replay of Azure-scale minute-count traces.

The discrete-event simulator materializes an ``Invocation`` per arrival
and walks every completion through the event queue — right for paper
figures at 10^4..10^6 invocations, hopeless at the public Azure trace's
scale (14 days, ~10^8 invocations).  The streaming replayer keeps the
whole replay columnar and bounded:

  * arrivals are generated one minute-chunk at a time straight into
    ``InvocationBatch`` columns (never a Python object per arrival);
  * each chunk is one re-snapshot + one fused ``Policy.fn_decisions``
    pass — the same jitted filter-cascade + argmin the control plane's
    ``_submit_columns`` uses — so replaying N chunks measures a loop
    over the fused admission step;
  * the columnar sink is the perf model itself: a chunk's admissions
    fold into the (function, platform) EWMA/P² arrays via
    ``fold_observations`` (the exact closed-form constant-input fold),
    plus bincount totals.  Peak memory is O(chunk rows + model cells),
    independent of trace length.

What this deliberately does NOT model: queueing and replica execution.
The replayer evolves admission decisions and perf-model state under the
full trace; per-invocation response curves stay the simulator's job at
simulator scale.  Chunk arrival columns are byte-identical to
``traces.counts_to_arrivals`` applied per chunk with the chunk's seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Sequence

import numpy as np

from repro.core.invocation_batch import InvocationBatch
from repro.core.scheduler import as_snapshot
from repro.core.types import FunctionSpec


@dataclass
class StreamStats:
    """Totals accumulated by ``stream_replay`` (arrays folded to dicts)."""

    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    chunks: int = 0
    peak_chunk_rows: int = 0
    per_platform: Dict[str, int] = field(default_factory=dict)
    per_function: Dict[str, int] = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {
            "submitted": self.submitted, "admitted": self.admitted,
            "rejected": self.rejected, "chunks": self.chunks,
            "peak_chunk_rows": self.peak_chunk_rows,
            "per_platform": dict(self.per_platform),
            "per_function": dict(self.per_function),
        }


def chunk_batch(spec_list: Sequence[FunctionSpec], sub: np.ndarray,
                m0: int, minute_s: float, seed: int) -> InvocationBatch:
    """One minute-chunk of a counts matrix as an ``InvocationBatch``.

    ``sub`` is the (F, W) count slice for minutes ``[m0, m0 + W)``.
    Arrivals land uniformly at random (seeded) inside their minute and
    the chunk is stable-sorted by time, exactly like
    ``counts_to_arrivals`` — a chunk is a replayable artifact."""
    w = sub.shape[1]
    flat = sub.T.ravel()                       # minute-major, fn order
    n = int(flat.sum())
    fn_of = np.tile(np.arange(sub.shape[0], dtype=np.int32), w)
    min_of = np.repeat(np.arange(m0, m0 + w), sub.shape[0])
    fn_col = np.repeat(fn_of, flat)
    rng = np.random.default_rng(seed)
    t_col = (np.repeat(min_of, flat) + rng.random(n)) * minute_s
    order = np.argsort(t_col, kind="stable")
    return InvocationBatch(list(spec_list), fn_col[order], t_col[order])


def stream_replay(cp, specs: Mapping[str, FunctionSpec],
                  counts: Mapping[str, np.ndarray], *,
                  minute_s: float = 60.0, chunk_minutes: int = 60,
                  seed: int = 0,
                  on_chunk: Optional[Callable[[int, int], None]] = None
                  ) -> StreamStats:
    """Stream an Azure-style minute-count trace through the control
    plane's fused admission step, chunk by chunk.

    ``counts`` maps function name -> per-minute invocation counts (the
    ``traces`` module's Azure format); ``specs`` resolves each name to
    its deployed ``FunctionSpec``.  Per chunk: build the arrival columns,
    re-snapshot the platforms, run one ``fn_decisions`` pass, then fold
    the chunk into the columnar sink — arrival-rate windows
    (``events.record_many`` per (fn, rate window)), co-invocation edges
    (``record_batch_columns``), per-cell EWMA/P² state
    (``fold_observations`` with the platform's predicted exec/response),
    and KB decision counters.  ``on_chunk(i, rows)`` fires after each
    chunk (RSS probes hook here).  Stateful policies that cannot make
    per-function decisions route via one representative materialized row
    per present function."""
    names = list(counts)
    tel = cp.metrics.telemetry    # live rollups fold per chunk when set
    spec_list = [specs[name] for name in names]
    mat = np.stack([np.asarray(counts[name], dtype=np.int64)
                    for name in names])
    n_fns, minutes = mat.shape
    admitted_fp: Dict[tuple, int] = {}
    stats = StreamStats()
    rej_f = np.zeros(n_fns, np.int64)
    adm_f = np.zeros(n_fns, np.int64)

    for ci, m0 in enumerate(range(0, minutes, chunk_minutes)):
        sub = mat[:, m0:m0 + chunk_minutes]
        fn_counts = sub.sum(axis=1)
        n = int(fn_counts.sum())
        if n == 0:
            continue
        batch = chunk_batch(spec_list, sub, m0, minute_s,
                            seed * 1_000_003 + ci)
        stats.chunks += 1
        stats.submitted += n
        stats.peak_chunk_rows = max(stats.peak_chunk_rows, n)

        # arrival bookkeeping: fold the chunk's real timestamps into the
        # rate model's own windows (lumping a minute's count at its
        # boundary would leave the intermediate windows empty and drag
        # the Holt level to zero), plus one columnar pass over the chunk
        # for co-invocation edges
        win_s = cp.events.window_s
        win_col = (batch.arrival_t // win_s).astype(np.int64)
        for j in range(n_fns):
            if not fn_counts[j]:
                continue
            wins, wc = np.unique(win_col[batch.fn_idx == j],
                                 return_counts=True)
            for w, c in zip(wins.tolist(), wc.tolist()):
                cp.events.record_many(names[j], w * win_s, int(c))
        cp.interactions.record_batch_columns(batch.fn_idx, names,
                                             (m0 + sub.shape[1]) * minute_s)

        # one fused decision per distinct function in the chunk
        present = [j for j in range(n_fns) if fn_counts[j]]
        pres_specs = [spec_list[j] for j in present]
        snap = as_snapshot(cp.alive_platforms())
        res = cp.policy.fn_decisions(pres_specs, snap, n=n)
        if res is None:                 # stateful policy: one row per fn
            reps = [batch.materialize(
                int(np.nonzero(batch.fn_idx == j)[0][0])) for j in present]
            tmap = cp.policy.choose_batch(reps, snap)
        else:
            idx, ok = res
            tmap = [snap.platforms[int(idx[g])] if ok[g] else None
                    for g in range(len(present))]

        chunk_admitted = 0
        for g, j in enumerate(present):
            k = int(fn_counts[j])
            target = tmap[g]
            if target is None:
                batch.state[batch.fn_idx == j] = InvocationBatch.REJECTED
                rej_f[j] += k
                continue
            batch.state[batch.fn_idx == j] = InvocationBatch.ADMITTED
            fn, prof = spec_list[j], target.prof
            exec_s = cp.perf.predict_exec(fn, prof)
            access_s = sum(cp.placement.access_time(key, prof.name)
                           for key in fn.data_objects)
            cp.perf.fold_observations(fn.name, prof.name, exec_s,
                                      exec_s + access_s, k)
            if tel is not None:
                tel.observe_many(prof.name, fn.name, "response_time",
                                 batch.arrival_t[batch.fn_idx == j],
                                 np.full(k, exec_s + access_s))
            adm_f[j] += k
            chunk_admitted += k
            cell = (j, prof.name)
            admitted_fp[cell] = admitted_fp.get(cell, 0) + k
        cp.kb.count_decisions(chunk_admitted)
        stats.admitted += chunk_admitted
        if tel is not None:
            # fold the chunk's rollups now: pending buffers stay O(chunk)
            # and a 14-day replay keeps O(tiers x capacity) rollup state
            tel.flush()
        if on_chunk is not None:
            on_chunk(ci, n)

    stats.rejected = int(rej_f.sum())
    stats.per_function = {names[j]: int(adm_f[j]) for j in range(n_fns)
                          if adm_f[j]}
    for (j, pname), k in admitted_fp.items():
        stats.per_platform[pname] = stats.per_platform.get(pname, 0) + k
    return stats
