"""Checkpointing: atomic, manifest-driven save/restore of arbitrary pytrees
with optional async writes and restore-time resharding — the substrate for
the FDN's fault-tolerance story (restart on another platform/mesh).

Layout:  <dir>/step_<N>/manifest.json + arrays.npz
Atomicity: written under step_<N>.tmp then renamed; readers only ever see
complete checkpoints. ``retain`` bounds disk usage; ``latest_step`` +
``restore`` implement the restart path; ``restore(..., shardings=...)``
re-device_puts onto a (possibly different) mesh, enabling elastic restarts.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't resolve ml_dtypes names from strings; map them explicitly
_EXTRA_DTYPES = {"bfloat16": ml_dtypes.bfloat16,
                 "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
                 "float8_e5m2": ml_dtypes.float8_e5m2}


def _resolve_dtype(name: str):
    return _EXTRA_DTYPES.get(name, name)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


class Checkpointer:
    def __init__(self, directory: str, retain: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.retain = retain
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        keys, vals, _ = _flatten_with_paths(tree)
        host_vals = []
        for v in vals:
            a = np.asarray(v)
            # store exotic dtypes as raw-widened floats; manifest keeps truth
            if a.dtype == ml_dtypes.bfloat16 or a.dtype.kind == "V":
                a = a.astype(np.float32)
            host_vals.append(a)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, keys, host_vals, extra))
            self._thread.start()
        else:
            self._write(step, keys, host_vals, extra)

    def _write(self, step: int, keys: List[str], vals, extra):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": v for i, v in enumerate(vals)})
        manifest = {"step": step, "keys": keys,
                    "dtypes": [str(v.dtype) for v in vals],
                    "shapes": [list(v.shape) for v in vals],
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.retain] if self.retain else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name,
                                                "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like`; optionally reshard."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        keys_new, vals_like, treedef = _flatten_with_paths(like)
        by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}
        out = []
        for k, v in zip(keys_new, vals_like):
            if k not in by_key:
                raise KeyError(f"checkpoint missing key {k}")
            arr = by_key[k]
            want = getattr(v, "dtype", None)
            if want is not None and str(want) != str(arr.dtype):
                arr = arr.astype(_resolve_dtype(str(want)))
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree

    def extra(self, step: int) -> Dict:
        path = os.path.join(self.dir, f"step_{step}", "manifest.json")
        with open(path) as f:
            return json.load(f)["extra"]
