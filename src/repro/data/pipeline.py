"""Deterministic data pipeline.

Production shape: a seeded, shardable synthetic token stream (documents with
zipfian token statistics and EOS-delimited boundaries) plus an optional
file-backed byte corpus. Each host reads only its slice of the global batch
(``host_index`` / ``host_count``), which is how the pipeline scales to
multi-pod launches; the returned arrays are the per-host shard of the global
batch, ready for ``jax.make_array_from_process_local_data``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    mean_doc_len: int = 512
    eos_id: int = 0
    corpus_path: Optional[str] = None   # optional raw-byte corpus
    host_index: int = 0
    host_count: int = 1


class TokenStream:
    """Seeded zipfian document stream; deterministic per (seed, host, step)."""

    def __init__(self, dc: DataConfig):
        assert dc.global_batch % dc.host_count == 0
        self.dc = dc
        self.local_batch = dc.global_batch // dc.host_count
        self._corpus = None
        if dc.corpus_path:
            with open(dc.corpus_path, "rb") as f:
                raw = np.frombuffer(f.read(), np.uint8).astype(np.int32)
            self._corpus = raw % dc.vocab_size

    def _rng(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.dc.seed, self.dc.host_index * self.local_batch + row, step))

    def _row(self, step: int, row: int) -> np.ndarray:
        dc = self.dc
        rng = self._rng(step, row)
        if self._corpus is not None:
            start = int(rng.integers(0, max(len(self._corpus) - dc.seq_len
                                            - 1, 1)))
            return self._corpus[start:start + dc.seq_len + 1]
        out = np.empty(dc.seq_len + 1, np.int32)
        i = 0
        while i < dc.seq_len + 1:
            n = int(rng.geometric(1.0 / dc.mean_doc_len))
            n = min(n, dc.seq_len + 1 - i)
            # zipfian body, reserving id 0 for EOS
            body = rng.zipf(1.2, size=n - 1 if n > 1 else 0)
            body = (body % (dc.vocab_size - 1)) + 1
            out[i:i + n - 1] = body[:max(n - 1, 0)]
            if n >= 1:
                out[i + n - 1] = dc.eos_id
            i += n
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        rows = np.stack([self._row(step, r) for r in range(self.local_batch)])
        tokens = rows[:, :-1]
        labels = rows[:, 1:]
        mask = (tokens != dc.eos_id).astype(np.float32)
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32), "mask": mask}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_request_stream(dc: DataConfig, mean_prompt: int = 128,
                        seed: int = 7) -> Iterator[np.ndarray]:
    """Inference-side: stream of variable-length prompts (serving engine)."""
    rng = np.random.default_rng(seed)
    while True:
        n = int(np.clip(rng.geometric(1.0 / mean_prompt), 4, dc.seq_len))
        yield (rng.integers(1, dc.vocab_size, n)).astype(np.int32)


def bursty_arrival_times(rate: float, duration_s: float, *,
                         burst_factor: float = 4.0,
                         period_s: float = 60.0,
                         seed: int = 11) -> np.ndarray:
    """Azure-functions-style bursty/diurnal arrivals (sorted seconds).

    A sinusoidal rate profile (1/burst_factor .. 1 of `rate*burst_factor`)
    sampled with a thinned Poisson process — the workload shape the FDN's
    EventModel forecasts and predictive prewarming are built for.
    """
    rng = np.random.default_rng(seed)
    peak = rate * burst_factor
    # oversample a homogeneous Poisson at the peak rate, then thin
    n = rng.poisson(peak * duration_s)
    t = np.sort(rng.uniform(0.0, duration_s, n))
    profile = 0.5 * (1 + np.sin(2 * np.pi * t / period_s))  # 0..1
    lam = rate * (1 + (burst_factor - 1) * profile)          # rate..peak
    keep = rng.uniform(0, 1, n) < lam / peak
    return t[keep]
