"""Jitted warm-pool forecasting: the predictive prewarmer's fused
Holt-linear + gap-histogram tick compiled with ``jax.jit`` over the
columnar per-(function, platform) state (repro.autoscale.forecast).

One call advances every managed row: Holt level/trend smoothing of the
tick's arrival counts, inter-arrival-gap histogram scatter (one-hot — the
row count is tiny relative to a device pass), Little's-law desired-pool
sizing, and the gap-quantile keep-alive TTL.  The NumPy reference in
``repro.autoscale.forecast`` stays the fallback and the parity oracle:
tests pin byte-identical prewarm decisions (desired pools and TTL ticks)
from both backends on seeded arrival streams.  Caveat mirrors
``policy_score``: without jax x64 this computes in float32 while the
oracle is float64 — a demand landing exactly on an integer in one
precision could in principle flip a ceil; parity is pinned empirically,
and the NumPy backend is preferred at the FDN's actual row counts anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INT = jnp.int32


@jax.jit
def predictive_tick(counts, level, trend, idle_ticks, hist, coeff,
                    alpha, beta, min_demand, max_pool, quantile,
                    default_ttl, min_ttl, max_ttl, min_gap_obs,
                    hold_thr):
    """Fused forecaster tick; returns the advanced state plus decisions:
    (level, trend, idle_ticks, hist, desired, ttl_ticks)."""
    pred = level + trend
    err = counts - pred
    new_level = pred + alpha * err
    new_trend = trend + (alpha * beta) * err

    active = counts > 0.0
    gap_closed = active & (idle_ticks > 0.0)
    bucket = jnp.clip(
        jnp.floor(jnp.log2(jnp.maximum(idle_ticks, 1.0))).astype(_INT),
        0, hist.shape[1] - 1)
    onehot = (jax.lax.broadcasted_iota(_INT, hist.shape, 1)
              == bucket[:, None]) & gap_closed[:, None]
    new_hist = hist + onehot.astype(hist.dtype)
    new_idle = jnp.where(active, 0.0, idle_ticks + 1.0)

    rate = jnp.maximum(new_level + new_trend, 0.0)
    hold = (rate >= hold_thr).astype(counts.dtype)   # warm floor of one
    desired = jnp.clip(jnp.maximum(jnp.ceil(rate * coeff - min_demand),
                                   hold), 0.0, max_pool)

    total = new_hist.sum(axis=1)
    cum = jnp.cumsum(new_hist, axis=1)
    b = jnp.argmax(cum >= (quantile * total)[:, None], axis=1)
    ttl = jnp.exp2(b + 1.0)
    ttl = jnp.where(total >= min_gap_obs, ttl, default_ttl)
    ttl = jnp.clip(ttl, min_ttl, max_ttl)
    return new_level, new_trend, new_idle, new_hist, desired, ttl
