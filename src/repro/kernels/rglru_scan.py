"""RG-LRU linear recurrence h_t = a_t * h_{t-1} + b_t as a Pallas TPU
kernel.

Chunked formulation: the grid walks (batch, width-block, chunk); inside a
chunk the recurrence is rewritten in log-space prefix form
    h_t = exp(cumlog_a_t) * (h_0 + sum_{j<=t} b_j / exp(cumlog_a_j))
(a_t in (0,1] so log is safe), which is two cumulative ops + elementwise
math on the VPU — no sequential loop over time steps. The carry h across
chunks lives in f32 VMEM scratch, persisting across grid iterations along
the (last) chunk axis exactly like the SSD kernel's state.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-20


def _kernel(a_ref, b_ref, h_ref, carry_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[...].astype(jnp.float32)              # (Q, W)
    b = b_ref[...].astype(jnp.float32)
    h0 = carry_ref[...]                             # (1, W)

    log_a = jnp.log(jnp.maximum(a, _EPS))
    cum = jnp.cumsum(log_a, axis=0)                 # (Q, W)
    # h_t = exp(cum_t) * (h0 + sum_{j<=t} b_j * exp(-cum_j))
    scaled_b = b * jnp.exp(-cum)
    prefix = jnp.cumsum(scaled_b, axis=0)
    h = jnp.exp(cum) * (h0 + prefix)
    h_ref[...] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1:, :]


@functools.partial(jax.jit, static_argnames=("chunk", "width_block",
                                             "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, chunk: int = 64,
               width_block: int = 128,
               interpret: bool = True) -> jax.Array:
    """a, b: (B, S, W) -> h: (B, S, W) with h_t = a_t*h_{t-1} + b_t."""
    bs, s, w = a.shape
    chunk = min(chunk, s)
    width_block = min(width_block, w)
    assert s % chunk == 0 and w % width_block == 0
    nc, nw = s // chunk, w // width_block

    kernel = functools.partial(_kernel, chunk=chunk)
    h = pl.pallas_call(
        kernel,
        grid=(bs, nw, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, width_block),
                         lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((None, chunk, width_block),
                         lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=pl.BlockSpec((None, chunk, width_block),
                               lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((bs, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, width_block), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return h
