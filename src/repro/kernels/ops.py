"""jit'd public wrappers around the Pallas kernels.

On TPU (`interpret=False`) these are the perf-critical paths; on this CPU
container every kernel runs in interpret mode and is validated against the
pure-jnp oracles in ref.py (tests/test_kernels.py sweeps shapes/dtypes).

``use_kernels(cfg)`` — models route through these when cfg.use_pallas.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.ssd_scan import ssd_scan as _ssd
from repro.kernels.rglru_scan import rglru_scan as _rglru


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    q_block: int = 128, kv_block: int = 128) -> jax.Array:
    return _flash(q, k, v, causal=causal, window=window, q_block=q_block,
                  kv_block=kv_block, interpret=not on_tpu())


def decode_attention(q, k, v, lengths, *, splits: int = 4,
                     kv_block: int = 128) -> jax.Array:
    return _decode(q, k, v, lengths, splits=splits, kv_block=kv_block,
                   interpret=not on_tpu())


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64
             ) -> Tuple[jax.Array, jax.Array]:
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=not on_tpu())


def rglru_scan(a, b, *, chunk: int = 64,
               width_block: int = 128) -> jax.Array:
    return _rglru(a, b, chunk=chunk, width_block=width_block,
                  interpret=not on_tpu())
