"""Jitted admission decisions: the Scheduler's policy filter cascades,
cost matrices and argmin compiled with ``jax.jit`` over the columnar
``PlatformSnapshot`` (paper §3.1.3).

Each function takes per-distinct-function matrices of shape (F, P) —
F functions being decided, P candidate platforms — plus per-platform or
per-function vectors, and returns the fused decision

    (choice: (F,) int32 platform index, ok: (F,) bool any-feasible)

with ties broken to the lowest platform index, exactly like the NumPy
``Policy.score`` + row-argmin path in ``repro.core.scheduler`` (which
stays as the fallback and the parity oracle — tests assert byte-identical
platform choices under both backends).  Caveat: without jax x64, the
cascades compute in float32 while the oracle is float64 — costs within
float32 eps of each other could in principle flip an argmin.  Parity is
pinned empirically on every registry scenario; if a live workload ever
manufactures such a near-tie, prefer the numpy backend.

The graceful-degrade cascades mirror the host policies:
  * utilization filter: drop loaded platforms unless that empties a row;
  * SLO feasibility: drop SLO-violating platforms unless that empties a
    row (per function).

``composite_decide`` additionally has a Pallas kernel variant fusing the
whole filter cascade + argmin in one VMEM-resident pass
(``composite_decide_pallas``); on TPU it runs compiled, elsewhere in
interpret mode.  Shapes are padded to (8, 128) tiles.  It is opt-in via
``set_use_pallas`` (the jnp path is faster at the tiny F x P of the FDN's
platform sets; the kernel exists for pod-scale platform registries).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INT = jnp.int32

# Filter-kill bitmask bits for the explain bundle.  Values mirror
# ``repro.core.scheduler.KILL_*`` (kernels must stay importable without
# the core package, so the literals are repeated here).
KILL_DEAD = 1    # platform failed / no replicas (alive mask)
KILL_UTIL = 2    # alive but dropped by the utilization filter
KILL_SLO = 4     # survived utilization but dropped by SLO feasibility

_use_pallas = False


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def set_use_pallas(enabled: bool) -> None:
    """Route ``composite_decide`` through the fused Pallas kernel."""
    global _use_pallas
    _use_pallas = bool(enabled)


def use_pallas() -> bool:
    return _use_pallas


# ---------------------------------------------------------------------------
# Shared argmin
# ---------------------------------------------------------------------------

def _masked_argmin(cost: jax.Array, mask: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """Row-wise argmin of ``where(mask, cost, inf)``; ok marks rows with
    at least one finite candidate.  First-lowest tie-break matches
    ``np.argmin`` over the host cost matrices."""
    masked = jnp.where(mask, cost, jnp.inf)
    finite = jnp.isfinite(masked)
    masked = jnp.where(finite, masked, jnp.inf)   # NaN -> inf, like host
    return (jnp.argmin(masked, axis=1).astype(_INT), finite.any(axis=1))


def _degrade(ok: jax.Array, fallback: jax.Array) -> jax.Array:
    """Per-row graceful degrade: rows where the filter left no candidate
    fall back to the unfiltered mask."""
    return jnp.where(ok.any(axis=1, keepdims=True), ok, fallback)


# ---------------------------------------------------------------------------
# Per-policy decisions (jit; shapes (F, P) compile once per shape)
# ---------------------------------------------------------------------------

@jax.jit
def perf_ranked_decide(exec_s, alive):
    """§5.1.1: fastest alive platform per function."""
    return _masked_argmin(exec_s, alive)


@jax.jit
def utilization_decide(exec_s, alive, unloaded):
    """§5.1.2: fastest among un-pressured platforms (degrade to alive)."""
    ok = _degrade(alive & unloaded[None, :], alive)
    return _masked_argmin(exec_s, ok)


@jax.jit
def locality_decide(exec_s, data_s, alive):
    """§5.1.4: execution + data-access seconds."""
    return _masked_argmin(exec_s + data_s, alive)


@jax.jit
def warm_decide(exec_s, data_s, warm_free, cold_start_s, alive):
    """Warm-pool-aware routing (repro.autoscale): execution + data-access
    seconds, plus the platform's cold-start penalty where the function has
    no idle warm replica standing by."""
    cold = jnp.where(warm_free > 0.0, 0.0, cold_start_s[None, :])
    return _masked_argmin(exec_s + data_s + cold, alive)


@jax.jit
def energy_decide(energy_j, p90_s, slo_s, alive):
    """§5.2: cheapest energy among SLO-feasible (degrade to alive)."""
    feasible = _degrade(alive & (p90_s <= slo_s[:, None]), alive)
    return _masked_argmin(energy_j, feasible)


@jax.jit
def composite_decide(exec_s, data_s, p90_s, energy_j, alive, unloaded,
                     slo_s, energy_weight):
    """The full SLOCompositePolicy cascade: utilization mask -> SLO
    feasibility -> locality-adjusted latency + energy tie-break."""
    ok = _degrade(alive & unloaded[None, :], alive)
    feasible = _degrade(ok & (p90_s <= slo_s[:, None]), ok)
    cost = (exec_s + data_s) + energy_weight * energy_j
    return _masked_argmin(cost, feasible)


# ---------------------------------------------------------------------------
# Explain bundle: decision + provenance in one fused pass
# ---------------------------------------------------------------------------

def _masked_argmin_explain(cost, mask):
    """``_masked_argmin`` plus the provenance extras: the runner-up
    (best feasible candidate excluding the winner, -1 when fewer than two
    are feasible) and the runner-up margin (inf in that case)."""
    masked = jnp.where(mask, cost, jnp.inf)
    finite = jnp.isfinite(masked)
    masked = jnp.where(finite, masked, jnp.inf)
    choice = jnp.argmin(masked, axis=1).astype(_INT)
    ok = finite.any(axis=1)
    ncols = masked.shape[1]
    col = jax.lax.broadcasted_iota(_INT, masked.shape, 1)
    rest = jnp.where(col == choice[:, None], jnp.inf, masked)
    runner = jnp.argmin(rest, axis=1).astype(_INT)
    best2 = rest.min(axis=1)
    chosen = jnp.take_along_axis(masked, choice[:, None], axis=1)[:, 0]
    margin = jnp.where(jnp.isfinite(best2), best2 - chosen, jnp.inf)
    runner = jnp.where(jnp.isfinite(best2), runner, -1)
    return choice, ok, runner, margin


@jax.jit
def composite_explain(exec_s, data_s, p90_s, energy_j, alive, unloaded,
                      slo_s, energy_weight):
    """``composite_decide`` returning the full explain bundle:

        (choice, ok, kill, runner, margin, cost)

    ``kill`` is a uint8 (F, P) filter-kill bitmask (KILL_DEAD / KILL_UTIL
    / KILL_SLO; 0 == feasible after graceful degrade), ``cost`` the
    unmasked score columns, ``runner``/``margin`` the runner-up platform
    and its cost gap.  Same cascade arithmetic as ``composite_decide`` —
    the host ``SLOCompositePolicy.cascade`` is the f64 parity oracle."""
    ok = _degrade(alive & unloaded[None, :], alive)
    feasible = _degrade(ok & (p90_s <= slo_s[:, None]), ok)
    cost = (exec_s + data_s) + energy_weight * energy_j
    kill = (jnp.where(~alive, KILL_DEAD, 0)
            | jnp.where(alive & ~ok, KILL_UTIL, 0)
            | jnp.where(ok & ~feasible, KILL_SLO, 0)).astype(jnp.uint8)
    choice, any_ok, runner, margin = _masked_argmin_explain(cost, feasible)
    return choice, any_ok, kill, runner, margin, cost


@jax.jit
def fused_composite_decide(ewma_v, ewma_n, analytic_s, resp_h2, resp_n,
                           data_s, nodes, loaded_w, alive, unloaded,
                           slo_s, energy_weight):
    """The whole admission step in ONE jit: snapshot prediction columns
    (exec EWMA-vs-analytic gate, P90 marker-vs-bootstrap gate, energy
    from the platform power model) are built on-device from the raw
    columnar estimator state (``FunctionPerformanceModel
    .estimator_columns``), then the SLOComposite filter cascade + argmin
    runs on them — no host-side prediction matrices at all.

    Arithmetic mirrors ``predict_matrix`` + ``composite_decide`` op for
    op (same operand association), so the only divergence from the NumPy
    oracle is the float32 compute width — covered by the same
    empirically-pinned near-tie caveat as the other cascades."""
    exec_s = jnp.where(ewma_n >= 3, ewma_v, analytic_s)
    p90_s = jnp.where(resp_n >= 10, resp_h2, exec_s * 1.5)
    energy_j = (exec_s * nodes[None, :]) * loaded_w[None, :]
    ok = _degrade(alive & unloaded[None, :], alive)
    feasible = _degrade(ok & (p90_s <= slo_s[:, None]), ok)
    cost = (exec_s + data_s) + energy_weight * energy_j
    return _masked_argmin(cost, feasible)


# ---------------------------------------------------------------------------
# Pallas variant: fused filter cascade + argmin in one kernel
# ---------------------------------------------------------------------------

def _composite_kernel(exec_ref, data_ref, p90_ref, wenergy_ref, alive_ref,
                      unloaded_ref, slo_ref, idx_ref, ok_ref):
    alive = alive_ref[...] > 0
    ok = alive & (unloaded_ref[...] > 0)
    ok = jnp.where(ok.any(axis=1, keepdims=True), ok, alive)
    feasible = ok & (p90_ref[...] <= slo_ref[...])
    feasible = jnp.where(feasible.any(axis=1, keepdims=True), feasible, ok)
    cost = (exec_ref[...] + data_ref[...]) + wenergy_ref[...]
    masked = jnp.where(feasible, cost, jnp.inf)
    row_min = masked.min(axis=1, keepdims=True)
    ncols = masked.shape[1]
    col = jax.lax.broadcasted_iota(_INT, masked.shape, 1)
    first = jnp.where(masked == row_min, col, ncols).min(
        axis=1, keepdims=True)
    idx_ref[...] = jnp.broadcast_to(first, idx_ref.shape)
    ok_ref[...] = jnp.broadcast_to(
        jnp.isfinite(row_min).astype(_INT), ok_ref.shape)


def _pad2(x, rows: int, cols: int, fill):
    f, p = x.shape
    return jnp.pad(x, ((0, rows - f), (0, cols - p)), constant_values=fill)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _composite_pallas(exec_s, data_s, p90_s, wenergy, alive, unloaded,
                      slo_s, *, interpret: bool):
    f, p = exec_s.shape
    fp = max(-(-f // 8) * 8, 8)           # sublane multiple
    pp = max(-(-p // 128) * 128, 128)     # lane multiple
    f32 = jnp.float32
    args = (_pad2(exec_s.astype(f32), fp, pp, 0.0),
            _pad2(data_s.astype(f32), fp, pp, 0.0),
            _pad2(p90_s.astype(f32), fp, pp, jnp.inf),
            _pad2(wenergy.astype(f32), fp, pp, 0.0),
            _pad2(alive.astype(_INT), fp, pp, 0),
            _pad2(jnp.broadcast_to(unloaded[None, :], (f, p)).astype(_INT),
                  fp, pp, 0),
            _pad2(jnp.broadcast_to(slo_s[:, None], (f, p)).astype(f32),
                  fp, pp, 0.0))
    idx, ok = pl.pallas_call(
        _composite_kernel,
        out_shape=(jax.ShapeDtypeStruct((fp, 128), _INT),
                   jax.ShapeDtypeStruct((fp, 128), _INT)),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY
                               if interpret else pltpu.VMEM)] * 7,
        out_specs=(pl.BlockSpec(memory_space=pltpu.ANY
                                if interpret else pltpu.VMEM),) * 2,
        interpret=interpret,
    )(*args)
    return idx[:f, 0], ok[:f, 0] > 0


def composite_decide_pallas(exec_s, data_s, p90_s, energy_j, alive,
                            unloaded, slo_s, energy_weight,
                            interpret=None):
    """Pallas-fused SLOComposite decision; same contract (and the same
    first-lowest tie-break) as ``composite_decide``."""
    if interpret is None:
        interpret = not on_tpu()
    wenergy = jnp.asarray(energy_weight, jnp.float32) * \
        jnp.asarray(energy_j, jnp.float32)
    return _composite_pallas(jnp.asarray(exec_s), jnp.asarray(data_s),
                             jnp.asarray(p90_s), wenergy,
                             jnp.asarray(alive), jnp.asarray(unloaded),
                             jnp.asarray(slo_s), interpret=bool(interpret))


# ---------------------------------------------------------------------------
# Fully-fused Pallas variant: estimator gates + prediction columns +
# filter cascade + argmin in one VMEM-resident kernel
# ---------------------------------------------------------------------------

def _fused_composite_kernel(ewma_v_ref, ewma_n_ref, analytic_ref,
                            resp_h2_ref, resp_n_ref, data_ref, nodes_ref,
                            loadedw_ref, weight_ref, alive_ref,
                            unloaded_ref, slo_ref, idx_ref, ok_ref):
    exec_s = jnp.where(ewma_n_ref[...] >= 3, ewma_v_ref[...],
                       analytic_ref[...])
    p90 = jnp.where(resp_n_ref[...] >= 10, resp_h2_ref[...],
                    exec_s * 1.5)
    energy = (exec_s * nodes_ref[...]) * loadedw_ref[...]
    alive = alive_ref[...] > 0
    ok = alive & (unloaded_ref[...] > 0)
    ok = jnp.where(ok.any(axis=1, keepdims=True), ok, alive)
    feasible = ok & (p90 <= slo_ref[...])
    feasible = jnp.where(feasible.any(axis=1, keepdims=True), feasible, ok)
    cost = (exec_s + data_ref[...]) + weight_ref[...] * energy
    masked = jnp.where(feasible, cost, jnp.inf)
    row_min = masked.min(axis=1, keepdims=True)
    ncols = masked.shape[1]
    col = jax.lax.broadcasted_iota(_INT, masked.shape, 1)
    first = jnp.where(masked == row_min, col, ncols).min(
        axis=1, keepdims=True)
    idx_ref[...] = jnp.broadcast_to(first, idx_ref.shape)
    ok_ref[...] = jnp.broadcast_to(
        jnp.isfinite(row_min).astype(_INT), ok_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fused_composite_pallas(ewma_v, ewma_n, analytic_s, resp_h2, resp_n,
                            data_s, nodes, loaded_w, weight, alive,
                            unloaded, slo_s, *, interpret: bool):
    f, p = analytic_s.shape
    fp = max(-(-f // 8) * 8, 8)           # sublane multiple
    pp = max(-(-p // 128) * 128, 128)     # lane multiple
    f32 = jnp.float32

    def row(v, fill):                      # (P,) vector -> padded (F, P)
        return _pad2(jnp.broadcast_to(v[None, :], (f, p)).astype(f32),
                     fp, pp, fill)

    args = (_pad2(ewma_v.astype(f32), fp, pp, 0.0),
            _pad2(ewma_n.astype(_INT), fp, pp, 0),
            _pad2(analytic_s.astype(f32), fp, pp, 0.0),
            _pad2(resp_h2.astype(f32), fp, pp, 0.0),
            _pad2(resp_n.astype(_INT), fp, pp, 0),
            _pad2(data_s.astype(f32), fp, pp, 0.0),
            row(nodes, 0.0), row(loaded_w, 0.0),
            _pad2(jnp.full((f, p), weight, f32), fp, pp, 0.0),
            _pad2(alive.astype(_INT), fp, pp, 0),
            _pad2(jnp.broadcast_to(unloaded[None, :], (f, p)).astype(_INT),
                  fp, pp, 0),
            _pad2(jnp.broadcast_to(slo_s[:, None], (f, p)).astype(f32),
                  fp, pp, -jnp.inf))
    idx, ok = pl.pallas_call(
        _fused_composite_kernel,
        out_shape=(jax.ShapeDtypeStruct((fp, 128), _INT),
                   jax.ShapeDtypeStruct((fp, 128), _INT)),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY
                               if interpret else pltpu.VMEM)] * 12,
        out_specs=(pl.BlockSpec(memory_space=pltpu.ANY
                                if interpret else pltpu.VMEM),) * 2,
        interpret=interpret,
    )(*args)
    return idx[:f, 0], ok[:f, 0] > 0


def fused_composite_decide_pallas(ewma_v, ewma_n, analytic_s, resp_h2,
                                  resp_n, data_s, nodes, loaded_w, alive,
                                  unloaded, slo_s, energy_weight,
                                  interpret=None):
    """Pallas twin of ``fused_composite_decide``: raw estimator state in,
    (choice, ok) out, one kernel.  Padding columns carry slo = -inf so a
    padded platform can never look SLO-feasible."""
    if interpret is None:
        interpret = not on_tpu()
    return _fused_composite_pallas(
        jnp.asarray(ewma_v), jnp.asarray(ewma_n), jnp.asarray(analytic_s),
        jnp.asarray(resp_h2), jnp.asarray(resp_n), jnp.asarray(data_s),
        jnp.asarray(nodes), jnp.asarray(loaded_w),
        jnp.float32(energy_weight), jnp.asarray(alive),
        jnp.asarray(unloaded), jnp.asarray(slo_s),
        interpret=bool(interpret))
