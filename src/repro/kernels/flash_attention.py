"""Flash attention (prefill/train) as a Pallas TPU kernel.

TPU adaptation of the classic algorithm: the grid walks (batch, kv_head,
q_block); each program holds one q block in VMEM, streams k/v blocks of the
same kv head through VMEM with `pl.ds`, and keeps the online-softmax
accumulators (m, l, acc) in f32 VMEM scratch. Block sizes default to
MXU-aligned (128) multiples; causal + sliding-window masks are applied from
block-relative iotas so no (S, T) mask is ever materialized.

GQA layout note: q arrives as (B, KH, G*Bq?, ...) — we fold the group dim
into the q rows (rows = G * q_block) so the MXU sees a tall skinny matmul,
which is the TPU-native way to exploit grouped queries sharing one kv head.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, q_block: int,
            causal: bool, window: Optional[int], scale: float,
            seq_q: int, seq_kv: int, groups: int):
    """One (b, kh, qi) program. Shapes inside:
    q_ref: (q_block*G, D); k_ref/v_ref: (T, D); o_ref: (q_block*G, D)."""
    qi = pl.program_id(2)
    d = q_ref.shape[-1]
    rows = q_ref.shape[0]                       # q_block * groups
    q = q_ref[...].astype(jnp.float32) * scale

    m = jnp.full((rows, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((rows, 1), jnp.float32)
    acc = jnp.zeros((rows, d), jnp.float32)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (rows, 1), 0) // groups      # row -> q position

    n_kv = seq_kv // kv_block

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(ki * kv_block, kv_block), :].astype(jnp.float32)
        v = v_ref[pl.ds(ki * kv_block, kv_block), :].astype(jnp.float32)
        s = q @ k.T                             # (rows, kv_block)
        k_pos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_block), 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return m_new, l, acc

    if causal:
        # only kv blocks that intersect the causal triangle for this q block
        hi = jnp.minimum(((qi + 1) * q_block + kv_block - 1) // kv_block,
                         n_kv)
    else:
        hi = n_kv
    lo = 0
    if window is not None:
        lo = jnp.maximum((qi * q_block - window) // kv_block, 0)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,T,KH,D) -> (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    q_block = min(q_block, sq)
    kv_block = min(kv_block, t)
    assert sq % q_block == 0 and t % kv_block == 0, (sq, q_block, t, kv_block)
    nq = sq // q_block

    # (B,Sq,H,D) -> (B,KH, Sq*G, D) rows grouped as (q position, group)
    qr = q.reshape(b, sq, kh, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b, kh, sq * g, d)
    kr = k.transpose(0, 2, 1, 3)                 # (B,KH,T,D)
    vr = v.transpose(0, 2, 1, 3)

    rows = q_block * g
    kernel = functools.partial(
        _kernel, kv_block=kv_block, q_block=q_block, causal=causal,
        window=window, scale=d ** -0.5, seq_q=sq, seq_kv=t, groups=g)

    out = pl.pallas_call(
        kernel,
        grid=(b, kh, nq),
        in_specs=[
            pl.BlockSpec((None, None, rows, d),
                         lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, t, d),
                         lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, t, d),
                         lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rows, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, sq * g, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)

    return out.reshape(b, kh, sq, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b, sq, h, d)
