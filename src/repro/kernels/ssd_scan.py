"""Mamba-2 SSD chunked forward as a Pallas TPU kernel.

The grid walks (batch*head-block, n_chunks); the chunk axis is the LAST grid
dimension, so TPU grid iteration order lets the inter-chunk SSM state live
in f32 VMEM scratch and carry across chunk programs — the sequential state
pass becomes free (no HBM round-trip per chunk). Intra-chunk work is two
dense matmuls (C·B^T decay-weighted, and the state in/out projections) that
map onto the MXU — this is the "state-space duality" insight restated for
TPU: quadratic-in-chunk attention-like compute + linear state recurrence.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, state_ref,
            *, chunk: int, nheads: int):
    """One (bh, ci) program.

    x_ref: (chunk, P); dt_ref: (chunk, 1); a_ref: (1, 1); b_ref/c_ref:
    (chunk, N); y_ref: (chunk, P); fin_ref: (P, N) final state output;
    state_ref: (P, N) f32 scratch carrying the running state.
    """
    ci = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)                  # (Q,P)
    dt = dt_ref[...].astype(jnp.float32)                # (Q,1)
    a = a_ref[0, 0].astype(jnp.float32)                 # scalar (<0)
    bm = b_ref[...].astype(jnp.float32)                 # (Q,N)
    cm = c_ref[...].astype(jnp.float32)                 # (Q,N)

    da = dt * a                                         # (Q,1)
    cum = jnp.cumsum(da, axis=0)                        # (Q,1)
    total = cum[-1, 0]

    # ---- intra-chunk (quadratic, MXU) ----
    li = cum                                            # (Q,1)
    lj = cum.T                                          # (1,Q)
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iq >= jq, jnp.exp(li - lj), 0.0)      # (Q,Q)
    cb = cm @ bm.T                                      # (Q,Q)
    w = cb * L * dt.T                                   # weight over j
    y = w @ x                                           # (Q,P)

    # ---- contribution of the incoming state ----
    state = state_ref[...]                              # (P,N)
    y += (cm @ state.T) * jnp.exp(cum)                  # (Q,N)@(N,P)->(Q,P)

    # ---- state update for the next chunk ----
    decay_to_end = jnp.exp(total - cum)                 # (Q,1)
    xdt = x * (dt * decay_to_end)                       # (Q,P)
    new_state = state * jnp.exp(total) + xdt.T @ bm     # (P,N)
    state_ref[...] = new_state

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _fin():
        fin_ref[...] = new_state.astype(fin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 64,
             interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,N).

    Returns (y: (B,S,H,P), final_state: (B,H,P,N)). G must divide H.
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hpg = h // g
    assert s % chunk == 0
    nc = s // chunk

    # lay out as (B*H, S, ...) with heads sharing their group's B/C
    xr = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    ar = jnp.repeat(A.reshape(1, h), b, axis=0).reshape(b * h, 1, 1)
    Br = jnp.repeat(Bm.transpose(0, 2, 1, 3), hpg, axis=1).reshape(
        b * h, s, n)
    Cr = jnp.repeat(Cm.transpose(0, 2, 1, 3), hpg, axis=1).reshape(
        b * h, s, n)

    kernel = functools.partial(_kernel, chunk=chunk, nheads=h)
    y, fin = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((None, chunk, 1), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((None, 1, 1), lambda i, ci: (i, 0, 0)),
            pl.BlockSpec((None, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((None, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((None, p, n), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, Br, Cr)

    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    fin = fin.reshape(b, h, p, n)
    return y, fin
