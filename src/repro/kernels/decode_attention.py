"""Flash-decode (split-K) attention for single-token decode over long KV
caches, as a Pallas TPU kernel.

The cache sequence axis is cut into `splits` segments; the grid walks
(batch, kv_head, split) and each program reduces its segment with online
softmax, emitting partial (max, sumexp, weighted-acc) triples. The cheap
cross-split combine runs in the jit'd wrapper (ops-level), mirroring how the
sequence-sharded decode path combines partial softmax across the "model"
mesh axis — the kernel is the single-chip version of that same pattern.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, m_ref, l_ref, acc_ref, *,
            split_len: int, kv_block: int, scale: float):
    """One (b, kh, split). q_ref: (G,D); k/v_ref: (split_len, D);
    len_ref: (1,1) valid length for this row; outputs per split."""
    si = pl.program_id(2)
    g, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    valid_len = len_ref[0, 0]                      # global valid prefix

    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc = jnp.zeros((g, d), jnp.float32)

    base = si * split_len
    n_blocks = split_len // kv_block

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(ki * kv_block, kv_block), :].astype(jnp.float32)
        v = v_ref[pl.ds(ki * kv_block, kv_block), :].astype(jnp.float32)
        s = q @ k.T                                # (G, kv_block)
        k_pos = base + ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_block), 1)
        s = jnp.where(k_pos < valid_len, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m, l, acc))
    m_ref[...] = m
    l_ref[...] = l
    acc_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("splits", "kv_block",
                                             "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, splits: int = 4,
                     kv_block: int = 128,
                     interpret: bool = True) -> jax.Array:
    """q: (B,H,D); k,v: (B,T,KH,D); lengths: (B,). Returns (B,H,D)."""
    b, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    while t % (splits * kv_block) and splits > 1:
        splits -= 1
    kv_block = min(kv_block, t // splits)
    assert t % splits == 0 and (t // splits) % kv_block == 0
    split_len = t // splits

    qr = q.reshape(b, kh, g, d)
    kr = k.transpose(0, 2, 1, 3)                  # (B,KH,T,D)
    vr = v.transpose(0, 2, 1, 3)
    lens = lengths.astype(jnp.int32).reshape(b, 1, 1)

    kernel = functools.partial(_kernel, split_len=split_len,
                               kv_block=kv_block, scale=d ** -0.5)
    m, l, acc = pl.pallas_call(
        kernel,
        grid=(b, kh, splits),
        in_specs=[
            pl.BlockSpec((None, None, g, d),
                         lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, split_len, d),
                         lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((None, None, split_len, d),
                         lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((None, 1, 1), lambda bi, hi, si: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, None, g, 1),
                         lambda bi, hi, si: (bi, hi, si, 0, 0)),
            pl.BlockSpec((None, None, None, g, 1),
                         lambda bi, hi, si: (bi, hi, si, 0, 0)),
            pl.BlockSpec((None, None, None, g, d),
                         lambda bi, hi, si: (bi, hi, si, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, splits, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, splits, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, splits, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, lens)

    # cross-split combine (tiny): renormalize partials by the global max
    m_g = jnp.max(m, axis=2, keepdims=True)               # (B,KH,1,G,1)
    w = jnp.exp(m - m_g)
    l_g = jnp.sum(l * w, axis=2)                          # (B,KH,G,1)
    acc_g = jnp.sum(acc * w, axis=2)                      # (B,KH,G,D)
    out = acc_g / jnp.maximum(l_g, 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)
