"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately naive (full materialization, f32) — correctness
references, not performance paths.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,T,KH,D) -> (B,Sq,H,D). GQA by head grouping."""
    b, sq, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qr = q.reshape(b, sq, kh, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,btkd->bkgqt", qr,
                        k.astype(jnp.float32)) * (d ** -0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((sq, t), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B,H,D); k,v: (B,T,KH,D); lengths: (B,) valid prefix lengths."""
    b, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qr = q.reshape(b, kh, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qr,
                        k.astype(jnp.float32)) * (d ** -0.5)
    mask = jnp.arange(t)[None, :] < lengths[:, None]          # (B,T)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, h0: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H,)<0; Bm/Cm: (B,S,G,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hpg = h // g
    xf = x.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), hpg, axis=2)   # (B,S,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), hpg, axis=2)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                              # (B,H,*)
        decay = jnp.exp(dtt * A[None, :])                  # (B,H)
        state = state * decay[..., None, None] + \
            (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = (h0.astype(jnp.float32) if h0 is not None
            else jnp.zeros((b, h, p, n), jnp.float32))
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3), final


def rglru_ref(a: jax.Array, b: jax.Array,
              h0: Optional[jax.Array] = None) -> jax.Array:
    """Sequential linear recurrence h_t = a_t*h_{t-1} + b_t. a,b: (B,S,W)."""
    bs, s, w = a.shape
    init = (h0.astype(jnp.float32) if h0 is not None
            else jnp.zeros((bs, w), jnp.float32))

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, init,
                         (a.astype(jnp.float32).transpose(1, 0, 2),
                          b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
