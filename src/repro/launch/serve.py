"""Serving launcher: continuous-batching engine over a reduced arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 --batch-size 4
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model_api as api
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-context", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=args.batch_size,
                        max_context=args.max_context)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(4, 48))
                                        ).astype(np.int32),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    lat = [r.done_s - r.submitted_s for r in reqs]
    ttft = [r.first_token_s - r.submitted_s for r in reqs]
    print(f"served {len(reqs)} requests in {dt:.2f}s")
    print(f"  p50/p90 latency: {np.percentile(lat, 50):.3f}/"
          f"{np.percentile(lat, 90):.3f}s")
    print(f"  p50 TTFT: {np.percentile(ttft, 50):.3f}s")
    print(f"  engine: {eng.stats()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
