import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape)
cell on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json

The very first two lines of this file force 512 host placeholder devices —
before ANY other import — because jax locks the device count on first use.
"""
import argparse
import json
import sys

from repro.configs.base import ALL_SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun_lib import lower_cell


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    choices=ARCH_IDS, help="architecture id(s); default all")
    ap.add_argument("--shape", action="append", default=None,
                    choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    archs = args.arch or ARCH_IDS
    shapes = ([get_shape(s) for s in args.shape] if args.shape
              else list(ALL_SHAPES))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                ok, reason = shape_applicable(cfg, shape)
                if not ok:
                    print(f"SKIP {arch} x {shape.name}: {reason}")
                    continue
                res = lower_cell(cfg, shape, mesh, args.microbatches)
                tag = "OK  " if res.ok else "FAIL"
                print(f"{tag} {arch:22s} {shape.name:12s} mesh={res.mesh:10s}"
                      f" lower={res.lower_s:6.1f}s compile={res.compile_s:6.1f}s"
                      f" flops/dev={res.flops_per_dev:.3e}"
                      f" coll/dev={res.coll_bytes_per_dev:.3e}", flush=True)
                if res.ok and args.verbose and res.mem:
                    print("     mem/dev: " + json.dumps(res.mem))
                if not res.ok:
                    print("     " + res.error)
                    failures.append(res)
                results.append(res.to_json())

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} cells -> {args.out}")
    print(f"{len(results) - len(failures)}/{len(results)} cells OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
