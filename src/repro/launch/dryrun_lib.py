"""Dry-run library: lower + compile every (arch x shape x mesh) cell and
extract memory / cost / collective statistics from the compiled artifact.

Import this ONLY after the XLA device-count flag is set (dryrun.py and the
roofline harness do that in their first two lines). Importing this module
itself does not touch jax device state.
"""
from __future__ import annotations

import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs.base import ModelConfig, InputShape, HYBRID, ENCDEC
from repro.models import model_api as api
from repro.models import params as pm
from repro.train import optimizer as opt
from repro.train import train_step as ts


# ---------------------------------------------------------------------------
# Depth control (used by the roofline 2-point scan-body calibration)
# ---------------------------------------------------------------------------


def with_depth(cfg: ModelConfig, d: int) -> ModelConfig:
    if cfg.family == HYBRID:
        pat = len(cfg.block_pattern)
        tail = cfg.num_layers % pat
        return cfg.replace(num_layers=pat * d + tail)
    if cfg.family == ENCDEC:
        return cfg.replace(num_layers=d, n_enc_layers=d)
    return cfg.replace(num_layers=d)


def full_depth_units(cfg: ModelConfig) -> int:
    if cfg.family == HYBRID:
        return cfg.num_layers // len(cfg.block_pattern)
    return cfg.num_layers


# ---------------------------------------------------------------------------
# Collective-bytes parsing from HLO text
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes for every collective op, by kind.

    Works on post-SPMD-partitioning HLO, so shapes are per-device; counts
    are per-device bytes moved per executable invocation (scan bodies appear
    once — the roofline harness undoes that with a depth fit).
    """
    by_kind = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = .+? ([a-z\-]+)(?:-start)?\(", ls)
        if not m:
            continue
        kind = m.group(1)
        if kind.endswith("-start"):
            kind = kind[:-6]
        if kind not in by_kind or "-done" in ls.split("=")[1][:40]:
            continue
        # operand shapes: inside the call parens
        paren = ls.find("(")
        args = ls[paren + 1:ls.rfind(")")]
        by_kind[kind] += _shape_bytes(args)
        counts[kind] += 1
    return {"bytes_by_kind": by_kind, "counts": counts,
            "total_bytes": sum(by_kind.values())}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    kind: str
    ok: bool
    error: str = ""
    lower_s: float = 0.0
    compile_s: float = 0.0
    flops_per_dev: float = 0.0
    bytes_per_dev: float = 0.0
    coll_bytes_per_dev: float = 0.0
    coll_detail: Optional[Dict] = None
    mem: Optional[Dict] = None
    n_devices: int = 0
    microbatches: int = 1

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def _mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def build_cell(cfg: ModelConfig, shape: InputShape, mesh,
               microbatches: Optional[int] = None):
    """Returns (fn, args, in_shardings, out_shardings, donate, n_micro)."""
    n_chips = mesh.devices.size
    oc = opt.OptConfig()
    mspecs = api.model_specs(cfg)
    params_abs = api.abstract_params(cfg)
    params_sh = api.param_shardings(cfg, mesh)

    if shape.kind == "train":
        n_micro = (microbatches if microbatches is not None
                   else ts.default_microbatches(cfg, shape, n_chips))
        step = ts.make_train_step(cfg, oc, n_micro)
        ostate_abs = jax.eval_shape(lambda: opt.init_state(oc, mspecs))
        ostate_sh = opt.state_shardings(oc, mspecs, mesh)
        batch_abs = api.input_specs(cfg, shape)
        batch_sh = api.batch_shardings(cfg, mesh, shape)
        scalar = shd.named_sharding(mesh, (), ())
        out_sh = (params_sh, ostate_sh,
                  {"loss": scalar, "lr": scalar, "grad_norm": scalar})
        return (step, (params_abs, ostate_abs, batch_abs),
                (params_sh, ostate_sh, batch_sh), out_sh, (0, 1), n_micro)

    if shape.kind == "prefill":
        step = ts.make_prefill_step(cfg, shape.seq_len)
        batch_abs = api.input_specs(cfg, shape)
        batch_sh = api.batch_shardings(cfg, mesh, shape)
        cache_sh = api.cache_shardings(cfg, mesh, shape.global_batch,
                                       shape.seq_len)
        logit_sh = shd.named_sharding(
            mesh, (shape.global_batch, 1, cfg.vocab_size),
            ("batch", None, "vocab"))
        return (step, (params_abs, batch_abs), (params_sh, batch_sh),
                (logit_sh, cache_sh), (), 1)

    # decode
    step = ts.make_serve_step(cfg)
    cache_abs = api.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cache_sh = api.cache_shardings(cfg, mesh, shape.global_batch,
                                   shape.seq_len)
    batch_abs = api.input_specs(cfg, shape)
    batch_sh = api.batch_shardings(cfg, mesh, shape)
    logit_sh = shd.named_sharding(
        mesh, (shape.global_batch, 1, cfg.vocab_size),
        ("batch", None, "vocab"))
    return (step, (params_abs, cache_abs, batch_abs),
            (params_sh, cache_sh, batch_sh), (logit_sh, cache_sh), (1,), 1)


def lower_cell(cfg: ModelConfig, shape: InputShape, mesh,
               microbatches: Optional[int] = None,
               keep_artifacts: bool = False) -> CellResult:
    res = CellResult(arch=cfg.name, shape=shape.name, mesh=_mesh_name(mesh),
                     kind=shape.kind, ok=False,
                     n_devices=int(mesh.devices.size))
    try:
        fn, args, in_sh, out_sh, donate, n_micro = build_cell(
            cfg, shape, mesh, microbatches)
        res.microbatches = n_micro
        t0 = time.time()
        with shd.use_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
        res.lower_s = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        res.compile_s = time.time() - t0
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):      # older JAX: list of dicts
            ca = ca[0] if ca else {}
        res.flops_per_dev = float(ca.get("flops", 0.0))
        res.bytes_per_dev = float(ca.get("bytes accessed", 0.0))
        try:
            ma = compiled.memory_analysis()
            res.mem = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "code_bytes": int(ma.generated_code_size_in_bytes),
            }
        except Exception:                      # pragma: no cover
            res.mem = None
        txt = compiled.as_text()
        cs = collective_stats(txt)
        res.coll_bytes_per_dev = float(cs["total_bytes"])
        res.coll_detail = cs
        res.ok = True
        if keep_artifacts:
            res.__dict__["_compiled"] = compiled
            res.__dict__["_hlo"] = txt
    except Exception as e:                     # noqa: BLE001
        res.error = f"{type(e).__name__}: {e}"[:2000]
    return res
