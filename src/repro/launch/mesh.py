"""Mesh construction for the production pod slices and FDN target platforms.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.

Version compat: ``jax.sharding.AxisType`` only exists on newer JAX; on
older installs ``jax.make_mesh`` takes no ``axis_types`` and every axis is
Auto by default, which is exactly what we request — so the shim just drops
the argument.  Always build meshes through this module, never by importing
``AxisType`` directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:                                    # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                     # older jax: all axes are Auto
    AxisType = None


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return _mk(shape, axes)


def make_local_mesh(model_parallel: Optional[int] = None) -> Mesh:
    """Mesh over whatever devices exist (CPU tests: 1 device)."""
    n = jax.device_count()
    mp = model_parallel or 1
    return make_mesh((n // mp, mp), ("data", "model"))
