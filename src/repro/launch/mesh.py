"""Mesh construction for the production pod slices and FDN target platforms.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel: Optional[int] = None) -> Mesh:
    """Mesh over whatever devices exist (CPU tests: 1 device)."""
    n = jax.device_count()
    mp = model_parallel or 1
    return make_mesh((n // mp, mp), ("data", "model"))
