"""Training launcher.

Two modes:
  * real CPU execution (reduced configs) — for smoke-scale runs here:
      PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
          --reduced --steps 20
  * pod-scale AOT check (lower+compile the full config on the production
    mesh — the dry-run path):
      PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
          --shape train_4k

Includes the fault-tolerance loop: periodic checkpoints, automatic restore
of the latest step on (re)start.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import model_api as api
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    oc = opt.OptConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps,
                       compress_grads=args.compress_grads)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init_state(oc, api.model_specs(cfg))
    step_fn = jax.jit(make_train_step(cfg, oc, args.microbatches))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    stream = TokenStream(dc)

    start_step = 0
    ck = None
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir, retain=3, async_save=True)
        latest = ck.latest_step()
        if latest is not None:
            restored = ck.restore(latest, {"params": params, "opt": state})
            params, state = restored["params"], restored["opt"]
            start_step = latest
            print(f"restored checkpoint step {latest}")

    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, state, m = step_fn(params, state, batch)
        print(f"step {i:4d} loss={float(m['loss']):.4f} "
              f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.3f}",
              flush=True)
        if ck and (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": state},
                    extra={"arch": cfg.name})
    if ck:
        ck.wait()
    tokens = args.steps * args.batch * args.seq
    dt = time.time() - t0
    print(f"done: {tokens} tokens in {dt:.1f}s "
          f"({tokens / max(dt, 1e-9):.0f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
