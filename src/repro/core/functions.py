"""The paper's benchmark functions (Table 2) as real JAX workloads, plus
ML-serving functions wrapping the model zoo.

Each FaaSProfiler-derived function keeps its compute/data character:
  nodeinfo            trivial metadata endpoint (latency-floor probe)
  primes-python       compute-bound: count primes below 10^7 (vectorized
                      sieve on the VPU instead of a Python loop — the TPU/
                      JAX-native equivalent)
  image-processing    reads an image object from the store; flip/rotate/
                      grayscale/filter/resize in jnp
  sentiment-analysis  tiny transformer forward (reduced qwen3) + 2-class head
  json-loads          I/O-bound: reads a 1000x3 coordinate object, averages

``real_fn`` callables actually execute (jitted) on the host CPU; the
ExecutionModel measures them once and scales by platform speed.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import FunctionSpec, SLO


# ---------------------------------------------------------------------------
# real JAX bodies
# ---------------------------------------------------------------------------


@jax.jit
def _nodeinfo_body():
    return jnp.asarray([jax.device_count(), 1, 0], jnp.int32)


@functools.partial(jax.jit, static_argnums=0)
def _primes_body(n: int = 1_000_000):
    """Vectorized sieve: mark multiples via division tests on the VPU."""
    xs = jnp.arange(2, n, dtype=jnp.int32)
    limit = int(np.sqrt(n)) + 1
    divs = jnp.arange(2, limit, dtype=jnp.int32)
    divisible = (xs[None, :] % divs[:, None]) == 0
    not_self = xs[None, :] != divs[:, None]
    composite = jnp.any(divisible & not_self, axis=0)
    return jnp.sum(~composite)


@jax.jit
def _image_body(img: jax.Array):
    """flip, rotate, filter(blur), grayscale, resize — paper Table 2."""
    img = img.astype(jnp.float32)
    flipped = img[:, ::-1]
    rotated = jnp.rot90(flipped)
    kernel = jnp.ones((3, 3), jnp.float32) / 9.0
    blurred = jax.scipy.signal.convolve2d(
        rotated.mean(-1), kernel, mode="same")
    gray = blurred
    small = jax.image.resize(gray, (gray.shape[0] // 2, gray.shape[1] // 2),
                             "bilinear")
    return jnp.mean(small)


@jax.jit
def _json_loads_body(coords: jax.Array):
    return jnp.mean(coords, axis=0)


def _sentiment_fns():
    from repro.configs.registry import get_config
    from repro.models import model_api as api
    cfg = get_config("qwen3-0.6b").reduced().replace(num_layers=2)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def body(token_ids: jax.Array):
        from repro.models import transformer as tfm
        emb = jnp.take(params["embed"], token_ids[None], axis=0)
        h, _, _ = tfm.forward_hidden(cfg, params, emb)
        return jax.nn.softmax(h[:, -1, :2])

    return body


# ---------------------------------------------------------------------------
# FunctionSpecs (analytic demands sized from the paper's workloads)
# ---------------------------------------------------------------------------


def paper_functions(image_key: str = "images/sample.jpg",
                    json_key: str = "json/coords.json"
                    ) -> Dict[str, FunctionSpec]:
    sentiment = _sentiment_fns()
    return {
        "nodeinfo": FunctionSpec(
            name="nodeinfo", flops=1e6, memory_mb=128, runtime="nodejs",
            real_fn=lambda *a: _nodeinfo_body().block_until_ready(),
            slo=SLO(2.0)),
        "primes-python": FunctionSpec(
            name="primes-python", flops=6e9, memory_mb=256,
            real_fn=lambda *a: _primes_body(400_000).block_until_ready(),
            slo=SLO(20.0)),
        "image-processing": FunctionSpec(
            name="image-processing", flops=2e8, read_bytes=2e6,
            memory_mb=256, data_objects=(image_key,),
            real_fn=lambda img=None, *a: _image_body(
                img if img is not None
                else jnp.ones((256, 256, 3))).block_until_ready(),
            slo=SLO(5.0)),
        "sentiment-analysis": FunctionSpec(
            name="sentiment-analysis", flops=8e8, memory_mb=512,
            real_fn=lambda *a: sentiment(
                jnp.arange(64, dtype=jnp.int32)).block_until_ready(),
            slo=SLO(10.0)),
        "JSON-loads": FunctionSpec(
            name="JSON-loads", flops=1e7, read_bytes=1e5, memory_mb=256,
            data_objects=(json_key,),
            real_fn=lambda coords=None, *a: _json_loads_body(
                coords if coords is not None
                else jnp.ones((1000, 3))).block_until_ready(),
            slo=SLO(7.0)),
    }


def serving_function(arch: str, kind: str = "decode",
                     tokens_per_req: int = 64) -> FunctionSpec:
    """An ML-serving 'function': one batched decode/prefill call of `arch`.

    FLOPs demand comes from the analytic model (2*N_active per token served
    for decode); weights are a data object whose locality drives cold-start
    and placement (§5.1.4 adapted to weight placement).
    """
    from repro.configs.registry import get_config
    cfg = get_config(arch)
    n_active = cfg.n_active_params()
    flops = 2.0 * n_active * tokens_per_req
    weight_bytes = 2.0 * cfg.n_params()
    return FunctionSpec(
        name=f"serve-{arch}", flops=flops, read_bytes=0.0,
        memory_mb=int(weight_bytes / 1e6) + 256,
        data_objects=(f"weights/{arch}",), arch=arch, kind="serve",
        slo=SLO(p90_response_s=2.0))


def seed_object_stores(placement, image_key="images/sample.jpg",
                       json_key="json/coords.json", location="local"):
    rng = np.random.default_rng(0)
    if location not in placement.stores:
        placement.add_store(location)
    st = placement.stores[location]
    st.put(image_key, 2e6, jnp.asarray(
        rng.integers(0, 255, (256, 256, 3)), jnp.uint8))
    st.put(json_key, 1e5, jnp.asarray(
        rng.normal(size=(1000, 3)), jnp.float32))
