"""Adaptive data management (paper §3.1.3 Data Placement + §5.1.4):
object stores with locality, distributed data caching, proactive
migration/staging, and access instrumentation feeding the DataAccessModel.

In the TPU adaptation the same machinery also places *weights* and *KV
caches*: a model's weights are just a (large) object whose locality decides
cold-start cost on a platform.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.behavioral import DataAccessModel


class ObjectStore:
    """One MinIO-like store at a location (platform name or region)."""

    def __init__(self, location: str, capacity_bytes: float = 1e12):
        self.location = location
        self.capacity = capacity_bytes
        self.objects: Dict[str, float] = {}      # key -> size bytes
        self.payloads: Dict[str, object] = {}    # optional real payloads
        self._used = 0.0                         # running byte total

    def put(self, key: str, size: float, payload: object = None):
        old = self.objects.get(key)
        if old is not None:
            self._used -= old
        self.objects[key] = size
        self._used += size
        if payload is not None:
            self.payloads[key] = payload

    def remove(self, key: str):
        size = self.objects.pop(key, None)
        if size is not None:
            self._used -= size
        self.payloads.pop(key, None)

    def has(self, key: str) -> bool:
        return key in self.objects

    def used(self) -> float:
        return self._used


class LRUCache:
    """Distributed data cache layer in front of the stores (§3.1.3 (1))."""

    def __init__(self, capacity_bytes: float):
        self.capacity = capacity_bytes
        self._items: "OrderedDict[str, float]" = OrderedDict()
        self._used = 0.0                         # running byte total

    def get(self, key: str) -> bool:
        if key in self._items:
            self._items.move_to_end(key)
            return True
        return False

    def put(self, key: str, size: float):
        if size > self.capacity:
            return
        old = self._items.pop(key, None)
        if old is not None:
            self._used -= old
        self._items[key] = size
        self._used += size
        while self._used > self.capacity:
            _, evicted = self._items.popitem(last=False)
            self._used -= evicted

    def used(self) -> float:
        return self._used


class DataPlacementManager:
    """Tracks object locations, computes access costs, migrates/stages.

    ``bw[(a, b)]`` is bytes/s between locations (Infiniband vs WAN — the
    paper's bandwidth-heterogeneity point); same-location access uses the
    store's local bandwidth.
    """

    def __init__(self, local_bw: float = 10e9, wan_bw: float = 50e6,
                 cache_enabled: bool = False):
        # Distributed data caching is an FDN *feature* (§3.1.3); it stays
        # OFF by default so baseline reproductions measure raw locality.
        self.cache_enabled = cache_enabled
        self.stores: Dict[str, ObjectStore] = {}
        self.caches: Dict[str, LRUCache] = {}
        self.bw: Dict[Tuple[str, str], float] = {}
        self.local_bw = local_bw
        self.wan_bw = wan_bw
        self.access_model = DataAccessModel()
        self.migrations: int = 0
        self.bytes_migrated: float = 0.0

    # ------------------------------------------------------------ setup ---
    def add_store(self, location: str, capacity: float = 1e12,
                  cache_bytes: float = 1e9) -> ObjectStore:
        st = ObjectStore(location, capacity)
        self.stores[location] = st
        self.caches[location] = LRUCache(cache_bytes)
        return st

    def set_bandwidth(self, a: str, b: str, bytes_per_s: float):
        self.bw[(a, b)] = bytes_per_s
        self.bw[(b, a)] = bytes_per_s

    def _bw(self, a: str, b: str) -> float:
        if a == b:
            return self.local_bw
        return self.bw.get((a, b), self.wan_bw)

    def bandwidth_matrix(self, locations: Sequence[str]) -> np.ndarray:
        """(P, P) bytes/s between ``locations`` (diagonal: local bandwidth).
        The chain planner inverts this into a seconds-per-byte transfer-cost
        matrix, so inter-platform data gravity becomes one array op."""
        names = list(locations)
        n = len(names)
        m = np.empty((n, n))
        for i, a in enumerate(names):
            for j, b in enumerate(names):
                m[i, j] = self._bw(a, b)
        return m

    def transfer_seconds(self, size: float, src: str, dst: str) -> float:
        """Seconds to move ``size`` bytes from ``src`` to ``dst``."""
        return size / self._bw(src, dst)

    # ----------------------------------------------------------- access ---
    def locate(self, key: str, origin: Optional[str] = None) -> \
            Optional[str]:
        """Location of a replica of ``key``; with ``origin`` given, the
        *nearest* replica (highest bandwidth from ``origin``, the origin's
        own store first).  Ties break on store-registration order."""
        locs = [loc for loc, st in self.stores.items() if st.has(key)]
        if not locs:
            return None
        if origin is None:
            return locs[0]
        if origin in locs:
            return origin
        return max(locs, key=lambda l: self._bw(origin, l))

    def locations(self, key: str) -> Set[str]:
        return {loc for loc, st in self.stores.items() if st.has(key)}

    def access_time(self, key: str, from_loc: str) -> float:
        """Seconds to read `key` from a function running at `from_loc`."""
        locs = self.locations(key)
        if not locs:
            return 0.0
        size = max(self.stores[next(iter(locs))].objects[key], 1.0)
        if from_loc in locs:
            return size / self.local_bw
        cache = self.caches.get(from_loc) if self.cache_enabled else None
        if cache is not None and cache.get(key):
            return size / self.local_bw          # cache hit == local
        best = min(locs, key=lambda l: size / self._bw(from_loc, l))
        t = size / self._bw(from_loc, best)
        if cache is not None:                    # write-through cache
            cache.put(key, size)
        return t

    def record_access(self, fn: str, key: str, write: bool = False,
                      count: int = 1):
        """Instrument ``count`` accesses at once (a drained burst makes
        one call per (fn, object) instead of one per invocation)."""
        if write:
            self.access_model.record_write(fn, key, count)
        else:
            self.access_model.record_read(fn, key, count)

    # -------------------------------------------------------- migration ---
    def migrate(self, key: str, to_loc: str):
        """Replicate ``key`` into ``to_loc``'s store, copying from the
        nearest existing replica (no-op if already local)."""
        src = self.locate(key, origin=to_loc)
        if src is None or src == to_loc or to_loc not in self.stores:
            return
        size = self.stores[src].objects[key]
        payload = self.stores[src].payloads.get(key)
        self.stores[to_loc].put(key, size, payload)
        self.migrations += 1
        self.bytes_migrated += size

    def stage_for(self, fn_name: str, objects, to_loc: str):
        """Proactive staging (§3.1.3 (2)) ahead of repeated executions."""
        for key in objects:
            self.migrate(key, to_loc)

    def payload(self, key: str):
        for st in self.stores.values():
            if key in st.payloads:
                return st.payloads[key]
        return None
