"""Core FDN datatypes: functions, invocations, SLOs, platform profiles,
deployment specifications.

Terminology follows the paper: a *function* is deployed onto one or more
*target platforms* (homogeneous cluster + FaaS platform); an *invocation* is
one request; the FDN *delivers* each invocation to the right platform.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

_inv_counter = itertools.count()


@dataclass(frozen=True)
class SLO:
    """Service Level Objective (paper §5.1: P90 response time)."""
    p90_response_s: float = 7.0
    max_error_rate: float = 0.01


@dataclass(frozen=True)
class FunctionSpec:
    """A deployable function: a JAX workload plus its resource demands.

    ``flops``/``read_bytes``/``write_bytes`` describe one invocation;
    ``memory_mb`` is the per-replica footprint; ``data_objects`` the object
    store keys read (drives data-locality scheduling, §5.1.4).
    """
    name: str
    flops: float = 1e6
    read_bytes: float = 0.0
    write_bytes: float = 0.0
    memory_mb: int = 256
    runtime: str = "python3"
    data_objects: Tuple[str, ...] = ()
    # Optional real JAX callable: (object_store_payloads) -> result.
    real_fn: Optional[Callable[..., Any]] = None
    # ML-serving functions: which arch config this function serves.
    arch: Optional[str] = None
    kind: str = "generic"            # generic | serve | train
    slo: SLO = SLO()

    def replace(self, **kw) -> "FunctionSpec":
        return dataclasses.replace(self, **kw)


class Invocation:
    """One request, with its full lifecycle for metric derivation."""

    __slots__ = ("id", "fn", "arrival_t", "vu", "args", "platform",
                 "scheduled_t", "start_t", "end_t", "status", "cold_start",
                 "exec_time", "data_time", "queue_time", "hedged_from",
                 "attempts", "arrival_recorded", "qos", "tenant",
                 "decision", "_on_done")

    def __init__(self, fn: FunctionSpec, arrival_t: float, vu: int = 0,
                 args: Any = None, qos: int = 1, tenant: int = 0):
        self.id = next(_inv_counter)
        self.fn = fn
        self.arrival_t = arrival_t
        self.vu = vu
        self.args = args
        # QoS class (repro.core.qos ids; 1 == standard) and tenant —
        # literal defaults keep this module import-independent of qos
        self.qos = qos
        self.tenant = tenant
        self.platform: Optional[str] = None
        self.scheduled_t: Optional[float] = None
        self.start_t: Optional[float] = None
        self.end_t: Optional[float] = None
        self.status = "pending"       # pending|queued|running|done|failed
        self.cold_start = False
        self.exec_time = 0.0
        self.data_time = 0.0
        self.queue_time = 0.0
        self.hedged_from: Optional[int] = None
        self.attempts = 0
        # decision-journal row id that routed this invocation (-1 when
        # provenance is off or the row bypassed the journaled fast path:
        # overrides, spillover, hedges, stateful policies)
        self.decision = -1
        # arrival recorded in the behavioral models exactly once, even if
        # the invocation is redelivered through submit() again
        self.arrival_recorded = False
        self._on_done: Optional[Callable[[], None]] = None

    @property
    def response_time(self) -> Optional[float]:
        if self.end_t is None:
            return None
        return self.end_t - self.arrival_t

    def __repr__(self):
        return (f"<Inv {self.id} {self.fn.name} @{self.arrival_t:.2f} "
                f"{self.status} on {self.platform}>")


@dataclass(frozen=True)
class PlatformProfile:
    """Hardware + FaaS-platform profile of one target platform.

    The paper's five CPU platforms and this framework's TPU pod profiles are
    both expressed with this type; compute speed enters through
    ``replica_flops`` (per-replica effective FLOP/s) and the roofline terms
    through ``peak_flops``/``hbm_bw``/``link_bw`` for pod-scale functions.
    """
    name: str
    faas: str                         # openwhisk | openfaas | gcf | tinyfaas
    nodes: int = 1
    replicas_per_node: int = 4        # concurrency slots (cores / chips)
    memory_mb_per_node: int = 8192
    replica_flops: float = 2e9        # effective FLOP/s per busy replica
    net_bw: float = 1e9               # bytes/s to/from object stores
    # pod-scale terms (TPU platforms; CPU platforms keep defaults)
    chips: int = 0
    peak_flops: float = 0.0
    hbm_bw: float = 0.0
    link_bw: float = 0.0
    # power model: P = idle + (loaded - idle) * utilization  (per node)
    idle_w_per_node: float = 5.0
    loaded_w_per_node: float = 20.0
    # keep-alive watts per *idle* warm replica (container resident in
    # memory): the energy price of avoiding cold starts.  0 keeps the
    # historical accounting (idle pools are free) for platforms that do
    # not opt in; the autoscale scenarios set it explicitly.
    warm_w_per_replica: float = 0.0
    # FaaS semantics
    overhead_s: float = 0.05          # gateway/controller/watchdog per req
    cold_start_s: float = 2.0
    prewarm_pool: int = 0             # openwhisk prewarm containers
    scale_to_zero_s: float = 120.0    # faas-idler inactivity window
    elastic: bool = False             # gcf-style unbounded replicas
    infra_metrics_visible: bool = True
    arm: bool = False                 # edge platforms: need ARM images
    region: str = "local"

    @property
    def total_replicas(self) -> int:
        return self.nodes * self.replicas_per_node

    @property
    def total_memory_mb(self) -> int:
        return self.nodes * self.memory_mb_per_node


@dataclass
class DeploymentSpec:
    """User-provided configuration specification (paper Fig. 3/Listing 1),
    annotated by the DeploymentGenerator."""
    test_name: str
    functions: List[FunctionSpec]
    target_platforms: List[str]
    test_instances: Dict[str, Dict] = field(default_factory=dict)
    annotations: Dict[str, Dict] = field(default_factory=dict)
