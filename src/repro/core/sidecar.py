"""Sidecar Controller (paper §3.2): the local half of the hierarchical
scheduling decision.

The control plane picks the *target platform*; the platform-local sidecar
(a) picks the node/replica (least-loaded first), and (b) for locally
triggered invocations decides whether to run locally or delegate up to the
control plane (when the local platform is under pressure or predicted to
violate the SLO).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

from repro.core.behavioral import FunctionPerformanceModel
from repro.core.platform import TargetPlatform
from repro.core.types import Invocation


class SidecarController:
    def __init__(self, platform: TargetPlatform,
                 perf: Optional[FunctionPerformanceModel] = None,
                 cpu_threshold: float = 0.95):
        self.platform = platform
        self.perf = perf
        self.cpu_threshold = cpu_threshold
        self.delegated = 0
        self.local = 0

    # node selection inside the platform --------------------------------
    def admit(self, inv: Invocation):
        """Control-plane-routed invocation: place onto this platform.

        Node choice is folded into the platform's replica picker (warm
        replicas first == least cold-start node); the sidecar records the
        decision for the knowledge base.
        """
        self.platform.invoke(inv)

    def admit_many(self, invs: Sequence[Invocation]):
        """Batched admission from the control plane's ``submit_batch``:
        the platform enqueues the whole group and drains once, instead of
        paying a full queue drain + metrics sample per invocation."""
        self.platform.invoke_batch(invs)

    def admit_columns(self, batch, idxs):
        """Columnar admission (``_submit_columns``): the platform queues
        the (batch, index-group) pair directly; ``Invocation`` objects
        appear only when the drain actually starts a row."""
        self.platform.invoke_columns(batch, idxs)

    # local trigger path -------------------------------------------------
    def _pressured(self) -> bool:
        p = self.platform
        return (p.failed or p.cpu_util() >= self.cpu_threshold
                or p.mem_util() >= 1.0)

    def _slo_risk(self, fn) -> bool:
        return (self.perf is not None and
                self.perf.predict_p90_response(fn, self.platform.prof)
                > fn.slo.p90_response_s)

    def handle_local_trigger(self, inv: Invocation,
                             delegate: Callable[[Invocation], None]):
        """§3.2: run locally unless pressure/SLO says delegate upward."""
        p = self.platform
        pressured = self._pressured()
        slo_risk = not pressured and self._slo_risk(inv.fn)
        if pressured or slo_risk or inv.fn.name not in p.deployed:
            self.delegated += 1
            delegate(inv)
        else:
            self.local += 1
            p.invoke(inv)

    def handle_local_triggers(self, invs: Sequence[Invocation],
                              delegate_batch: Callable[
                                  [Sequence[Invocation]], None]):
        """Batched §3.2 decision for a burst of locally triggered
        invocations: platform pressure is sampled once, SLO risk once per
        distinct function, and the burst splits into one local
        ``invoke_batch`` plus one upward ``delegate_batch`` — the local-
        trigger mirror of the control plane's grouped admission."""
        if not invs:
            return
        p = self.platform
        pressured = self._pressured()
        local: List[Invocation] = []
        delegated: List[Invocation] = []
        risk_by_fn: Dict[int, bool] = {}
        for inv in invs:
            fn = inv.fn
            if pressured or fn.name not in p.deployed:
                delegated.append(inv)
                continue
            risk = risk_by_fn.get(id(fn))
            if risk is None:
                risk = self._slo_risk(fn)
                risk_by_fn[id(fn)] = risk
            (delegated if risk else local).append(inv)
        self.delegated += len(delegated)
        self.local += len(local)
        if local:
            p.invoke_batch(local)
        if delegated:
            delegate_batch(delegated)
