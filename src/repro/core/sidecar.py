"""Sidecar Controller (paper §3.2): the local half of the hierarchical
scheduling decision.

The control plane picks the *target platform*; the platform-local sidecar
(a) picks the node/replica (least-loaded first), and (b) for locally
triggered invocations decides whether to run locally or delegate up to the
control plane (when the local platform is under pressure or predicted to
violate the SLO).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.core.behavioral import FunctionPerformanceModel
from repro.core.platform import TargetPlatform
from repro.core.types import Invocation


class SidecarController:
    def __init__(self, platform: TargetPlatform,
                 perf: Optional[FunctionPerformanceModel] = None,
                 cpu_threshold: float = 0.95):
        self.platform = platform
        self.perf = perf
        self.cpu_threshold = cpu_threshold
        self.delegated = 0
        self.local = 0

    # node selection inside the platform --------------------------------
    def admit(self, inv: Invocation):
        """Control-plane-routed invocation: place onto this platform.

        Node choice is folded into the platform's replica picker (warm
        replicas first == least cold-start node); the sidecar records the
        decision for the knowledge base.
        """
        self.platform.invoke(inv)

    def admit_many(self, invs: Sequence[Invocation]):
        """Batched admission from the control plane's ``submit_batch``:
        the platform enqueues the whole group and drains once, instead of
        paying a full queue drain + metrics sample per invocation."""
        self.platform.invoke_batch(invs)

    # local trigger path -------------------------------------------------
    def handle_local_trigger(self, inv: Invocation,
                             delegate: Callable[[Invocation], None]):
        """§3.2: run locally unless pressure/SLO says delegate upward."""
        p = self.platform
        pressured = (p.failed or p.cpu_util() >= self.cpu_threshold
                     or p.mem_util() >= 1.0)
        slo_risk = False
        if self.perf is not None and not pressured:
            slo_risk = (self.perf.predict_p90_response(inv.fn, p.prof)
                        > inv.fn.slo.p90_response_s)
        if pressured or slo_risk or inv.fn.name not in p.deployed:
            self.delegated += 1
            delegate(inv)
        else:
            self.local += 1
            p.invoke(inv)
