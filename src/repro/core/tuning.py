"""External components (paper §3.6): Threshold Tuning and the function-
composition optimizer (§6.3).

ThresholdTuner replays historic load (via a caller-supplied evaluation
closure, usually an FDNInspector run on the sim clock) across a grid of
scheduler thresholds and returns the SLO-best setting — offline tuning of
the FDN from Knowledge-Base history, exactly the role the paper assigns to
this component.

compose_functions folds producer->consumer chains (detected by the
InteractionModel) into a single composed function, removing the
inter-function transition (the "double spending" cost of §6.3).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.behavioral import InteractionModel
from repro.core.types import FunctionSpec, SLO


@dataclass
class TuningResult:
    best: Dict[str, float]
    score: float
    trials: List[Tuple[Dict[str, float], float]]


class ThresholdTuner:
    """Grid-search scheduler thresholds against a replayable evaluation.

    ``evaluate(thresholds) -> score`` should run a (simulated) workload
    with an SLOCompositePolicy configured from `thresholds` and return a
    quality score (higher better), e.g. fraction of SLO-met requests minus
    an energy penalty.
    """

    def __init__(self, grid: Optional[Dict[str, Sequence[float]]] = None):
        self.grid = grid or {
            "cpu_threshold": (0.7, 0.8, 0.9, 0.95),
            "mem_threshold": (0.8, 0.9, 0.95),
            "energy_weight": (0.0, 0.1, 0.5),
        }

    def tune(self, evaluate: Callable[[Dict[str, float]], float]
             ) -> TuningResult:
        keys = sorted(self.grid)
        trials: List[Tuple[Dict[str, float], float]] = []
        best, best_score = None, float("-inf")
        for combo in itertools.product(*(self.grid[k] for k in keys)):
            thresholds = dict(zip(keys, combo))
            score = evaluate(thresholds)
            trials.append((thresholds, score))
            if score > best_score:
                best, best_score = thresholds, score
        return TuningResult(best or {}, best_score, trials)


def compose_functions(a: FunctionSpec, b: FunctionSpec,
                      transition_overhead_s: float = 0.0) -> FunctionSpec:
    """Compose a->b into one function (paper §6.3).

    The composed function's demands are the sums; intermediate-result I/O
    between members disappears (b's reads of a's writes become in-memory),
    and the platform charges one invocation instead of two — the paper's
    cost argument for composition.
    """
    internal = min(a.write_bytes, b.read_bytes)
    real_fn = None
    if a.real_fn is not None and b.real_fn is not None:
        def real_fn(*args, _a=a.real_fn, _b=b.real_fn):
            return _b(_a(*args))
    return FunctionSpec(
        name=f"{a.name}+{b.name}",
        flops=a.flops + b.flops,
        read_bytes=a.read_bytes + max(b.read_bytes - internal, 0.0),
        write_bytes=max(a.write_bytes - internal, 0.0) + b.write_bytes,
        memory_mb=max(a.memory_mb, b.memory_mb),
        runtime=a.runtime,
        data_objects=tuple(dict.fromkeys(a.data_objects + b.data_objects)),
        real_fn=real_fn,
        slo=SLO(min(a.slo.p90_response_s, b.slo.p90_response_s)),
    )


def composition_plan(im: InteractionModel, fns: Dict[str, FunctionSpec],
                     min_count: int = 10) -> List[FunctionSpec]:
    """Fold every hot producer->consumer edge into a composed function."""
    out = []
    for src, dst in im.compose_candidates(min_count):
        if src in fns and dst in fns:
            out.append(compose_functions(fns[src], fns[dst]))
    return out
