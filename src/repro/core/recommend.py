"""Recommendation & Visualization (paper §3.6): explains FDN runtime
decisions to the user and recommends deployment configurations from the
Knowledge Base + behavioral models.

Everything renders to plain markdown/ASCII (the paper's Grafana dashboards,
minus the browser)."""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional

from repro.core.behavioral import FunctionPerformanceModel
from repro.core.knowledge_base import KnowledgeBase
from repro.core.monitoring import MetricsRegistry
from repro.core.types import FunctionSpec, PlatformProfile


def _bar(frac: float, width: int = 30) -> str:
    n = max(0, min(width, round(frac * width)))
    return "#" * n + "." * (width - n)


class Recommender:
    def __init__(self, kb: KnowledgeBase, perf: FunctionPerformanceModel,
                 metrics: MetricsRegistry):
        self.kb = kb
        self.perf = perf
        self.metrics = metrics

    # ----------------------------------------------------------- advice ---
    def recommend(self, fn: FunctionSpec,
                  profiles: List[PlatformProfile]) -> Dict[str, object]:
        """Per-function advice: best platform for latency, for energy, and
        whether the two disagree (the paper's SLO-vs-energy trade-off)."""
        lat = {p.name: self.perf.predict_exec(fn, p) for p in profiles}
        eng = {p.name: self.perf.predict_energy(fn, p) for p in profiles}
        feasible = [p for p in profiles
                    if p.total_memory_mb >= fn.memory_mb]
        if not feasible:
            return {"function": fn.name, "error": "fits nowhere"}
        best_lat = min(feasible, key=lambda p: lat[p.name]).name
        best_eng = min(feasible, key=lambda p: eng[p.name]).name
        hist = self.kb.best_platform(fn.name)
        return {
            "function": fn.name,
            "latency_best": best_lat,
            "energy_best": best_eng,
            "tradeoff": best_lat != best_eng,
            "historical": hist,
            "predicted_exec_s": {k: round(v, 4) for k, v in lat.items()},
            "predicted_energy_j": {k: round(v, 3) for k, v in eng.items()},
        }

    # ------------------------------------------------------ explanations --
    def explain_decisions(self, fn_name: Optional[str] = None) -> str:
        """Markdown: where did the FDN send each function, and why."""
        by_fn: Dict[str, Dict[str, int]] = defaultdict(
            lambda: defaultdict(int))
        for d in self.kb.decisions:
            if fn_name and d["fn"] != fn_name:
                continue
            by_fn[d["fn"]][d["platform"]] += 1
        lines = ["| function | platform | share |", "|---|---|---|"]
        for fn, plats in sorted(by_fn.items()):
            total = sum(plats.values())
            for p, n in sorted(plats.items(), key=lambda kv: -kv[1]):
                lines.append(f"| {fn} | {p} | {_bar(n / total, 16)} "
                             f"{100 * n / total:.0f}% |")
        return "\n".join(lines)

    def platform_report(self, platforms: List[str]) -> str:
        """ASCII utilization/latency overview per platform."""
        lines = []
        for p in platforms:
            served = self.metrics.requests_served(p)
            p90 = self.metrics.p90_response(p)
            lines.append(f"{p:>22s} served={served:7d} "
                         f"p90={p90 if p90 == p90 else 0:7.3f}s")
        return "\n".join(lines)
