"""Fault tolerance (paper §3.1.3 "Fault Tolerance"): failure detection,
re-delivery to another platform, hedged requests for stragglers, and
platform ejection / elastic re-admission.

  * FailureDetector — heartbeat-based with a phi-accrual-style suspicion
    score; platforms that miss heartbeats are ejected from scheduling.
  * Redeliverer    — failed/lost invocations are retried on the next-best
    platform (at-least-once delivery with bounded attempts).
  * HedgePolicy    — straggler mitigation: if an invocation has not
    completed within k x predicted P90, a speculative duplicate is sent to
    the second-best platform; first completion wins.
"""
from __future__ import annotations

import math
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

from repro.core.behavioral import FunctionPerformanceModel
from repro.core.platform import TargetPlatform
from repro.core.simulator import SimClock
from repro.core.types import Invocation


class FailureDetector:
    """Phi-accrual-lite: suspicion grows with missed heartbeat intervals."""

    def __init__(self, clock: SimClock, interval_s: float = 5.0,
                 phi_threshold: float = 3.0):
        self.clock = clock
        self.interval = interval_s
        self.phi_threshold = phi_threshold
        self.last_beat: Dict[str, float] = {}
        self.ejected: Dict[str, bool] = defaultdict(bool)
        self.on_eject: List[Callable[[str], None]] = []
        self.on_recover: List[Callable[[str], None]] = []

    def heartbeat(self, platform: str):
        self.last_beat[platform] = self.clock.now()
        if self.ejected[platform]:
            self.ejected[platform] = False
            for cb in self.on_recover:
                cb(platform)

    def phi(self, platform: str) -> float:
        last = self.last_beat.get(platform)
        if last is None:
            return 0.0
        return (self.clock.now() - last) / self.interval

    def check(self, platform: str) -> bool:
        """True if the platform is considered alive."""
        if self.phi(platform) > self.phi_threshold:
            if not self.ejected[platform]:
                self.ejected[platform] = True
                for cb in self.on_eject:
                    cb(platform)
            return False
        return True


class Redeliverer:
    """At-least-once delivery with bounded attempts across platforms."""

    def __init__(self, max_attempts: int = 3):
        self.max_attempts = max_attempts
        self.redelivered = 0
        self.exhausted: List[Invocation] = []

    def handle_failure(self, inv: Invocation,
                       resubmit: Callable[[Invocation], None]):
        inv.attempts += 1
        if inv.attempts >= self.max_attempts:
            self.exhausted.append(inv)
            return
        inv.status = "pending"
        inv.platform = None
        inv.end_t = None
        self.redelivered += 1
        resubmit(inv)


class HedgePolicy:
    """Speculative duplicates after k x predicted P90 (straggler cut).

    Two watch granularities:
      * ``watch``       — one timer per invocation (the scalar path);
      * ``watch_group`` — ONE timer per (fn, platform) admission group: a
        burst of 10^4 admissions arms a handful of timers instead of 10^4,
        and the still-pending stragglers are duplicated and re-admitted as
        a single batch.  Equivalent to per-invocation watchers (same
        budget, same fire instant — every member of an admission group
        shares arrival time, function and platform).

    Group timers are *cancellable*: every armed group registers its
    members in a timer index, completions tick the group's pending count
    down, and when the last member finishes before the hedge budget the
    timer is dropped from the clock (the closure and its captured batch
    are freed immediately) instead of firing as a no-op.  Under sustained
    bursts that keeps the live-timer count proportional to the number of
    *straggling* groups, not the number of admitted groups.

    ``on_duplicate`` callbacks fire for every speculative duplicate
    created — the chain executor uses this to let a winning duplicate
    complete its stage.
    """

    def __init__(self, clock: SimClock, perf: FunctionPerformanceModel,
                 k: float = 2.0, enabled: bool = True):
        self.clock = clock
        self.perf = perf
        self.k = k
        self.enabled = enabled
        self.hedges_sent = 0
        self.hedges_won = 0
        self.group_timers_armed = 0
        self.group_timers_cancelled = 0
        self._live_groups = 0
        self._done: Dict[int, bool] = {}
        # cancellable group-timer index: inv.id -> its group's shared
        # record [pending_count, member_ids, TimerHandle]
        self._groups: Dict[int, list] = {}
        self.on_duplicate: List[Callable[[Invocation, Invocation],
                                         None]] = []

    def live_group_timers(self) -> int:
        """Armed group timers that have neither fired nor been cancelled
        (== groups with at least one still-pending member)."""
        return self._live_groups

    def _budget(self, fn, platform: TargetPlatform) -> Optional[float]:
        """Hedge delay, or None while the model lacks real latency
        observations — otherwise analytic estimates under cold starts
        cause hedge storms."""
        obs = self.perf.resp_p90.get((fn.name, platform.prof.name))
        if obs is None or obs.count < 10:
            return None
        return self.k * max(
            self.perf.predict_p90_response(fn, platform.prof), 1e-3)

    def _make_dup(self, inv: Invocation) -> Invocation:
        dup = Invocation(inv.fn, self.clock.now(), vu=inv.vu,
                         args=inv.args)
        dup.hedged_from = inv.id
        self.hedges_sent += 1
        for cb in self.on_duplicate:
            cb(inv, dup)
        return dup

    def watch(self, inv: Invocation, platform: TargetPlatform,
              alternates: List[TargetPlatform],
              submit: Callable[[Invocation, TargetPlatform], None]):
        if not self.enabled or not alternates:
            return
        budget = self._budget(inv.fn, platform)
        if budget is None:
            return
        self._done[inv.id] = False

        def maybe_hedge():
            if self._done.get(inv.id) or inv.status == "done":
                self._done.pop(inv.id, None)
                return
            submit(self._make_dup(inv), alternates[0])

        self.clock.after(budget, maybe_hedge)

    def watch_group(self, invs: List[Invocation],
                    platform: TargetPlatform,
                    alternates: List[TargetPlatform],
                    submit_many: Callable[[List[Invocation],
                                           TargetPlatform], None]):
        """One vectorized hedge timer for a whole (fn, platform) admission
        group; stragglers are duplicated in admission order and batch-
        submitted to the best alternate.  The timer is indexed by member:
        when every member completes before the budget it is cancelled and
        dropped from the clock instead of firing as a no-op."""
        if not self.enabled or not alternates or not invs:
            return
        budget = self._budget(invs[0].fn, platform)
        if budget is None:
            return
        member_ids = [inv.id for inv in invs]
        group = [len(invs), member_ids, None]
        groups = self._groups

        def maybe_hedge_group():
            self._live_groups -= 1
            dups = []
            for inv in invs:
                groups.pop(inv.id, None)
                if inv.status == "done":
                    continue
                dups.append(self._make_dup(inv))
            if dups:
                submit_many(dups, alternates[0])

        group[2] = self.clock.after_cancellable(budget, maybe_hedge_group)
        for iid in member_ids:
            groups[iid] = group
        self.group_timers_armed += 1
        self._live_groups += 1

    def completed(self, inv: Invocation):
        if inv.hedged_from is not None:
            self.hedges_won += 1
        # only flip invocations a per-invocation watcher registered —
        # unconditional inserts would grow the dict by one entry per
        # completion forever (group timers use the cancellable index)
        if inv.id in self._done:
            self._done[inv.id] = True
        group = self._groups.pop(inv.id, None)
        if group is not None:
            group[0] -= 1
            if group[0] <= 0:            # last member: drop the timer
                group[2].cancel()
                self.group_timers_cancelled += 1
                self._live_groups -= 1
                for iid in group[1]:
                    self._groups.pop(iid, None)
