"""Energy accounting (paper §5.2, Table 4).

Per-platform power model: P(t) = nodes * (idle + (loaded - idle) * util(t))
plus a warm-pool keep-alive term: every *idle* warm replica burns
``warm_w_per_replica`` watts (container resident in memory, runtime pinned
— the idle-watt side of the cold-start/energy trade-off the autoscaler
navigates; 0 by default, so platforms without a configured keep-alive cost
are unchanged).  The meter integrates piecewise-constant utilization and
idle-pool size on the sim clock, so ``joules(platform)`` reproduces the
paper's "average power x duration" measurements (RAPL on the HPC sockets,
POM_5V_CPU rails on the Jetsons), and ``keepalive_joules`` isolates what
the warm pools cost.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.types import PlatformProfile


class EnergyMeter:
    def __init__(self):
        self._last_t: Dict[str, float] = {}
        self._last_util: Dict[str, float] = {}
        self._last_idle: Dict[str, int] = {}
        self._joules: Dict[str, float] = defaultdict(float)
        self._busy_joules: Dict[str, float] = defaultdict(float)
        self._keepalive_joules: Dict[str, float] = defaultdict(float)
        self._profiles: Dict[str, PlatformProfile] = {}

    def register(self, prof: PlatformProfile, t: float = 0.0):
        self._profiles[prof.name] = prof
        self._last_t[prof.name] = t
        self._last_util[prof.name] = 0.0
        self._last_idle[prof.name] = 0

    def power_w(self, name: str, util: float) -> float:
        p = self._profiles[name]
        util = min(max(util, 0.0), 1.0)
        return p.nodes * (p.idle_w_per_node +
                          (p.loaded_w_per_node - p.idle_w_per_node) * util)

    def update(self, name: str, t: float, util: float,
               idle_warm: Optional[int] = None):
        """Advance to time t with the utilization (and idle warm-pool
        size) held since the last update.  ``idle_warm=None`` keeps the
        previous pool size (legacy callers that only know utilization)."""
        lt = self._last_t.get(name, t)
        lu = self._last_util.get(name, 0.0)
        if t > lt:
            dt = t - lt
            self._joules[name] += self.power_w(name, lu) * dt
            dyn = self.power_w(name, lu) - self.power_w(name, 0.0)
            self._busy_joules[name] += dyn * dt
            w = self._profiles[name].warm_w_per_replica
            if w > 0.0:
                keep = w * self._last_idle.get(name, 0) * dt
                self._keepalive_joules[name] += keep
                self._joules[name] += keep
        self._last_t[name] = t
        self._last_util[name] = util
        if idle_warm is not None:
            self._last_idle[name] = idle_warm

    def joules(self, name: str) -> float:
        return self._joules[name]

    def dynamic_joules(self, name: str) -> float:
        return self._busy_joules[name]

    def keepalive_joules(self, name: str) -> float:
        """Energy spent holding idle replicas warm (idle-Wh numerator)."""
        return self._keepalive_joules[name]

    def table(self) -> List[Tuple[str, float, float, float]]:
        """(platform, idle W, loaded W, total J) rows — Table 4 shape."""
        out = []
        for name, p in self._profiles.items():
            out.append((name, p.nodes * p.idle_w_per_node,
                        p.nodes * p.loaded_w_per_node, self._joules[name]))
        return out
