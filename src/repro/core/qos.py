"""Per-tenant QoS and overload resilience (paper §SLO / §energy
objectives): the FDaaS objective is scheduling functions to *meet SLO
requirements*, which best-effort FIFO cannot do once arrival rate
exceeds capacity — someone must lose, and the operator should choose
who.  This module makes that choice explicit with three ingredients:

  * **QoS classes** — ``latency_critical`` / ``standard`` / ``batch``
    ride every invocation as an int8 column (tenant as int32), so the
    columnar admission path stays array-native.  Per-class SLO
    multipliers tighten or relax each class's effective deadline.
  * **Deficit round robin** (Shreedhar & Varghese) at each platform
    queue: classes drain in weight proportion instead of pure FIFO, so
    a batch flood cannot starve latency-critical traffic.  The drain is
    vectorized — one ``np.lexsort`` over (round, class-rank) per drain,
    with DRR state in preallocated int64 arrays — and parity-tested
    against the scalar reference below.  Weights are *integers* and
    deficits int64 on purpose: integer arithmetic makes the closed-form
    plan bit-identical to the sequential loop (repeated float addition
    rounds differently than multiplication at quantum boundaries).
  * **Admission control** at the gateway: per-class token buckets,
    load-shedding on queue-depth / telemetry burn-rate signals with a
    shed-vs-degrade-vs-spillover policy knob, and a *brownout* mode
    where an energy cap (paper §energy objective) degrades batch-class
    service first.

FIFO recovery is exact and structural: with uniform weights the
platform never builds per-class queues at all (``QosSpec.drr_enabled``
is False), so the qos-off fast paths — and their goldens — are
untouched byte for byte.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "QOS_LATENCY_CRITICAL", "QOS_STANDARD", "QOS_BATCH", "N_QOS",
    "QOS_NAMES", "DEFAULT_QOS", "DEFAULT_TENANT", "qos_id", "QosSpec",
    "drr_drain_scalar", "drr_plan", "drr_commit", "TokenBuckets",
    "AdmissionController",
]

QOS_LATENCY_CRITICAL = 0
QOS_STANDARD = 1
QOS_BATCH = 2
N_QOS = 3
QOS_NAMES = ("latency_critical", "standard", "batch")
DEFAULT_QOS = QOS_STANDARD
DEFAULT_TENANT = 0

OVERLOAD_ACTIONS = ("shed", "degrade", "spillover")


def qos_id(cls) -> int:
    """Class name or id -> id (class rank: lower drains first per round)."""
    if isinstance(cls, str):
        try:
            return QOS_NAMES.index(cls)
        except ValueError:
            raise ValueError(f"unknown QoS class {cls!r}; "
                             f"one of {QOS_NAMES}") from None
    c = int(cls)
    if not 0 <= c < N_QOS:
        raise ValueError(f"QoS class id {c} out of range 0..{N_QOS - 1}")
    return c


@dataclass(frozen=True)
class QosSpec:
    """The QoS layer's knobs, in class order (latency_critical,
    standard, batch).  ``weights`` are integer DRR quanta (rows per
    round); uniform weights disable DRR entirely — exact FIFO, zero
    hot-path cost.  ``rate_limits`` (req/s per class, None = unlimited)
    arms per-class token buckets; ``shed_queue_depth`` arms overload
    handling (batch sheds at the threshold, standard too beyond
    ``shed_hard_factor`` times it; latency_critical is never
    overload-shed); ``overload_action`` picks what "handling" means:
    drop ("shed"), demote standard to batch class ("degrade" — they
    run, deprioritized, keeping their original deadline), or reroute
    low classes to the least-loaded platform ("spillover").
    ``burn_threshold`` adds a telemetry signal: shed when the trailing
    ``burn_window_s`` error-budget burn rate (vs ``burn_slo_target``)
    crosses it.  ``energy_cap_w`` arms brownout: when fleet power
    exceeds the cap, batch-class arrivals shed first (§energy
    objective)."""

    weights: Tuple[int, ...] = (4, 2, 1)
    slo_multipliers: Tuple[float, ...] = (0.5, 1.0, 4.0)
    rate_limits: Optional[Tuple[Optional[float], ...]] = None
    burst: Tuple[float, ...] = (256.0, 256.0, 256.0)
    shed_queue_depth: Optional[float] = None
    shed_hard_factor: float = 2.0
    overload_action: str = "shed"
    burn_threshold: Optional[float] = None
    burn_window_s: float = 30.0
    burn_slo_target: float = 0.99
    signal_interval_s: float = 1.0
    energy_cap_w: Optional[float] = None

    def __post_init__(self):
        for name in ("weights", "slo_multipliers", "burst"):
            v = getattr(self, name)
            if len(v) != N_QOS:
                raise ValueError(f"{name} needs {N_QOS} entries, got {v!r}")
        if any(int(w) != w or w < 1 for w in self.weights):
            raise ValueError(f"DRR weights must be integers >= 1 "
                             f"(got {self.weights!r}): integer quanta keep "
                             f"the vectorized plan exact vs the scalar "
                             f"reference")
        object.__setattr__(self, "weights",
                           tuple(int(w) for w in self.weights))
        if self.overload_action not in OVERLOAD_ACTIONS:
            raise ValueError(f"overload_action must be one of "
                             f"{OVERLOAD_ACTIONS}, "
                             f"got {self.overload_action!r}")
        if self.rate_limits is not None and \
                len(self.rate_limits) != N_QOS:
            raise ValueError(f"rate_limits needs {N_QOS} entries")

    def uniform_weights(self) -> bool:
        return len(set(self.weights)) == 1

    def drr_enabled(self) -> bool:
        """Non-uniform weights only: uniform DRR *is* FIFO (every class
        gets one quantum per round), so the platform keeps its single
        FIFO deque — the documented exact-recovery specialization."""
        return not self.uniform_weights()

    def to_dict(self) -> Dict:
        return {
            "weights": list(self.weights),
            "slo_multipliers": list(self.slo_multipliers),
            "rate_limits": (None if self.rate_limits is None
                            else list(self.rate_limits)),
            "burst": list(self.burst),
            "shed_queue_depth": self.shed_queue_depth,
            "shed_hard_factor": self.shed_hard_factor,
            "overload_action": self.overload_action,
            "burn_threshold": self.burn_threshold,
            "burn_window_s": self.burn_window_s,
            "burn_slo_target": self.burn_slo_target,
            "signal_interval_s": self.signal_interval_s,
            "energy_cap_w": self.energy_cap_w,
        }

    @staticmethod
    def from_dict(d: Dict) -> "QosSpec":
        keys = {f for f in QosSpec.__dataclass_fields__}  # type: ignore
        kw = {k: v for k, v in d.items() if k in keys}
        for name in ("weights", "slo_multipliers", "burst", "rate_limits"):
            if kw.get(name) is not None:
                kw[name] = tuple(kw[name])
        return QosSpec(**kw)


# ------------------------------------------------------------------ DRR ---
def drr_drain_scalar(backlogs: Sequence[int], deficits: Sequence[int],
                     weights: Sequence[int], capacity: int
                     ) -> Tuple[List[int], List[int]]:
    """Reference deficit-round-robin drain: serve up to ``capacity``
    rows from per-class backlogs, visiting classes in rank order each
    round, crediting each non-empty class its weight quantum per round.
    Returns (class id per served row, final deficits).  A class that
    fully drains (or arrives empty) resets its deficit — standard DRR:
    credit does not accrue while a queue is empty.  This is the oracle
    the vectorized ``drr_plan`` / ``drr_commit`` pair is parity-tested
    against."""
    n = len(backlogs)
    rem = [int(b) for b in backlogs]
    d = [int(x) for x in deficits]
    w = [int(x) for x in weights]
    for c in range(n):
        if rem[c] == 0:
            d[c] = 0
    order: List[int] = []
    cap = int(capacity)
    while cap > 0 and any(rem):
        for c in range(n):
            if rem[c] == 0:
                continue
            d[c] += w[c]
            take = min(d[c], rem[c], cap)
            order.extend([c] * take)
            d[c] -= take
            rem[c] -= take
            cap -= take
            if rem[c] == 0:
                d[c] = 0
            if cap == 0:
                break
    return order, d


def drr_plan(backlogs: np.ndarray, deficits: np.ndarray,
             weights: np.ndarray, capacity: int
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized DRR serve order, closed form: row ``k`` (1-indexed)
    of class ``c`` is served in round ``max(1, ceil((k - d0_c)/w_c))``,
    and the global order is one stable ``np.lexsort`` keyed (round,
    class rank) — stability preserves FIFO within a class.  Only
    ``min(backlog_c, capacity + 1)`` candidate rows per class are
    planned (the +1 keeps the first *blocked* row in-plan, so a drain
    that stops early still knows where it stopped).  Returns
    (class id, round) per planned row, in serve order."""
    backlogs = np.asarray(backlogs, dtype=np.int64)
    deficits = np.asarray(deficits, dtype=np.int64)
    weights = np.asarray(weights, dtype=np.int64)
    cand = np.minimum(backlogs, capacity + 1)
    total = int(cand.sum())
    if total == 0:
        empty = np.empty(0, np.int64)
        return empty, empty
    cls = np.repeat(np.arange(len(cand), dtype=np.int64), cand)
    offs = np.cumsum(cand) - cand
    k = np.arange(1, total + 1, dtype=np.int64) - np.repeat(offs, cand)
    rounds = -(-(k - deficits[cls]) // weights[cls])
    np.maximum(rounds, 1, out=rounds)
    order = np.lexsort((cls, rounds))
    return cls[order], rounds[order]


def drr_commit(deficits: np.ndarray, weights: np.ndarray,
               backlogs: np.ndarray, served: Sequence[int],
               plan_cls: np.ndarray, plan_rounds: np.ndarray,
               n_served: int) -> np.ndarray:
    """Final deficits after serving the first ``n_served`` plan rows —
    exactly what the scalar loop would leave with capacity ==
    ``n_served``.  Credited rounds follow from the LAST SERVED row
    (round ``rb``, class ``cb``): classes ranked at-or-before ``cb``
    received their round-``rb`` quantum, later-ranked classes only
    rounds ``1..rb-1`` (the scalar loop breaks inside ``cb``'s visit
    the moment capacity hits zero, before crediting anyone after it).
    Classes that fully drained — or were empty — reset to 0."""
    deficits = np.asarray(deficits, dtype=np.int64)
    weights = np.asarray(weights, dtype=np.int64)
    backlogs = np.asarray(backlogs, dtype=np.int64)
    served = np.asarray(served, dtype=np.int64)
    new = deficits.copy()
    if n_served > 0:
        rb = int(plan_rounds[n_served - 1])
        cb = int(plan_cls[n_served - 1])
        credited = np.where(np.arange(len(new)) <= cb, rb, rb - 1)
        active = (backlogs > 0) & (served < backlogs)
        new = np.where(active,
                       deficits + credited * weights - served,
                       0).astype(np.int64)
    else:
        new[backlogs == 0] = 0
    return new


# -------------------------------------------------------- token buckets ---
class TokenBuckets:
    """Per-class token buckets, refilled lazily in one vectorized step.
    ``None`` rate entries mean unlimited for that class."""

    __slots__ = ("rates", "caps", "tokens", "last_t", "limited")

    def __init__(self, rates: Sequence[Optional[float]],
                 burst: Sequence[float]):
        self.limited = np.array([r is not None for r in rates])
        self.rates = np.array([0.0 if r is None else float(r)
                               for r in rates])
        self.caps = np.asarray(burst, dtype=np.float64)
        self.tokens = self.caps.copy()
        self.last_t = 0.0

    def take(self, counts: np.ndarray, now: float) -> np.ndarray:
        """Admit up to ``counts`` per class; returns the admitted
        counts.  Refill is rate * elapsed, clipped at burst."""
        dt = now - self.last_t
        if dt > 0.0:
            np.minimum(self.caps, self.tokens + self.rates * dt,
                       out=self.tokens)
            self.last_t = now
        allowed = np.minimum(counts,
                             np.floor(self.tokens)).astype(np.int64)
        np.maximum(allowed, 0, out=allowed)
        allowed = np.where(self.limited, allowed, counts)
        self.tokens -= np.where(self.limited, allowed, 0)
        return allowed


# --------------------------------------------------- admission control ----
class AdmissionController:
    """The gate inside the control plane's unified ``admit()`` core:
    token buckets -> overload action (shed / degrade / spillover) ->
    brownout, each acting on whatever the previous stage let through.
    Ingress-shed rows never reach the behavioral models — they are
    dropped before the control plane "sees" them, exactly like a
    gateway 429.  All counters live here and feed the ScenarioReport
    ``qos`` section."""

    def __init__(self, spec: QosSpec, clock):
        self.spec = spec
        self.clock = clock
        self.buckets = (TokenBuckets(spec.rate_limits, spec.burst)
                        if spec.rate_limits is not None else None)
        mults = np.asarray(spec.slo_multipliers, dtype=np.float64)
        # identity multipliers skip the per-burst column write entirely
        self._mults = None if np.all(mults == 1.0) else mults
        self.token_shed = np.zeros(N_QOS, np.int64)
        self.overload_shed = np.zeros(N_QOS, np.int64)
        self.brownout_shed = np.zeros(N_QOS, np.int64)
        self.shed_by_tenant: Dict[int, int] = {}
        self.degraded = 0
        self.spilled = 0
        self.overload_events = 0
        self.brownout_events = 0
        self._sig_t = -np.inf         # cached burn-rate signal
        self._sig_over = False

    # ------------------------------------------------------- signals ------
    def _queue_depth(self, cp) -> float:
        depth = 0
        for p in cp.platforms.values():
            if not p.failed:
                depth += p.queued_rows
        return float(depth)

    def _burn_over(self, cp, now: float) -> bool:
        """Trailing-window error-budget burn from the telemetry rollups
        (PR-8 engine), cached at ``signal_interval_s`` so the gate never
        walks rollup buckets more than once per sim-second."""
        eng = cp.telemetry
        if eng is None:
            return False
        if now - self._sig_t < self.spec.signal_interval_s:
            return self._sig_over
        self._sig_t = now
        eng.flush()
        tier_s = float(eng.cfg.tiers_s[0])
        w = max(1, int(round(self.spec.burn_window_s / tier_s)))
        cutoff = int(now // tier_s) - w
        tot = 0.0
        bad = 0.0
        for (_p, _f, m), sr in eng.series.items():
            if m != "response_time":
                continue
            ids, counts, _sums, _mins, _maxs, badv, _q = sr.series(0)
            if not len(ids):
                continue
            sel = ids >= cutoff
            tot += float(counts[sel].sum())
            bad += float(badv[sel].sum())
        budget = max(1.0 - self.spec.burn_slo_target, 1e-9)
        burn = (bad / tot / budget) if tot else 0.0
        self._sig_over = burn >= self.spec.burn_threshold
        return self._sig_over

    def _spill_target(self, cp, fn_counts=()) -> Optional[str]:
        """Spill destination respecting data gravity: platforms are
        scored by the mean per-invocation transfer seconds the spilled
        functions' data objects would cost from each candidate
        (``DataPlacementManager.access_time`` — the same seconds-per-byte
        accounting the chains planner uses) plus a normalized load term
        (queued rows + busy replicas per total replica).  A platform
        already holding the hot objects therefore beats a marginally
        less-loaded one that would pull every byte over the WAN.

        ``fn_counts`` is a sequence of ``(FunctionSpec, count)`` for the
        rows being spilled; empty falls back to pure least-load (name as
        the deterministic tie-break either way)."""
        placement = getattr(cp, "placement", None)
        total = sum(c for _fn, c in fn_counts)
        best = None
        for name, p in cp.platforms.items():
            if p.failed:
                continue
            load = (p.queued_rows + p.busy_replicas()) / \
                max(p.prof.total_replicas, 1)
            transfer = 0.0
            if total and placement is not None:
                for fn, c in fn_counts:
                    for obj in fn.data_objects:
                        transfer += c * placement.access_time(obj, name)
                transfer /= total
            score = transfer + load
            if best is None or (score, name) < best:
                best = (score, name)
        return None if best is None else best[1]

    def _fleet_power_w(self, cp) -> float:
        return sum(cp.energy.power_w(name, p.cpu_util())
                   for name, p in cp.platforms.items() if not p.failed)

    # ---------------------------------------------------- shed plumbing ---
    def _tally_tenants(self, tenants: np.ndarray):
        counts = np.bincount(tenants)
        for t in np.nonzero(counts)[0]:
            t = int(t)
            self.shed_by_tenant[t] = \
                self.shed_by_tenant.get(t, 0) + int(counts[t])

    def _reject_columns(self, cp, batch, rows: np.ndarray, now: float):
        """Mirror of the admission paths' reject idiom: REJECTED state,
        rejected counter, retained materialized rows, per-fn recorder
        rejects."""
        batch.state[rows] = batch.REJECTED
        cp.rejected_count += int(rows.size)
        if cp.retain_completions:
            for i in rows:
                inv = batch.materialize(int(i))
                inv.status = "failed"
                cp.rejected.append(inv)
        self._tally_tenants(batch.tenant[rows])
        rec = cp.recorder
        if rec is not None:
            counts = np.bincount(batch.fn_idx[rows],
                                 minlength=len(batch.specs))
            for j in np.nonzero(counts)[0]:
                rec.record_reject(batch.specs[int(j)].name, None, now,
                                  int(counts[j]))

    def _reject_objects(self, cp, invs: List, now: float):
        rec = cp.recorder
        fn_counts: Dict[str, int] = {}
        for inv in invs:
            inv.status = "failed"
            cp._reject(inv)
            self.shed_by_tenant[inv.tenant] = \
                self.shed_by_tenant.get(inv.tenant, 0) + 1
            if rec is not None:
                name = inv.fn.name
                fn_counts[name] = fn_counts.get(name, 0) + 1
        if rec is not None:
            for name, c in fn_counts.items():
                rec.record_reject(name, None, now, c)

    # ------------------------------------------------------ gate: batch ---
    def gate_columns(self, cp, batch):
        """Gate one columnar burst.  Returns ``(kept, spill)`` where
        ``kept`` is the surviving batch (the original, a filtered copy,
        or None) and ``spill`` is ``(invocations, platform_name)`` to
        admit after the main rows, or None."""
        spec = self.spec
        now = self.clock.now()
        qcol = batch.qos
        n = batch.n
        if self._mults is not None:
            # effective per-class deadline: columnar-only metadata (the
            # report derives class-adjusted violations from the spec)
            batch.deadline_s *= self._mults[qcol]
        keep: Optional[np.ndarray] = None
        # 1. per-class token buckets (tail rows beyond allowance shed)
        if self.buckets is not None:
            counts = np.bincount(qcol, minlength=N_QOS)
            allowed = self.buckets.take(counts, now)
            short = np.nonzero(allowed < counts)[0]
            if short.size:
                keep = np.ones(n, bool)
                for c in short:
                    rows = np.nonzero(qcol == np.int8(c))[0]
                    drop = rows[int(allowed[c]):]
                    keep[drop] = False
                    self.token_shed[c] += drop.size
                self._reject_columns(cp, batch, np.nonzero(~keep)[0], now)
        # 2. overload action over the survivors
        spill = None
        over = hard = False
        if spec.shed_queue_depth is not None:
            depth = self._queue_depth(cp)
            over = depth >= spec.shed_queue_depth
            hard = depth >= spec.shed_queue_depth * spec.shed_hard_factor
        if not over and spec.burn_threshold is not None:
            over = self._burn_over(cp, now)
        if over:
            self.overload_events += 1
            kept = keep if keep is not None else np.ones(n, bool)
            if spec.overload_action == "degrade":
                sel = kept & (qcol == np.int8(QOS_STANDARD))
                dn = int(np.count_nonzero(sel))
                if dn:
                    qcol[sel] = QOS_BATCH
                    self.degraded += dn
            else:
                low = kept & (qcol == np.int8(QOS_BATCH))
                if hard:
                    low |= kept & (qcol == np.int8(QOS_STANDARD))
                rows = np.nonzero(low)[0]
                target = None
                if spec.overload_action == "spillover" and rows.size:
                    counts = np.bincount(batch.fn_idx[rows],
                                         minlength=len(batch.specs))
                    fc = [(batch.specs[int(j)], int(counts[j]))
                          for j in np.nonzero(counts)[0]]
                    target = self._spill_target(cp, fc)
                if rows.size and target is not None:
                    kept[rows] = False
                    keep = kept
                    spill_invs = []
                    for i in rows:
                        i = int(i)
                        inv = batch.materialize(i)
                        batch.state[i] = batch.ADMITTED
                        spill_invs.append(inv)
                    self.spilled += rows.size
                    spill = (spill_invs, target)
                elif rows.size:          # shed (or nowhere to spill)
                    kept[rows] = False
                    keep = kept
                    sc = np.bincount(qcol[rows], minlength=N_QOS)
                    self.overload_shed += sc
                    self._reject_columns(cp, batch, rows, now)
        # 3. brownout: fleet power above the energy cap sheds batch
        if spec.energy_cap_w is not None and \
                self._fleet_power_w(cp) > spec.energy_cap_w:
            kept = keep if keep is not None else np.ones(n, bool)
            rows = np.nonzero(kept & (qcol == np.int8(QOS_BATCH)))[0]
            if rows.size:
                self.brownout_events += 1
                kept[rows] = False
                keep = kept
                self.brownout_shed[QOS_BATCH] += rows.size
                self._reject_columns(cp, batch, rows, now)
        if keep is None:
            return batch, spill
        kept_idx = np.nonzero(keep)[0]
        if kept_idx.size == n:
            return batch, spill
        if kept_idx.size == 0:
            return None, spill
        sub = type(batch)(batch.specs, batch.fn_idx[kept_idx],
                          batch.arrival_t[kept_idx],
                          batch.payload_bytes[kept_idx],
                          batch.deadline_s[kept_idx],
                          batch.state[kept_idx],
                          qos=batch.qos[kept_idx],
                          tenant=batch.tenant[kept_idx],
                          decision=batch.decision[kept_idx])
        return sub, spill

    # ----------------------------------------------------- gate: objects --
    def gate_objects(self, cp, invs):
        """Object-path twin of ``gate_columns`` (same stages, same
        counters) over a sequence of ``Invocation`` objects."""
        spec = self.spec
        now = self.clock.now()
        kept = list(invs)
        # 1. token buckets
        if self.buckets is not None:
            counts = np.zeros(N_QOS, np.int64)
            for inv in kept:
                counts[inv.qos] += 1
            allowed = self.buckets.take(counts, now)
            if (allowed < counts).any():
                left = allowed.copy()
                admit, shed = [], []
                for inv in kept:
                    if left[inv.qos] > 0:
                        left[inv.qos] -= 1
                        admit.append(inv)
                    else:
                        shed.append(inv)
                        self.token_shed[inv.qos] += 1
                kept = admit
                self._reject_objects(cp, shed, now)
        # 2. overload action
        spill = None
        over = hard = False
        if spec.shed_queue_depth is not None:
            depth = self._queue_depth(cp)
            over = depth >= spec.shed_queue_depth
            hard = depth >= spec.shed_queue_depth * spec.shed_hard_factor
        if not over and spec.burn_threshold is not None:
            over = self._burn_over(cp, now)
        if over and kept:
            self.overload_events += 1
            if spec.overload_action == "degrade":
                for inv in kept:
                    if inv.qos == QOS_STANDARD:
                        inv.qos = QOS_BATCH
                        self.degraded += 1
            else:
                low_classes = {QOS_BATCH, QOS_STANDARD} if hard \
                    else {QOS_BATCH}
                low = [inv for inv in kept if inv.qos in low_classes]
                if low:
                    target = None
                    if spec.overload_action == "spillover":
                        groups: Dict[int, List] = {}
                        for inv in low:
                            g = groups.get(id(inv.fn))
                            if g is None:
                                groups[id(inv.fn)] = [inv.fn, 1]
                            else:
                                g[1] += 1
                        target = self._spill_target(
                            cp, [(fn, c) for fn, c in groups.values()])
                    kept = [inv for inv in kept
                            if inv.qos not in low_classes]
                    if target is not None:
                        self.spilled += len(low)
                        spill = (low, target)
                    else:
                        for inv in low:
                            self.overload_shed[inv.qos] += 1
                        self._reject_objects(cp, low, now)
        # 3. brownout
        if spec.energy_cap_w is not None and kept and \
                self._fleet_power_w(cp) > spec.energy_cap_w:
            low = [inv for inv in kept if inv.qos == QOS_BATCH]
            if low:
                self.brownout_events += 1
                kept = [inv for inv in kept if inv.qos != QOS_BATCH]
                self.brownout_shed[QOS_BATCH] += len(low)
                self._reject_objects(cp, low, now)
        return kept, spill

    # ------------------------------------------------------- reporting ----
    def section(self) -> Dict:
        """The admission fragment of the ScenarioReport ``qos`` section."""
        def per_class(a: np.ndarray) -> Dict[str, int]:
            return {QOS_NAMES[c]: int(a[c]) for c in range(N_QOS)}
        total = self.token_shed + self.overload_shed + self.brownout_shed
        return {
            "shed_total": int(total.sum()),
            "shed_by_class": per_class(total),
            "token_shed": per_class(self.token_shed),
            "overload_shed": per_class(self.overload_shed),
            "brownout_shed": per_class(self.brownout_shed),
            "shed_by_tenant": {str(t): int(c) for t, c in
                               sorted(self.shed_by_tenant.items())},
            "degraded": int(self.degraded),
            "spilled": int(self.spilled),
            "overload_events": int(self.overload_events),
            "brownout_events": int(self.brownout_events),
        }
