"""Struct-of-arrays invocation batches: the array-native admission
currency.

An ``InvocationBatch`` carries an arrival burst as flat columns — function
index, arrival timestamp, payload bytes, SLO deadline, QoS class, tenant,
admission state — over one shared list of distinct ``FunctionSpec``s.  The
whole admission pipeline (gateway -> control plane -> sidecar -> platform
queue) moves the columns; per-invocation ``Invocation`` objects materialize
lazily, exactly when a replica actually starts one (or a fault / completion
path needs the object form).  A trace replay therefore allocates Python
objects proportional to *in-flight* work, not to arrivals, and a long
stream can be walked as zero-copy chunk ``view``s over one preallocated
column set.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.types import FunctionSpec, Invocation


class InvocationBatch:
    """One arrival burst in struct-of-arrays form.

    Columns (length ``n``, NumPy; ``view`` slices share memory with the
    parent so admission-state writes propagate):

    * ``fn_idx``  (int32)  — index into ``specs`` per arrival
    * ``arrival_t`` (f8)   — arrival timestamp (sim seconds)
    * ``payload_bytes`` (f8) — request payload size (0 when unknown)
    * ``deadline_s`` (f8)  — per-arrival SLO budget (from the spec's SLO
      unless the caller supplies its own column)
    * ``qos``     (int8)   — QoS class id (repro.core.qos; 1 == standard)
    * ``tenant``  (int32)  — tenant id (0 == default tenant)
    * ``state``   (int8)   — PENDING / ADMITTED / REJECTED
    """

    PENDING, ADMITTED, REJECTED = 0, 1, 2

    __slots__ = ("specs", "fn_idx", "arrival_t", "payload_bytes",
                 "deadline_s", "state", "qos", "tenant", "decision", "n",
                 "arrival_recorded", "_objs")

    def __init__(self, specs: Sequence[FunctionSpec], fn_idx, arrival_t,
                 payload_bytes=None, deadline_s=None, state=None,
                 qos=None, tenant=None, decision=None):
        self.specs: List[FunctionSpec] = \
            specs if isinstance(specs, list) else list(specs)
        self.fn_idx = np.asarray(fn_idx, np.int32)
        self.arrival_t = np.asarray(arrival_t, np.float64)
        n = int(self.fn_idx.size)
        self.n = n
        if payload_bytes is None:
            payload_bytes = np.zeros(n)
        self.payload_bytes = np.asarray(payload_bytes, np.float64)
        if deadline_s is None:
            slo = np.array([s.slo.p90_response_s for s in self.specs],
                           np.float64)
            deadline_s = slo[self.fn_idx] if n else np.empty(0)
        self.deadline_s = np.asarray(deadline_s, np.float64)
        self.state = np.zeros(n, np.int8) if state is None \
            else np.asarray(state, np.int8)
        # 1 == standard (repro.core.qos.DEFAULT_QOS); kept literal so a
        # qos-free caller never imports the qos module
        self.qos = np.full(n, 1, np.int8) if qos is None \
            else np.asarray(qos, np.int8)
        self.tenant = np.zeros(n, np.int32) if tenant is None \
            else np.asarray(tenant, np.int32)
        # decision-journal row id per arrival (-1 == not journaled); the
        # control plane stamps it at admission when provenance is on
        self.decision = np.full(n, -1, np.int64) if decision is None \
            else np.asarray(decision, np.int64)
        # set once the control plane has folded this batch's arrivals into
        # the rate/interaction models (mirrors Invocation.arrival_recorded)
        self.arrival_recorded = False
        self._objs: Dict[int, Invocation] = {}

    def __len__(self) -> int:
        return self.n

    # ------------------------------------------------------------ views --
    def view(self, lo: int, hi: int) -> "InvocationBatch":
        """Zero-copy sub-batch over rows ``[lo, hi)``: columns are NumPy
        views into the parent (state writes propagate back); the lazy
        object cache is per-view."""
        return InvocationBatch(self.specs, self.fn_idx[lo:hi],
                               self.arrival_t[lo:hi],
                               self.payload_bytes[lo:hi],
                               self.deadline_s[lo:hi],
                               self.state[lo:hi],
                               qos=self.qos[lo:hi],
                               tenant=self.tenant[lo:hi],
                               decision=self.decision[lo:hi])

    # ------------------------------------------------- object round-trip --
    def materialize(self, i: int) -> Invocation:
        """The ``Invocation`` object for row ``i``, created on first use
        and cached (hooks and fault paths must see one identity per row)."""
        inv = self._objs.get(i)
        if inv is None:
            inv = Invocation(self.specs[self.fn_idx[i]],
                             float(self.arrival_t[i]),
                             qos=int(self.qos[i]),
                             tenant=int(self.tenant[i]))
            inv.decision = int(self.decision[i])
            self._objs[i] = inv
        return inv

    def to_invocations(self) -> List[Invocation]:
        """Materialize every row, in arrival order (the object-path
        fallback: stateful policies, decision-row logging, hedging)."""
        return [self.materialize(i) for i in range(self.n)]

    @classmethod
    def from_invocations(cls, invs: Sequence[Invocation],
                         payload_bytes=None) -> "InvocationBatch":
        """Columnarize existing objects (specs dedupe by identity, first-
        appearance order — the mirror of ``scheduler.group_by_fn``).  The
        originals are kept as the row cache, so a round trip through
        ``to_invocations`` returns the very same objects."""
        n = len(invs)
        specs: List[FunctionSpec] = []
        smap: Dict[int, int] = {}
        fidx = np.empty(n, np.int32)
        arr = np.empty(n)
        qos = np.empty(n, np.int8)
        tenant = np.empty(n, np.int32)
        decision = np.empty(n, np.int64)
        for i, inv in enumerate(invs):
            j = smap.get(id(inv.fn))
            if j is None:
                j = len(specs)
                smap[id(inv.fn)] = j
                specs.append(inv.fn)
            fidx[i] = j
            arr[i] = inv.arrival_t
            qos[i] = inv.qos
            tenant[i] = inv.tenant
            decision[i] = inv.decision
        b = cls(specs, fidx, arr, payload_bytes=payload_bytes,
                qos=qos, tenant=tenant, decision=decision)
        b._objs = dict(enumerate(invs))
        return b

    # ------------------------------------------------------ group helper --
    def present_fns(self) -> np.ndarray:
        """Distinct ``specs`` indices present in this batch, first-
        appearance order (so columnar routing admits groups in exactly the
        order the object path's identity grouping would)."""
        uniq, first = np.unique(self.fn_idx, return_index=True)
        return uniq[np.argsort(first, kind="stable")]
