"""Deterministic discrete-event clock.

One CPU core has to impersonate five target platforms, so every latency in
the FDN (queueing, cold starts, execution, data transfer) is advanced on
this clock. Small functions can still *really* execute (jitted on CPU) to
calibrate the analytic costs — see platform.ExecutionModel.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class TimerHandle:
    """Cancellation token for a scheduled callback.

    ``cancel`` drops the callback reference immediately (the closure and
    everything it captures become collectable right away); the heap entry
    itself is skipped silently when its time comes.  Cancelled timers are
    therefore "dropped", not "fired as no-ops"."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable[[], None]):
        self.fn: Optional[Callable[[], None]] = fn

    def cancel(self) -> None:
        self.fn = None

    @property
    def cancelled(self) -> bool:
        return self.fn is None

    def __call__(self) -> None:
        if self.fn is not None:
            self.fn()


class SimClock:
    def __init__(self):
        self._t = 0.0
        self._q: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self._t

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        assert t >= self._t - 1e-9, (t, self._t)
        heapq.heappush(self._q, (t, next(self._seq), fn))

    def schedule_cancellable(self, t: float,
                             fn: Callable[[], None]) -> TimerHandle:
        """Like ``schedule`` but returns a handle whose ``cancel`` drops
        the callback (hedge group timers whose members all completed)."""
        handle = TimerHandle(fn)
        self.schedule(t, handle)
        return handle

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.schedule(self._t + max(dt, 0.0), fn)

    def after_cancellable(self, dt: float,
                          fn: Callable[[], None]) -> TimerHandle:
        return self.schedule_cancellable(self._t + max(dt, 0.0), fn)

    def schedule_many(self, times, fns) -> None:
        """Bulk-schedule parallel sequences of times and callbacks (one
        validation for the whole batch — used by the open-loop load
        generator, which enqueues thousands of window events at once)."""
        times = list(times)
        if not times:
            return
        assert min(times) >= self._t - 1e-9, (min(times), self._t)
        q, seq = self._q, self._seq
        for t, fn in zip(times, fns):
            heapq.heappush(q, (t, next(seq), fn))

    def step(self) -> bool:
        if not self._q:
            return False
        t, _, fn = heapq.heappop(self._q)
        self._t = max(self._t, t)
        fn()
        return True

    def run_until(self, t_end: float) -> None:
        while self._q and self._q[0][0] <= t_end:
            self.step()
        self._t = max(self._t, t_end)

    def run(self) -> None:
        while self.step():
            pass

    @property
    def pending(self) -> int:
        return len(self._q)
