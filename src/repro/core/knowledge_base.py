"""Knowledge Base (paper §3.4): stores behavioral models, scheduling
decisions and benchmarking results; consulted by the DeploymentGenerator for
annotation of re-deployments and by external components (FDNInspector,
threshold tuning)."""
from __future__ import annotations

import json
import os
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple


class KnowledgeBase:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.decisions: List[Dict] = []
        # log_decisions=False keeps only the counter: a 10^6-invocation
        # FDNInspector scenario must not grow a per-decision dict list
        self.log_decisions = True
        self.decision_count = 0
        self.benchmarks: Dict[Tuple[str, str], Dict] = {}
        self.models: Dict[str, Any] = {}
        if path and os.path.exists(path):
            self.load()

    # decisions ----------------------------------------------------------
    def record_decision(self, t: float, fn: str, platform: str,
                        policy: str, predicted_s: float):
        self.decision_count += 1
        if self.log_decisions:
            self.decisions.append({"t": t, "fn": fn, "platform": platform,
                                   "policy": policy,
                                   "predicted_s": predicted_s})

    def record_decisions(self, rows: List[Dict]):
        """Bulk append from the control plane's batched submit path."""
        self.decision_count += len(rows)
        if self.log_decisions:
            self.decisions.extend(rows)

    def count_decisions(self, n: int):
        """Row-free bookkeeping for un-logged batched decisions."""
        self.decision_count += n

    def best_platform(self, fn: str) -> Optional[str]:
        """Most frequent successful placement for fn (deployment hints)."""
        counts: Dict[str, int] = defaultdict(int)
        for d in self.decisions:
            if d["fn"] == fn:
                counts[d["platform"]] += 1
        if not counts:
            b = [(k[1], v) for k, v in self.benchmarks.items()
                 if k[0] == fn and "exec_p50" in v]
            if b:
                return min(b, key=lambda x: x[1]["exec_p50"])[0]
            return None
        return max(counts, key=counts.get)

    # benchmark results (from FDNInspector) ------------------------------
    def record_benchmark(self, fn: str, platform: str, stats: Dict):
        self.benchmarks[(fn, platform)] = dict(stats)

    def benchmark(self, fn: str, platform: str) -> Optional[Dict]:
        return self.benchmarks.get((fn, platform))

    # persistence --------------------------------------------------------
    def save(self):
        if not self.path:
            return
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"decisions": self.decisions,
                       "benchmarks": {f"{k[0]}|{k[1]}": v
                                      for k, v in self.benchmarks.items()}},
                      f)

    def load(self):
        with open(self.path) as f:
            data = json.load(f)
        self.decisions = data.get("decisions", [])
        self.benchmarks = {tuple(k.split("|")): v
                           for k, v in data.get("benchmarks", {}).items()}
