"""Gateway: the FDN's single point of entry (the NGINX analogue of
§5.1.3), with access control and optional collaboration load-balancing in
front of the control plane's scheduler.

``request`` resolves the load-balancer target first and then calls
``cp.submit`` exactly once, so every invocation's arrival is recorded
exactly once in the behavioral models.  ``request_batch`` is the burst
path: one auth check and one policy evaluation for the whole batch.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.control_plane import FDNControlPlane
from repro.core.invocation_batch import InvocationBatch
from repro.core.scheduler import Policy
from repro.core.types import Invocation


class Gateway:
    def __init__(self, cp: FDNControlPlane,
                 lb_policy: Optional[Policy] = None,
                 principal: str = "default", token: str = "secret"):
        self.cp = cp
        self.lb_policy = lb_policy
        cp.access.grant(principal, token)
        self.principal, self.token = principal, token
        self.unauthorized = 0
        # principal -> tenant id: multi-tenant ingress stamping (QoS
        # layer); empty dict keeps both request paths at one falsy check
        self.tenants: Dict[str, int] = {}

    def set_tenant(self, principal: str, tenant: int):
        """Map an authenticated principal to a tenant id: every
        invocation arriving under that principal is stamped with the
        tenant before admission (the per-tenant column the QoS fairness
        and shed-rate report sections aggregate over)."""
        self.tenants[principal] = int(tenant)

    def _stamp_tenant(self, invs, principal: Optional[str]):
        tenant = self.tenants.get(
            principal if principal is not None else self.principal)
        if tenant is None:
            return
        if isinstance(invs, InvocationBatch):
            invs.tenant[:] = tenant
        else:
            for inv in invs:
                inv.tenant = tenant

    def _authorized(self, principal: Optional[str],
                    token: Optional[str]) -> bool:
        principal = principal if principal is not None else self.principal
        token = token if token is not None else self.token
        return self.cp.access.check(principal, token)

    def request(self, inv: Invocation, principal: Optional[str] = None,
                token: Optional[str] = None) -> bool:
        if not self._authorized(principal, token):
            self.unauthorized += 1
            inv.status = "failed"
            rec = self.cp.recorder
            if rec is not None:
                rec.record_reject(inv.fn.name, None, self.cp.clock.now(), 1)
            return False
        if self.tenants:
            self._stamp_tenant((inv,), principal)
        override = None
        if self.lb_policy is not None:
            target = self.lb_policy.choose(inv, self.cp.alive_platforms())
            if target is not None:
                override = target.prof.name
        return self.cp.submit(inv, platform_override=override)

    def request_batch(self, invs: Sequence[Invocation],
                      principal: Optional[str] = None,
                      token: Optional[str] = None) -> int:
        """Admit a whole arrival burst: auth once, route once, submit in
        per-platform groups.  Accepts a plain sequence or an
        ``InvocationBatch`` (columnar batches pass straight through to the
        control plane; a gateway load-balancer needs object rows).
        Returns the number of accepted invocations."""
        if not len(invs):
            return 0
        if not self._authorized(principal, token):
            self.unauthorized += len(invs)
            if isinstance(invs, InvocationBatch):
                invs.state[:] = InvocationBatch.REJECTED
            else:
                for inv in invs:
                    inv.status = "failed"
            rec = self.cp.recorder
            if rec is not None:
                rec.record_reject(None, None, self.cp.clock.now(),
                                  len(invs))
            return 0
        if self.tenants:
            self._stamp_tenant(invs, principal)
        if self.lb_policy is None:
            return self.cp.submit_batch(invs)
        if isinstance(invs, InvocationBatch):
            invs = invs.to_invocations()
        targets = self.lb_policy.choose_batch(invs,
                                              self.cp.alive_platforms())
        groups: Dict[str, List[Invocation]] = {}
        unrouted: List[Invocation] = []
        for inv, target in zip(invs, targets):
            if target is None:
                unrouted.append(inv)
            else:
                groups.setdefault(target.prof.name, []).append(inv)
        accepted = 0
        for pname, group in groups.items():
            accepted += self.cp.submit_batch(group, platform_override=pname)
        if unrouted:       # fall back to the scheduler, still a single path
            accepted += self.cp.submit_batch(unrouted)
        return accepted
