"""Gateway: the FDN's single point of entry (the NGINX analogue of
§5.1.3), with access control and optional collaboration load-balancing in
front of the control plane's scheduler."""
from __future__ import annotations

from typing import Optional

from repro.core.control_plane import FDNControlPlane
from repro.core.scheduler import Policy
from repro.core.types import Invocation


class Gateway:
    def __init__(self, cp: FDNControlPlane,
                 lb_policy: Optional[Policy] = None,
                 principal: str = "default", token: str = "secret"):
        self.cp = cp
        self.lb_policy = lb_policy
        cp.access.grant(principal, token)
        self.principal, self.token = principal, token
        self.unauthorized = 0

    def request(self, inv: Invocation, principal: Optional[str] = None,
                token: Optional[str] = None) -> bool:
        principal = principal if principal is not None else self.principal
        token = token if token is not None else self.token
        if not self.cp.access.check(principal, token):
            self.unauthorized += 1
            inv.status = "failed"
            return False
        if self.lb_policy is not None:
            target = self.lb_policy.choose(inv, self.cp.alive_platforms())
            if target is not None:
                return self.cp.submit(inv,
                                      platform_override=target.prof.name)
        return self.cp.submit(inv)
