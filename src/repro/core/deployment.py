"""Deployment Generator (paper §3.5): annotates the user's deployment
specification with placement hints, replica counts and data-staging plans
derived from the Knowledge Base, and instruments data accesses."""
from __future__ import annotations

import math
from typing import Dict, Optional

from repro.core.behavioral import EventModel
from repro.core.knowledge_base import KnowledgeBase
from repro.core.types import DeploymentSpec, FunctionSpec


class DeploymentGenerator:
    def __init__(self, kb: KnowledgeBase,
                 events: Optional[EventModel] = None):
        self.kb = kb
        self.events = events

    def annotate(self, spec: DeploymentSpec) -> DeploymentSpec:
        for fn in spec.functions:
            ann: Dict = dict(spec.annotations.get(fn.name, {}))
            hint = self.kb.best_platform(fn.name)
            if hint is not None:
                ann["preferred_platform"] = hint
            # initial replica count from the forecast arrival rate and the
            # benchmarked exec time (Little's law: L = lambda * W)
            if self.events is not None and hint is not None:
                bench = self.kb.benchmark(fn.name, hint) or {}
                w = bench.get("exec_p50", 0.1)
                lam = self.events.forecast_rate(fn.name)
                if lam > 0:
                    ann["min_replicas"] = max(1, math.ceil(lam * w))
            if fn.data_objects:
                ann["instrument_data_access"] = True
                ann["stage_objects"] = list(fn.data_objects)
            spec.annotations[fn.name] = ann
        return spec
