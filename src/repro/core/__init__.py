"""Function Delivery Network (FDN) — the paper's contribution as a library.

Quick start:

    from repro.core import FDNControlPlane, Gateway
    from repro.core import profiles, functions, loadgen

    cp = FDNControlPlane()
    for prof in profiles.PAPER_PLATFORMS.values():
        cp.create_platform(prof)
    fns = functions.paper_functions()
    ...
"""
from repro.core.types import (SLO, FunctionSpec, Invocation,
                              PlatformProfile, DeploymentSpec)
from repro.core.invocation_batch import InvocationBatch
from repro.core.simulator import SimClock
from repro.core.control_plane import (AccessControl, AdmissionRequest,
                                      FDNControlPlane)
from repro.core.gateway import Gateway
from repro.core.platform import TargetPlatform, ExecutionModel
from repro.core.scheduler import (POLICIES, PerformanceRankedPolicy,
                                  UtilizationAwarePolicy,
                                  RoundRobinCollaboration,
                                  WeightedCollaboration, DataLocalityPolicy,
                                  EnergyAwarePolicy, SLOCompositePolicy,
                                  WarmAwarePolicy)
from repro.core.sidecar import SidecarController
from repro.core.monitoring import (ColumnarWindowSeries, MetricsRegistry,
                                   WindowSeries)
from repro.core.behavioral import (P2Quantile, EWMA, EventModel,
                                   FunctionPerformanceModel, PerfState,
                                   compose_functions, composition_plan)
from repro.core.knowledge_base import KnowledgeBase
from repro.core.deployment import DeploymentGenerator
from repro.core.data_placement import DataPlacementManager, ObjectStore
from repro.core.energy import EnergyMeter
from repro.core.faults import FailureDetector, Redeliverer, HedgePolicy
from repro.core.qos import (AdmissionController, QosSpec,
                            QOS_BATCH, QOS_LATENCY_CRITICAL, QOS_NAMES,
                            QOS_STANDARD, qos_id)

__all__ = [
    "SLO", "FunctionSpec", "Invocation", "InvocationBatch",
    "PlatformProfile",
    "DeploymentSpec", "SimClock", "FDNControlPlane", "AccessControl",
    "AdmissionRequest", "AdmissionController", "QosSpec", "qos_id",
    "QOS_LATENCY_CRITICAL", "QOS_STANDARD", "QOS_BATCH", "QOS_NAMES",
    "Gateway", "TargetPlatform", "ExecutionModel", "POLICIES",
    "PerformanceRankedPolicy", "UtilizationAwarePolicy",
    "RoundRobinCollaboration", "WeightedCollaboration",
    "DataLocalityPolicy", "EnergyAwarePolicy", "SLOCompositePolicy",
    "WarmAwarePolicy",
    "SidecarController", "MetricsRegistry", "ColumnarWindowSeries",
    "WindowSeries", "P2Quantile", "EWMA",
    "EventModel", "FunctionPerformanceModel", "PerfState",
    "KnowledgeBase",
    "DeploymentGenerator", "DataPlacementManager", "ObjectStore",
    "EnergyMeter", "FailureDetector", "Redeliverer", "HedgePolicy",
    "compose_functions", "composition_plan",
]
