"""Target-platform profiles.

Part 1 — the paper's five platforms (Table 3), with power calibrated to
Table 4 (edge: Jetson POM_5V_CPU rails; HPC: RAPL PKG0/PKG1) and relative
speeds calibrated to Figures 5-7.

Part 2 — the TPU-pod platforms this framework targets (v5e numbers from the
assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI), forming the
heterogeneous FDN the serving examples schedule over.
"""
from __future__ import annotations

from typing import Dict

from repro.core.types import PlatformProfile

# ---------------------------------------------------------------------------
# Paper platforms (Table 3 / Table 4)
# ---------------------------------------------------------------------------

# Calibration anchor: JSON-loads @ 400 req/s for 600 s (Table 4):
#   edge  : power w/o load 0.445 W/node, with load ~1.47 W/node -> 2647 J
#   hpc   : 30.12 W/socket idle, 37.2 W/socket loaded (2 sockets)-> 44646 J
PAPER_PLATFORMS: Dict[str, PlatformProfile] = {
    "hpc-node-cluster": PlatformProfile(
        name="hpc-node-cluster", faas="openwhisk", nodes=1,
        replicas_per_node=44, memory_mb_per_node=754 * 1024,
        replica_flops=6.0e9, net_bw=10e9, overhead_s=0.08,
        idle_w_per_node=60.24, loaded_w_per_node=74.41,
        cold_start_s=2.5, prewarm_pool=2, scale_to_zero_s=300.0),
    "old-hpc-node-cluster": PlatformProfile(
        name="old-hpc-node-cluster", faas="openwhisk", nodes=1,
        replicas_per_node=40, memory_mb_per_node=251 * 1024,
        replica_flops=4.2e9, net_bw=10e9, overhead_s=0.09,
        idle_w_per_node=110.0, loaded_w_per_node=145.0,
        cold_start_s=2.5, prewarm_pool=2, scale_to_zero_s=300.0),
    "cloud-cluster": PlatformProfile(
        name="cloud-cluster", faas="openwhisk", nodes=3,
        replicas_per_node=4, memory_mb_per_node=8 * 1024,
        replica_flops=4.8e9, net_bw=1e9, overhead_s=0.10,
        idle_w_per_node=40.0, loaded_w_per_node=65.0,
        cold_start_s=2.5, prewarm_pool=1, scale_to_zero_s=300.0),
    "google-cloud-cluster": PlatformProfile(
        name="google-cloud-cluster", faas="gcf", nodes=1,
        replicas_per_node=100, memory_mb_per_node=1 << 20,
        replica_flops=0.45e9, net_bw=0.5e9, overhead_s=0.09,
        idle_w_per_node=50.0, loaded_w_per_node=90.0,
        cold_start_s=1.5, elastic=True, infra_metrics_visible=False,
        scale_to_zero_s=60.0, region="us-east"),
    "edge-cluster": PlatformProfile(
        name="edge-cluster", faas="openfaas", nodes=3,
        replicas_per_node=4, memory_mb_per_node=4 * 1024,
        replica_flops=0.55e9, net_bw=0.2e9, overhead_s=0.28,
        idle_w_per_node=0.445, loaded_w_per_node=1.471,
        cold_start_s=4.0, scale_to_zero_s=120.0, arm=True),
}

# ---------------------------------------------------------------------------
# TPU-pod platforms (the hardware this framework actually targets)
# ---------------------------------------------------------------------------

V5E_PEAK = 197e12
V5E_HBM = 819e9
V5E_LINK = 50e9


def _pod(name: str, chips: int, faas: str = "openwhisk",
         peak: float = V5E_PEAK, power_per_chip: float = 180.0,
         idle_frac: float = 0.35, **kw) -> PlatformProfile:
    return PlatformProfile(
        name=name, faas=faas, nodes=chips, replicas_per_node=1,
        memory_mb_per_node=16 * 1024,
        replica_flops=peak * 0.4,            # effective per-chip FLOP/s
        net_bw=100e9, chips=chips, peak_flops=peak, hbm_bw=V5E_HBM,
        link_bw=V5E_LINK, idle_w_per_node=power_per_chip * idle_frac,
        loaded_w_per_node=power_per_chip, cold_start_s=30.0,
        prewarm_pool=1, scale_to_zero_s=600.0, **kw)


TPU_PLATFORMS: Dict[str, PlatformProfile] = {
    # full v5e pod slice — the "hpc-node-cluster" analogue
    "hpc-pod": _pod("hpc-pod", 256),
    # previous-gen pod — lower peak, worse perf/W ("old-hpc" analogue)
    "old-pod": _pod("old-pod", 128, peak=0.55 * V5E_PEAK,
                    power_per_chip=220.0),
    # small cloud slice
    "cloud-pod": _pod("cloud-pod", 16, power_per_chip=190.0),
    # opaque autoscaled public endpoint ("google-cloud-cluster" analogue)
    "public-cloud": _pod("public-cloud", 64, faas="gcf",
                         elastic=True, infra_metrics_visible=False),
    # low-power edge inference box ("edge-cluster" analogue)
    "edge-tpu": _pod("edge-tpu", 4, faas="tinyfaas",
                     peak=0.12 * V5E_PEAK, power_per_chip=18.0,
                     idle_frac=0.2),
}


def paper_profile(name: str) -> PlatformProfile:
    return PAPER_PLATFORMS[name]


def tpu_profile(name: str) -> PlatformProfile:
    return TPU_PLATFORMS[name]
