"""FDN Scheduler (paper §3.1.3): delivers each invocation to the right
target platform. One policy class per opportunity evaluated in §5:

  PerformanceRankedPolicy   §5.1.1  rank platforms by benchmarked performance
  UtilizationAwarePolicy    §5.1.2  avoid platforms under CPU/memory pressure
  RoundRobinCollaboration   §5.1.3  NGINX-style RR across platforms
  WeightedCollaboration     §5.1.3  weighted (e.g. 5:1) across platforms
  DataLocalityPolicy        §5.1.4  schedule near the function's data
  EnergyAwarePolicy         §5.2    cheapest energy among SLO-feasible
  SLOCompositePolicy        the full FDN decision: utilization filter ->
                            SLO feasibility -> locality cost -> energy tie-
                            break (hierarchical; node choice delegated to
                            the platform's SidecarController)

Policies are *vectorized*: the platform set is snapshotted once into
columnar NumPy arrays (``PlatformSnapshot``) and each policy produces a
``score(invs, snapshot) -> (N, P)`` cost matrix in one pass, so a whole
arrival batch is routed with array ops instead of N x P Python calls.
``choose`` is the batch-of-1 case of ``choose_batch``; row-wise argmin
breaks ties exactly like the historical per-platform ``min`` scan
(first-lowest in platform order), so scalar and batch paths pick
identical platforms.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.behavioral import FunctionPerformanceModel
from repro.core.data_placement import DataPlacementManager
from repro.core.platform import TargetPlatform
from repro.core.types import FunctionSpec, Invocation


class FnView:
    """Per-function columns over a snapshot's platforms (one row of the
    decision problem, broadcast to every invocation of that function)."""

    __slots__ = ("fn", "alive", "exec_s", "p90_s", "energy_j", "data_s")

    def __init__(self, fn: FunctionSpec):
        self.fn = fn
        self.alive: Optional[np.ndarray] = None
        self.exec_s: Optional[np.ndarray] = None
        self.p90_s: Optional[np.ndarray] = None
        self.energy_j: Optional[np.ndarray] = None
        self.data_s: Optional[np.ndarray] = None


class PlatformSnapshot:
    """Columnar view of a platform set at one scheduling instant.

    Platform state (memory, CPU/memory utilization, liveness, deployment)
    is captured eagerly; per-function predictions (exec / P90 / energy /
    data-access time) are computed lazily, once per distinct function, and
    cached for the lifetime of the snapshot.  A snapshot is only valid for
    the scheduling instant it was taken at — take a fresh one per batch.
    """

    __slots__ = ("platforms", "profs", "names", "n", "failed",
                 "total_memory_mb", "cpu_util", "mem_util", "_fn_cache")

    def __init__(self, platforms: Sequence[TargetPlatform]):
        self.platforms = list(platforms)
        self.n = len(self.platforms)
        self.profs = [p.prof for p in self.platforms]
        self.names = [pr.name for pr in self.profs]
        self.total_memory_mb = np.array(
            [float(pr.total_memory_mb) for pr in self.profs])
        self.failed = np.array(
            [bool(getattr(p, "failed", False)) for p in self.platforms])
        self.cpu_util = np.array([self._util(p, "cpu_util")
                                  for p in self.platforms])
        self.mem_util = np.array([self._util(p, "mem_util")
                                  for p in self.platforms])
        self._fn_cache: Dict[tuple, FnView] = {}

    @staticmethod
    def _util(p, attr: str) -> float:
        f = getattr(p, attr, None)
        return float(f()) if callable(f) else 0.0

    def fn_view(self, fn: FunctionSpec,
                perf: Optional[FunctionPerformanceModel] = None,
                placement: Optional[DataPlacementManager] = None,
                p90: bool = False, energy: bool = False) -> FnView:
        """Columns are computed on demand (a perf-ranked policy must not
        pay for P90/energy predictions) and filled incrementally on cache
        hits when a later policy asks for more."""
        # keyed by object identity: FunctionSpec hashing walks every field,
        # which is far too slow for 10^5-row batches
        key = (id(fn), id(perf), id(placement))
        v = self._fn_cache.get(key)
        if v is None:
            v = FnView(fn)
            deployed = np.array([fn.name in getattr(p, "deployed", {})
                                 for p in self.platforms])
            v.alive = (~self.failed) & deployed & \
                (self.total_memory_mb >= fn.memory_mb)
            if placement is not None and fn.data_objects:
                v.data_s = np.array(
                    [sum(placement.access_time(o, name)
                         for o in fn.data_objects) for name in self.names])
            else:
                v.data_s = np.zeros(self.n)
            self._fn_cache[key] = v
        if perf is not None:
            if v.exec_s is None:
                v.exec_s = np.array([perf.predict_exec(fn, pr)
                                     for pr in self.profs])
            if p90 and v.p90_s is None:
                v.p90_s = np.array([perf.predict_p90_response(fn, pr)
                                    for pr in self.profs])
            if energy and v.energy_j is None:
                v.energy_j = np.array([perf.predict_energy(fn, pr)
                                       for pr in self.profs])
        return v


PlatformsLike = Union[PlatformSnapshot, Sequence[TargetPlatform]]


def as_snapshot(platforms: PlatformsLike) -> PlatformSnapshot:
    if isinstance(platforms, PlatformSnapshot):
        return platforms
    return PlatformSnapshot(platforms)


class _SpecInv:
    """Invocation-shaped wrapper: lets bare FunctionSpecs flow through
    ``Policy.score`` (policies only read ``inv.fn``).  Chain planning
    scores *stages* — functions that have no live invocation yet."""

    __slots__ = ("fn",)

    def __init__(self, fn: FunctionSpec):
        self.fn = fn


class Policy:
    name = "base"

    # ------------------------------------------------- vectorized core ---
    def score(self, invs: Sequence[Invocation],
              snap: PlatformSnapshot) -> np.ndarray:
        """(N, P) cost matrix; np.inf marks an infeasible pairing."""
        raise NotImplementedError

    def score_specs(self, specs: Sequence[FunctionSpec],
                    platforms: PlatformsLike) -> np.ndarray:
        """(N, P) cost matrix for bare FunctionSpecs (one row per spec) —
        the whole-chain planner's entry point."""
        return self.score([_SpecInv(f) for f in specs],
                          as_snapshot(platforms))

    def choose_batch(self, invs: Sequence[Invocation],
                     platforms: PlatformsLike
                     ) -> List[Optional[TargetPlatform]]:
        """Route a whole batch in one policy evaluation (row-wise argmin)."""
        snap = as_snapshot(platforms)
        if not invs or snap.n == 0:
            return [None] * len(invs)
        costs = self.score(invs, snap)
        finite = np.isfinite(costs)
        any_ok = finite.any(axis=1)
        idx = np.argmin(np.where(finite, costs, np.inf), axis=1)
        plats = snap.platforms
        return [plats[j] if ok else None
                for j, ok in zip(idx.tolist(), any_ok.tolist())]

    def choose(self, inv: Invocation,
               platforms: PlatformsLike) -> Optional[TargetPlatform]:
        return self.choose_batch([inv], platforms)[0]

    # --------------------------------------------------------- helpers ---
    def _per_fn_rows(self, invs: Sequence[Invocation],
                     snap: PlatformSnapshot, row_fn) -> np.ndarray:
        """Assemble the (N, P) matrix from one cost row per distinct
        function (policy cost depends on the FunctionSpec, not on which
        invocation carries it)."""
        out = np.empty((len(invs), snap.n))
        groups: Dict[int, tuple] = {}
        for i, inv in enumerate(invs):
            g = groups.get(id(inv.fn))
            if g is None:
                groups[id(inv.fn)] = (inv.fn, [i])
            else:
                g[1].append(i)
        for fn, idxs in groups.values():
            out[idxs] = row_fn(fn)
        return out


def _masked(cost: np.ndarray, mask: np.ndarray) -> np.ndarray:
    return np.where(mask, cost, np.inf)


class PerformanceRankedPolicy(Policy):
    name = "perf_ranked"

    def __init__(self, perf: FunctionPerformanceModel):
        self.perf = perf

    def score(self, invs, snap):
        def row(fn):
            v = snap.fn_view(fn, self.perf)
            return _masked(v.exec_s, v.alive)
        return self._per_fn_rows(invs, snap, row)


class UtilizationAwarePolicy(Policy):
    name = "utilization_aware"

    def __init__(self, perf: FunctionPerformanceModel,
                 cpu_threshold: float = 0.9, mem_threshold: float = 0.9):
        self.perf = perf
        self.cpu_threshold = cpu_threshold
        self.mem_threshold = mem_threshold

    def score(self, invs, snap):
        unloaded = (snap.cpu_util < self.cpu_threshold) & \
            (snap.mem_util < self.mem_threshold)

        def row(fn):
            v = snap.fn_view(fn, self.perf)
            ok = v.alive & unloaded
            if not ok.any():                    # degrade gracefully
                ok = v.alive
            return _masked(v.exec_s, ok)
        return self._per_fn_rows(invs, snap, row)


class RoundRobinCollaboration(Policy):
    """Stateful: ``score`` consumes one rotation tick per row, so batch
    routing advances the round-robin exactly like N scalar ``choose``s."""
    name = "round_robin"

    def __init__(self):
        self._rr = itertools.count()

    def score(self, invs, snap):
        out = np.full((len(invs), snap.n), np.inf)
        cand_cache: Dict[int, List[int]] = {}
        for i, inv in enumerate(invs):
            cand = cand_cache.get(id(inv.fn))
            if cand is None:
                alive = snap.fn_view(inv.fn).alive
                cand = np.flatnonzero(alive).tolist()
                cand_cache[id(inv.fn)] = cand
            if cand:
                out[i, cand[next(self._rr) % len(cand)]] = 0.0
        return out


class WeightedCollaboration(Policy):
    """Static weights (paper used old-hpc:cloud = 5:1); weights may also be
    derived from the performance model (capacity-proportional). Stateful:
    ``score`` walks the weighted schedule one row at a time."""
    name = "weighted"

    def __init__(self, weights: Dict[str, int]):
        self.weights = dict(weights)
        self._sched: List[str] = []
        for name, w in weights.items():
            self._sched += [name] * max(int(w), 0)
        self._i = 0

    @classmethod
    def from_perf(cls, fn: FunctionSpec, perf: FunctionPerformanceModel,
                  platforms: Sequence[TargetPlatform], scale: int = 10):
        """Capacity-proportional weights: w ~ replicas / exec_time."""
        ws = {}
        for p in platforms:
            t = max(perf.predict_exec(fn, p.prof), 1e-6)
            ws[p.prof.name] = max(1, round(
                scale * p.prof.total_replicas / t /
                max(sum(q.prof.total_replicas for q in platforms), 1)))
        return cls(ws)

    def _pick(self, cand_cols: Dict[str, int]) -> Optional[int]:
        if not cand_cols or not self._sched:
            return next(iter(cand_cols.values()), None)
        for _ in range(len(self._sched)):
            name = self._sched[self._i % len(self._sched)]
            self._i += 1
            if name in cand_cols:
                return cand_cols[name]
        return next(iter(cand_cols.values()), None)

    def score(self, invs, snap):
        out = np.full((len(invs), snap.n), np.inf)
        cand_cache: Dict[int, Dict[str, int]] = {}
        for i, inv in enumerate(invs):
            cand = cand_cache.get(id(inv.fn))
            if cand is None:
                alive = snap.fn_view(inv.fn).alive
                cand = {snap.names[j]: j for j in np.flatnonzero(alive)}
                cand_cache[id(inv.fn)] = cand
            col = self._pick(cand)
            if col is not None:
                out[i, col] = 0.0
        return out


class DataLocalityPolicy(Policy):
    name = "data_locality"

    def __init__(self, perf: FunctionPerformanceModel,
                 placement: DataPlacementManager):
        self.perf = perf
        self.placement = placement

    def score(self, invs, snap):
        def row(fn):
            v = snap.fn_view(fn, self.perf, self.placement)
            return _masked(v.exec_s + v.data_s, v.alive)
        return self._per_fn_rows(invs, snap, row)


class EnergyAwarePolicy(Policy):
    """§5.2: among platforms predicted to meet the SLO, pick the one with
    the lowest predicted energy per invocation (the 17x edge result)."""
    name = "energy_aware"

    def __init__(self, perf: FunctionPerformanceModel):
        self.perf = perf

    def score(self, invs, snap):
        def row(fn):
            v = snap.fn_view(fn, self.perf, p90=True, energy=True)
            feasible = v.alive & (v.p90_s <= fn.slo.p90_response_s)
            if not feasible.any():
                feasible = v.alive
            return _masked(v.energy_j, feasible)
        return self._per_fn_rows(invs, snap, row)


class SLOCompositePolicy(Policy):
    """The FDN's production policy: hierarchical composite decision,
    reduced to a filter cascade over the snapshot's columns:
    utilization mask -> SLO-feasibility mask -> locality-adjusted latency
    + energy tie-break."""
    name = "slo_composite"

    def __init__(self, perf: FunctionPerformanceModel,
                 placement: Optional[DataPlacementManager] = None,
                 cpu_threshold: float = 0.9, mem_threshold: float = 0.95,
                 energy_weight: float = 0.1):
        self.perf = perf
        self.placement = placement
        self.cpu_threshold = cpu_threshold
        self.mem_threshold = mem_threshold
        self.energy_weight = energy_weight

    def score(self, invs, snap):
        unloaded = (snap.cpu_util < self.cpu_threshold) & \
            (snap.mem_util < self.mem_threshold)

        def row(fn):
            v = snap.fn_view(fn, self.perf, self.placement,
                             p90=True, energy=True)
            # (1) utilization filter (§5.1.2)
            ok = v.alive & unloaded
            if not ok.any():
                ok = v.alive
            # (2) SLO feasibility (§5.1.1)
            feasible = ok & (v.p90_s <= fn.slo.p90_response_s)
            if not feasible.any():
                feasible = ok
            # (3) locality-adjusted latency + energy tie-break (§5.1.4, §5.2)
            cost = (v.exec_s + v.data_s) + self.energy_weight * v.energy_j
            return _masked(cost, feasible)
        return self._per_fn_rows(invs, snap, row)


POLICIES = {cls.name: cls for cls in
            (PerformanceRankedPolicy, UtilizationAwarePolicy,
             RoundRobinCollaboration, WeightedCollaboration,
             DataLocalityPolicy, EnergyAwarePolicy, SLOCompositePolicy)}
