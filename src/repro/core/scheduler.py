"""FDN Scheduler (paper §3.1.3): delivers each invocation to the right
target platform. One policy class per opportunity evaluated in §5:

  PerformanceRankedPolicy   §5.1.1  rank platforms by benchmarked performance
  UtilizationAwarePolicy    §5.1.2  avoid platforms under CPU/memory pressure
  RoundRobinCollaboration   §5.1.3  NGINX-style RR across platforms
  WeightedCollaboration     §5.1.3  weighted (e.g. 5:1) across platforms
  DataLocalityPolicy        §5.1.4  schedule near the function's data
  EnergyAwarePolicy         §5.2    cheapest energy among SLO-feasible
  SLOCompositePolicy        the full FDN decision: utilization filter ->
                            SLO feasibility -> locality cost -> energy tie-
                            break (hierarchical; node choice delegated to
                            the platform's SidecarController)

Policies are *vectorized*: the platform set is snapshotted once into
columnar NumPy arrays (``PlatformSnapshot``) and each policy produces a
``score(invs, snapshot) -> (N, P)`` cost matrix in one pass, so a whole
arrival batch is routed with array ops instead of N x P Python calls.

A batch admission decision additionally collapses to one row per
*distinct function* (policy cost depends on the FunctionSpec, not on
which invocation carries it): ``fn_decisions`` evaluates the filter
cascade + cost + argmin once per (function, platform-set) and the batch
router broadcasts the per-function choice to every invocation of that
function.  The cascade runs on one of two backends:

  * ``numpy`` — host arrays (the historical path; always available);
  * ``jax``   — the ``jax.jit``-compiled cascades in
    ``repro.kernels.policy_score`` (with an optional fused Pallas
    filter+argmin kernel for the composite policy).

``set_score_backend("numpy"|"jax"|"auto")`` selects it; ``auto`` (the
default) uses jax for batches of at least ``JAX_DECIDE_MIN`` invocations
and numpy below that (tiny batches are dominated by dispatch overhead).
Both backends pick byte-identical platforms (tests pin parity on seeded
scenarios), so the choice is a throughput knob, not a semantic one.

``choose`` is the batch-of-1 case of ``choose_batch``; row-wise argmin
breaks ties exactly like the historical per-platform ``min`` scan
(first-lowest in platform order), so scalar and batch paths pick
identical platforms.
"""
from __future__ import annotations

import itertools
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.behavioral import FunctionPerformanceModel
from repro.core.data_placement import DataPlacementManager
from repro.core.platform import TargetPlatform
from repro.core.types import FunctionSpec, Invocation

# Minimum batch size at which the "auto" backend switches to the jitted
# cascades (below it, host NumPy wins on dispatch overhead alone).
JAX_DECIDE_MIN = 64

_SCORE_BACKEND = os.environ.get("FDN_SCORE_BACKEND", "auto")


def set_score_backend(mode: str) -> None:
    """Select the decision backend: "numpy", "jax", or "auto"."""
    if mode not in ("numpy", "jax", "auto"):
        raise ValueError(f"unknown score backend {mode!r}")
    global _SCORE_BACKEND
    _SCORE_BACKEND = mode


def get_score_backend() -> str:
    return _SCORE_BACKEND


_ps_mod = None
_ps_error: Optional[BaseException] = None


def _policy_score_mod():
    """The jitted-cascade module, or None when jax is unavailable (the
    NumPy fallback keeps the scheduler fully functional without it)."""
    global _ps_mod, _ps_error
    if _ps_mod is None and _ps_error is None:
        try:
            from repro.kernels import policy_score as mod
            _ps_mod = mod
        except Exception as exc:          # missing/incompatible jax
            _ps_error = exc
    return _ps_mod


def _use_jax_backend(n: int) -> bool:
    if _SCORE_BACKEND == "numpy":
        return False
    if _SCORE_BACKEND == "auto" and n < JAX_DECIDE_MIN:
        return False
    if _policy_score_mod() is None:
        if _SCORE_BACKEND == "jax":
            # an explicit jax request must not silently measure (or CI-
            # gate) the NumPy path — only "auto" may degrade
            raise RuntimeError(
                "score backend 'jax' requested but the jitted cascades "
                "are unavailable") from _ps_error
        return False
    return True


class FnView:
    """Per-function columns over a snapshot's platforms (one row of the
    decision problem, broadcast to every invocation of that function)."""

    __slots__ = ("fn", "alive", "exec_s", "p90_s", "energy_j", "data_s",
                 "warm_free")

    def __init__(self, fn: FunctionSpec):
        self.fn = fn
        self.alive: Optional[np.ndarray] = None
        self.exec_s: Optional[np.ndarray] = None
        self.p90_s: Optional[np.ndarray] = None
        self.energy_j: Optional[np.ndarray] = None
        self.data_s: Optional[np.ndarray] = None
        self.warm_free: Optional[np.ndarray] = None


class PlatformSnapshot:
    """Columnar view of a platform set at one scheduling instant.

    Platform state (memory, CPU/memory utilization, liveness, deployment)
    is captured eagerly; per-function predictions (exec / P90 / energy /
    data-access time) are computed lazily, once per distinct function, and
    cached for the lifetime of the snapshot.  A snapshot is only valid for
    the scheduling instant it was taken at — take a fresh one per batch.
    """

    __slots__ = ("platforms", "profs", "names", "n", "failed",
                 "total_memory_mb", "cpu_util", "mem_util", "cold_start_s",
                 "_warm_total", "_power", "_fn_cache")

    def __init__(self, platforms: Sequence[TargetPlatform]):
        self.platforms = list(platforms)
        self.n = len(self.platforms)
        self.profs = [p.prof for p in self.platforms]
        self.names = [pr.name for pr in self.profs]
        self.total_memory_mb = np.array(
            [float(pr.total_memory_mb) for pr in self.profs])
        self.failed = np.array(
            [bool(getattr(p, "failed", False)) for p in self.platforms])
        self.cpu_util = np.array([self._util(p, "cpu_util")
                                  for p in self.platforms])
        self.mem_util = np.array([self._util(p, "mem_util")
                                  for p in self.platforms])
        # warm-pool columns (repro.autoscale): per-platform cold-start
        # seconds and total idle warm replicas, so policies can prefer
        # platforms with warm capacity standing by (the total is lazy —
        # no current policy consumes it on the admission hot path)
        self.cold_start_s = np.array([float(pr.cold_start_s)
                                      for pr in self.profs])
        self._warm_total: Optional[np.ndarray] = None
        self._power: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._fn_cache: Dict[tuple, FnView] = {}

    @property
    def warm_total(self) -> np.ndarray:
        if self._warm_total is None:
            self._warm_total = np.array(
                [float(p.idle_warm_total()) for p in self.platforms])
        return self._warm_total

    @property
    def power(self) -> Tuple[np.ndarray, np.ndarray]:
        """(nodes, loaded watts/node) per-platform vectors — the energy
        terms of the fused admission step."""
        if self._power is None:
            self._power = (
                np.array([float(pr.nodes) for pr in self.profs]),
                np.array([pr.loaded_w_per_node for pr in self.profs]))
        return self._power

    @staticmethod
    def _util(p, attr: str) -> float:
        f = getattr(p, attr, None)
        return float(f()) if callable(f) else 0.0

    def _base_view(self, key: tuple, fn: FunctionSpec,
                   placement: Optional[DataPlacementManager]) -> FnView:
        """The prediction-free columns of one function's view (liveness,
        data-access seconds, warm-pool) — created once per cache key."""
        v = self._fn_cache.get(key)
        if v is None:
            v = FnView(fn)
            deployed = np.array([fn.name in getattr(p, "deployed", {})
                                 for p in self.platforms])
            v.alive = (~self.failed) & deployed & \
                (self.total_memory_mb >= fn.memory_mb)
            if placement is not None and fn.data_objects:
                v.data_s = np.array(
                    [sum(placement.access_time(o, name)
                         for o in fn.data_objects) for name in self.names])
            else:
                v.data_s = np.zeros(self.n)
            v.warm_free = np.array(
                [float(p.idle_warm(fn.name)) for p in self.platforms])
            self._fn_cache[key] = v
        return v

    def fn_view(self, fn: FunctionSpec,
                perf: Optional[FunctionPerformanceModel] = None,
                placement: Optional[DataPlacementManager] = None,
                p90: bool = False, energy: bool = False) -> FnView:
        """Columns are computed on demand (a perf-ranked policy must not
        pay for P90/energy predictions) and filled incrementally on cache
        hits when a later policy asks for more."""
        # keyed by object identity: FunctionSpec hashing walks every field,
        # which is far too slow for 10^5-row batches
        v = self._base_view((id(fn), id(perf), id(placement)), fn,
                            placement)
        if perf is not None:
            if v.exec_s is None:
                v.exec_s = np.array([perf.predict_exec(fn, pr)
                                     for pr in self.profs])
            if p90 and v.p90_s is None:
                v.p90_s = np.array([perf.predict_p90_response(fn, pr)
                                    for pr in self.profs])
            if energy and v.energy_j is None:
                v.energy_j = np.array([perf.predict_energy(fn, pr)
                                       for pr in self.profs])
        return v

    def fn_matrix(self, fns: Sequence[FunctionSpec],
                  perf: Optional[FunctionPerformanceModel] = None,
                  placement: Optional[DataPlacementManager] = None,
                  p90: bool = False, energy: bool = False
                  ) -> Dict[str, np.ndarray]:
        """(F, P) matrices stacked from the per-function views — the
        columnar input the jitted decision cascades consume.

        Prediction columns for functions not yet in the snapshot cache
        are built by ONE vectorized ``perf.predict_matrix`` pass over the
        columnar estimator state (bit-identical to the scalar
        ``predict_*`` loop the single-function path keeps)."""
        if perf is None or len(fns) == 1:
            views = [self.fn_view(fn, perf, placement, p90=p90,
                                  energy=energy) for fn in fns]
        else:
            views = [self._base_view((id(fn), id(perf), id(placement)),
                                     fn, placement) for fn in fns]
            seen = set()
            fill_fns, fill_views = [], []
            for fn, v in zip(fns, views):
                if id(fn) in seen:
                    continue
                seen.add(id(fn))
                if v.exec_s is None or (p90 and v.p90_s is None) or \
                        (energy and v.energy_j is None):
                    fill_fns.append(fn)
                    fill_views.append(v)
            if fill_fns:
                m = perf.predict_matrix(fill_fns, self.profs, p90=p90,
                                        energy=energy)
                for r, v in enumerate(fill_views):
                    if v.exec_s is None:
                        v.exec_s = m["exec_s"][r]
                    if p90 and v.p90_s is None:
                        v.p90_s = m["p90_s"][r]
                    if energy and v.energy_j is None:
                        v.energy_j = m["energy_j"][r]
        if len(views) == 1:                  # scalar choose: views, no copy
            v = views[0]
            out = {"alive": v.alive[None], "data_s": v.data_s[None],
                   "warm_free": v.warm_free[None]}
            if perf is not None:
                out["exec_s"] = v.exec_s[None]
                if p90:
                    out["p90_s"] = v.p90_s[None]
                if energy:
                    out["energy_j"] = v.energy_j[None]
            return out
        out = {"alive": np.stack([v.alive for v in views]),
               "data_s": np.stack([v.data_s for v in views]),
               "warm_free": np.stack([v.warm_free for v in views])}
        if perf is not None:
            out["exec_s"] = np.stack([v.exec_s for v in views])
            if p90:
                out["p90_s"] = np.stack([v.p90_s for v in views])
            if energy:
                out["energy_j"] = np.stack([v.energy_j for v in views])
        return out


PlatformsLike = Union[PlatformSnapshot, Sequence[TargetPlatform]]


def as_snapshot(platforms: PlatformsLike) -> PlatformSnapshot:
    if isinstance(platforms, PlatformSnapshot):
        return platforms
    return PlatformSnapshot(platforms)


def group_by_fn(invs: Sequence[Invocation]
                ) -> List[Tuple[FunctionSpec, List[int]]]:
    """Distinct functions (by object identity, first-appearance order)
    with the invocation indices that carry each."""
    groups: Dict[int, Tuple[FunctionSpec, List[int]]] = {}
    order: List[Tuple[FunctionSpec, List[int]]] = []
    for i, inv in enumerate(invs):
        g = groups.get(id(inv.fn))
        if g is None:
            g = (inv.fn, [i])
            groups[id(inv.fn)] = g
            order.append(g)
        else:
            g[1].append(i)
    return order


class _SpecInv:
    """Invocation-shaped wrapper: lets bare FunctionSpecs flow through
    ``Policy.score`` (policies only read ``inv.fn``).  Chain planning
    scores *stages* — functions that have no live invocation yet."""

    __slots__ = ("fn",)

    def __init__(self, fn: FunctionSpec):
        self.fn = fn


# Filter-kill bitmask bits recorded by the decision journal
# (repro.obs.provenance).  Values mirror ``repro.kernels.policy_score``.
KILL_DEAD = 1    # platform failed / no replicas (alive mask)
KILL_UTIL = 2    # alive but dropped by the utilization filter
KILL_SLO = 4     # survived utilization but dropped by SLO feasibility


def _row(x: np.ndarray) -> np.ndarray:
    """Broadcast a per-platform (P,) vector against (F, P) matrices; a
    journal replay passes already-row-shaped (rows, P) matrices through
    unchanged — broadcasting duplicates values, so the elementwise
    arithmetic is bit-identical either way."""
    return x if x.ndim == 2 else x[None, :]


def decision_features(fns: Sequence[FunctionSpec], snap: PlatformSnapshot,
                      perf: FunctionPerformanceModel,
                      placement: Optional[DataPlacementManager]
                      ) -> Dict[str, np.ndarray]:
    """The full standard feature set every stateless policy cascade is a
    pure function of — one (F, P) matrix or (P,)/(F,) vector per signal.
    The decision journal snapshots exactly these columns so an offline
    what-if replay can re-score them under *any* policy/params.

    Base columns and predictions are fetched separately — the same
    two-step shape as the fused jit path, so on the admission hot path
    both the snapshot's base-view cache and the perf model's gather
    memo hit and this costs stacks + three ``np.where`` passes."""
    base = snap.fn_matrix(fns, None, placement)
    pred = perf.predict_matrix(fns, snap.profs, p90=True, energy=True)
    return {
        "alive": base["alive"], "exec_s": pred["exec_s"],
        "data_s": base["data_s"], "p90_s": pred["p90_s"],
        "energy_j": pred["energy_j"], "warm_free": base["warm_free"],
        "cpu_util": snap.cpu_util, "mem_util": snap.mem_util,
        "cold_start_s": snap.cold_start_s,
        "slo_s": _slo_vector(fns),
    }


class Policy:
    name = "base"

    # Stateless policies expose ``cascade``: a pure staticmethod over the
    # ``decision_features`` columns returning (cost (F, P) float64,
    # kill (F, P) uint8 bitmask; kill == 0 marks feasible-after-degrade).
    # It mirrors ``fn_cost_matrix`` op for op, so re-running it over
    # journaled feature columns reproduces the original numpy-backend
    # choices byte-identically (the what-if correctness oracle).
    # Stateful rotation policies keep ``cascade = None``.
    cascade = None
    # Tunables ``cascade`` reads from its params dict, with defaults
    # matching the policy constructor; ``cascade_params`` extracts the
    # live instance's values.
    CASCADE_PARAMS: Dict[str, float] = {}

    def cascade_params(self) -> Dict[str, float]:
        return {k: getattr(self, k) for k in type(self).CASCADE_PARAMS}

    # ------------------------------------------------- vectorized core ---
    def fn_cost_matrix(self, fns: Sequence[FunctionSpec],
                       snap: PlatformSnapshot) -> Optional[np.ndarray]:
        """(F, P) masked cost matrix, one row per distinct function
        (np.inf marks an infeasible pairing) — or None for policies whose
        score is per-invocation stateful (rotation policies)."""
        return None

    def _jax_decide(self, fns: Sequence[FunctionSpec],
                    snap: PlatformSnapshot
                    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Jitted-cascade decision (repro.kernels.policy_score), or None
        when this policy has no compiled variant."""
        return None

    def fn_decisions(self, fns: Sequence[FunctionSpec],
                     snap: PlatformSnapshot, n: Optional[int] = None
                     ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Fused decision per distinct function: (platform index, any-
        feasible) arrays of shape (F,).  ``n`` is the size of the batch
        being routed (backend selection under "auto").  Returns None for
        stateful policies — callers fall back to the full score matrix.
        """
        if _use_jax_backend(len(fns) if n is None else n):
            res = self._jax_decide(fns, snap)
            if res is not None:
                return np.asarray(res[0]), np.asarray(res[1])
        rows = self.fn_cost_matrix(fns, snap)
        if rows is None:
            return None
        finite = np.isfinite(rows)
        return (np.argmin(np.where(finite, rows, np.inf), axis=1),
                finite.any(axis=1))

    def score(self, invs: Sequence[Invocation],
              snap: PlatformSnapshot) -> np.ndarray:
        """(N, P) cost matrix; np.inf marks an infeasible pairing."""
        groups = group_by_fn(invs)
        rows = self.fn_cost_matrix([g[0] for g in groups], snap)
        if rows is None:
            raise NotImplementedError
        out = np.empty((len(invs), snap.n))
        for g, (_fn, idxs) in enumerate(groups):
            out[idxs] = rows[g]
        return out

    def score_specs(self, specs: Sequence[FunctionSpec],
                    platforms: PlatformsLike) -> np.ndarray:
        """(N, P) cost matrix for bare FunctionSpecs (one row per spec) —
        the whole-chain planner's entry point."""
        return self.score([_SpecInv(f) for f in specs],
                          as_snapshot(platforms))

    def choose_batch(self, invs: Sequence[Invocation],
                     platforms: PlatformsLike
                     ) -> List[Optional[TargetPlatform]]:
        """Route a whole batch in one policy evaluation.

        Stateless policies collapse to one fused decision per distinct
        function (``fn_decisions``); stateful ones keep the historical
        full-matrix row-wise argmin.  Both break ties first-lowest."""
        snap = as_snapshot(platforms)
        if not invs or snap.n == 0:
            return [None] * len(invs)
        groups = group_by_fn(invs)
        res = self.fn_decisions([g[0] for g in groups], snap, n=len(invs))
        plats = snap.platforms
        if res is None:
            costs = self.score(invs, snap)
            finite = np.isfinite(costs)
            any_ok = finite.any(axis=1)
            idx = np.argmin(np.where(finite, costs, np.inf), axis=1)
            return [plats[j] if ok else None
                    for j, ok in zip(idx.tolist(), any_ok.tolist())]
        idx, ok_arr = res
        out: List[Optional[TargetPlatform]] = [None] * len(invs)
        for g, (_fn, idxs) in enumerate(groups):
            if ok_arr[g]:
                p = plats[int(idx[g])]
                for i in idxs:
                    out[i] = p
        return out

    def choose(self, inv: Invocation,
               platforms: PlatformsLike) -> Optional[TargetPlatform]:
        return self.choose_batch([inv], platforms)[0]


def _masked(cost: np.ndarray, mask: np.ndarray) -> np.ndarray:
    return np.where(mask, cost, np.inf)


class PerformanceRankedPolicy(Policy):
    name = "perf_ranked"

    def __init__(self, perf: FunctionPerformanceModel):
        self.perf = perf

    def fn_cost_matrix(self, fns, snap):
        m = snap.fn_matrix(fns, self.perf)
        return _masked(m["exec_s"], m["alive"])

    def _jax_decide(self, fns, snap):
        ps = _policy_score_mod()
        m = snap.fn_matrix(fns, self.perf)
        return ps.perf_ranked_decide(m["exec_s"], m["alive"])

    @staticmethod
    def cascade(feats, params):
        alive = feats["alive"]
        kill = np.where(~alive, KILL_DEAD, 0).astype(np.uint8)
        return feats["exec_s"], kill


class UtilizationAwarePolicy(Policy):
    name = "utilization_aware"

    def __init__(self, perf: FunctionPerformanceModel,
                 cpu_threshold: float = 0.9, mem_threshold: float = 0.9):
        self.perf = perf
        self.cpu_threshold = cpu_threshold
        self.mem_threshold = mem_threshold

    def _unloaded(self, snap):
        return (snap.cpu_util < self.cpu_threshold) & \
            (snap.mem_util < self.mem_threshold)

    def fn_cost_matrix(self, fns, snap):
        m = snap.fn_matrix(fns, self.perf)
        ok = m["alive"] & self._unloaded(snap)[None, :]
        ok = np.where(ok.any(axis=1, keepdims=True), ok, m["alive"])
        return _masked(m["exec_s"], ok)

    def _jax_decide(self, fns, snap):
        ps = _policy_score_mod()
        m = snap.fn_matrix(fns, self.perf)
        return ps.utilization_decide(m["exec_s"], m["alive"],
                                     self._unloaded(snap))

    CASCADE_PARAMS = {"cpu_threshold": 0.9, "mem_threshold": 0.9}

    @staticmethod
    def cascade(feats, params):
        alive = feats["alive"]
        unloaded = _row((feats["cpu_util"] < params["cpu_threshold"]) &
                        (feats["mem_util"] < params["mem_threshold"]))
        ok = alive & unloaded
        ok = np.where(ok.any(axis=1, keepdims=True), ok, alive)
        kill = (np.where(~alive, KILL_DEAD, 0) |
                np.where(alive & ~ok, KILL_UTIL, 0)).astype(np.uint8)
        return feats["exec_s"], kill


class RoundRobinCollaboration(Policy):
    """Stateful: ``score`` consumes one rotation tick per row, so batch
    routing advances the round-robin exactly like N scalar ``choose``s."""
    name = "round_robin"

    def __init__(self):
        self._rr = itertools.count()

    def score(self, invs, snap):
        out = np.full((len(invs), snap.n), np.inf)
        cand_cache: Dict[int, List[int]] = {}
        for i, inv in enumerate(invs):
            cand = cand_cache.get(id(inv.fn))
            if cand is None:
                alive = snap.fn_view(inv.fn).alive
                cand = np.flatnonzero(alive).tolist()
                cand_cache[id(inv.fn)] = cand
            if cand:
                out[i, cand[next(self._rr) % len(cand)]] = 0.0
        return out


class WeightedCollaboration(Policy):
    """Static weights (paper used old-hpc:cloud = 5:1); weights may also be
    derived from the performance model (capacity-proportional). Stateful:
    ``score`` walks the weighted schedule one row at a time."""
    name = "weighted"

    def __init__(self, weights: Dict[str, int]):
        self.weights = dict(weights)
        self._sched: List[str] = []
        for name, w in weights.items():
            self._sched += [name] * max(int(w), 0)
        self._i = 0

    @classmethod
    def from_perf(cls, fn: FunctionSpec, perf: FunctionPerformanceModel,
                  platforms: Sequence[TargetPlatform], scale: int = 10):
        """Capacity-proportional weights: w ~ replicas / exec_time."""
        ws = {}
        for p in platforms:
            t = max(perf.predict_exec(fn, p.prof), 1e-6)
            ws[p.prof.name] = max(1, round(
                scale * p.prof.total_replicas / t /
                max(sum(q.prof.total_replicas for q in platforms), 1)))
        return cls(ws)

    def _pick(self, cand_cols: Dict[str, int]) -> Optional[int]:
        if not cand_cols or not self._sched:
            return next(iter(cand_cols.values()), None)
        for _ in range(len(self._sched)):
            name = self._sched[self._i % len(self._sched)]
            self._i += 1
            if name in cand_cols:
                return cand_cols[name]
        return next(iter(cand_cols.values()), None)

    def score(self, invs, snap):
        out = np.full((len(invs), snap.n), np.inf)
        cand_cache: Dict[int, Dict[str, int]] = {}
        for i, inv in enumerate(invs):
            cand = cand_cache.get(id(inv.fn))
            if cand is None:
                alive = snap.fn_view(inv.fn).alive
                cand = {snap.names[j]: j for j in np.flatnonzero(alive)}
                cand_cache[id(inv.fn)] = cand
            col = self._pick(cand)
            if col is not None:
                out[i, col] = 0.0
        return out


class DataLocalityPolicy(Policy):
    name = "data_locality"

    def __init__(self, perf: FunctionPerformanceModel,
                 placement: DataPlacementManager):
        self.perf = perf
        self.placement = placement

    def fn_cost_matrix(self, fns, snap):
        m = snap.fn_matrix(fns, self.perf, self.placement)
        return _masked(m["exec_s"] + m["data_s"], m["alive"])

    def _jax_decide(self, fns, snap):
        ps = _policy_score_mod()
        m = snap.fn_matrix(fns, self.perf, self.placement)
        return ps.locality_decide(m["exec_s"], m["data_s"], m["alive"])

    @staticmethod
    def cascade(feats, params):
        alive = feats["alive"]
        kill = np.where(~alive, KILL_DEAD, 0).astype(np.uint8)
        return feats["exec_s"] + feats["data_s"], kill


class WarmAwarePolicy(Policy):
    """Cold-start-aware routing over the snapshot's warm-pool columns
    (repro.autoscale): locality-adjusted latency plus the platform's full
    cold-start penalty whenever the function has no idle warm replica
    standing by — so traffic prefers platforms whose warm pools (TTL'd or
    predictively prewarmed) already hold capacity for it."""

    name = "warm_aware"

    def __init__(self, perf: FunctionPerformanceModel,
                 placement: Optional[DataPlacementManager] = None):
        self.perf = perf
        self.placement = placement

    def fn_cost_matrix(self, fns, snap):
        m = snap.fn_matrix(fns, self.perf, self.placement)
        cold = np.where(m["warm_free"] > 0.0, 0.0,
                        snap.cold_start_s[None, :])
        return _masked(m["exec_s"] + m["data_s"] + cold, m["alive"])

    def _jax_decide(self, fns, snap):
        ps = _policy_score_mod()
        m = snap.fn_matrix(fns, self.perf, self.placement)
        return ps.warm_decide(m["exec_s"], m["data_s"], m["warm_free"],
                              snap.cold_start_s, m["alive"])

    @staticmethod
    def cascade(feats, params):
        alive = feats["alive"]
        cold = np.where(feats["warm_free"] > 0.0, 0.0,
                        _row(feats["cold_start_s"]))
        kill = np.where(~alive, KILL_DEAD, 0).astype(np.uint8)
        return feats["exec_s"] + feats["data_s"] + cold, kill


def _slo_vector(fns: Sequence[FunctionSpec]) -> np.ndarray:
    return np.array([fn.slo.p90_response_s for fn in fns])


class EnergyAwarePolicy(Policy):
    """§5.2: among platforms predicted to meet the SLO, pick the one with
    the lowest predicted energy per invocation (the 17x edge result)."""
    name = "energy_aware"

    def __init__(self, perf: FunctionPerformanceModel):
        self.perf = perf

    def fn_cost_matrix(self, fns, snap):
        m = snap.fn_matrix(fns, self.perf, p90=True, energy=True)
        feasible = m["alive"] & (m["p90_s"] <= _slo_vector(fns)[:, None])
        feasible = np.where(feasible.any(axis=1, keepdims=True), feasible,
                            m["alive"])
        return _masked(m["energy_j"], feasible)

    def _jax_decide(self, fns, snap):
        ps = _policy_score_mod()
        m = snap.fn_matrix(fns, self.perf, p90=True, energy=True)
        return ps.energy_decide(m["energy_j"], m["p90_s"],
                                _slo_vector(fns), m["alive"])

    @staticmethod
    def cascade(feats, params):
        alive = feats["alive"]
        feasible = alive & (feats["p90_s"] <= feats["slo_s"][:, None])
        feasible = np.where(feasible.any(axis=1, keepdims=True), feasible,
                            alive)
        kill = (np.where(~alive, KILL_DEAD, 0) |
                np.where(alive & ~feasible, KILL_SLO, 0)).astype(np.uint8)
        return feats["energy_j"], kill


class SLOCompositePolicy(Policy):
    """The FDN's production policy: hierarchical composite decision,
    reduced to a filter cascade over the snapshot's columns:
    utilization mask -> SLO-feasibility mask -> locality-adjusted latency
    + energy tie-break."""

    name = "slo_composite"

    def __init__(self, perf: FunctionPerformanceModel,
                 placement: Optional[DataPlacementManager] = None,
                 cpu_threshold: float = 0.9, mem_threshold: float = 0.95,
                 energy_weight: float = 0.1):
        self.perf = perf
        self.placement = placement
        self.cpu_threshold = cpu_threshold
        self.mem_threshold = mem_threshold
        self.energy_weight = energy_weight

    def _unloaded(self, snap):
        return (snap.cpu_util < self.cpu_threshold) & \
            (snap.mem_util < self.mem_threshold)

    def _columns(self, fns, snap):
        return snap.fn_matrix(fns, self.perf, self.placement,
                              p90=True, energy=True)

    def fn_cost_matrix(self, fns, snap):
        m = self._columns(fns, snap)
        # (1) utilization filter (§5.1.2)
        ok = m["alive"] & self._unloaded(snap)[None, :]
        ok = np.where(ok.any(axis=1, keepdims=True), ok, m["alive"])
        # (2) SLO feasibility (§5.1.1)
        feasible = ok & (m["p90_s"] <= _slo_vector(fns)[:, None])
        feasible = np.where(feasible.any(axis=1, keepdims=True), feasible,
                            ok)
        # (3) locality-adjusted latency + energy tie-break (§5.1.4, §5.2)
        cost = (m["exec_s"] + m["data_s"]) + \
            self.energy_weight * m["energy_j"]
        return _masked(cost, feasible)

    def _jax_decide(self, fns, snap):
        """ONE fused jit step from raw estimator state: snapshot
        prediction columns (EWMA/P² gates, power model), filter cascade
        and argmin all compile into a single device program — the host
        never materializes exec/P90/energy matrices on this path."""
        ps = _policy_score_mod()
        base = snap.fn_matrix(fns, None, self.placement)
        est = self.perf.estimator_columns(fns, snap.profs)
        nodes, loaded_w = snap.power
        args = (est["ewma_v"], est["ewma_n"], est["analytic_s"],
                est["resp_h2"], est["resp_n"], base["data_s"], nodes,
                loaded_w, base["alive"], self._unloaded(snap),
                _slo_vector(fns), self.energy_weight)
        if ps.use_pallas():
            return ps.fused_composite_decide_pallas(*args)
        return ps.fused_composite_decide(*args)

    CASCADE_PARAMS = {"cpu_threshold": 0.9, "mem_threshold": 0.95,
                      "energy_weight": 0.1}

    @staticmethod
    def cascade(feats, params):
        alive = feats["alive"]
        unloaded = _row((feats["cpu_util"] < params["cpu_threshold"]) &
                        (feats["mem_util"] < params["mem_threshold"]))
        ok = alive & unloaded
        ok = np.where(ok.any(axis=1, keepdims=True), ok, alive)
        feasible = ok & (feats["p90_s"] <= feats["slo_s"][:, None])
        feasible = np.where(feasible.any(axis=1, keepdims=True), feasible,
                            ok)
        cost = (feats["exec_s"] + feats["data_s"]) + \
            params["energy_weight"] * feats["energy_j"]
        kill = (np.where(~alive, KILL_DEAD, 0) |
                np.where(alive & ~ok, KILL_UTIL, 0) |
                np.where(ok & ~feasible, KILL_SLO, 0)).astype(np.uint8)
        return cost, kill


POLICIES = {cls.name: cls for cls in
            (PerformanceRankedPolicy, UtilizationAwarePolicy,
             RoundRobinCollaboration, WeightedCollaboration,
             DataLocalityPolicy, WarmAwarePolicy, EnergyAwarePolicy,
             SLOCompositePolicy)}
