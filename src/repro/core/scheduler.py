"""FDN Scheduler (paper §3.1.3): delivers each invocation to the right
target platform. One policy class per opportunity evaluated in §5:

  PerformanceRankedPolicy   §5.1.1  rank platforms by benchmarked performance
  UtilizationAwarePolicy    §5.1.2  avoid platforms under CPU/memory pressure
  RoundRobinCollaboration   §5.1.3  NGINX-style RR across platforms
  WeightedCollaboration     §5.1.3  weighted (e.g. 5:1) across platforms
  DataLocalityPolicy        §5.1.4  schedule near the function's data
  EnergyAwarePolicy         §5.2    cheapest energy among SLO-feasible
  SLOCompositePolicy        the full FDN decision: utilization filter ->
                            SLO feasibility -> locality cost -> energy tie-
                            break (hierarchical; node choice delegated to
                            the platform's SidecarController)
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence

from repro.core.behavioral import FunctionPerformanceModel
from repro.core.data_placement import DataPlacementManager
from repro.core.platform import TargetPlatform
from repro.core.types import FunctionSpec, Invocation


class Policy:
    name = "base"

    def choose(self, inv: Invocation,
               platforms: Sequence[TargetPlatform]
               ) -> Optional[TargetPlatform]:
        raise NotImplementedError

    def _alive(self, inv: Invocation, platforms) -> List[TargetPlatform]:
        """Deployed, alive, and the function FITS (a 405B model's weights
        cannot be delivered to a 16-chip slice — hard capability check)."""
        return [p for p in platforms
                if not p.failed and inv.fn.name in p.deployed
                and p.prof.total_memory_mb >= inv.fn.memory_mb]


class PerformanceRankedPolicy(Policy):
    name = "perf_ranked"

    def __init__(self, perf: FunctionPerformanceModel):
        self.perf = perf

    def choose(self, inv, platforms):
        cands = self._alive(inv, platforms)
        if not cands:
            return None
        return min(cands,
                   key=lambda p: self.perf.predict_exec(inv.fn, p.prof))


class UtilizationAwarePolicy(Policy):
    name = "utilization_aware"

    def __init__(self, perf: FunctionPerformanceModel,
                 cpu_threshold: float = 0.9, mem_threshold: float = 0.9):
        self.perf = perf
        self.cpu_threshold = cpu_threshold
        self.mem_threshold = mem_threshold

    def choose(self, inv, platforms):
        cands = self._alive(inv, platforms)
        if not cands:
            return None
        ok = [p for p in cands
              if p.cpu_util() < self.cpu_threshold
              and p.mem_util() < self.mem_threshold]
        pool = ok or cands                      # degrade gracefully
        return min(pool,
                   key=lambda p: self.perf.predict_exec(inv.fn, p.prof))


class RoundRobinCollaboration(Policy):
    name = "round_robin"

    def __init__(self):
        self._rr = itertools.count()

    def choose(self, inv, platforms):
        cands = self._alive(inv, platforms)
        if not cands:
            return None
        return cands[next(self._rr) % len(cands)]


class WeightedCollaboration(Policy):
    """Static weights (paper used old-hpc:cloud = 5:1); weights may also be
    derived from the performance model (capacity-proportional)."""
    name = "weighted"

    def __init__(self, weights: Dict[str, int]):
        self.weights = dict(weights)
        self._sched: List[str] = []
        for name, w in weights.items():
            self._sched += [name] * max(int(w), 0)
        self._i = 0

    @classmethod
    def from_perf(cls, fn: FunctionSpec, perf: FunctionPerformanceModel,
                  platforms: Sequence[TargetPlatform], scale: int = 10):
        """Capacity-proportional weights: w ~ replicas / exec_time."""
        ws = {}
        for p in platforms:
            t = max(perf.predict_exec(fn, p.prof), 1e-6)
            ws[p.prof.name] = max(1, round(
                scale * p.prof.total_replicas / t /
                max(sum(q.prof.total_replicas for q in platforms), 1)))
        return cls(ws)

    def choose(self, inv, platforms):
        cands = {p.prof.name: p for p in self._alive(inv, platforms)}
        if not cands or not self._sched:
            return next(iter(cands.values()), None)
        for _ in range(len(self._sched)):
            name = self._sched[self._i % len(self._sched)]
            self._i += 1
            if name in cands:
                return cands[name]
        return next(iter(cands.values()), None)


class DataLocalityPolicy(Policy):
    name = "data_locality"

    def __init__(self, perf: FunctionPerformanceModel,
                 placement: DataPlacementManager):
        self.perf = perf
        self.placement = placement

    def score(self, inv: Invocation, p: TargetPlatform) -> float:
        data_t = sum(self.placement.access_time(o, p.prof.name)
                     for o in inv.fn.data_objects)
        return self.perf.predict_exec(inv.fn, p.prof) + data_t

    def choose(self, inv, platforms):
        cands = self._alive(inv, platforms)
        if not cands:
            return None
        return min(cands, key=lambda p: self.score(inv, p))


class EnergyAwarePolicy(Policy):
    """§5.2: among platforms predicted to meet the SLO, pick the one with
    the lowest predicted energy per invocation (the 17x edge result)."""
    name = "energy_aware"

    def __init__(self, perf: FunctionPerformanceModel):
        self.perf = perf

    def choose(self, inv, platforms):
        cands = self._alive(inv, platforms)
        if not cands:
            return None
        feasible = [p for p in cands
                    if self.perf.predict_p90_response(inv.fn, p.prof)
                    <= inv.fn.slo.p90_response_s]
        pool = feasible or cands
        return min(pool,
                   key=lambda p: self.perf.predict_energy(inv.fn, p.prof))


class SLOCompositePolicy(Policy):
    """The FDN's production policy: hierarchical composite decision."""
    name = "slo_composite"

    def __init__(self, perf: FunctionPerformanceModel,
                 placement: Optional[DataPlacementManager] = None,
                 cpu_threshold: float = 0.9, mem_threshold: float = 0.95,
                 energy_weight: float = 0.1):
        self.perf = perf
        self.placement = placement
        self.cpu_threshold = cpu_threshold
        self.mem_threshold = mem_threshold
        self.energy_weight = energy_weight

    def choose(self, inv, platforms):
        cands = self._alive(inv, platforms)
        if not cands:
            return None
        # (1) utilization filter (§5.1.2)
        ok = [p for p in cands if p.cpu_util() < self.cpu_threshold
              and p.mem_util() < self.mem_threshold] or cands
        # (2) SLO feasibility (§5.1.1)
        feasible = [p for p in ok
                    if self.perf.predict_p90_response(inv.fn, p.prof)
                    <= inv.fn.slo.p90_response_s] or ok

        # (3) locality-adjusted latency + energy tie-break (§5.1.4, §5.2)
        def score(p: TargetPlatform) -> float:
            t = self.perf.predict_exec(inv.fn, p.prof)
            if self.placement is not None:
                t += sum(self.placement.access_time(o, p.prof.name)
                         for o in inv.fn.data_objects)
            e = self.perf.predict_energy(inv.fn, p.prof)
            return t + self.energy_weight * e

        return min(feasible, key=score)


POLICIES = {cls.name: cls for cls in
            (PerformanceRankedPolicy, UtilizationAwarePolicy,
             RoundRobinCollaboration, WeightedCollaboration,
             DataLocalityPolicy, EnergyAwarePolicy, SLOCompositePolicy)}
