"""FDN Control Plane (paper §3.1): the joint management layer over all
target platforms — access control, monitoring, hierarchical scheduling,
data placement, fault tolerance, and elastic platform membership.

Flow per invocation (Fig. 3): Gateway -> access control -> Scheduler policy
chooses the target platform -> that platform's SidecarController admits it
locally -> completion feeds Monitoring + Behavioral models + KnowledgeBase.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.behavioral import (EventModel, FunctionPerformanceModel,
                                   InteractionModel)
from repro.core.data_placement import DataPlacementManager
from repro.core.energy import EnergyMeter
from repro.core.faults import FailureDetector, HedgePolicy, Redeliverer
from repro.core.invocation_batch import InvocationBatch
from repro.core.knowledge_base import KnowledgeBase
from repro.core.monitoring import MetricsRegistry
from repro.core.platform import TargetPlatform
from repro.core.scheduler import Policy, SLOCompositePolicy, as_snapshot
from repro.core.sidecar import SidecarController
from repro.core.simulator import SimClock
from repro.core.types import DeploymentSpec, FunctionSpec, Invocation


class AccessControl:
    """§3.1.1 — per-platform credentials; deny unknown principals."""

    def __init__(self):
        self._tokens: Dict[str, str] = {}

    def grant(self, principal: str, token: str):
        self._tokens[principal] = token

    def check(self, principal: str, token: str) -> bool:
        return self._tokens.get(principal) == token


@dataclass
class AdmissionRequest:
    """THE admission surface: every entry point — scalar ``submit``,
    object-list ``submit_batch``, columnar ``_submit_columns`` — wraps
    its arguments into one of these and hands it to
    ``FDNControlPlane.admit``.  ``invs`` is either a sequence of
    ``Invocation`` objects (a single invocation travels as a batch of
    one) or an ``InvocationBatch``; QoS class and tenant ride the
    invocations/columns themselves, so they enter the plane exactly
    once, here."""

    invs: Union[Sequence[Invocation], InvocationBatch]
    platform_override: Optional[str] = None


class FDNControlPlane:
    def __init__(self, clock: Optional[SimClock] = None,
                 policy: Optional[Policy] = None,
                 enable_hedging: bool = False,
                 predictive_prewarm: bool = False,
                 kb_path: Optional[str] = None,
                 retain_completions: bool = True):
        self.clock = clock or SimClock()
        self.metrics = MetricsRegistry()
        self.energy = EnergyMeter()
        self.placement = DataPlacementManager()
        self.perf = FunctionPerformanceModel()
        self.events = EventModel()
        self.interactions = InteractionModel()
        self.kb = KnowledgeBase(kb_path)
        self.access = AccessControl()
        self.platforms: Dict[str, TargetPlatform] = {}
        self.sidecars: Dict[str, SidecarController] = {}
        self.policy: Policy = policy or SLOCompositePolicy(
            self.perf, self.placement)
        self.detector = FailureDetector(self.clock)
        self.redeliverer = Redeliverer()
        self.hedge = HedgePolicy(self.clock, self.perf,
                                 enabled=enable_hedging)
        self.predictive_prewarm = predictive_prewarm
        # warm-pool lifecycle control loop (repro.autoscale); None until
        # attach_autoscaler — platforms then manage their own keep-alive
        # via the legacy faas-idler
        self.autoscaler = None
        # flight recorder (repro.obs); None until attach_recorder — every
        # tap in the admission paths guards on it with one check per burst
        self.recorder = None
        self._hedge_tap = False
        # live telemetry engine (repro.obs.telemetry); None until
        # attach_telemetry — metrics-ingest and platform-health taps all
        # guard on it with one ``is None`` check
        self.telemetry = None
        # QoS layer (repro.core.qos); None until attach_qos — the admit
        # core consults the admission controller with one ``is None``
        # check per request
        self.qos = None
        self.admission = None
        # decision journal (repro.obs.provenance); None until
        # attach_provenance — the fused-decision sites guard on it with
        # one ``is None`` check per burst, so provenance-off admission
        # costs nothing per invocation
        self.journal = None
        # retain_completions=False drops the per-invocation completed and
        # rejected lists (open-loop sinks own the samples; 10^6-invocation
        # scenarios must not retain a million Invocation objects here)
        self.retain_completions = retain_completions
        self.completed_count = 0
        self.rejected_count = 0
        self.completed: List[Invocation] = []
        self.rejected: List[Invocation] = []

    # ------------------------------------------------- platform lifecycle -
    def create_platform(self, prof, **kw) -> TargetPlatform:
        """Factory wiring the platform to this control plane's substrate."""
        p = TargetPlatform(prof, self.clock, self.metrics, self.energy,
                           placement=self.placement, **kw)
        return self.add_platform(p)

    def add_platform(self, platform: TargetPlatform) -> TargetPlatform:
        """Elastic membership: platforms may join at any time."""
        name = platform.prof.name
        self.platforms[name] = platform
        self.sidecars[name] = SidecarController(platform, self.perf)
        platform.placement = platform.placement or self.placement
        platform.metrics = self.metrics
        if platform.energy is not self.energy:
            platform.energy = self.energy
            self.energy.register(platform.prof, self.clock.now())
        if name not in self.placement.stores:
            self.placement.add_store(name)
        platform.on_complete.append(self._on_complete)
        platform.on_fail.append(self._on_fail)
        platform.recorder = self.recorder
        platform.telemetry = self.telemetry
        if self.qos is not None:
            platform.set_qos(self.qos)
        self.detector.heartbeat(name)
        self._schedule_heartbeat(platform)
        if self.autoscaler is not None:
            self.autoscaler.adopt(platform)
        return platform

    def _schedule_heartbeat(self, platform: TargetPlatform):
        """Platforms self-report liveness on the clock; a failed platform
        stops beating and the detector ejects it (§3.1.3 Fault Tolerance)."""
        name = platform.prof.name

        def beat():
            if self.platforms.get(name) is not platform:
                return                      # removed (elastic scale-in)
            if not platform.failed:
                self.detector.heartbeat(name)
            else:
                self.detector.check(name)   # accrue suspicion -> eject
            tel = self.telemetry
            if tel is not None:
                # periodic health sample even when the platform is idle
                # or failed (drain-side taps go quiet in both states)
                platform.sample_health(tel)
            self.clock.after(self.detector.interval, beat)

        self.clock.after(self.detector.interval, beat)

    def remove_platform(self, name: str):
        """Elastic scale-in (drain is the caller's concern)."""
        self.platforms.pop(name, None)
        self.sidecars.pop(name, None)

    def alive_platforms(self) -> List[TargetPlatform]:
        return [p for name, p in self.platforms.items()
                if not p.failed and self.detector.check(name)]

    # ----------------------------------------------------------- deploy ---
    def deploy(self, spec: DeploymentSpec):
        for fn in spec.functions:
            for pname in spec.target_platforms:
                if pname in self.platforms:
                    self.platforms[pname].deploy(fn)
            stage = spec.annotations.get(fn.name, {}).get("stage_objects")
            pref = spec.annotations.get(fn.name, {}).get(
                "preferred_platform")
            if stage and pref:
                self.placement.stage_for(fn.name, stage, pref)

    # ------------------------------------------------------------ submit --
    def _record_arrival(self, inv: Invocation, now: float):
        """Arrival bookkeeping, exactly once per invocation: redelivery and
        gateway fall-through must not double-count in the EventModel /
        InteractionModel."""
        if inv.arrival_recorded:
            return
        inv.arrival_recorded = True
        self.events.record(inv.fn.name, now)
        self.interactions.record(inv.fn.name, now)

    def submit(self, inv: Invocation,
               platform_override: Optional[str] = None) -> bool:
        """Deprecated shim: wraps the invocation into an
        ``AdmissionRequest`` batch of one and routes it through the
        unified ``admit`` core.  Decisions, knowledge-base rows, hedge
        timers and queue timings are byte-identical to the historical
        scalar body (the parity tests pin batch-of-1 against sequential
        submits).  Returns True iff the invocation was admitted
        somewhere."""
        return self.admit(AdmissionRequest((inv,), platform_override)) > 0

    def admit(self, req: AdmissionRequest) -> int:
        """THE admission core (every legacy entry point is a shim over
        this): consult the QoS admission controller once — token
        buckets, overload shed/degrade/spillover, brownout — then route
        the survivors down the columnar or object path, and any
        spillover rows to their override platform *after* the main
        rows.  With no controller attached the gate costs one ``is
        None`` check.  Returns the number of admitted invocations."""
        invs = req.invs
        columnar = isinstance(invs, InvocationBatch)
        n = invs.n if columnar else len(invs)
        if n == 0:
            return 0
        adm = self.admission
        spill = None
        if adm is not None:
            if columnar:
                invs, spill = adm.gate_columns(self, invs)
            else:
                invs, spill = adm.gate_objects(self, invs)
        accepted = 0
        if columnar:
            if invs is not None and invs.n:
                accepted = self._admit_columns(invs,
                                               req.platform_override)
        elif invs:
            accepted = self._admit_objects(invs, req.platform_override)
        if spill is not None:
            accepted += self._admit_objects(spill[0], spill[1])
        return accepted

    def _admit_one(self, inv: Invocation,
                   platform_override: Optional[str] = None) -> bool:
        """Scalar admission body (the object path's batch-of-1 fast
        path — same decisions as the grouped path, pinned by tests; no
        grouping/snapshot overhead for closed-loop callers)."""
        self._record_arrival(inv, self.clock.now())
        if self.predictive_prewarm:
            self._maybe_prewarm(inv.fn)
        if platform_override is not None:
            target = self.platforms.get(platform_override)
        elif self.journal is None:
            target = self.policy.choose(inv, self.alive_platforms())
        else:
            # journaled scalar path: same decision as ``choose`` (one
            # fused fn_decisions over the same snapshot), plus one
            # provenance row stamped onto the invocation
            snap = as_snapshot(self.alive_platforms())
            res = self.policy.fn_decisions([inv.fn], snap, n=1)
            if res is None:                 # stateful: never journaled
                target = self.policy.choose(inv, snap)
            else:
                idx, ok = res
                rowids = self.journal.record(
                    self.clock.now(), [inv.fn], snap, idx, ok,
                    np.ones(1, np.int32))
                inv.decision = int(rowids[0])
                target = snap.platforms[int(idx[0])] if ok[0] else None
        rec = self.recorder
        if target is None:
            inv.status = "failed"
            self._reject(inv)
            if rec is not None:
                rec.record_reject(inv.fn.name, None, self.clock.now(), 1)
            return False
        self.kb.record_decision(
            self.clock.now(), inv.fn.name, target.prof.name,
            self.policy.name, self.perf.predict_exec(inv.fn, target.prof))
        if rec is not None:
            rec.record_admit(inv.fn.name, target.prof.name,
                             self.clock.now(), 1)
        self.sidecars[target.prof.name].admit(inv)
        if self.hedge.enabled:
            alternates = [p for p in self.alive_platforms()
                          if p is not target]
            self.hedge.watch(inv, target, alternates,
                             lambda i, p: self.sidecars[p.prof.name].admit(i))
        return True

    def submit_batch(self,
                     invs: Union[Sequence[Invocation], InvocationBatch],
                     platform_override: Optional[str] = None) -> int:
        """Admit a whole arrival batch in ONE fused policy evaluation.

        Accepts either a sequence of ``Invocation`` objects or an
        ``InvocationBatch`` (struct-of-arrays).  The columnar form routes
        through ``_submit_columns`` — same decisions, same admission
        order, but no per-arrival Python object until a replica actually
        starts one.

        One pass groups the batch by distinct function and folds the
        arrival bookkeeping (rate model counts, co-invocation edges) into
        bulk updates; the policy then makes one fused decision per
        (function, platform-set) — the jitted cascade + argmin of
        ``scheduler.fn_decisions`` — instead of scoring an (N, P) matrix
        row per invocation (stateful rotation policies keep the full-
        matrix path).  Decisions are logged to the knowledge base in bulk,
        each target platform drains its queue once per batch, and with
        hedging enabled ONE vectorized hedge timer is armed per
        (fn, platform) admission group rather than per invocation.

        Platform choices are identical to per-invocation ``submit`` calls
        (tests pin this).  Queue order inside ONE batch: arrivals in a
        batch share a timestamp, so with knowledge-base row logging off
        (the production config) admission is grouped per distinct
        function — a deterministic tie-break between simultaneous
        arrivals; with logging on, strict arrival order is kept and the
        logged rows match sequential submits row for row.  Returns the
        number of accepted invocations; rejected ones land in
        ``self.rejected``.

        Deprecated shim: this is now a thin adapter over the unified
        ``admit`` core (where QoS admission control runs once for every
        entry point).
        """
        return self.admit(AdmissionRequest(invs, platform_override))

    def _admit_objects(self,
                       invs: Sequence[Invocation],
                       platform_override: Optional[str] = None) -> int:
        """Object-path admission body (see ``submit_batch`` for the
        grouped-decision semantics; ``admit`` has already run the QoS
        gate by the time this is called)."""
        if len(invs) == 1:
            return 1 if self._admit_one(invs[0], platform_override) else 0
        now = self.clock.now()
        # one pass: distinct-function grouping (mirror of
        # scheduler.group_by_fn — identity-keyed, first-appearance order;
        # keep the two in sync) fused with arrival bookkeeping (exactly
        # once per invocation, rate-model counts folded per fn)
        groups: List[Tuple[FunctionSpec, List[int]]] = []
        gmap: Dict[int, Tuple[FunctionSpec, List[int]]] = {}
        fn_counts: Dict[str, int] = {}
        new_names: List[str] = []
        for i, inv in enumerate(invs):
            fn = inv.fn
            g = gmap.get(id(fn))
            if g is None:
                g = (fn, [i])
                gmap[id(fn)] = g
                groups.append(g)
            else:
                g[1].append(i)
            if not inv.arrival_recorded:
                inv.arrival_recorded = True
                name = fn.name
                fn_counts[name] = fn_counts.get(name, 0) + 1
                new_names.append(name)
        for name, c in fn_counts.items():
            self.events.record_many(name, now, c)
        self.interactions.record_batch(new_names, now)
        if self.predictive_prewarm:
            seen: Dict[str, FunctionSpec] = {}
            for fn, _idxs in groups:
                seen.setdefault(fn.name, fn)
            for fn in seen.values():
                self._maybe_prewarm(fn)

        alive = self.alive_platforms()
        n = len(invs)
        # per-GROUP routing: (fn, idxs, target) — valid whenever every
        # invocation of a function shares one decision (fused decisions
        # and overrides); None for stateful per-row policies
        fast: Optional[List[Tuple[FunctionSpec, List[int],
                                  Optional[TargetPlatform]]]] = None
        targets: Optional[List[Optional[TargetPlatform]]] = None
        if platform_override is not None:
            ov = self.platforms.get(platform_override)
            fast = [(fn, idxs, ov) for fn, idxs in groups]
        else:
            snap = as_snapshot(alive)
            res = self.policy.fn_decisions([g[0] for g in groups], snap,
                                           n=n)
            if res is None:                 # stateful policy: full matrix
                targets = self.policy.choose_batch(invs, snap)
            else:
                idx, ok = res
                plats = snap.platforms
                fast = [(fn, idxs,
                         plats[int(idx[g])] if ok[g] else None)
                        for g, (fn, idxs) in enumerate(groups)]
                if self.journal is not None:
                    rowids = self.journal.record(
                        now, [g[0] for g in groups], snap, idx, ok,
                        np.array([len(g[1]) for g in groups], np.int32))
                    for g, (_fn, idxs) in enumerate(groups):
                        rid = int(rowids[g])
                        for i in idxs:
                            invs[i].decision = rid

        accepted = 0
        rec = self.recorder
        pname_groups: Dict[str, List[Invocation]] = {}
        # (target, members) per (fn, platform) — ONE hedge timer each
        hedge_groups: List[Tuple[TargetPlatform, List[Invocation]]] = []
        log_decisions = self.kb.log_decisions
        want_hedges = self.hedge.enabled
        if fast is not None and not log_decisions:
            # production path: admission grouped per distinct function
            # (arrivals inside one batch are simultaneous — group order
            # is the documented deterministic tie-break)
            for fn, idxs, target in fast:
                if target is None:
                    for i in idxs:
                        inv = invs[i]
                        inv.status = "failed"
                        self._reject(inv)
                    if rec is not None:
                        rec.record_reject(fn.name, None, now, len(idxs))
                    continue
                members = [invs[i] for i in idxs]
                if rec is not None:
                    rec.record_admit(fn.name, target.prof.name, now,
                                     len(members))
                if want_hedges:
                    hedge_groups.append((target, members))
                pname = target.prof.name
                group = pname_groups.get(pname)
                if group is None:
                    # hedge groups keep `members` — hand the platform
                    # group a copy so later extends don't alias into it
                    pname_groups[pname] = members[:] if want_hedges \
                        else members
                else:
                    group.extend(members)
                accepted += len(members)
            self.kb.count_decisions(accepted)
        else:
            # debug/stateful path: strict arrival order (knowledge-base
            # rows match sequential submits row for row)
            if targets is None:
                targets = [None] * n
                for fn, idxs, target in fast:
                    if target is not None:
                        for i in idxs:
                            targets[i] = target
            pred_cache: Dict[Tuple[str, str], float] = {}
            rows: List[Dict] = []
            policy_name = self.policy.name
            hgroups: Dict[Tuple[int, str],
                          Tuple[TargetPlatform, List[Invocation]]] = {}
            admit_counts: Dict[Tuple[str, str], int] = {}
            for inv, target in zip(invs, targets):
                if target is None:
                    inv.status = "failed"
                    self._reject(inv)
                    if rec is not None:
                        rec.record_reject(inv.fn.name, None, now, 1)
                    continue
                pname = target.prof.name
                if rec is not None:
                    akey = (inv.fn.name, pname)
                    admit_counts[akey] = admit_counts.get(akey, 0) + 1
                if log_decisions:
                    key = (inv.fn.name, pname)
                    pred = pred_cache.get(key)
                    if pred is None:
                        pred = self.perf.predict_exec(inv.fn, target.prof)
                        pred_cache[key] = pred
                    rows.append({"t": now, "fn": inv.fn.name,
                                 "platform": pname, "policy": policy_name,
                                 "predicted_s": pred})
                group = pname_groups.get(pname)
                if group is None:
                    pname_groups[pname] = [inv]
                else:
                    group.append(inv)
                if want_hedges:
                    hkey = (id(inv.fn), pname)
                    entry = hgroups.get(hkey)
                    if entry is None:
                        hgroups[hkey] = (target, [inv])
                    else:
                        entry[1].append(inv)
                accepted += 1
            if log_decisions:
                self.kb.record_decisions(rows)
            else:
                self.kb.count_decisions(accepted)
            if rec is not None:
                for (fname, pname), c in admit_counts.items():
                    rec.record_admit(fname, pname, now, c)
            hedge_groups.extend(hgroups.values())

        for pname, group in pname_groups.items():
            self.sidecars[pname].admit_many(group)
        if want_hedges:
            alt_cache: Dict[str, List[TargetPlatform]] = {}
            for target, members in hedge_groups:
                pname = target.prof.name
                alternates = alt_cache.get(pname)
                if alternates is None:
                    alternates = [p for p in alive if p is not target]
                    alt_cache[pname] = alternates
                self.hedge.watch_group(members, target, alternates,
                                       self._admit_hedges)
        return accepted

    def _submit_columns(self, batch: InvocationBatch,
                        platform_override: Optional[str] = None) -> int:
        """Deprecated shim over the unified ``admit`` core (kept because
        callers and tests address the columnar path by this name)."""
        return self.admit(AdmissionRequest(batch, platform_override))

    def _admit_columns(self, batch: InvocationBatch,
                       platform_override: Optional[str] = None) -> int:
        """Array-native ``submit_batch``: decide and route straight off
        the batch's columns.

        Arrival bookkeeping is one bincount + one columnar interaction
        fold; the policy makes one fused decision per distinct function
        present (``present_fns`` keeps the object path's first-appearance
        group order, so per-platform admission order — and therefore
        every queue timing — is identical to submitting the materialized
        objects).  Paths that need real objects (decision-row logging,
        hedging, stateful per-row policies) fall back to the object path
        wholesale.  Platform targets receive ``admit_columns`` index
        groups; ``Invocation`` objects only materialize when a replica
        starts (or for retained rejections).
        """
        if batch.n == 0:
            return 0
        if self.kb.log_decisions or self.hedge.enabled:
            # object-path fallback must NOT re-enter admit(): the QoS
            # gate already ran for these rows
            return self._admit_objects(batch.to_invocations(),
                                       platform_override)
        now = self.clock.now()
        specs = batch.specs
        fidx = batch.fn_idx
        if not batch.arrival_recorded:
            batch.arrival_recorded = True
            counts = np.bincount(fidx, minlength=len(specs))
            for j, c in enumerate(counts):
                if c:
                    self.events.record_many(specs[j].name, now, int(c))
            self.interactions.record_batch_columns(
                fidx, [s.name for s in specs], now)
        present = batch.present_fns()
        pres_specs = [specs[int(j)] for j in present]
        if self.predictive_prewarm:
            seen: Dict[str, FunctionSpec] = {}
            for fn in pres_specs:
                seen.setdefault(fn.name, fn)
            for fn in seen.values():
                self._maybe_prewarm(fn)

        if platform_override is not None:
            ov = self.platforms.get(platform_override)
            tmap: List[Optional[TargetPlatform]] = [ov] * len(present)
        else:
            snap = as_snapshot(self.alive_platforms())
            res = self.policy.fn_decisions(pres_specs, snap, n=batch.n)
            if res is None:             # stateful policy: needs real rows
                invs = batch.to_invocations()
                for inv in invs:        # bookkeeping already folded above
                    inv.arrival_recorded = True
                return self._admit_objects(invs, platform_override)
            idx, ok = res
            plats = snap.platforms
            tmap = [plats[int(idx[g])] if ok[g] else None
                    for g in range(len(present))]
            if self.journal is not None:
                cnt = np.bincount(fidx, minlength=len(specs))
                rowids = self.journal.record(now, pres_specs, snap,
                                             idx, ok, cnt[present])

        accepted = 0
        rec = self.recorder
        pname_groups: Dict[str, List[np.ndarray]] = {}
        for g, j in enumerate(present):
            target = tmap[g]
            idxs = np.nonzero(fidx == j)[0]
            if self.journal is not None and platform_override is None:
                batch.decision[idxs] = rowids[g]
            if target is None:
                batch.state[idxs] = InvocationBatch.REJECTED
                self.rejected_count += int(idxs.size)
                if self.retain_completions:
                    for i in idxs:
                        inv = batch.materialize(int(i))
                        inv.status = "failed"
                        self.rejected.append(inv)
                if rec is not None:
                    rec.record_reject(pres_specs[g].name, None, now,
                                      int(idxs.size))
                continue
            batch.state[idxs] = InvocationBatch.ADMITTED
            if rec is not None:
                rec.record_admit(pres_specs[g].name, target.prof.name,
                                 now, int(idxs.size))
            group = pname_groups.get(target.prof.name)
            if group is None:
                pname_groups[target.prof.name] = [idxs]
            else:
                group.append(idxs)
            accepted += int(idxs.size)
        self.kb.count_decisions(accepted)
        for pname, parts in pname_groups.items():
            idxs = parts[0] if len(parts) == 1 else np.concatenate(parts)
            self.sidecars[pname].admit_columns(batch, idxs)
        return accepted

    def _admit_hedges(self, dups: List[Invocation],
                      platform: TargetPlatform):
        """Batch-admit speculative duplicates at their alternate platform
        (hedge traffic bypasses arrival recording, like the scalar path)."""
        self.sidecars[platform.prof.name].admit_many(dups)

    def _reject(self, inv: Invocation):
        self.rejected_count += 1
        if self.retain_completions:
            self.rejected.append(inv)

    # ---------------------------------------------------------- feedback --
    def _on_complete(self, inv: Invocation):
        self.perf.observe(inv)
        self.hedge.completed(inv)
        self.completed_count += 1
        if self.retain_completions:
            self.completed.append(inv)

    def _on_fail(self, inv: Invocation):
        self.redeliverer.handle_failure(
            inv, lambda i: self.submit(i))

    def _maybe_prewarm(self, fn: FunctionSpec):
        """§3.3(1): start containers ahead of the forecast workload."""
        rate = self.events.forecast_rate(fn.name)
        if rate <= 0:
            return
        target = self.policy.choose(Invocation(fn, self.clock.now()),
                                    self.alive_platforms())
        if target is None:
            return
        w = self.perf.predict_exec(fn, target.prof)
        want = int(rate * w) + 1
        have = target.replica_count(fn.name)
        if want > have:
            n = min(want - have, 8)
            target.prewarm(fn.name, n)
            rec = self.recorder
            if rec is not None:
                rec.record_prewarm(target.prof.name, fn.name,
                                   self.clock.now(), n)

    # -------------------------------------------------------- autoscale ---
    def attach_autoscaler(self, policy: str = "predictive",
                          tick_s: float = 1.0,
                          backend: Optional[str] = None,
                          policy_kwargs: Optional[Dict] = None,
                          start: bool = True):
        """Attach the warm-pool lifecycle controller (repro.autoscale):
        takes over keep-alive from every platform's faas-idler and drives
        prewarm/retire pool transitions from the named keep-alive policy
        ("ttl" | "scale_to_zero" | "concurrency" | "predictive")."""
        from repro.autoscale import WarmPoolController, make_policy
        kw = dict(policy_kwargs or {})
        if backend is not None:
            kw["backend"] = backend
        self.autoscaler = WarmPoolController(
            self.platforms, self.perf, self.clock,
            make_policy(policy, **kw), tick_s=tick_s).attach()
        self.autoscaler.recorder = self.recorder
        if start:
            self.autoscaler.start()
        return self.autoscaler

    # ----------------------------------------------------- observability --
    def attach_recorder(self, recorder):
        """Attach a flight recorder (repro.obs) plane-wide: admission taps
        here, launch taps at every platform, warm-pool taps at the
        autoscaler, and a hedge-duplicate tap on the hedge policy."""
        self.recorder = recorder
        for p in self.platforms.values():
            p.recorder = recorder
        if self.autoscaler is not None:
            self.autoscaler.recorder = recorder
        if not self._hedge_tap:
            self._hedge_tap = True

            def _hedge_span(orig, dup):
                rec = self.recorder
                if rec is not None:
                    rec.record_hedge(dup, orig, self.clock.now())

            self.hedge.on_duplicate.append(_hedge_span)
        return recorder

    def attach_provenance(self, journal):
        """Attach a decision journal (repro.obs.provenance): every fused
        ``fn_decisions`` admission records one provenance row per
        distinct function — snapshot feature columns, filter-kill
        bitmask, chosen/runner-up slots and margin — and stamps the row
        id onto the routed invocations for the completion join.  Binds
        the live policy's cascade + params and this plane's perf and
        placement models; rows routed by overrides, spillover, hedging
        or stateful rotation policies are never journaled (their
        ``decision`` stays -1)."""
        self.journal = journal.bind(self.policy, self.perf,
                                    self.placement)
        return journal

    def attach_telemetry(self, engine):
        """Attach a live telemetry engine (repro.obs.telemetry)
        plane-wide: metrics-ingest taps via the registry, platform-health
        taps (queue depth / utilization / watts) at every platform's
        drain tail and the liveness heartbeat.  Callers register SLO
        thresholds via ``engine.set_slo`` so rollup buckets count
        error-budget burn."""
        self.telemetry = engine
        self.metrics.telemetry = engine
        for p in self.platforms.values():
            p.telemetry = engine
        return engine

    def attach_qos(self, spec):
        """Attach the QoS layer (repro.core.qos) plane-wide: one
        ``AdmissionController`` gating the unified ``admit`` core
        (per-class token buckets, overload shed/degrade/spillover,
        brownout under an energy cap) and per-class DRR queues at every
        platform — current and elastically joined later.  ``spec`` is a
        ``QosSpec`` or its dict form.  Returns the controller."""
        from repro.core.qos import AdmissionController, QosSpec
        if isinstance(spec, dict):
            spec = QosSpec.from_dict(spec)
        self.qos = spec
        self.admission = AdmissionController(spec, self.clock)
        for p in self.platforms.values():
            p.set_qos(spec)
        return self.admission

    # ----------------------------------------------------------- chains ---
    def chain_executor(self, fns: Dict[str, FunctionSpec], **kw):
        """Factory for a chain executor bound to this control plane (the
        collaborative-execution layer, repro.chains): stage batches flow
        through ``submit_batch``, intermediates land in this plane's
        object stores, transfer accounting in this plane's metrics."""
        from repro.chains.executor import ChainExecutor
        return ChainExecutor(self, fns, **kw)

    # --------------------------------------------------------------- run --
    def run_until(self, t: float):
        self.clock.run_until(t)
        for name, p in self.platforms.items():
            if not p.failed:
                self.detector.heartbeat(name)
            p.energy.update(name, self.clock.now(), p.cpu_util())
