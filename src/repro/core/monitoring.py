"""Monitoring (paper §3.1.2, Table 1): user-, platform- and infrastructure-
centric metrics, aggregated per sampling window (default 10 s, as in the
paper's evaluation).

The registry is the FDN's Prometheus stand-in: platforms push raw samples,
the window aggregator derives the Table-1 metric set, and the scheduler /
behavioral models / FDNInspector benchmarks all read from here.
"""
from __future__ import annotations

import bisect
import math
from collections import defaultdict

import numpy as np
from typing import Dict, List, Optional, Tuple

from repro.core.types import Invocation


def percentile(sorted_vals, q: float) -> float:
    """Linear-interpolated percentile over an ascending list OR ndarray."""
    if len(sorted_vals) == 0:
        return float("nan")
    idx = q * (len(sorted_vals) - 1)
    lo = int(math.floor(idx))
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = idx - lo
    return float(sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac)


class WindowSeries:
    """Per-window scalar aggregation: sum / last / values-for-percentiles."""

    def __init__(self, window_s: float):
        self.window_s = window_s
        self.sums: Dict[int, float] = defaultdict(float)
        self.counts: Dict[int, int] = defaultdict(int)
        self.values: Dict[int, List[float]] = defaultdict(list)

    def add(self, t: float, v: float):
        w = int(t // self.window_s)
        self.sums[w] += v
        self.counts[w] += 1
        self.values[w].append(v)

    def add_many(self, ts, vs):
        """Columnar ingest: fold parallel (t, v) arrays window-by-window
        (one dict update per touched window, not per sample)."""
        ts = np.asarray(ts, dtype=float)
        vs = np.asarray(vs, dtype=float)
        if ts.size == 0:
            return
        ws = (ts // self.window_s).astype(int)
        order = np.argsort(ws, kind="stable")
        ws, vs = ws[order], vs[order]
        bounds = np.flatnonzero(np.diff(ws)) + 1
        for chunk_w, chunk_v in zip(np.split(ws, bounds),
                                    np.split(vs, bounds)):
            w = int(chunk_w[0])
            self.sums[w] += float(chunk_v.sum())
            self.counts[w] += int(chunk_v.size)
            self.values[w].extend(chunk_v.tolist())

    def windows(self) -> List[int]:
        return sorted(self.sums)

    def series(self, agg: str = "sum") -> List[Tuple[float, float]]:
        out = []
        for w in self.windows():
            t = w * self.window_s
            if agg == "sum":
                out.append((t, self.sums[w]))
            elif agg == "mean":
                out.append((t, self.sums[w] / max(self.counts[w], 1)))
            elif agg == "p90":
                out.append((t, percentile(sorted(self.values[w]), 0.90)))
            elif agg == "count":
                out.append((t, float(self.counts[w])))
        return out

    def total(self) -> float:
        return sum(self.sums.values())

    def count(self) -> int:
        return sum(self.counts.values())

    def all_values(self) -> List[float]:
        out: List[float] = []
        for w in self.windows():
            out.extend(self.values[w])
        return out

    def p90(self) -> float:
        return percentile(sorted(self.all_values()), 0.90)


class MetricsRegistry:
    """Keyed by (platform, function, metric)."""

    USER = ("response_time", "requests")                      # user-centric
    PLATFORM = ("invocations", "cold_starts", "exec_time",    # platform-
                "replicas", "memory_mb")                      # centric
    INFRA = ("cpu_util", "mem_util", "disk_io")               # infra-centric

    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self._m: Dict[Tuple[str, str, str], WindowSeries] = {}

    def _get(self, platform: str, fn: str, metric: str) -> WindowSeries:
        key = (platform, fn, metric)
        if key not in self._m:
            self._m[key] = WindowSeries(self.window_s)
        return self._m[key]

    def add(self, platform: str, fn: str, metric: str, t: float, v: float):
        self._get(platform, fn, metric).add(t, v)

    def add_many(self, platform: str, fn: str, metric: str, ts, vs):
        """Bulk sample ingest (columnar result sinks, batched replays)."""
        self._get(platform, fn, metric).add_many(ts, vs)

    def record_completion(self, inv: Invocation, visible_infra: bool = True):
        p, f, t = inv.platform or "?", inv.fn.name, inv.end_t or 0.0
        self.add(p, f, "requests", t, 1.0)
        self.add(p, f, "response_time", t, inv.response_time or 0.0)
        self.add(p, f, "invocations", t, 1.0)
        self.add(p, f, "exec_time", t, inv.exec_time)
        if inv.cold_start:
            self.add(p, f, "cold_starts", t, 1.0)
        self.add(p, f, "memory_mb", t, float(inv.fn.memory_mb))
        if visible_infra:
            self.add(p, f, "disk_io", t,
                     inv.fn.read_bytes + inv.fn.write_bytes)

    def series(self, platform: str, fn: str, metric: str,
               agg: str = "sum") -> List[Tuple[float, float]]:
        return self._get(platform, fn, metric).series(agg)

    def p90_response(self, platform: str, fn: str = "*") -> float:
        vals: List[float] = []
        for (p, f, m), ws in self._m.items():
            if m != "response_time" or p != platform:
                continue
            if fn != "*" and f != fn:
                continue
            vals.extend(ws.all_values())
        return percentile(sorted(vals), 0.90)

    def total(self, platform: str, fn: str, metric: str) -> float:
        return self._get(platform, fn, metric).total()

    def requests_served(self, platform: str, fn: str = "*") -> int:
        n = 0
        for (p, f, m), ws in self._m.items():
            if m == "requests" and p == platform and (fn == "*" or f == fn):
                n += int(ws.total())
        return n
