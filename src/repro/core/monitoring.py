"""Monitoring (paper §3.1.2, Table 1): user-, platform- and infrastructure-
centric metrics, aggregated per sampling window (default 10 s, as in the
paper's evaluation).

The registry is the FDN's Prometheus stand-in: platforms push raw samples,
the window aggregator derives the Table-1 metric set, and the scheduler /
behavioral models / FDNInspector benchmarks all read from here.

Two series backends share one API:

  * ``WindowSeries``         — per-window Python lists (the original,
                               kept as the per-sample baseline);
  * ``ColumnarWindowSeries`` — samples buffered into flat NumPy columns,
                               per-window aggregation computed in one
                               vectorized flush when read.  The registry
                               defaults to this backend, so a 10^6-sample
                               run never appends to a Python list.

``MetricsRegistry.record_completions`` is the bulk completion path: it
ingests a whole ``ColumnarResultSink`` (arrival/end/platform/function/cold
columns) with one ``add_many`` per (platform, function, metric) group.
"""
from __future__ import annotations

import math
from collections import defaultdict

import numpy as np
from typing import Dict, List, Optional, Tuple, Union

from repro.core.types import Invocation


def _interp_indices(n: int, q: float) -> Tuple[int, int, float]:
    """The one shared definition of linear-interpolated percentiles
    (numpy's default 'linear' method): the two order statistics bracketing
    rank ``q * (n - 1)`` and the interpolation fraction between them.
    Every percentile in the repo routes through here."""
    idx = q * (n - 1)
    lo = int(math.floor(idx))
    hi = min(lo + 1, n - 1)
    return lo, hi, idx - lo


def percentile(sorted_vals, q: float) -> float:
    """Linear-interpolated percentile over an ascending list OR ndarray."""
    n = len(sorted_vals)
    if n == 0:
        return float("nan")
    lo, hi, frac = _interp_indices(n, q)
    return float(sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac)


def percentile_unsorted(vals: np.ndarray, q: float) -> float:
    """``percentile`` without the O(n log n) sort: ``np.partition`` places
    just the two order statistics the interpolation needs."""
    vals = np.asarray(vals)
    n = vals.size
    if n == 0:
        return float("nan")
    lo, hi, frac = _interp_indices(n, q)
    part = np.partition(vals, (lo, hi))
    return float(part[lo] * (1 - frac) + part[hi] * frac)


class WindowSeries:
    """Per-window scalar aggregation: sum / last / values-for-percentiles."""

    def __init__(self, window_s: float):
        self.window_s = window_s
        self.sums: Dict[int, float] = defaultdict(float)
        self.counts: Dict[int, int] = defaultdict(int)
        self.values: Dict[int, List[float]] = defaultdict(list)

    def add(self, t: float, v: float):
        w = int(t // self.window_s)
        self.sums[w] += v
        self.counts[w] += 1
        self.values[w].append(v)

    def add_many(self, ts, vs):
        """Columnar ingest: fold parallel (t, v) arrays window-by-window
        (one dict update per touched window, not per sample)."""
        ts = np.asarray(ts, dtype=float)
        vs = np.asarray(vs, dtype=float)
        if ts.size == 0:
            return
        ws = (ts // self.window_s).astype(int)
        order = np.argsort(ws, kind="stable")
        ws, vs = ws[order], vs[order]
        bounds = np.flatnonzero(np.diff(ws)) + 1
        for chunk_w, chunk_v in zip(np.split(ws, bounds),
                                    np.split(vs, bounds)):
            w = int(chunk_w[0])
            self.sums[w] += float(chunk_v.sum())
            self.counts[w] += int(chunk_v.size)
            self.values[w].extend(chunk_v.tolist())

    def windows(self) -> List[int]:
        return sorted(self.sums)

    def series(self, agg: str = "sum") -> List[Tuple[float, float]]:
        out = []
        for w in self.windows():
            t = w * self.window_s
            if agg == "sum":
                out.append((t, self.sums[w]))
            elif agg == "mean":
                out.append((t, self.sums[w] / max(self.counts[w], 1)))
            elif agg == "p90":
                out.append((t, percentile_unsorted(
                    np.asarray(self.values[w]), 0.90)))
            elif agg == "count":
                out.append((t, float(self.counts[w])))
        return out

    def total(self) -> float:
        return sum(self.sums.values())

    def count(self) -> int:
        return sum(self.counts.values())

    def all_values(self) -> List[float]:
        out: List[float] = []
        for w in self.windows():
            out.extend(self.values[w])
        return out

    def values_array(self) -> np.ndarray:
        """All samples as one flat column (any order: percentile fodder)."""
        if not self.values:
            return np.empty(0)
        return np.concatenate([np.asarray(self.values[w])
                               for w in self.windows()])

    def p90(self) -> float:
        return percentile_unsorted(self.values_array(), 0.90)


class ColumnarWindowSeries:
    """``WindowSeries`` semantics over flat NumPy columns.

    Samples append into growable (t, v) arrays — scalar ``add`` costs one
    array store, ``add_many`` one slice copy — and the per-window
    aggregation (sums / counts / per-window value slices) is produced
    lazily by a single vectorized flush, cached until the next append.
    """

    __slots__ = ("window_s", "_t", "_v", "_n", "_agg")

    def __init__(self, window_s: float, capacity: int = 64):
        self.window_s = window_s
        self._t = np.empty(capacity)
        self._v = np.empty(capacity)
        self._n = 0
        self._agg = None

    # -------------------------------------------------------- ingest ---
    def _grow(self, need: int):
        cap = max(self._t.size * 2, need)
        for name in ("_t", "_v"):
            a = getattr(self, name)
            b = np.empty(cap, a.dtype)
            b[:self._n] = a[:self._n]
            setattr(self, name, b)

    def add(self, t: float, v: float):
        n = self._n
        if n == self._t.size:
            self._grow(n + 1)
        self._t[n] = t
        self._v[n] = v
        self._n = n + 1
        self._agg = None

    def add_many(self, ts, vs):
        ts = np.asarray(ts, dtype=float)
        vs = np.asarray(vs, dtype=float)
        if ts.size == 0:
            return
        need = self._n + ts.size
        if need > self._t.size:
            self._grow(need)
        self._t[self._n:need] = ts
        self._v[self._n:need] = vs
        self._n = need
        self._agg = None

    # --------------------------------------------------------- flush ---
    def _flush(self):
        """One vectorized group-by-window pass over the buffered columns:
        (window ids, per-window start offsets, counts, sums, values sorted
        by window with arrival order preserved inside a window)."""
        if self._agg is None:
            n = self._n
            if n == 0:
                e = np.empty(0)
                self._agg = (np.empty(0, np.int64), np.empty(0, np.int64),
                             np.empty(0, np.int64), e, e)
            else:
                w = (self._t[:n] // self.window_s).astype(np.int64)
                order = np.argsort(w, kind="stable")
                ws = w[order]
                vs = self._v[:n][order]
                uniq, starts = np.unique(ws, return_index=True)
                sums = np.add.reduceat(vs, starts)
                counts = np.diff(np.append(starts, n))
                self._agg = (uniq, starts, counts, sums, vs)
        return self._agg

    def windows(self) -> List[int]:
        return self._flush()[0].tolist()

    def series(self, agg: str = "sum") -> List[Tuple[float, float]]:
        uniq, starts, counts, sums, vs = self._flush()
        out = []
        for i, w in enumerate(uniq.tolist()):
            t = w * self.window_s
            if agg == "sum":
                out.append((t, float(sums[i])))
            elif agg == "mean":
                out.append((t, float(sums[i]) / max(int(counts[i]), 1)))
            elif agg == "p90":
                lo = int(starts[i])
                out.append((t, percentile_unsorted(
                    vs[lo:lo + int(counts[i])], 0.90)))
            elif agg == "count":
                out.append((t, float(counts[i])))
        return out

    def total(self) -> float:
        return float(self._v[:self._n].sum())

    def count(self) -> int:
        return self._n

    def all_values(self) -> List[float]:
        return self._flush()[4].tolist()

    def values_array(self) -> np.ndarray:
        return self._v[:self._n]

    def p90(self) -> float:
        return percentile_unsorted(self._v[:self._n], 0.90)


SeriesLike = Union[WindowSeries, ColumnarWindowSeries]


class MetricsRegistry:
    """Keyed by (platform, function, metric)."""

    USER = ("response_time", "requests")                      # user-centric
    PLATFORM = ("invocations", "cold_starts", "exec_time",    # platform-
                "replicas", "memory_mb")                      # centric
    INFRA = ("cpu_util", "mem_util", "disk_io")               # infra-centric
    # chain-centric (recorded under the "_chain" pseudo-platform, keyed by
    # chain label): end-to-end latency, bytes crossing platforms, seconds
    # spent moving them (repro.chains.ChainExecutor)
    CHAIN = ("chain_latency", "bytes_moved", "transfer_s")

    def __init__(self, window_s: float = 10.0, columnar: bool = True):
        self.window_s = window_s
        self._series_cls = ColumnarWindowSeries if columnar else WindowSeries
        self._m: Dict[Tuple[str, str, str], SeriesLike] = {}
        # When set, per-invocation ``record_completion`` becomes a no-op:
        # the caller owns a ColumnarResultSink and ingests it in bulk at
        # the end of the run via ``record_completions`` (FDNInspector's
        # 10^6-invocation scenarios never pay a per-sample hot path).
        self.defer_completions = False
        # Live telemetry subscription (repro.obs.telemetry): every ingest
        # through add/add_many is mirrored to the engine's rollups.  One
        # ``is None`` check per call — same discipline as the recorder.
        self.telemetry = None

    def _get(self, platform: str, fn: str, metric: str) -> SeriesLike:
        key = (platform, fn, metric)
        if key not in self._m:
            self._m[key] = self._series_cls(self.window_s)
        return self._m[key]

    def add(self, platform: str, fn: str, metric: str, t: float, v: float):
        self._get(platform, fn, metric).add(t, v)
        tel = self.telemetry
        if tel is not None:
            tel.observe(platform, fn, metric, t, v)

    def add_many(self, platform: str, fn: str, metric: str, ts, vs):
        """Bulk sample ingest (columnar result sinks, batched replays)."""
        self._get(platform, fn, metric).add_many(ts, vs)
        tel = self.telemetry
        if tel is not None:
            tel.observe_many(platform, fn, metric, np.asarray(ts, float),
                             np.asarray(vs, float))

    def record_completion(self, inv: Invocation, visible_infra: bool = True):
        if self.defer_completions:
            return
        p, f, t = inv.platform or "?", inv.fn.name, inv.end_t or 0.0
        self.add(p, f, "requests", t, 1.0)
        self.add(p, f, "response_time", t, inv.response_time or 0.0)
        self.add(p, f, "invocations", t, 1.0)
        self.add(p, f, "exec_time", t, inv.exec_time)
        if inv.cold_start:
            self.add(p, f, "cold_starts", t, 1.0)
        self.add(p, f, "memory_mb", t, float(inv.fn.memory_mb))
        if visible_infra:
            self.add(p, f, "disk_io", t,
                     inv.fn.read_bytes + inv.fn.write_bytes)

    def record_completions(self, sink,
                           visible_infra: Union[bool, Dict[str, bool]]
                           = True):
        """Bulk completion ingest from a ``loadgen.ColumnarResultSink``:
        the Table-1 metric set of ``record_completion``, derived from the
        sink's flat columns with one ``add_many`` per (platform, function,
        metric) group — no per-sample Python work.

        ``visible_infra`` may be a bool or a per-platform dict (GCF-style
        platforms expose no infrastructure metrics)."""
        cols = sink.completion_columns()
        end, arrival = cols["end"], cols["arrival"]
        plat_col, fn_col = cols["platform"], cols["fn"]
        cold = cols["cold"]
        exec_col = cols["exec"]
        rt = end - arrival
        for pname, pid in cols["platform_ids"].items():
            pmask = plat_col == pid
            if not pmask.any():
                continue
            infra = (visible_infra.get(pname, True)
                     if isinstance(visible_infra, dict) else visible_infra)
            for fname, fid in cols["fn_ids"].items():
                mask = pmask & (fn_col == fid)
                n = int(np.count_nonzero(mask))
                if n == 0:
                    continue
                ts = end[mask]
                ones = np.ones(n)
                spec = cols["fn_specs"][fname]
                self.add_many(pname, fname, "requests", ts, ones)
                self.add_many(pname, fname, "response_time", ts, rt[mask])
                self.add_many(pname, fname, "invocations", ts, ones)
                self.add_many(pname, fname, "exec_time", ts, exec_col[mask])
                cmask = mask & cold
                if cmask.any():
                    self.add_many(pname, fname, "cold_starts", end[cmask],
                                  np.ones(int(cmask.sum())))
                self.add_many(pname, fname, "memory_mb", ts,
                              np.full(n, float(spec.memory_mb)))
                if infra:
                    self.add_many(pname, fname, "disk_io", ts,
                                  np.full(n, spec.read_bytes +
                                          spec.write_bytes))

    def series(self, platform: str, fn: str, metric: str,
               agg: str = "sum") -> List[Tuple[float, float]]:
        return self._get(platform, fn, metric).series(agg)

    def response_values(self, platform: str, fn: str = "*") -> np.ndarray:
        """All response-time samples for (platform, fn) as one column."""
        cols = [ws.values_array() for (p, f, m), ws in self._m.items()
                if m == "response_time" and p == platform
                and (fn == "*" or f == fn)]
        cols = [c for c in cols if c.size]
        if not cols:
            return np.empty(0)
        return cols[0] if len(cols) == 1 else np.concatenate(cols)

    def p90_response(self, platform: str, fn: str = "*") -> float:
        return percentile_unsorted(self.response_values(platform, fn), 0.90)

    def total(self, platform: str, fn: str, metric: str) -> float:
        return self._get(platform, fn, metric).total()

    def requests_served(self, platform: str, fn: str = "*") -> int:
        n = 0
        for (p, f, m), ws in self._m.items():
            if m == "requests" and p == platform and (fn == "*" or f == fn):
                n += int(ws.total())
        return n
