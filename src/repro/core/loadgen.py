"""k6-style load generator (paper §4.3), in two workload models:

Closed loop — ``run_load``: N virtual users (VUs) iterate request ->
wait-for-completion -> sleep, exactly the way the paper's k6 scripts drove
the five platforms (VUs 10-50, duration 600 s, optional sleep).

Open loop — arrival-driven: ``poisson_arrivals`` / ``trace_arrivals``
produce a NumPy array of arrival timestamps (seeded Poisson process, or a
replayable trace), and ``run_arrivals`` admits them through a batch-submit
callable (``FDNControlPlane.submit_batch`` / ``Gateway.request_batch``),
grouping arrivals into sub-window bursts.  ``run_arrival_mix`` is the
multi-function variant: a merged arrival stream tagged with a function
index per arrival (see ``repro.inspector.traces.WorkloadMix``).  Results
stream into a ``ColumnarResultSink`` — flat NumPy columns, no Python
object retained per latency sample — so a run can sustain ~10^6
invocations.

Everything is deterministic on the SimClock; all randomness is seeded.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.invocation_batch import InvocationBatch
from repro.core.simulator import SimClock
from repro.core.types import FunctionSpec, Invocation


@dataclass
class LoadResult:
    invocations: List[Invocation]

    @property
    def completed(self) -> List[Invocation]:
        return [i for i in self.invocations if i.status == "done"]

    def p90_response(self) -> float:
        from repro.core.monitoring import percentile_unsorted
        vals = np.array([i.response_time for i in self.completed
                         if i.response_time is not None])
        return percentile_unsorted(vals, 0.90)

    def requests_per_s(self, duration: float) -> float:
        return len(self.completed) / max(duration, 1e-9)


def spawn_vus(clock: SimClock, submit: Callable[[Invocation], None],
              fn: FunctionSpec, vus: int, t_end: float,
              sleep_s: float = 0.0, seed: int = 42, jitter: float = 0.05,
              out: Optional[List[Invocation]] = None,
              qos: int = 1, tenant: int = 0) -> List[Invocation]:
    """Schedule `vus` virtual-user loops on the clock WITHOUT running it.

    Each VU iterates request -> wait-for-completion -> think-sleep until
    ``t_end``.  The caller advances the clock (``run_load`` drives a single
    workload; the FDNInspector scenario runner spawns several VU pools plus
    open-loop arrival streams and runs them all on one clock)."""
    rng = random.Random(seed)
    invs: List[Invocation] = out if out is not None else []

    def vu_loop(vu_id: int):
        if clock.now() >= t_end:
            return
        inv = Invocation(fn, clock.now(), vu=vu_id, qos=qos,
                         tenant=tenant)
        invs.append(inv)
        done_flag = {"fired": False}

        def next_iter(_inv=inv):
            if done_flag["fired"]:
                return
            done_flag["fired"] = True
            think = sleep_s + rng.random() * jitter
            clock.after(think, lambda: vu_loop(vu_id))

        inv._on_done = next_iter          # platform completion hook
        submit(inv)
        # safety: if the invocation was rejected outright, keep iterating —
        # but only if the completion hook has not already rescheduled this
        # VU.  A platform that both fails the submit AND later fires
        # _on_done (redelivery, hedging) must not fork the virtual user.
        if inv.status == "failed" and not done_flag["fired"]:
            done_flag["fired"] = True
            clock.after(max(sleep_s, 0.1), lambda: vu_loop(vu_id))

    for v in range(vus):
        clock.after(rng.random() * 0.1, lambda v=v: vu_loop(v))
    return invs


def run_load(clock: SimClock, submit: Callable[[Invocation], None],
             fn: FunctionSpec, vus: int, duration_s: float,
             sleep_s: float = 0.0, seed: int = 42,
             jitter: float = 0.05, drain_s: float = 120.0) -> LoadResult:
    """Spawn `vus` virtual users for `duration_s` sim-seconds.

    After the VU window closes, the clock drains for up to `drain_s` so
    in-flight invocations complete (k6's gracefulStop)."""
    t_end = clock.now() + duration_s
    out = spawn_vus(clock, submit, fn, vus, t_end, sleep_s=sleep_s,
                    seed=seed, jitter=jitter)
    clock.run_until(t_end)
    clock.run_until(t_end + drain_s)          # gracefulStop: drain in-flight
    return LoadResult(out)


def run_open_loop(clock: SimClock, submit: Callable[[Invocation], bool],
                  fn: FunctionSpec, rps: float, duration_s: float,
                  seed: int = 42) -> LoadResult:
    """Open-loop (arrival-rate) load: k6's constant-arrival-rate executor.
    Used for the Table-4 energy experiment (fixed 40 req/s per platform).

    Thin wrapper over ``uniform_arrivals`` + ``run_arrivals`` (the
    hand-rolled arrival loop predated the batch path); ``batch_window_s=0``
    keeps the historical per-invocation submit semantics.  ``seed`` is
    retained for signature compatibility — evenly spaced arrivals need no
    randomness."""
    del seed
    out: List[Invocation] = []

    def submit_each(invs: List[Invocation]) -> int:
        out.extend(invs)
        return sum(1 for inv in invs if submit(inv))

    arrivals = uniform_arrivals(rps, duration_s, t0=clock.now())
    run_arrivals(clock, submit_each, fn, arrivals, batch_window_s=0.0,
                 drain_s=60.0)
    return LoadResult(out)


# ---------------------------------------------------------------------------
# Open-loop arrival processes (workload-model diversity: the paper's k6
# constant-arrival executor, a Poisson process, and trace replay)
# ---------------------------------------------------------------------------

def poisson_arrivals(rps: float, duration_s: float, seed: int = 42,
                     t0: float = 0.0) -> np.ndarray:
    """Seeded Poisson arrival process: exponential inter-arrival gaps at
    mean rate ``rps`` for ``duration_s`` seconds.  Same seed -> identical
    arrival array (replayable)."""
    if rps <= 0 or duration_s <= 0:
        return np.empty(0)
    rng = np.random.default_rng(seed)
    # draw with headroom, extend until the window is covered
    n = max(int(rps * duration_s * 1.2) + 16, 16)
    gaps = rng.exponential(1.0 / rps, size=n)
    t = np.cumsum(gaps)
    while t[-1] < duration_s:
        more = rng.exponential(1.0 / rps, size=n)
        t = np.concatenate([t, t[-1] + np.cumsum(more)])
    return t0 + t[t < duration_s]


def uniform_arrivals(rps: float, duration_s: float,
                     t0: float = 0.0) -> np.ndarray:
    """k6 constant-arrival-rate executor: evenly spaced arrivals."""
    n = int(rps * duration_s)
    return t0 + np.arange(n) / rps


def trace_arrivals(times: Sequence[float], t0: float = 0.0,
                   time_scale: float = 1.0) -> np.ndarray:
    """Replay a recorded arrival trace (e.g. production timestamps),
    shifted to start at ``t0`` and optionally time-dilated."""
    t = np.sort(np.asarray(list(times), dtype=float))
    if t.size == 0:
        return t
    return t0 + (t - t[0]) * time_scale


class ColumnarResultSink:
    """Flat-column result collector for open-loop runs.

    Completions append scalars into growable NumPy columns (arrival time,
    end time, platform id, function id, exec time, cold-start flag);
    nothing per-sample survives in Python object form, so a 10^6-invocation
    run costs ~50 MB instead of a list of a million Invocation objects.
    """

    def __init__(self, capacity: int = 1024):
        self._n = 0
        self._arrival = np.empty(capacity)
        self._end = np.empty(capacity)
        self._exec = np.empty(capacity)
        self._platform = np.empty(capacity, np.int32)
        self._fn = np.empty(capacity, np.int32)
        self._cold = np.empty(capacity, bool)
        self._inv = np.empty(capacity, np.int64)
        self._qos = np.empty(capacity, np.int8)
        self._tenant = np.empty(capacity, np.int32)
        self._decision = np.empty(capacity, np.int64)
        self._platform_ids: Dict[str, int] = {}
        self._fn_ids: Dict[str, int] = {}
        self._fn_specs: Dict[str, FunctionSpec] = {}
        self.submitted = 0
        self.rejected = 0

    # -------------------------------------------------------- ingest ---
    def _grow(self, need: int):
        cap = max(self._arrival.size * 2, need)
        for name in ("_arrival", "_end", "_exec", "_platform", "_fn",
                     "_cold", "_inv", "_qos", "_tenant", "_decision"):
            a = getattr(self, name)
            b = np.empty(cap, a.dtype)
            b[:self._n] = a[:self._n]
            setattr(self, name, b)

    def record_completion(self, inv: Invocation):
        if self._n == self._arrival.size:
            self._grow(self._n + 1)
        i = self._n
        self._arrival[i] = inv.arrival_t
        self._end[i] = inv.end_t if inv.end_t is not None else np.nan
        self._exec[i] = inv.exec_time
        pid = self._platform_ids.setdefault(inv.platform or "?",
                                            len(self._platform_ids))
        self._platform[i] = pid
        fname = inv.fn.name
        fid = self._fn_ids.get(fname)
        if fid is None:
            fid = len(self._fn_ids)
            self._fn_ids[fname] = fid
            self._fn_specs[fname] = inv.fn
        self._fn[i] = fid
        self._cold[i] = inv.cold_start
        self._inv[i] = inv.id
        self._qos[i] = inv.qos
        self._tenant[i] = inv.tenant
        self._decision[i] = inv.decision
        self._n = i + 1

    @classmethod
    def from_columns(cls, arrival: np.ndarray, end: np.ndarray,
                     platforms: Sequence[str], platform_idx: np.ndarray,
                     fns: Sequence[FunctionSpec], fn_idx: np.ndarray,
                     cold: Optional[np.ndarray] = None,
                     exec_s: Optional[np.ndarray] = None
                     ) -> "ColumnarResultSink":
        """Build a sink directly from completion columns (synthetic-ingest
        benchmarks and tests; the live path is ``record_completion``)."""
        n = int(np.asarray(arrival).size)
        sink = cls(capacity=max(n, 1))
        sink._arrival[:n] = arrival
        sink._end[:n] = end
        sink._exec[:n] = exec_s if exec_s is not None \
            else np.asarray(end) - np.asarray(arrival)
        sink._platform[:n] = platform_idx
        sink._fn[:n] = fn_idx
        sink._cold[:n] = cold if cold is not None else False
        sink._inv[:n] = np.arange(n, dtype=np.int64)   # synthetic ids
        sink._qos[:n] = 1                              # standard class
        sink._tenant[:n] = 0
        sink._decision[:n] = -1                        # not journaled
        sink._platform_ids = {name: i for i, name in enumerate(platforms)}
        sink._fn_ids = {f.name: i for i, f in enumerate(fns)}
        sink._fn_specs = {f.name: f for f in fns}
        sink._n = n
        sink.submitted = n
        return sink

    def install(self, control_plane) -> "ColumnarResultSink":
        """Subscribe to every platform's completion stream."""
        for p in control_plane.platforms.values():
            if self.record_completion not in p.on_complete:
                p.on_complete.append(self.record_completion)
        return self

    # --------------------------------------------------------- stats ---
    @property
    def completed(self) -> int:
        return self._n

    def completion_columns(self) -> Dict:
        """The collected columns (views, not copies) plus the id maps —
        the contract consumed by ``MetricsRegistry.record_completions``."""
        n = self._n
        return {"arrival": self._arrival[:n], "end": self._end[:n],
                "exec": self._exec[:n], "platform": self._platform[:n],
                "fn": self._fn[:n], "cold": self._cold[:n],
                "inv_id": self._inv[:n], "qos": self._qos[:n],
                "tenant": self._tenant[:n],
                "decision": self._decision[:n],
                "platform_ids": dict(self._platform_ids),
                "fn_ids": dict(self._fn_ids),
                "fn_specs": dict(self._fn_specs)}

    def response_times(self) -> np.ndarray:
        return self._end[:self._n] - self._arrival[:self._n]

    def p90_response(self) -> float:
        from repro.core.monitoring import percentile_unsorted
        rt = self.response_times()
        return percentile_unsorted(rt[~np.isnan(rt)], 0.90)

    def mean_response(self) -> float:
        rt = self.response_times()
        return float(np.nanmean(rt)) if rt.size else float("nan")

    def requests_per_s(self, duration: float) -> float:
        return self._n / max(duration, 1e-9)

    def cold_start_count(self) -> int:
        return int(self._cold[:self._n].sum())

    def platform_counts(self) -> Dict[str, int]:
        counts = np.bincount(self._platform[:self._n],
                             minlength=len(self._platform_ids))
        return {name: int(counts[pid])
                for name, pid in self._platform_ids.items()}

    def fn_counts(self) -> Dict[str, int]:
        counts = np.bincount(self._fn[:self._n],
                             minlength=len(self._fn_ids))
        return {name: int(counts[fid])
                for name, fid in self._fn_ids.items()}

    def to_metrics(self, registry, platform: str = "_loadgen",
                   fn: str = "*") -> None:
        """Push the collected latency column into a MetricsRegistry in one
        columnar ingest."""
        rt = self.response_times()
        ok = ~np.isnan(rt)
        registry.add_many(platform, fn, "response_time",
                          self._end[:self._n][ok], rt[ok])


def _burst_bounds(arrivals: np.ndarray,
                  batch_window_s: float) -> List[Tuple[int, int]]:
    """Index ranges of arrivals grouped into ``batch_window_s`` sub-window
    bursts (``<= 0``: every arrival is its own batch)."""
    if batch_window_s > 0:
        edges = np.arange(float(arrivals[0]),
                          float(arrivals[-1]) + batch_window_s,
                          batch_window_s)
        starts = np.searchsorted(arrivals, edges, side="left")
        return [(int(a), int(b)) for a, b in
                zip(starts, list(starts[1:]) + [arrivals.size]) if b > a]
    return [(i, i + 1) for i in range(arrivals.size)]


def schedule_arrival_mix(clock: SimClock,
                         submit_batch: Callable[[List[Invocation]], int],
                         specs: Sequence[FunctionSpec], times: np.ndarray,
                         fn_idx: np.ndarray, batch_window_s: float = 0.05,
                         sink: Optional[ColumnarResultSink] = None,
                         columnar: bool = False,
                         qos: Optional[np.ndarray] = None,
                         tenant: Optional[np.ndarray] = None
                         ) -> ColumnarResultSink:
    """Enqueue a multi-function arrival stream WITHOUT running the clock.

    ``times`` is the merged, sorted admission stream; ``fn_idx[i]`` indexes
    ``specs`` for arrival i (a single-function stream is the all-zeros
    case).  Optional ``qos`` / ``tenant`` columns (aligned with ``times``)
    tag each arrival with its QoS class id and tenant; omitted they keep
    the defaults (standard class, tenant 0).  Arrivals inside one
    ``batch_window_s`` sub-window are admitted together at the window's
    close; each invocation keeps its true arrival timestamp, so measured
    response times include the admission delay.

    ``columnar=True`` builds ONE ``InvocationBatch`` over the whole stream
    and fires zero-copy chunk views per sub-window — no per-arrival
    ``Invocation`` object is created at admission time (the platform
    materializes rows lazily as replicas start them).  Decisions and
    timings are identical to the object path.
    """
    sink = sink or ColumnarResultSink()
    times = np.asarray(times, dtype=float)
    fn_idx = np.asarray(fn_idx, dtype=np.int64)
    if times.size == 0:
        return sink
    bounds = _burst_bounds(times, batch_window_s)

    if columnar:
        stream = InvocationBatch(list(specs), fn_idx, times,
                                 qos=qos, tenant=tenant)

        def fire(lo: int, hi: int):
            chunk = stream.view(lo, hi)
            sink.submitted += chunk.n
            accepted = submit_batch(chunk)
            sink.rejected += chunk.n - accepted
    else:
        def fire(lo: int, hi: int):
            invs = [Invocation(specs[fn_idx[i]], float(times[i]),
                               qos=1 if qos is None else int(qos[i]),
                               tenant=0 if tenant is None
                               else int(tenant[i]))
                    for i in range(lo, hi)]
            sink.submitted += len(invs)
            accepted = submit_batch(invs)
            sink.rejected += len(invs) - accepted

    clock.schedule_many([float(times[hi - 1]) for lo, hi in bounds],
                        [lambda lo=lo, hi=hi: fire(lo, hi)
                         for lo, hi in bounds])
    return sink


def run_arrival_mix(clock: SimClock,
                    submit_batch: Callable[[List[Invocation]], int],
                    specs: Sequence[FunctionSpec], times: np.ndarray,
                    fn_idx: np.ndarray, batch_window_s: float = 0.05,
                    sink: Optional[ColumnarResultSink] = None,
                    drain_s: float = 120.0,
                    columnar: bool = False) -> ColumnarResultSink:
    """Open-loop replay of a multi-function arrival mix, then drain."""
    times = np.asarray(times, dtype=float)
    sink = schedule_arrival_mix(clock, submit_batch, specs, times, fn_idx,
                                batch_window_s, sink, columnar=columnar)
    if times.size:
        t_end = float(times[-1])
        clock.run_until(t_end)
        clock.run_until(t_end + drain_s)      # gracefulStop: drain in-flight
    return sink


def run_arrivals(clock: SimClock, submit_batch: Callable[[List[Invocation]],
                                                         int],
                 fn: FunctionSpec, arrivals: np.ndarray,
                 batch_window_s: float = 0.05, sink:
                 Optional[ColumnarResultSink] = None,
                 drain_s: float = 120.0) -> ColumnarResultSink:
    """Open-loop replay: admit ``arrivals`` through a batch-submit callable.

    Single-function case of ``run_arrival_mix`` (one spec, all-zero
    function indices).  With ``batch_window_s <= 0`` every arrival is its
    own batch (the per-invocation baseline).
    """
    arrivals = np.asarray(arrivals, dtype=float)
    return run_arrival_mix(clock, submit_batch, [fn], arrivals,
                           np.zeros(arrivals.size, np.int64),
                           batch_window_s, sink, drain_s)


def attach_completion_hooks(control_plane) -> None:
    """Wire Invocation._on_done callbacks through the control plane.

    Idempotent: the hook closure is cached on the control plane, so
    repeated calls (the scenario runner and a ChainExecutor both want the
    hooks) never double-fire a callback."""
    fire = getattr(control_plane, "_completion_hook", None)
    if fire is None:
        def fire(inv):
            cb = getattr(inv, "_on_done", None)
            if cb is not None:
                cb()
        control_plane._completion_hook = fire
    for p in control_plane.platforms.values():
        if fire not in p.on_complete:
            p.on_complete.append(fire)
