"""k6-style load generator (paper §4.3): N virtual users (VUs) iterate
request -> wait-for-completion -> sleep for a fixed duration. Deterministic
on the SimClock; per-VU think-time jitter is seeded.

``run_load`` drives an FDNControlPlane (or a raw TargetPlatform through a
submit callable) exactly the way the paper's k6 scripts drove the five
platforms (VUs 10-50, duration 600 s, optional sleep between requests).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, List, Optional

from repro.core.simulator import SimClock
from repro.core.types import FunctionSpec, Invocation


@dataclass
class LoadResult:
    invocations: List[Invocation]

    @property
    def completed(self) -> List[Invocation]:
        return [i for i in self.invocations if i.status == "done"]

    def p90_response(self) -> float:
        from repro.core.monitoring import percentile
        vals = sorted(i.response_time for i in self.completed
                      if i.response_time is not None)
        return percentile(vals, 0.90)

    def requests_per_s(self, duration: float) -> float:
        return len(self.completed) / max(duration, 1e-9)


def run_load(clock: SimClock, submit: Callable[[Invocation], None],
             fn: FunctionSpec, vus: int, duration_s: float,
             sleep_s: float = 0.0, seed: int = 42,
             jitter: float = 0.05, drain_s: float = 120.0) -> LoadResult:
    """Spawn `vus` virtual users for `duration_s` sim-seconds.

    After the VU window closes, the clock drains for up to `drain_s` so
    in-flight invocations complete (k6's gracefulStop)."""
    rng = random.Random(seed)
    t_start = clock.now()
    t_end = t_start + duration_s
    out: List[Invocation] = []

    def vu_loop(vu_id: int):
        if clock.now() >= t_end:
            return
        inv = Invocation(fn, clock.now(), vu=vu_id)
        out.append(inv)
        done_flag = {"fired": False}

        def next_iter(_inv=inv):
            if done_flag["fired"]:
                return
            done_flag["fired"] = True
            think = sleep_s + rng.random() * jitter
            clock.after(think, lambda: vu_loop(vu_id))

        inv._on_done = next_iter          # platform completion hook
        submit(inv)
        # safety: if the invocation was rejected outright, keep iterating
        if inv.status == "failed":
            clock.after(max(sleep_s, 0.1), lambda: vu_loop(vu_id))

    for v in range(vus):
        clock.after(rng.random() * 0.1, lambda v=v: vu_loop(v))
    clock.run_until(t_end)
    clock.run_until(t_end + drain_s)          # gracefulStop: drain in-flight
    return LoadResult(out)


def run_open_loop(clock: SimClock, submit: Callable[[Invocation], None],
                  fn: FunctionSpec, rps: float, duration_s: float,
                  seed: int = 42) -> LoadResult:
    """Open-loop (arrival-rate) load: k6's constant-arrival-rate executor.
    Used for the Table-4 energy experiment (fixed 40 req/s per platform)."""
    rng = random.Random(seed)
    t0 = clock.now()
    out: List[Invocation] = []
    n = int(rps * duration_s)
    for i in range(n):
        t = t0 + i / rps + rng.random() * 1e-3

        def fire(t=t):
            inv = Invocation(fn, clock.now())
            out.append(inv)
            submit(inv)

        clock.schedule(t, fire)
    clock.run_until(t0 + duration_s)
    # allow in-flight work to drain
    clock.run_until(t0 + duration_s + 60.0)
    return LoadResult(out)


def attach_completion_hooks(control_plane) -> None:
    """Wire Invocation._on_done callbacks through the control plane."""
    def fire(inv):
        cb = getattr(inv, "_on_done", None)
        if cb is not None:
            cb()
    for p in control_plane.platforms.values():
        if fire not in p.on_complete:
            p.on_complete.append(fire)
