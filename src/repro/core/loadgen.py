"""k6-style load generator (paper §4.3), in two workload models:

Closed loop — ``run_load``: N virtual users (VUs) iterate request ->
wait-for-completion -> sleep, exactly the way the paper's k6 scripts drove
the five platforms (VUs 10-50, duration 600 s, optional sleep).

Open loop — arrival-driven: ``poisson_arrivals`` / ``trace_arrivals``
produce a NumPy array of arrival timestamps (seeded Poisson process, or a
replayable trace), and ``run_arrivals`` admits them through a batch-submit
callable (``FDNControlPlane.submit_batch`` / ``Gateway.request_batch``),
grouping arrivals into sub-window bursts.  Results stream into a
``ColumnarResultSink`` — flat NumPy columns, no Python object retained per
latency sample — so a run can sustain ~10^6 invocations.

Everything is deterministic on the SimClock; all randomness is seeded.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.simulator import SimClock
from repro.core.types import FunctionSpec, Invocation


@dataclass
class LoadResult:
    invocations: List[Invocation]

    @property
    def completed(self) -> List[Invocation]:
        return [i for i in self.invocations if i.status == "done"]

    def p90_response(self) -> float:
        from repro.core.monitoring import percentile
        vals = sorted(i.response_time for i in self.completed
                      if i.response_time is not None)
        return percentile(vals, 0.90)

    def requests_per_s(self, duration: float) -> float:
        return len(self.completed) / max(duration, 1e-9)


def run_load(clock: SimClock, submit: Callable[[Invocation], None],
             fn: FunctionSpec, vus: int, duration_s: float,
             sleep_s: float = 0.0, seed: int = 42,
             jitter: float = 0.05, drain_s: float = 120.0) -> LoadResult:
    """Spawn `vus` virtual users for `duration_s` sim-seconds.

    After the VU window closes, the clock drains for up to `drain_s` so
    in-flight invocations complete (k6's gracefulStop)."""
    rng = random.Random(seed)
    t_start = clock.now()
    t_end = t_start + duration_s
    out: List[Invocation] = []

    def vu_loop(vu_id: int):
        if clock.now() >= t_end:
            return
        inv = Invocation(fn, clock.now(), vu=vu_id)
        out.append(inv)
        done_flag = {"fired": False}

        def next_iter(_inv=inv):
            if done_flag["fired"]:
                return
            done_flag["fired"] = True
            think = sleep_s + rng.random() * jitter
            clock.after(think, lambda: vu_loop(vu_id))

        inv._on_done = next_iter          # platform completion hook
        submit(inv)
        # safety: if the invocation was rejected outright, keep iterating
        if inv.status == "failed":
            clock.after(max(sleep_s, 0.1), lambda: vu_loop(vu_id))

    for v in range(vus):
        clock.after(rng.random() * 0.1, lambda v=v: vu_loop(v))
    clock.run_until(t_end)
    clock.run_until(t_end + drain_s)          # gracefulStop: drain in-flight
    return LoadResult(out)


def run_open_loop(clock: SimClock, submit: Callable[[Invocation], None],
                  fn: FunctionSpec, rps: float, duration_s: float,
                  seed: int = 42) -> LoadResult:
    """Open-loop (arrival-rate) load: k6's constant-arrival-rate executor.
    Used for the Table-4 energy experiment (fixed 40 req/s per platform)."""
    rng = random.Random(seed)
    t0 = clock.now()
    out: List[Invocation] = []
    n = int(rps * duration_s)
    for i in range(n):
        t = t0 + i / rps + rng.random() * 1e-3

        def fire(t=t):
            inv = Invocation(fn, clock.now())
            out.append(inv)
            submit(inv)

        clock.schedule(t, fire)
    clock.run_until(t0 + duration_s)
    # allow in-flight work to drain
    clock.run_until(t0 + duration_s + 60.0)
    return LoadResult(out)


# ---------------------------------------------------------------------------
# Open-loop arrival processes (workload-model diversity: the paper's k6
# constant-arrival executor, a Poisson process, and trace replay)
# ---------------------------------------------------------------------------

def poisson_arrivals(rps: float, duration_s: float, seed: int = 42,
                     t0: float = 0.0) -> np.ndarray:
    """Seeded Poisson arrival process: exponential inter-arrival gaps at
    mean rate ``rps`` for ``duration_s`` seconds.  Same seed -> identical
    arrival array (replayable)."""
    if rps <= 0 or duration_s <= 0:
        return np.empty(0)
    rng = np.random.default_rng(seed)
    # draw with headroom, extend until the window is covered
    n = max(int(rps * duration_s * 1.2) + 16, 16)
    gaps = rng.exponential(1.0 / rps, size=n)
    t = np.cumsum(gaps)
    while t[-1] < duration_s:
        more = rng.exponential(1.0 / rps, size=n)
        t = np.concatenate([t, t[-1] + np.cumsum(more)])
    return t0 + t[t < duration_s]


def uniform_arrivals(rps: float, duration_s: float,
                     t0: float = 0.0) -> np.ndarray:
    """k6 constant-arrival-rate executor: evenly spaced arrivals."""
    n = int(rps * duration_s)
    return t0 + np.arange(n) / rps


def trace_arrivals(times: Sequence[float], t0: float = 0.0,
                   time_scale: float = 1.0) -> np.ndarray:
    """Replay a recorded arrival trace (e.g. production timestamps),
    shifted to start at ``t0`` and optionally time-dilated."""
    t = np.sort(np.asarray(list(times), dtype=float))
    if t.size == 0:
        return t
    return t0 + (t - t[0]) * time_scale


class ColumnarResultSink:
    """Flat-column result collector for open-loop runs.

    Completions append scalars into growable NumPy columns (arrival time,
    end time, platform id, cold-start flag); nothing per-sample survives in
    Python object form, so a 10^6-invocation run costs ~40 MB instead of a
    list of a million Invocation objects.
    """

    def __init__(self, capacity: int = 1024):
        self._n = 0
        self._arrival = np.empty(capacity)
        self._end = np.empty(capacity)
        self._platform = np.empty(capacity, np.int32)
        self._cold = np.empty(capacity, bool)
        self._platform_ids: Dict[str, int] = {}
        self.submitted = 0
        self.rejected = 0

    # -------------------------------------------------------- ingest ---
    def _grow(self):
        cap = self._arrival.size * 2
        for name in ("_arrival", "_end", "_platform", "_cold"):
            a = getattr(self, name)
            b = np.empty(cap, a.dtype)
            b[:self._n] = a[:self._n]
            setattr(self, name, b)

    def record_completion(self, inv: Invocation):
        if self._n == self._arrival.size:
            self._grow()
        i = self._n
        self._arrival[i] = inv.arrival_t
        self._end[i] = inv.end_t if inv.end_t is not None else np.nan
        pid = self._platform_ids.setdefault(inv.platform or "?",
                                            len(self._platform_ids))
        self._platform[i] = pid
        self._cold[i] = inv.cold_start
        self._n = i + 1

    def install(self, control_plane) -> "ColumnarResultSink":
        """Subscribe to every platform's completion stream."""
        for p in control_plane.platforms.values():
            if self.record_completion not in p.on_complete:
                p.on_complete.append(self.record_completion)
        return self

    # --------------------------------------------------------- stats ---
    @property
    def completed(self) -> int:
        return self._n

    def response_times(self) -> np.ndarray:
        return self._end[:self._n] - self._arrival[:self._n]

    def p90_response(self) -> float:
        from repro.core.monitoring import percentile
        rt = self.response_times()
        return percentile(np.sort(rt[~np.isnan(rt)]), 0.90)

    def mean_response(self) -> float:
        rt = self.response_times()
        return float(np.nanmean(rt)) if rt.size else float("nan")

    def requests_per_s(self, duration: float) -> float:
        return self._n / max(duration, 1e-9)

    def cold_start_count(self) -> int:
        return int(self._cold[:self._n].sum())

    def platform_counts(self) -> Dict[str, int]:
        counts = np.bincount(self._platform[:self._n],
                             minlength=len(self._platform_ids))
        return {name: int(counts[pid])
                for name, pid in self._platform_ids.items()}

    def to_metrics(self, registry, platform: str = "_loadgen",
                   fn: str = "*") -> None:
        """Push the collected latency column into a MetricsRegistry in one
        columnar ingest."""
        rt = self.response_times()
        ok = ~np.isnan(rt)
        registry.add_many(platform, fn, "response_time",
                          self._end[:self._n][ok], rt[ok])


def run_arrivals(clock: SimClock, submit_batch: Callable[[List[Invocation]],
                                                         int],
                 fn: FunctionSpec, arrivals: np.ndarray,
                 batch_window_s: float = 0.05, sink:
                 Optional[ColumnarResultSink] = None,
                 drain_s: float = 120.0) -> ColumnarResultSink:
    """Open-loop replay: admit ``arrivals`` through a batch-submit callable.

    Arrivals inside one ``batch_window_s`` sub-window are admitted together
    at the window's close (one policy evaluation per burst); each
    invocation keeps its true arrival timestamp, so measured response
    times include the admission delay.  With ``batch_window_s <= 0`` every
    arrival is its own batch (the per-invocation baseline).
    """
    sink = sink or ColumnarResultSink()
    arrivals = np.asarray(arrivals, dtype=float)
    if arrivals.size == 0:
        return sink
    t_end = float(arrivals[-1])
    if batch_window_s > 0:
        edges = np.arange(float(arrivals[0]), t_end + batch_window_s,
                          batch_window_s)
        starts = np.searchsorted(arrivals, edges, side="left")
        bounds = [(int(a), int(b)) for a, b in
                  zip(starts, list(starts[1:]) + [arrivals.size]) if b > a]
    else:
        bounds = [(i, i + 1) for i in range(arrivals.size)]

    def fire(lo: int, hi: int):
        invs = [Invocation(fn, float(arrivals[i])) for i in range(lo, hi)]
        sink.submitted += len(invs)
        accepted = submit_batch(invs)
        sink.rejected += len(invs) - accepted

    times = [float(arrivals[hi - 1]) for lo, hi in bounds]
    clock.schedule_many(times,
                        [lambda lo=lo, hi=hi: fire(lo, hi)
                         for lo, hi in bounds])
    clock.run_until(t_end)
    clock.run_until(t_end + drain_s)          # gracefulStop: drain in-flight
    return sink


def attach_completion_hooks(control_plane) -> None:
    """Wire Invocation._on_done callbacks through the control plane."""
    def fire(inv):
        cb = getattr(inv, "_on_done", None)
        if cb is not None:
            cb()
    for p in control_plane.platforms.values():
        if fire not in p.on_complete:
            p.on_complete.append(fire)
