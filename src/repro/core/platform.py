"""TargetPlatform: one homogeneous cluster + its FaaS platform (paper §3).

Reproduces the FaaS semantics the paper measures against:
  * replicas with cold / prewarm / warm lifecycle (OpenWhisk §6.1),
  * reactive autoscaling + faas-idler scale-to-zero (OpenFaaS §2.2.2),
  * GCF elastic unbounded instances w/ per-instance concurrency 1 (§2.2.3),
  * CPU / memory interference from background load (§5.1.2, Figs. 8-9),
  * queueing when capacity is exhausted,
  * per-platform energy accounting (§5.2).

Execution latency comes from an ExecutionModel that can either (a) use the
analytic cost (flops / replica_flops + data-access time from the placement
manager) or (b) really execute the function's JAX callable on the host CPU
once, cache the measurement, and scale it by the platform/host speed ratio.
Everything advances on the deterministic SimClock.

The queue drain is *columnar*: replicas are still assigned FIFO (warmest
free replica first, identical head-of-line semantics to the historical
one-invocation-at-a-time loop), but the per-start math — startup latency,
interference crossovers as busy replicas spill onto background-loaded
cores, the swap cliff as created replicas push memory demand past
physical, execution seconds — is evaluated once per drained burst as
NumPy array ops, with per-function costs (data-access seconds, analytic
execution estimate) hoisted out of the per-invocation path.  A drained
burst therefore makes one vectorized placement pass instead of N scalar
``_start`` calls, while producing bit-identical invocation timings.
"""
from __future__ import annotations

import time as wall_time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import qos as qos_mod
from repro.core.data_placement import DataPlacementManager
from repro.core.energy import EnergyMeter
from repro.core.monitoring import MetricsRegistry
from repro.core.simulator import SimClock
from repro.core.types import FunctionSpec, Invocation, PlatformProfile

COLD, PREWARM, WARM = "cold", "prewarm", "warm"


class _ColumnarEntry:
    """Queue entry for one columnar admission group: row indices into an
    ``InvocationBatch``, consumed head-first by the drain.  ``Invocation``
    objects materialize one by one exactly when a replica starts them;
    ``t`` is the group's enqueue time (the members' ``scheduled_t``)."""

    __slots__ = ("batch", "idxs", "pos", "t")

    def __init__(self, batch, idxs, t: float):
        self.batch = batch
        self.idxs = idxs
        self.pos = 0
        self.t = t


class Replica:
    __slots__ = ("state", "busy", "last_used", "fn", "retired")

    def __init__(self, fn: str, state: str = COLD):
        self.fn = fn
        self.state = state
        self.busy = False
        self.last_used = 0.0
        # set when the idler / destroy / recover removes the replica; lets
        # the free-list skip stale entries lazily instead of rebuilding
        self.retired = False


class ExecutionModel:
    """Latency model with optional real-measurement calibration."""

    def __init__(self, host_flops: float = 2e9):
        self.host_flops = host_flops
        self._measured: Dict[str, float] = {}

    def measure_real(self, fn: FunctionSpec, payloads) -> Optional[float]:
        if fn.real_fn is None:
            return None
        if fn.name not in self._measured:
            try:
                fn.real_fn(*payloads)              # warmup/compile
                t0 = wall_time.perf_counter()
                fn.real_fn(*payloads)
                self._measured[fn.name] = wall_time.perf_counter() - t0
            except Exception:
                self._measured[fn.name] = -1.0
        m = self._measured[fn.name]
        return None if m < 0 else m

    def exec_seconds(self, fn: FunctionSpec, prof: PlatformProfile,
                     payloads=()) -> float:
        real = self.measure_real(fn, payloads)
        if real is not None:
            # scale host measurement by platform-vs-host speed ratio
            return real * (self.host_flops / max(prof.replica_flops, 1.0))
        return fn.flops / max(prof.replica_flops, 1.0)


class TargetPlatform:
    def __init__(self, prof: PlatformProfile, clock: SimClock,
                 metrics: MetricsRegistry, energy: EnergyMeter,
                 placement: Optional[DataPlacementManager] = None,
                 exec_model: Optional[ExecutionModel] = None,
                 seed: int = 0):
        self.prof = prof
        self.clock = clock
        self.metrics = metrics
        self.energy = energy
        self.placement = placement
        self.exec_model = exec_model or ExecutionModel()
        self.replicas: Dict[str, List[Replica]] = defaultdict(list)
        # O(1) admission accounting: busy-replica counter + per-function
        # free-replica pools keyed by lifecycle state + a running replica-
        # memory total.  The old full scans of every replica per admission
        # went quadratic under sustained batch load (elastic platforms
        # grow replicas without bound).
        self._busy = 0
        self._free: Dict[str, Dict[str, List[Replica]]] = {}
        self._mem_replicas_mb = 0.0
        # warm-pool accounting (repro.autoscale): exact per-function idle
        # replica counts by lifecycle state (free pools keep lazily-
        # skipped stale entries, so they cannot be counted directly), a
        # running idle total for keep-alive energy, and a generation
        # counter so the warm-pool controller can cache its row view
        self._idle_counts: Dict[str, Dict[str, int]] = {}
        self._idle_total = 0
        self.idle_gen = 0
        # set by the warm-pool controller: per-function admission counts
        # it drains every tick (None == autoscaling off, zero hot-path
        # cost), and a flag disabling the platform's own faas-idler so
        # the controller owns the keep-alive decision
        self.autoscale_counts: Optional[Dict[str, int]] = None
        self.managed_keepalive = False
        self.queue: deque = deque()
        self.deployed: Dict[str, FunctionSpec] = {}
        self.failed = False
        self.bg_cpu = 0.0                  # §5.1.2 interference knobs
        self.bg_mem = 0.0
        self.on_complete: List[Callable[[Invocation], None]] = []
        self.on_fail: List[Callable[[Invocation], None]] = []
        # flight recorder (repro.obs); None keeps every tap to one check
        self.recorder = None
        # live telemetry engine (repro.obs.telemetry); same guard
        # discipline.  queued_rows mirrors the queue depth in rows (a
        # _ColumnarEntry is one deque entry but many rows) so health
        # samples never walk the deque.
        self.telemetry = None
        self.queued_rows = 0
        # QoS layer (repro.core.qos): per-class DRR queues, built by
        # set_qos only for non-uniform weights — _cqueues is None keeps
        # every enqueue/drain on the single-FIFO fast path (exact FIFO
        # recovery AND zero qos-off cost)
        self.qos: Optional[qos_mod.QosSpec] = None
        self._cqueues: Optional[List[deque]] = None
        self._crows: Optional[np.ndarray] = None
        self._deficit: Optional[np.ndarray] = None
        self._weights: Optional[np.ndarray] = None
        self.inflight: Dict[int, Invocation] = {}
        energy.register(prof, clock.now())
        self._idler_scheduled = False

    # ------------------------------------------------------------ deploy --
    def deploy(self, fn: FunctionSpec):
        """Function Deployer: registers fn; ARM platforms need ARM images."""
        if self.prof.arm and fn.runtime == "docker-x86":
            raise ValueError(f"{fn.name}: x86 image cannot run on ARM "
                             f"platform {self.prof.name}")
        old = self.deployed.get(fn.name)
        if old is not None and old.memory_mb != fn.memory_mb:
            # re-deploy with a new footprint: existing replicas are
            # accounted at the *current* deployed spec's size
            self._mem_replicas_mb += len(self.replicas[fn.name]) * \
                (fn.memory_mb - old.memory_mb)
        self.deployed[fn.name] = fn
        for _ in range(self.prof.prewarm_pool):
            rep = Replica(fn.name, PREWARM)
            self.replicas[fn.name].append(rep)
            self._mem_replicas_mb += fn.memory_mb
            self._push_free(rep)

    def destroy(self, fn_name: str):
        spec = self.deployed.pop(fn_name, None)
        reps = self.replicas.pop(fn_name, [])
        if spec is not None:
            self._mem_replicas_mb -= len(reps) * spec.memory_mb
        for r in reps:
            if not r.retired:
                if r.busy:
                    self._busy -= 1
                else:
                    self._idle_sub(fn_name, r.state)
            r.retired = True
        self._free.pop(fn_name, None)
        self._idle_counts.pop(fn_name, None)

    # -------------------------------------------------------------- qos ---
    def set_qos(self, spec: Optional["qos_mod.QosSpec"]):
        """Attach per-class deficit-round-robin queueing.  Uniform
        weights (or None) keep the single FIFO deque — DRR with equal
        quanta *is* FIFO, so the recovery is structural and the qos-off
        drain stays byte-identical."""
        self.qos = spec
        if spec is not None and spec.drr_enabled():
            if self._cqueues is None:
                self._cqueues = [deque() for _ in range(qos_mod.N_QOS)]
                self._crows = np.zeros(qos_mod.N_QOS, np.int64)
                self._deficit = np.zeros(qos_mod.N_QOS, np.int64)
            self._weights = np.asarray(spec.weights, np.int64)
        else:
            self._cqueues = None
            self._crows = None
            self._deficit = None
            self._weights = None

    # ------------------------------------------------------- accounting ---
    def busy_replicas(self) -> int:
        return self._busy

    def _idle_pools(self, fn: str) -> Dict[str, int]:
        counts = self._idle_counts.get(fn)
        if counts is None:
            counts = {WARM: 0, PREWARM: 0, COLD: 0}
            self._idle_counts[fn] = counts
        return counts

    def _idle_add(self, fn: str, state: str):
        self._idle_pools(fn)[state] += 1
        self._idle_total += 1
        self.idle_gen += 1

    def _idle_sub(self, fn: str, state: str):
        self._idle_pools(fn)[state] -= 1
        self._idle_total -= 1
        self.idle_gen += 1

    def idle_warm(self, fn: str) -> int:
        """Free replicas of ``fn`` that would serve without a cold start
        (WARM + PREWARM) — O(1), exact (stale free-pool entries excluded)."""
        counts = self._idle_counts.get(fn)
        if counts is None:
            return 0
        return counts[WARM] + counts[PREWARM]

    def idle_warm_total(self) -> int:
        """All idle replicas across functions (keep-alive watt accounting)."""
        return self._idle_total

    def _push_free(self, rep: Replica):
        pools = self._free.get(rep.fn)
        if pools is None:
            pools = {WARM: [], PREWARM: [], COLD: []}
            self._free[rep.fn] = pools
        pools[rep.state].append(rep)
        self._idle_add(rep.fn, rep.state)

    def replica_count(self, fn: str) -> int:
        return len(self.replicas[fn])

    def cpu_util(self) -> float:
        cap = max(self.prof.total_replicas, 1)
        return min(1.0, self.bg_cpu + self.busy_replicas() / cap)

    def mem_used_mb(self) -> float:
        return self._mem_replicas_mb + \
            self.bg_mem * self.prof.total_memory_mb

    def mem_util(self) -> float:
        return min(1.5, self.mem_used_mb() / max(self.prof.total_memory_mb,
                                                 1))

    def _touch_energy(self):
        self.energy.update(self.prof.name, self.clock.now(), self.cpu_util(),
                           idle_warm=self._idle_total)

    def _sample_infra(self):
        if not self.prof.infra_metrics_visible:
            return
        t = self.clock.now()
        self.metrics.add(self.prof.name, "_infra", "cpu_util", t,
                         self.cpu_util())
        self.metrics.add(self.prof.name, "_infra", "mem_util", t,
                         self.mem_util())

    # ------------------------------------------------------- scheduling ---
    def can_start_replica(self, fn: FunctionSpec) -> bool:
        if self.prof.elastic:
            return True
        # Background CPU load does NOT reserve replica slots (the OS time-
        # shares; slowdown is modeled in _interference_factor — Fig. 8).
        if self.busy_replicas() >= self.prof.total_replicas:
            return False
        free_mb = self.prof.total_memory_mb - self.mem_used_mb()
        if free_mb >= fn.memory_mb:
            return True
        # CPU platforms can overcommit into swap (Fig. 9's cliff applies);
        # TPU pods (chips > 0) cannot — HBM does not swap.
        return self.prof.chips == 0 and \
            fn.memory_mb <= self.prof.total_memory_mb

    def invoke(self, inv: Invocation):
        """Entry point from the sidecar/control plane."""
        if not self._enqueue(inv):
            return
        self._drain()
        self._schedule_idler()

    def invoke_batch(self, invs):
        """Batched entry point: enqueue the whole group, then drain once.

        FIFO semantics are identical to repeated ``invoke`` calls (the
        drain assigns replicas in queue order either way); the saving is
        one vectorized queue drain + one energy/infra sample per batch
        instead of per invocation (with the per-invocation ``_enqueue``
        body inlined over hoisted locals — it is the one loop every
        admitted invocation must pass through)."""
        if self.failed:
            for inv in invs:
                self._fail(inv, "platform down")
            return
        deployed = self.deployed
        inflight = self.inflight
        queue_append = self.queue.append
        cq = self._cqueues
        crows = self._crows
        pname = self.prof.name
        now = self.clock.now()
        counts = self.autoscale_counts
        queued = False
        for inv in invs:
            name = inv.fn.name
            if name not in deployed:
                self._fail(inv, "function not deployed")
                continue
            inv.platform = pname
            inv.scheduled_t = now
            inv.status = "queued"
            inflight[inv.id] = inv
            if cq is None:
                queue_append(inv)
            else:
                cq[inv.qos].append(inv)
                crows[inv.qos] += 1
            self.queued_rows += 1
            if counts is not None:
                counts[name] = counts.get(name, 0) + 1
            queued = True
        if queued:
            self._drain()
            self._schedule_idler()

    def invoke_columns(self, batch, idxs: np.ndarray):
        """Array-native entry point: enqueue a whole admission group as
        ONE ``_ColumnarEntry`` and drain once.

        FIFO semantics are identical to ``invoke_batch`` over the
        materialized rows — the drain consumes the entry head-first in
        index order — but no ``Invocation`` object exists until a replica
        actually starts a row (undeployed/failed rows materialize just to
        travel the failure path, like the object path fails them before
        queueing the rest)."""
        if idxs.size == 0:
            return
        batch_fidx = batch.fn_idx
        specs = batch.specs
        if self.failed:
            for i in idxs:
                self._fail(batch.materialize(int(i)), "platform down")
            return
        deployed = self.deployed
        dep_ok = np.array([s.name in deployed for s in specs])
        if not dep_ok.all():
            member_ok = dep_ok[batch_fidx[idxs]]
            if not member_ok.all():
                for i in idxs[~member_ok]:
                    self._fail(batch.materialize(int(i)),
                               "function not deployed")
                idxs = idxs[member_ok]
                if idxs.size == 0:
                    return
        counts = self.autoscale_counts
        if counts is not None:
            c = np.bincount(batch_fidx[idxs], minlength=len(specs))
            for j, k in enumerate(c):
                if k:
                    name = specs[j].name
                    counts[name] = counts.get(name, 0) + int(k)
        cq = self._cqueues
        if cq is None:
            self.queue.append(_ColumnarEntry(batch, idxs, self.clock.now()))
        else:
            # split the group by class: one entry per class present, FIFO
            # within class preserved (idxs are in admission order)
            now = self.clock.now()
            qcol = batch.qos[idxs]
            crows = self._crows
            for c in range(qos_mod.N_QOS):
                sel = idxs[qcol == np.int8(c)]
                if sel.size:
                    cq[c].append(_ColumnarEntry(batch, sel, now))
                    crows[c] += int(sel.size)
        self.queued_rows += int(idxs.size)
        self._drain()
        self._schedule_idler()

    def _enqueue(self, inv: Invocation) -> bool:
        if self.failed:
            self._fail(inv, "platform down")
            return False
        if inv.fn.name not in self.deployed:
            self._fail(inv, "function not deployed")
            return False
        inv.platform = self.prof.name
        inv.scheduled_t = self.clock.now()
        inv.status = "queued"
        self.inflight[inv.id] = inv
        if self._cqueues is None:
            self.queue.append(inv)
        else:
            self._cqueues[inv.qos].append(inv)
            self._crows[inv.qos] += 1
        self.queued_rows += 1
        counts = self.autoscale_counts
        if counts is not None:
            name = inv.fn.name
            counts[name] = counts.get(name, 0) + 1
        return True

    def _find_replica(self, fn: str) -> Optional[Replica]:
        """Warmest free replica (WARM > PREWARM > COLD), popped from the
        per-state free pools in O(1); stale entries (retired by the idler,
        or whose state moved on) are skipped lazily."""
        pools = self._free.get(fn)
        if pools is None:
            return None
        for state in (WARM, PREWARM, COLD):
            lst = pools[state]
            while lst:
                r = lst.pop()
                if r.retired or r.busy or r.state != state:
                    continue
                self._idle_sub(fn, state)
                return r
        return None

    def _fn_start_cost(self, fn: FunctionSpec) -> Tuple[float, float]:
        """(analytic/measured exec seconds, data-access seconds) for one
        invocation of ``fn`` right now — constant within one drain, so it
        is computed once per distinct function and broadcast."""
        data_t = 0.0
        payloads = []
        if self.placement is not None:
            for obj in fn.data_objects:
                data_t += self.placement.access_time(obj, self.prof.name)
                payloads.append(self.placement.payload(obj))
        return self.exec_model.exec_seconds(fn, self.prof, payloads), data_t

    def _drain(self):
        """Assign free/new replicas to the queue head (FIFO; stops at the
        first invocation that cannot start), then launch every assigned
        invocation in one vectorized pass."""
        if self._cqueues is not None:
            return self._drain_qos()
        queue = self.queue
        if queue and not self.failed:
            now = self.clock.now()
            prof = self.prof
            base_busy = self._busy
            starts: List[Tuple[Invocation, FunctionSpec, Replica]] = []
            startups: List[float] = []
            colds: List[bool] = []
            mem_at: List[float] = []
            exec_base: List[float] = []
            data_ts: List[float] = []
            # per-fn hoisting is only sound while access costs are pure;
            # with the LRU data cache enabled every access mutates cache
            # state, so costs are evaluated per invocation in FIFO order
            hoist = self.placement is None or not self.placement.cache_enabled
            fn_cache: Dict[int, list] = {}   # id(fn) -> [exec, data, fn, n]
            pname = prof.name
            while queue:
                head = queue[0]
                entry = head if type(head) is _ColumnarEntry else None
                if entry is not None:
                    b = entry.batch
                    i = int(entry.idxs[entry.pos])
                    fn = b.specs[b.fn_idx[i]]
                else:
                    fn = head.fn
                rep = self._find_replica(fn.name)
                if rep is None:
                    if not self.can_start_replica(fn):
                        break
                    rep = Replica(fn.name, COLD)
                    self.replicas[fn.name].append(rep)
                    spec = self.deployed.get(fn.name)
                    if spec is not None:
                        self._mem_replicas_mb += spec.memory_mb
                if entry is None:
                    inv = head
                    queue.popleft()
                else:
                    # lazy materialization: the Invocation object is born
                    # at replica-assignment time, with the bookkeeping the
                    # object path applied at enqueue
                    inv = b.materialize(i)
                    inv.platform = pname
                    inv.scheduled_t = entry.t
                    inv.status = "queued"
                    self.inflight[inv.id] = inv
                    entry.pos += 1
                    if entry.pos == entry.idxs.size:
                        queue.popleft()
                state = rep.state
                if state == COLD:
                    startups.append(prof.cold_start_s)
                    colds.append(True)
                elif state == PREWARM:
                    # a prewarmed container pays only its attach cost and
                    # does NOT count as a cold start — avoiding the cold
                    # flag is exactly what prewarming buys (§6.1)
                    startups.append(prof.cold_start_s * 0.15)
                    colds.append(False)
                else:
                    startups.append(0.0)
                    colds.append(False)
                rep.state = WARM
                rep.busy = True
                rep.last_used = now
                self._busy += 1
                mem_at.append(self._mem_replicas_mb)
                if hoist:
                    cached = fn_cache.get(id(fn))
                    if cached is None:
                        e, d = self._fn_start_cost(fn)
                        cached = [e, d, fn, 0]
                        fn_cache[id(fn)] = cached
                    cached[3] += 1
                    e, d = cached[0], cached[1]
                else:
                    e, d = self._fn_start_cost(fn)
                    if self.placement is not None:
                        for obj in fn.data_objects:
                            self.placement.record_access(fn.name, obj)
                exec_base.append(e)
                data_ts.append(d)
                starts.append((inv, fn, rep))
            if starts:
                if hoist and self.placement is not None:
                    for _e, _d, fn, count in fn_cache.values():
                        for obj in fn.data_objects:
                            self.placement.record_access(fn.name, obj,
                                                         count=count)
                self._launch(starts, startups, colds, mem_at, exec_base,
                             data_ts, base_busy, now)
                self.queued_rows -= len(starts)
        self._touch_energy()
        self._sample_infra()
        tel = self.telemetry
        if tel is not None:
            self.sample_health(tel)

    def _drain_qos(self):
        """DRR twin of ``_drain``: the per-start body is identical (same
        replica assignment, same hoisting, same ``_launch``), but the
        serve *order* follows a vectorized deficit-round-robin plan over
        the per-class queues — one ``np.lexsort`` per drain
        (``qos.drr_plan``), deficits committed back afterwards
        (``qos.drr_commit``).  Head-of-line blocking is global: the
        first planned row that cannot start stops the drain, exactly
        like the FIFO drain stops at its queue head."""
        cq = self._cqueues
        crows = self._crows
        total_backlog = int(crows.sum())
        if total_backlog and not self.failed:
            now = self.clock.now()
            prof = self.prof
            # upper bound on possible starts this drain: every start
            # either consumes a free replica or creates one (creation
            # stops at total_replicas busy) — keeps the plan size
            # proportional to serveable rows, not to the backlog
            if prof.elastic:
                cap = total_backlog
            else:
                cap = min(total_backlog, self._idle_total +
                          max(0, prof.total_replicas - self._busy))
            if cap > 0:
                plan_cls, plan_rounds = qos_mod.drr_plan(
                    crows, self._deficit, self._weights, cap)
                base_busy = self._busy
                starts: List[Tuple[Invocation, FunctionSpec, Replica]] = []
                startups: List[float] = []
                colds: List[bool] = []
                mem_at: List[float] = []
                exec_base: List[float] = []
                data_ts: List[float] = []
                hoist = self.placement is None or \
                    not self.placement.cache_enabled
                fn_cache: Dict[int, list] = {}
                pname = prof.name
                served = [0] * qos_mod.N_QOS
                plan_len = int(plan_cls.size)
                p = 0
                while p < plan_len:
                    c = int(plan_cls[p])
                    queue = cq[c]
                    head = queue[0]
                    entry = head if type(head) is _ColumnarEntry else None
                    if entry is not None:
                        b = entry.batch
                        i = int(entry.idxs[entry.pos])
                        fn = b.specs[b.fn_idx[i]]
                    else:
                        fn = head.fn
                    rep = self._find_replica(fn.name)
                    if rep is None:
                        if not self.can_start_replica(fn):
                            break
                        rep = Replica(fn.name, COLD)
                        self.replicas[fn.name].append(rep)
                        spec = self.deployed.get(fn.name)
                        if spec is not None:
                            self._mem_replicas_mb += spec.memory_mb
                    if entry is None:
                        inv = head
                        queue.popleft()
                    else:
                        inv = b.materialize(i)
                        inv.platform = pname
                        inv.scheduled_t = entry.t
                        inv.status = "queued"
                        self.inflight[inv.id] = inv
                        entry.pos += 1
                        if entry.pos == entry.idxs.size:
                            queue.popleft()
                    state = rep.state
                    if state == COLD:
                        startups.append(prof.cold_start_s)
                        colds.append(True)
                    elif state == PREWARM:
                        startups.append(prof.cold_start_s * 0.15)
                        colds.append(False)
                    else:
                        startups.append(0.0)
                        colds.append(False)
                    rep.state = WARM
                    rep.busy = True
                    rep.last_used = now
                    self._busy += 1
                    mem_at.append(self._mem_replicas_mb)
                    if hoist:
                        cached = fn_cache.get(id(fn))
                        if cached is None:
                            e, d = self._fn_start_cost(fn)
                            cached = [e, d, fn, 0]
                            fn_cache[id(fn)] = cached
                        cached[3] += 1
                        e, d = cached[0], cached[1]
                    else:
                        e, d = self._fn_start_cost(fn)
                        if self.placement is not None:
                            for obj in fn.data_objects:
                                self.placement.record_access(fn.name, obj)
                    exec_base.append(e)
                    data_ts.append(d)
                    starts.append((inv, fn, rep))
                    served[c] += 1
                    p += 1
                self._deficit = qos_mod.drr_commit(
                    self._deficit, self._weights, crows, served,
                    plan_cls, plan_rounds, p)
                crows -= np.asarray(served, np.int64)
                if starts:
                    if hoist and self.placement is not None:
                        for _e, _d, fn, count in fn_cache.values():
                            for obj in fn.data_objects:
                                self.placement.record_access(fn.name, obj,
                                                             count=count)
                    self._launch(starts, startups, colds, mem_at,
                                 exec_base, data_ts, base_busy, now)
                    self.queued_rows -= len(starts)
        self._touch_energy()
        self._sample_infra()
        tel = self.telemetry
        if tel is not None:
            self.sample_health(tel)

    # -------------------------------------------------------- execution ---
    def _interference_factor(self) -> float:
        """Instantaneous CPU + memory interference — the scalar form of
        the per-burst vectors in ``_launch`` (see its docstring).  The
        two MUST stay formula-identical: the n == 1 drain fast path uses
        this, larger bursts the vectorized copy."""
        total = max(self.prof.total_replicas, 1)
        free_cores = (1.0 - self.bg_cpu) * total
        factor = 1.0 if self.busy_replicas() <= free_cores + 1e-9 else 2.0
        if self.mem_util() > 1.0 + 1e-6:                # swap cliff
            factor *= 7.0
        return factor

    def _launch(self, starts, startups, colds, mem_at, exec_base, data_ts,
                base_busy: int, now: float):
        """Vectorized ``_start``: one pass of array math for the whole
        drained burst (paper §5.1.2, Figs. 8-9 interference semantics).

        CPU interference: background load occupies bg_cpu * cores fully;
        while function replicas fit on the remaining free cores there is
        no slowdown (paper: +50% load -> no effect).  Once they spill onto
        bg-occupied cores the OS time-shares 1:1 -> ~2x (paper: +100% load
        -> ~2x P90).  The busy count each start observes is the running
        total *including itself* (``base_busy + 1 + i``), exactly like the
        sequential loop this replaces.

        Memory: swap thrash is a cliff — as soon as demand (including
        replicas created earlier in this very drain, tracked by
        ``mem_at``) exceeds physical memory, latency jumps ~7x (paper:
        0.8 s -> 6 s P90).

        Interference slows the whole request path (gateway/watchdog/
        invoker contend for the same cores and memory as the function).
        """
        prof = self.prof
        n = len(starts)
        total = max(prof.total_replicas, 1)
        free_cores = (1.0 - self.bg_cpu) * total
        if n == 1:                     # scalar drain (closed-loop path):
            inv, fn, rep = starts[0]   # same formulas, no array overhead
            # a single start observes exactly the platform's current
            # state (busy == base_busy + 1, memory == mem_at[0])
            factor = self._interference_factor()
            exec_time = (exec_base[0] + prof.overhead_s) * factor \
                + data_ts[0]
            st = now + startups[0]
            inv.status = "running"
            inv.start_t = st
            inv.queue_time = st - inv.arrival_t
            inv.exec_time = exec_time
            inv.data_time = data_ts[0]
            if colds[0]:
                inv.cold_start = True
            self.clock.schedule(now + (startups[0] + exec_time),
                                self._finish_cb(inv, fn, rep))
            rec = self.recorder
            if rec is not None:
                # fire expression repeated verbatim: the recorded EXEC end
                # must equal the scheduled completion instant bit-for-bit
                rec.record_launch((inv,), (fn,), prof.name, now,
                                  (startups[0],), (data_ts[0],),
                                  (now + (startups[0] + exec_time),),
                                  (colds[0],))
            return
        busy_at = base_busy + 1 + np.arange(n)
        factor = np.where(busy_at <= free_cores + 1e-9, 1.0, 2.0)
        pressure = np.minimum(
            1.5, (np.asarray(mem_at) + self.bg_mem * prof.total_memory_mb)
            / max(prof.total_memory_mb, 1))
        factor = np.where(pressure > 1.0 + 1e-6, factor * 7.0, factor)

        startup = np.asarray(startups)
        exec_times = (np.asarray(exec_base) + prof.overhead_s) * factor \
            + np.asarray(data_ts)
        fire_at = now + (startup + exec_times)

        start_l = (now + startup).tolist()
        exec_l = exec_times.tolist()
        cbs: List[Callable[[], None]] = []
        for i, (inv, fn, rep) in enumerate(starts):
            st = start_l[i]
            inv.status = "running"
            inv.start_t = st
            inv.queue_time = st - inv.arrival_t
            inv.exec_time = exec_l[i]
            inv.data_time = data_ts[i]
            if colds[i]:
                inv.cold_start = True
            cbs.append(self._finish_cb(inv, fn, rep))
        self.clock.schedule_many(fire_at.tolist(), cbs)
        rec = self.recorder
        if rec is not None:
            rec.record_launch([s[0] for s in starts],
                              [s[1] for s in starts], prof.name, now,
                              startup, data_ts, fire_at, colds)

    def _finish_cb(self, inv: Invocation, fn: FunctionSpec,
                   rep: Replica) -> Callable[[], None]:
        def finish():
            rep.busy = False
            rep.last_used = self.clock.now()
            if not rep.retired:
                self._busy -= 1
                self._push_free(rep)
            if self.failed or inv.status == "failed":
                return
            inv.end_t = self.clock.now()
            inv.status = "done"
            self.inflight.pop(inv.id, None)
            self.metrics.record_completion(
                inv, visible_infra=self.prof.infra_metrics_visible)
            self.metrics.add(self.prof.name, fn.name, "replicas",
                             inv.end_t, float(self.replica_count(fn.name)))
            for cb in self.on_complete:
                cb(inv)
            self._drain()

        return finish

    def _fail(self, inv: Invocation, reason: str):
        inv.status = "failed"
        inv.end_t = self.clock.now()
        self.inflight.pop(inv.id, None)
        for cb in self.on_fail:
            cb(inv)

    # ------------------------------------------------ faas-idler / warm ---
    def _schedule_idler(self):
        if self._idler_scheduled or self.prof.scale_to_zero_s <= 0 or \
                self.managed_keepalive:
            return
        self._idler_scheduled = True

        def idle_check():
            self._idler_scheduled = False
            if self.managed_keepalive:   # controller attached mid-run
                return
            now = self.clock.now()
            for fn, rs in list(self.replicas.items()):
                spec = self.deployed.get(fn)
                keep = []
                for r in rs:
                    if r.busy or now - r.last_used < \
                            self.prof.scale_to_zero_s or r.state == PREWARM:
                        keep.append(r)
                    else:
                        r.retired = True
                        self._idle_sub(fn, r.state)
                        if spec is not None:
                            self._mem_replicas_mb -= spec.memory_mb
                self.replicas[fn] = keep
            self._touch_energy()
            if any(self.replicas.values()):
                self._schedule_idler()

        self.clock.after(self.prof.scale_to_zero_s, idle_check)

    def prewarm(self, fn_name: str, n: int):
        """Warm-pool grow transition: start ``n`` prewarmed containers
        (predictive prewarming, §3.3 (1) / repro.autoscale)."""
        if n <= 0 or self.failed:
            return
        spec = self.deployed.get(fn_name)
        if spec is None:                 # undeployed (or destroyed mid-run)
            return
        now = self.clock.now()
        for _ in range(n):
            rep = Replica(fn_name, PREWARM)
            rep.last_used = now          # keep-alive TTL runs from creation
            self.replicas[fn_name].append(rep)
            self._mem_replicas_mb += spec.memory_mb
            self._push_free(rep)
        self._touch_energy()

    def retire(self, fn_name: str, n: int) -> int:
        """Warm-pool shrink transition: retire up to ``n`` idle replicas of
        ``fn_name``, coldest-first (COLD, then PREWARM, then WARM), and
        release their memory from the O(1) running total.  Returns the
        number actually retired (busy replicas are never touched)."""
        pools = self._free.get(fn_name)
        retired = 0
        if pools is not None and n > 0:
            spec = self.deployed.get(fn_name)
            for state in (COLD, PREWARM, WARM):
                lst = pools[state]
                while lst and retired < n:
                    r = lst.pop()
                    if r.retired or r.busy or r.state != state:
                        continue
                    r.retired = True
                    self._idle_sub(fn_name, state)
                    if spec is not None:
                        self._mem_replicas_mb -= spec.memory_mb
                    retired += 1
                if retired >= n:
                    break
            if retired:
                live = [r for r in self.replicas[fn_name] if not r.retired]
                self.replicas[fn_name] = live
                self._touch_energy()
        return retired

    def enforce_keepalive(self, fn_name: str, ttl_s: float,
                          keep: int = 0) -> Tuple[int, float]:
        """TTL sweep for one function's warm pool: retire idle replicas
        unused for at least ``ttl_s`` seconds, preserving the ``keep``
        youngest-idle ones (the controller's desired pool floor).

        Returns ``(retired, next_due)`` where ``next_due`` is the earliest
        sim-time any of the *surviving* idle replicas could expire (+inf
        when none are idle) — the controller uses it to skip sweeps that
        cannot retire anything."""
        now = self.clock.now()
        n_idle = self.idle_warm(fn_name)
        if n_idle <= keep:
            # nothing retirable *at this desired level*; if the desired
            # floor drops later, re-check after a TTL (bounded staleness)
            # — a pool that empties bumps idle_gen and re-arms the sweep
            return 0, (now + ttl_s if n_idle else float("inf"))
        spec = self.deployed.get(fn_name)
        idle = [r for r in self.replicas[fn_name]
                if not r.busy and not r.retired]
        idle.sort(key=lambda r: r.last_used)      # oldest-idle first
        surplus = len(idle) - keep
        retired = 0
        for r in idle[:surplus]:
            if now - r.last_used < ttl_s:
                break
            r.retired = True
            self._idle_sub(fn_name, r.state)
            if spec is not None:
                self._mem_replicas_mb -= spec.memory_mb
            retired += 1
        if retired:
            live = [r for r in self.replicas[fn_name] if not r.retired]
            self.replicas[fn_name] = live
            self._touch_energy()
        survivors = idle[retired:]
        next_due = survivors[0].last_used + ttl_s if survivors \
            else float("inf")
        return retired, next_due

    # ------------------------------------------------------------ faults --
    def fail(self):
        """Platform outage: every in-flight invocation is lost.  Queued
        columnar rows that never materialized are materialized now so they
        travel the same failure path (redelivery sees real objects)."""
        self.failed = True
        lost = list(self.inflight.values())
        queues = [self.queue] if self._cqueues is None \
            else [self.queue, *self._cqueues]
        for q in queues:
            for head in q:
                if type(head) is _ColumnarEntry:
                    for i in head.idxs[head.pos:]:
                        inv = head.batch.materialize(int(i))
                        inv.platform = self.prof.name
                        inv.scheduled_t = head.t
                        lost.append(inv)
        self.inflight.clear()
        for q in queues:
            q.clear()
        if self._crows is not None:
            self._crows[:] = 0
        self.queued_rows = 0
        for inv in lost:
            self._fail(inv, "platform failure")
        self._touch_energy()

    def sample_health(self, tel) -> None:
        """Push one (queue depth, utilization, watts) health sample to
        the telemetry engine — called from the drain tail and the
        control plane's liveness heartbeat."""
        util = 0.0 if self.failed else self.cpu_util()
        tel.record_health(self.prof.name, self.clock.now(),
                          float(self.queued_rows), util,
                          self.energy.power_w(self.prof.name, util))

    def recover(self):
        self.failed = False
        self.queued_rows = 0
        if self._crows is not None:
            self._crows[:] = 0
            self._deficit[:] = 0
        for rs in self.replicas.values():
            for r in rs:
                r.retired = True
            rs.clear()
        self._free.clear()
        self._busy = 0
        self._mem_replicas_mb = 0.0
        self._idle_counts.clear()
        self._idle_total = 0
        self.idle_gen += 1
